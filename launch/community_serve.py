"""Request-batching community-detection service (DESIGN.md §Serving,
§Resilience).

The single-graph drivers answer one graph per dispatch; serving traffic is
many small graphs arriving independently.  ``CommunityServeEngine`` is the
thin queueing layer that turns that traffic into the batched engine's
shape:

    submit() → admission control (bounded depth + estimated-cost budget,
               typed ``OverloadError`` sheds) → canonical ingest (per
               request, so a poisoned edge list is rejected/repaired BEFORE
               it can share a batch with clean traffic) → queue
    flush()  → group by (algo, capacity signature) → ``louvain_batch`` /
               ``plp_batch`` dispatch per group → unpack per-request
               responses with the PR-7 ``RunReport`` and wall-clock latency

Batching changes throughput, never answers: every response is bit-identical
to running the single-graph driver on the same request (the batch engine's
parity contract).  On top of PR 8's batching, this layer keeps the service
HEALTHY under sustained faults (DESIGN.md §Resilience):

* **Deadlines** — a request may carry ``deadline_ms``; its batch dispatch
  runs under the watchdog (``utils.resilience.call_with_deadline``) with the
  tightest member budget.  A busted deadline fails ONLY the expired
  requests with a typed ``DeadlineError``; still-alive batch-mates are
  re-run sequentially under their own remaining budgets.
* **Backpressure** — the queue is bounded (``max_queue_depth`` requests and
  optionally ``max_queue_cost`` estimated padded-capacity units from
  ``capacity_signature``); ``submit`` sheds excess load immediately with a
  typed ``OverloadError`` response instead of growing silently.
* **Retries** — a transiently failed batch dispatch (``is_retryable`` over
  the PR-7 taxonomy) is retried with deterministic jittered backoff, never
  past the tightest member deadline.
* **Circuit breakers** — a signature bucket whose batched dispatches keep
  failing trips a per-(algo, signature) breaker: while open, new
  submissions for that signature are rejected at the door (no further
  breaker accounting) and already-queued members route around the batched
  path to the sequential ladder; after the reset window one half-open
  batched probe decides whether it closes.
* **Preemption** — a ``resilience.Preempted`` kill at the dispatch tick is
  absorbed by re-running the tick (the fault is an event, not a state);
  long cascades additionally resume from stage checkpoints when
  ``LouvainConfig.checkpoint_dir`` is set (``core.louvain``).

If a batch trips a non-retryable typed taxonomy error, the engine degrades
that ONE group to sequential single-graph runs so clean requests still get
answers and only the offending request carries the error — recorded in
``stats()["counters"]`` as ``serve.batch_fallback_sequential``.

Deliberately synchronous and in-process: flush() is the unit a real
transport (thread, asyncio loop, RPC server) would call on its batching
tick; the engine itself stays free of I/O so it can be tested and
benchmarked hermetically.  ``python launch/community_serve.py --smoke``
drives a small end-to-end traffic sample (the CI chaos step runs it under
each fault point with a hard wall-clock timeout).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.batch import louvain_batch, plp_batch
from repro.core.louvain import LouvainConfig, louvain
from repro.core.plp import PLPConfig, plp
from repro.core import progcache
from repro.graph.builders import from_numpy_edges_robust
from repro.kernels.common import capacity_signature
from repro.utils import faultinject, resilience, telemetry
from repro.utils.errors import (CommunityDetectionError, DeadlineError,
                                OverloadError, RunReport)

ALGOS = ("louvain", "plp")


@dataclasses.dataclass
class CommunityRequest:
    """One graph to cluster: an undirected edge list + algorithm choice."""

    request_id: str
    u: np.ndarray
    v: np.ndarray
    w: Optional[np.ndarray] = None
    algo: str = "louvain"          # "louvain" | "plp"
    n: Optional[int] = None        # vertex count override (else max id + 1)
    deadline_ms: Optional[float] = None  # wall-clock budget from submit()


@dataclasses.dataclass
class CommunityResponse:
    """Per-request outcome, positionally independent of batch placement."""

    request_id: str
    ok: bool
    labels: Optional[np.ndarray] = None
    result: object = None          # LouvainResult | PLPResult when ok
    error: Optional[str] = None    # typed-taxonomy message when not ok
    repairs: dict = dataclasses.field(default_factory=dict)
    signature: Optional[tuple] = None
    latency_s: float = 0.0         # submit() → response unpack, wall clock
    batch_size: int = 0            # slots sharing this request's dispatch
    report: Optional[RunReport] = None  # failure-path RunReport (ok=False)


@dataclasses.dataclass
class _Queued:
    req: CommunityRequest
    graph: object
    repairs: dict
    t_submit: float
    seq: int
    deadline: Optional[resilience.Deadline] = None
    cost: int = 0


def _estimate_cost(req: CommunityRequest) -> int:
    """Admission-control cost of a request BEFORE ingest: the padded
    capacity units (n_cap + m_cap) its batch slot will occupy, from the
    same ``capacity_signature`` the flush-time bucketing uses."""
    m_est = 2 * int(len(req.u))
    if req.n is not None:
        n_est = int(req.n)
    elif len(req.u):
        n_est = int(max(np.max(req.u), np.max(req.v))) + 1
    else:
        n_est = 1
    sig = capacity_signature(max(n_est, 1), max(m_est, 1))
    return int(sig.n_cap) + int(sig.m_cap)


def _fail(q: _Queued, err_text: str, batch: int,
          warning: str) -> CommunityResponse:
    sig = (tuple(capacity_signature(q.graph.n_max, q.graph.m_max))
           if q.graph.n_max else None)
    return CommunityResponse(
        request_id=q.req.request_id, ok=False, error=err_text,
        repairs=q.repairs, signature=sig,
        latency_s=time.perf_counter() - q.t_submit, batch_size=batch,
        report=RunReport(warnings=[warning],
                         faults=sorted(faultinject.active())))


class CommunityServeEngine:
    """Queue → admit → bucket → batch-dispatch (deadline/retry/breaker
    guarded) → unpack (module docstring).

    ``max_batch`` caps the slot count of one dispatch (memory bound);
    larger groups are chunked.  ``max_queue_depth`` / ``max_queue_cost``
    bound the queue (requests / estimated padded-capacity units) —
    ``submit`` sheds the excess with typed ``OverloadError`` responses.
    ``max_retries`` transient-failure retries use jittered backoff seeded
    per dispatch (``backoff_base_s``).  ``breaker`` is injectable for
    deterministic tests (else a ``CircuitBreaker(breaker_threshold,
    breaker_reset_s)``).  ``ingest`` kwargs forward to
    ``from_numpy_edges_robust`` (e.g. ``bad_weights="drop"`` to repair
    rather than reject poisoned weights).

    Leave ``louvain_cfg.checkpoint_dir`` unset here: the stage-checkpoint
    directory is one-run-per-dir and sequential fallbacks would collide.
    """

    def __init__(self, louvain_cfg: Optional[LouvainConfig] = None,
                 plp_cfg: Optional[PLPConfig] = None, max_batch: int = 256,
                 max_queue_depth: int = 1024,
                 max_queue_cost: Optional[int] = None,
                 max_retries: int = 2, backoff_base_s: float = 0.05,
                 breaker_threshold: int = 3, breaker_reset_s: float = 30.0,
                 breaker: Optional[resilience.CircuitBreaker] = None,
                 **ingest):
        # default configs are built PER ENGINE (a shared default-argument
        # instance would leak config mutations across engines)
        self.louvain_cfg = (louvain_cfg if louvain_cfg is not None
                            else LouvainConfig())
        self.plp_cfg = plp_cfg if plp_cfg is not None else PLPConfig()
        self.max_batch = int(max_batch)
        self.max_queue_depth = int(max_queue_depth)
        self.max_queue_cost = (None if max_queue_cost is None
                               else int(max_queue_cost))
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.breaker = (breaker if breaker is not None
                        else resilience.CircuitBreaker(
                            threshold=breaker_threshold,
                            reset_after_s=breaker_reset_s, name="serve"))
        self.ingest = ingest
        self._queue: List[_Queued] = []
        self._queue_cost = 0
        self._rejects: List[Tuple[int, CommunityResponse]] = []
        self._seq = 0
        self._served = 0
        self._shed = 0
        self._dispatches = 0

    # ------------------------------------------------------------ submit

    def submit(self, req: CommunityRequest) -> Optional[CommunityResponse]:
        """Admit + validate + canonicalize one request onto the queue.

        Returns ``None`` when the request was accepted (its response comes
        back from the next ``flush()``, including typed ingest rejections).
        Returns an immediate ``ok=False`` response when admission control
        sheds it — queue at depth/cost bound, or the signature's circuit
        breaker is open — so the caller learns to back off NOW, without
        the shed load ever occupying queue memory.
        """
        if req.algo not in ALGOS:
            raise ValueError(f"unknown algo {req.algo!r}; choose {ALGOS}")
        t0 = time.perf_counter()

        if len(self._queue) >= self.max_queue_depth:
            return self._shed_response(req, t0, (
                f"queue depth {len(self._queue)} at bound "
                f"{self.max_queue_depth}"))
        cost = _estimate_cost(req)
        if (self.max_queue_cost is not None
                and self._queue_cost + cost > self.max_queue_cost):
            return self._shed_response(req, t0, (
                f"queued cost {self._queue_cost} + {cost} would bust bound "
                f"{self.max_queue_cost}"))

        self._seq += 1
        deadline = (resilience.Deadline(req.deadline_ms / 1000.0)
                    if req.deadline_ms is not None else None)
        try:
            g, rep = from_numpy_edges_robust(req.u, req.v, req.w, n=req.n,
                                             **self.ingest)
        except CommunityDetectionError as err:
            telemetry.bump("serve.ingest_reject")
            self._rejects.append((self._seq, CommunityResponse(
                request_id=req.request_id, ok=False,
                error=f"{type(err).__name__}: {err}",
                latency_s=time.perf_counter() - t0)))
            return None

        sig = (tuple(capacity_signature(g.n_max, g.m_max))
               if g.n_max else None)
        if self.breaker.state((req.algo, sig)) == "open":
            # reject at the door: a known-bad signature class must not
            # consume queue space or breaker accounting while open
            telemetry.bump("serve.breaker_reject")
            err = OverloadError(
                f"circuit breaker open for {(req.algo, sig)!r}; retry "
                f"after the reset window")
            return CommunityResponse(
                request_id=req.request_id, ok=False,
                error=f"OverloadError: {err}", signature=sig,
                latency_s=time.perf_counter() - t0)

        self._queue.append(
            _Queued(req, g, dataclasses.asdict(rep), t0, self._seq,
                    deadline=deadline, cost=cost))
        self._queue_cost += cost
        return None

    def _shed_response(self, req: CommunityRequest, t0: float,
                       why: str) -> CommunityResponse:
        self._shed += 1
        telemetry.bump("serve.shed")
        err = OverloadError(f"admission control shed {req.request_id!r}: "
                            f"{why}")
        return CommunityResponse(
            request_id=req.request_id, ok=False,
            error=f"OverloadError: {err}",
            latency_s=time.perf_counter() - t0)

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------- flush

    def flush(self) -> List[CommunityResponse]:
        """Serve everything queued; responses in submit order."""
        queue, self._queue = self._queue, []
        self._queue_cost = 0
        rejects, self._rejects = self._rejects, []
        groups: Dict[Tuple, List[_Queued]] = {}
        for q in queue:
            sig = (tuple(capacity_signature(q.graph.n_max, q.graph.m_max))
                   if q.graph.n_max else None)
            groups.setdefault((q.req.algo, sig), []).append(q)

        tagged: List[Tuple[int, CommunityResponse]] = list(rejects)
        for key, members in groups.items():
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                tagged += zip((q.seq for q in chunk),
                              self._dispatch(key, chunk))
        tagged.sort(key=lambda t: t[0])   # submit order
        return [r for _, r in tagged]

    # ---------------------------------------------------------- dispatch

    def _dispatch(self, key: Tuple,
                  members: List[_Queued]) -> List[CommunityResponse]:
        algo = key[0]
        cfg = self.louvain_cfg if algo == "louvain" else self.plp_cfg
        self._dispatches += 1

        # requests that expired while queued fail BEFORE burning a dispatch
        expired: List[_Queued] = []
        alive: List[_Queued] = []
        for q in members:
            (expired if q.deadline is not None and q.deadline.expired
             else alive).append(q)
        out = [(q, DeadlineError(
            f"deadline expired while queued ({q.req.deadline_ms}ms)"))
            for q in expired]
        if expired:
            telemetry.bump("serve.deadline_expired_queued", len(expired))

        if alive:
            if self.breaker.state(key) == "open":
                # open breaker: route around the batched path entirely;
                # sequential outcomes are per-request and do NOT feed the
                # breaker (it re-evaluates only via the half-open probe)
                telemetry.bump("serve.breaker_routed_sequential")
                out += zip(alive, self._sequential(algo, cfg, alive))
            else:
                out += self._dispatch_batched(key, algo, cfg, alive)

        return [self._unpack(q, res, len(members)) for q, res in out]

    def _dispatch_batched(self, key, algo, cfg, alive):
        run_batch = louvain_batch if algo == "louvain" else plp_batch
        graphs = [q.graph for q in alive]
        try:
            results = self._run_with_retries(
                run_batch, graphs, cfg,
                lambda: resilience.min_remaining_s(
                    q.deadline for q in alive))
            self.breaker.record_success(key)
            return list(zip(alive, results))
        except DeadlineError as err:
            # the watchdog cancelled the batch: only requests whose budget
            # is actually spent fail; batch-mates re-run sequentially under
            # their own remaining budgets.  Not a breaker signal — the
            # budget was spent, the signature is not (known) poisoned.
            telemetry.bump("serve.batch_deadline_split")
            busted: List[_Queued] = []
            rest: List[_Queued] = []
            for q in alive:
                (busted if q.deadline is not None and q.deadline.expired
                 else rest).append(q)
            out = [(q, err) for q in busted]
            out += zip(rest, self._sequential(algo, cfg, rest))
            return out
        except CommunityDetectionError as err:
            # retry budget exhausted (or non-retryable): one poisoned slot
            # must not starve its batch-mates — degrade this group to
            # single-graph runs, isolating the error to the request that
            # owns it.  THIS is the breaker's signal: the batched path for
            # this signature failed outright.
            self.breaker.record_failure(key)
            telemetry.bump("serve.batch_fallback_sequential")
            return list(zip(alive, self._sequential(algo, cfg, alive)))

    def _run_with_retries(self, run_batch, graphs, cfg, deadline_s_fn):
        """One batched dispatch with preemption re-runs and jittered-backoff
        retries for transient failures, bounded by ``max_retries`` and the
        tightest member deadline."""
        delays = resilience.backoff_delays(
            self.max_retries, base_s=self.backoff_base_s,
            seed=self._dispatches)
        attempt = 0
        while True:
            try:
                if faultinject.consume("preempt_stage"):
                    raise resilience.Preempted(
                        "injected preemption at the serve dispatch tick")
                return run_batch(graphs, cfg, deadline_s=deadline_s_fn())
            except resilience.Preempted:
                # an event, not a state: the tick survives a kill by
                # re-running (bounded like any other retry, minus backoff)
                telemetry.bump("serve.preempt_rerun")
                attempt += 1
                if attempt > self.max_retries + 1:
                    raise CommunityDetectionError(
                        "dispatch tick preempted repeatedly; giving up")
            except Exception as err:  # noqa: BLE001 — taxonomy-routed below
                if attempt >= self.max_retries \
                        or not resilience.is_retryable(err):
                    raise
                delay = next(delays)
                rem = deadline_s_fn()
                if rem is not None and delay >= rem:
                    raise DeadlineError(
                        f"retry backoff ({delay:.3f}s) would bust the "
                        f"tightest member deadline ({rem:.3f}s remaining)"
                    ) from err
                telemetry.bump("serve.retry")
                telemetry.observe("serve.retry_backoff_s", delay)
                time.sleep(delay)
                attempt += 1

    def _sequential(self, algo, cfg, members):
        """Single-graph degradation path: each request under its OWN
        remaining deadline, errors isolated per request."""
        single = louvain if algo == "louvain" else plp
        results = []
        for q in members:
            budget = (q.deadline.remaining_s()
                      if q.deadline is not None else None)
            try:
                results.append(resilience.call_with_deadline(
                    lambda g=q.graph: single(g, cfg), budget))
            except CommunityDetectionError as err:
                results.append(err)
        return results

    def _unpack(self, q: _Queued, res, batch: int) -> CommunityResponse:
        if isinstance(res, CommunityDetectionError):
            kind = type(res).__name__
            return _fail(q, f"{kind}: {res}", batch,
                         warning=f"serve:{kind}:{q.req.request_id}")
        now = time.perf_counter()
        sig = (tuple(capacity_signature(q.graph.n_max, q.graph.m_max))
               if q.graph.n_max else None)
        self._served += 1
        return CommunityResponse(
            request_id=q.req.request_id, ok=True, labels=res.labels,
            result=res, repairs=q.repairs, signature=sig,
            latency_s=now - q.t_submit, batch_size=batch)

    # ------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Service + resilience + compiled-program-cache observability."""
        return {
            "pending": len(self._queue),
            "queued_cost": self._queue_cost,
            "served": self._served,
            "shed": self._shed,
            "dispatches": self._dispatches,
            "breakers": self.breaker.snapshot(),
            "programs": progcache.cache_stats(),
            "counters": {k: v for k, v in telemetry.snapshot().items()
                         if k.startswith(("batch.", "serve.", "ladder.",
                                          "resilience.", "fault."))},
            "values": telemetry.values(),
        }


# ----------------------------------------------------------------- CLI smoke


def _smoke(n_requests: int, deadline_ms: Optional[float],
           seed: int = 0) -> int:
    """End-to-end traffic sample for the CI chaos step: submit a mix of
    small graphs (two size classes → two signature buckets) with deadlines,
    flush, and REQUIRE a typed response for every accepted request.  Armed
    fault points (``REPRO_FAULTS``) perturb the run; the contract is
    "never hang, never drop" — errors are acceptable, silence is not."""
    import json as _json

    rng = np.random.default_rng(seed)
    eng = CommunityServeEngine(max_batch=8, max_retries=2,
                               backoff_base_s=0.01)
    accepted, shed = [], 0
    for i in range(n_requests):
        n = 24 if i % 2 else 96
        m = 3 * n
        u = rng.integers(0, n, size=m).astype(np.int64)
        v = rng.integers(0, n, size=m).astype(np.int64)
        req = CommunityRequest(request_id=f"smoke-{i}", u=u, v=v,
                               algo="louvain" if i % 3 else "plp", n=n,
                               deadline_ms=deadline_ms)
        resp = eng.submit(req)
        if resp is None:
            accepted.append(req.request_id)
        else:
            shed += 1
    responses = eng.flush()
    got = {r.request_id for r in responses}
    missing = [rid for rid in accepted if rid not in got]
    ok = sum(r.ok for r in responses)
    print(_json.dumps({
        "faults": sorted(faultinject.active()),
        "submitted": n_requests, "accepted": len(accepted), "shed": shed,
        "responses": len(responses), "ok": ok,
        "errors": sorted({r.error.split(":")[0] for r in responses
                          if r.error}),
        "missing": missing,
        "stats": {k: eng.stats()[k]
                  for k in ("served", "shed", "dispatches", "breakers")},
    }, default=str, indent=2))
    if missing:
        print(f"FATAL: {len(missing)} accepted request(s) got no response")
        return 1
    return 0


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run the end-to-end traffic sample and exit")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--deadline-ms", type=float, default=30000.0)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    if not a.smoke:
        ap.error("this entrypoint only implements --smoke")
    sys.exit(_smoke(a.requests, a.deadline_ms, a.seed))
