"""Request-batching community-detection service (DESIGN.md §Serving).

The single-graph drivers answer one graph per dispatch; serving traffic is
many small graphs arriving independently.  ``CommunityServeEngine`` is the
thin queueing layer that turns that traffic into the batched engine's
shape:

    submit() → canonical ingest (per request, so a poisoned edge list is
               rejected/repaired BEFORE it can share a batch with clean
               traffic) → queue
    flush()  → group by (algo, capacity signature) → ``louvain_batch`` /
               ``plp_batch`` dispatch per group → unpack per-request
               responses with the PR-7 ``RunReport`` and wall-clock latency

Batching changes throughput, never answers: every response is bit-identical
to running the single-graph driver on the same request (the batch engine's
parity contract).  If a batch trips a typed taxonomy error anyway (e.g. a
numeric guard on inputs that passed ingest), the engine degrades that ONE
group to sequential single-graph runs so clean requests still get answers
and only the offending request carries the error — recorded in
``stats()["counters"]`` as ``serve.batch_fallback_sequential``.

Deliberately synchronous and in-process: flush() is the unit a real
transport (thread, asyncio loop, RPC server) would call on its batching
tick; the engine itself stays free of I/O so it can be tested and
benchmarked hermetically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.batch import louvain_batch, plp_batch
from repro.core.louvain import LouvainConfig, louvain
from repro.core.plp import PLPConfig, plp
from repro.core import progcache
from repro.graph.builders import from_numpy_edges_robust
from repro.kernels.common import capacity_signature
from repro.utils import telemetry
from repro.utils.errors import CommunityDetectionError

ALGOS = ("louvain", "plp")


@dataclasses.dataclass
class CommunityRequest:
    """One graph to cluster: an undirected edge list + algorithm choice."""

    request_id: str
    u: np.ndarray
    v: np.ndarray
    w: Optional[np.ndarray] = None
    algo: str = "louvain"          # "louvain" | "plp"
    n: Optional[int] = None        # vertex count override (else max id + 1)


@dataclasses.dataclass
class CommunityResponse:
    """Per-request outcome, positionally independent of batch placement."""

    request_id: str
    ok: bool
    labels: Optional[np.ndarray] = None
    result: object = None          # LouvainResult | PLPResult when ok
    error: Optional[str] = None    # typed-taxonomy message when not ok
    repairs: dict = dataclasses.field(default_factory=dict)
    signature: Optional[tuple] = None
    latency_s: float = 0.0         # submit() → response unpack, wall clock
    batch_size: int = 0            # slots sharing this request's dispatch


@dataclasses.dataclass
class _Queued:
    req: CommunityRequest
    graph: object
    repairs: dict
    t_submit: float
    seq: int


class CommunityServeEngine:
    """Queue → bucket → batch-dispatch → unpack (module docstring).

    ``max_batch`` caps the slot count of one dispatch (memory bound);
    larger groups are chunked.  ``ingest`` kwargs forward to
    ``from_numpy_edges_robust`` (e.g. ``bad_weights="drop"`` to repair
    rather than reject poisoned weights).
    """

    def __init__(self, louvain_cfg: LouvainConfig = LouvainConfig(),
                 plp_cfg: PLPConfig = PLPConfig(), max_batch: int = 256,
                 **ingest):
        self.louvain_cfg = louvain_cfg
        self.plp_cfg = plp_cfg
        self.max_batch = int(max_batch)
        self.ingest = ingest
        self._queue: List[_Queued] = []
        self._rejects: List[Tuple[int, CommunityResponse]] = []
        self._seq = 0
        self._served = 0
        self._dispatches = 0

    def submit(self, req: CommunityRequest) -> None:
        """Validate + canonicalize one request onto the queue.

        Ingest failures (typed ``InputValidationError`` etc.) consume the
        request immediately — the error response comes back from the next
        ``flush()`` — so a malformed edge list can never join a batch.
        """
        if req.algo not in ALGOS:
            raise ValueError(f"unknown algo {req.algo!r}; choose {ALGOS}")
        t0 = time.perf_counter()
        self._seq += 1
        try:
            g, rep = from_numpy_edges_robust(req.u, req.v, req.w, n=req.n,
                                             **self.ingest)
        except CommunityDetectionError as err:
            telemetry.bump("serve.ingest_reject")
            self._rejects.append((self._seq, CommunityResponse(
                request_id=req.request_id, ok=False,
                error=f"{type(err).__name__}: {err}",
                latency_s=time.perf_counter() - t0)))
            return
        self._queue.append(
            _Queued(req, g, dataclasses.asdict(rep), t0, self._seq))

    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> List[CommunityResponse]:
        """Serve everything queued; responses in submit order."""
        queue, self._queue = self._queue, []
        rejects, self._rejects = self._rejects, []
        groups: Dict[Tuple, List[_Queued]] = {}
        for q in queue:
            sig = (capacity_signature(q.graph.n_max, q.graph.m_max)
                   if q.graph.n_max else None)
            groups.setdefault((q.req.algo, sig), []).append(q)

        tagged: List[Tuple[int, CommunityResponse]] = list(rejects)
        for (algo, _sig), members in groups.items():
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                tagged += zip((q.seq for q in chunk),
                              self._dispatch(algo, chunk))
        tagged.sort(key=lambda t: t[0])   # submit order
        return [r for _, r in tagged]

    def _dispatch(self, algo: str,
                  members: List[_Queued]) -> List[CommunityResponse]:
        run_batch = louvain_batch if algo == "louvain" else plp_batch
        cfg = self.louvain_cfg if algo == "louvain" else self.plp_cfg
        graphs = [q.graph for q in members]
        self._dispatches += 1
        try:
            results = run_batch(graphs, cfg)
        except CommunityDetectionError:
            # one poisoned slot must not starve its batch-mates: degrade
            # this group to single-graph runs, isolating the error to the
            # request that owns it
            telemetry.bump("serve.batch_fallback_sequential")
            results = []
            single = louvain if algo == "louvain" else plp
            for q in members:
                try:
                    results.append(single(q.graph, cfg))
                except CommunityDetectionError as err:
                    results.append(f"{type(err).__name__}: {err}")
        out = []
        for q, res in zip(members, results):
            now = time.perf_counter()
            sig = (tuple(capacity_signature(q.graph.n_max, q.graph.m_max))
                   if q.graph.n_max else None)
            if isinstance(res, str):
                out.append(CommunityResponse(
                    request_id=q.req.request_id, ok=False, error=res,
                    repairs=q.repairs, signature=sig,
                    latency_s=now - q.t_submit, batch_size=len(members)))
                continue
            self._served += 1
            out.append(CommunityResponse(
                request_id=q.req.request_id, ok=True, labels=res.labels,
                result=res, repairs=q.repairs, signature=sig,
                latency_s=now - q.t_submit, batch_size=len(members)))
        return out

    def stats(self) -> dict:
        """Service + compiled-program-cache observability, one call."""
        return {
            "pending": len(self._queue),
            "served": self._served,
            "dispatches": self._dispatches,
            "programs": progcache.cache_stats(),
            "counters": {k: v for k, v in telemetry.snapshot().items()
                         if k.startswith(("batch.", "serve.", "ladder."))},
        }
