"""Production mesh construction (required API: ``make_production_mesh``).

A FUNCTION, not a module constant — importing this module never touches jax
device state (device count is locked on first jax init; the dry-run must set
XLA_FLAGS before that).

Mesh layout (TPU v5e pods of 256 chips):
  single-pod:  (16, 16)        axes ('data', 'model')
  multi-pod:   (2, 16, 16)     axes ('pod', 'data', 'model')

'model' maps to the innermost ICI ring (highest-bandwidth collectives for TP),
'data' to the second ring (FSDP all-gathers / gradient reduce-scatters),
'pod' to the DCI/optical inter-pod links (data-parallel only: one gradient
all-reduce per step crosses pods).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:  # jax >= 0.5: explicit axis types
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    except ImportError:
        return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests)."""
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(mesh.shape)


# Hardware constants for the roofline model (TPU v5e per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~45-100 GB/s; spec midpoint)
HBM_BYTES = 16 * 1024**3      # 16 GiB
