"""Logical-axis sharding: named dims -> mesh axes, MaxText-style.

Model code never mentions mesh axes; it tags tensors/params with *logical*
names ('embed', 'heads', 'mlp', 'experts', 'batch', ...).  A rule table maps
logical names to physical mesh axes.  Resolution is divisibility-aware: a rule
is dropped (dim replicated) when the dim size does not divide the axis size —
this is what lets one config compile on a laptop (mesh absent -> everything is
a no-op), a 256-chip pod, and a 512-chip 2-pod mesh without edits.

The rule table below is the baseline (§Perf hillclimbs mutate it):

  'embed'   -> FSDP over ('pod','data')  — weight rows; ZeRO-3-style
  'vocab', 'heads', 'mlp', 'experts' -> 'model'  — tensor/expert parallel
  'batch'   -> ('pod','data')            — data parallel activations
  'heads_act', 'vocab_act' -> 'model'    — activation TP dims
  'embed_act' -> 'model' iff cfg.shard_residual_embed (SP-like residual)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

_state = threading.local()


DEFAULT_RULES: dict[str, object] = {
    "embed": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "mlp": "model",
    "experts": "model",
    "layers": None,
    "batch": ("pod", "data"),
    "heads_act": "model",
    "vocab_act": "model",
    "experts_act": "model",
    "embed_act": None,          # flipped to 'model' by shard_residual_embed
    "kv": None,
    "seq": None,
}


def _get() -> tuple[Optional[Mesh], dict]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + rule table for logical-axis resolution."""
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES))
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _state.mesh, _state.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _get()[0]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(axis, 1)


def _filter_axes(mesh: Mesh, axis):
    """Drop axes not present in the mesh (e.g. 'pod' on a single-pod mesh)."""
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.shape)
        return kept if kept else None
    return axis if axis in mesh.shape else None


def resolve_spec(names: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> PS:
    """Logical names -> PartitionSpec under the active mesh + rules.

    When ``shape`` is given, rules whose axis size does not divide the dim are
    dropped (replicated) — divisibility-aware resolution.
    """
    mesh, rules = _get()
    if mesh is None:
        return PS()
    parts = []
    used: set = set()
    for i, nm in enumerate(names):
        axis = _filter_axes(mesh, rules.get(nm)) if nm else None
        if axis is not None:
            flat = axis if isinstance(axis, tuple) else (axis,)
            if any(a in used for a in flat):
                axis = None  # an axis may appear once per spec
        if axis is not None and shape is not None:
            if shape[i] % _axis_size(mesh, axis) != 0:
                axis = None
        if axis is not None:
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                used.add(a)
        parts.append(axis)
    while parts and parts[-1] is None:
        parts.pop()
    return PS(*parts)


def sharding_for(names: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
    mesh, _ = _get()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(names, shape))


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint via logical names; identity without a mesh."""
    mesh, _ = _get()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, resolve_spec(names, x.shape))


# ------------------------------------------------------------ param trees


def param_specs(decls) -> object:
    """ParamDecl tree -> PartitionSpec tree (divisibility-aware)."""
    from repro.models.common import is_decl
    return jax.tree.map(
        lambda d: resolve_spec(d.names, d.shape), decls, is_leaf=is_decl)


def param_shardings(decls) -> object:
    from repro.models.common import is_decl
    mesh, _ = _get()
    if mesh is None:
        raise RuntimeError("param_shardings requires an active mesh")
    return jax.tree.map(
        lambda d: NamedSharding(mesh, resolve_spec(d.names, d.shape)),
        decls, is_leaf=is_decl)


def spec_bytes_per_device(decls) -> int:
    """Static estimate: per-device parameter bytes under current rules."""
    from repro.models.common import is_decl
    mesh, _ = _get()
    total = 0
    for d in jax.tree.leaves(decls, is_leaf=is_decl):
        n = 1
        for s in d.shape:
            n *= s
        shard = 1
        spec = resolve_spec(d.names, d.shape)
        for ax in spec:
            if ax is not None:
                shard *= _axis_size(mesh, ax)
        total += n // max(1, shard) * jnp.dtype(d.dtype).itemsize
    return total
