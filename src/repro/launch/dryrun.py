import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
  * 512 placeholder host devices stand in for 2 pods x 256 chips;
  * every cell's step function is jit-lowered with ShapeDtypeStruct inputs
    (zero allocation) and compiled for the production mesh;
  * ``compiled.memory_analysis()`` proves the per-device working set,
    ``compiled.cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # full sweep
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi   # 2x16x16 only
Artifacts: benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api as model_api
from repro.models.arch_config import SHAPES, cell_applicable
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.train_step import (
    make_decode_step, make_prefill_step, make_train_step)
from repro.train import optim

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'bf16[16,512,128]{...}' -> byte size (0 for tuples/tokens)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (per-device) HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    # instruction form: %name = TYPE op-name(...operands...)
    for m in re.finditer(
            r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start|-done)?\(([^)]*)\)", hlo_text):
        result_t, op, operands = m.groups()
        if op.endswith("-done)"):
            continue
        # operand bytes: parse each operand's declared type if present; fall
        # back to result type (all-reduce/permute: operand size == result)
        obytes = 0
        for ot in re.finditer(r"([a-z0-9]+\[[0-9,]*\])", operands):
            obytes += _shape_bytes(ot.group(1))
        if obytes == 0:
            if result_t.startswith("("):
                for rt in re.finditer(r"([a-z0-9]+\[[0-9,]*\])", result_t):
                    obytes += _shape_bytes(rt.group(1))
            else:
                obytes = _shape_bytes(result_t)
        out[op]["count"] += 1
        out[op]["bytes"] += obytes
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if k in _COLLECTIVES)
    out["total_count"] = sum(v["count"] for k, v in out.items() if k in _COLLECTIVES)
    return out


def run_cell(arch_id: str, shape_name: str, mesh, mesh_tag: str,
             *, save: bool = True, hlo_dump: bool = False) -> dict:
    c = configs.get(arch_id)
    cell = SHAPES[shape_name]
    ok, reason = cell_applicable(c, cell)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
           "kind": cell.kind, "status": "skipped", "reason": reason}
    if not ok:
        return _finish(rec, save)

    model = model_api.build(c)
    t0 = time.time()
    try:
        rules = {"embed_act": "model"} if c.shard_residual_embed else {}
        with shd.use_mesh(mesh, rules):
            if cell.kind == "train":
                opt_cfg = optim.OptimConfig(name=c.optimizer)
                step, in_sh, out_sh, _ = make_train_step(model, opt_cfg, cell, mesh)
                pspecs = model_api.to_shape_tree(model.decls)
                opt_specs = _opt_state_specs(c, model, pspecs)
                jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                                 donate_argnums=(0, 1))
                lowered = jitted.lower(pspecs, opt_specs, model.input_specs(cell))
            elif cell.kind == "prefill":
                step, in_sh, out_sh = make_prefill_step(model, cell, mesh)
                pspecs = model_api.to_shape_tree(model.decls)
                jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
                lowered = jitted.lower(pspecs, model.input_specs(cell))
            else:  # decode
                step, in_sh, out_sh = make_decode_step(model, cell, mesh)
                pspecs = model_api.to_shape_tree(model.decls)
                st = model.decode_state_specs(cell)
                tok = model.input_specs(cell)["token"]
                jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                                 donate_argnums=(2,))
                lowered = jitted.lower(pspecs, tok, st)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        from repro.launch import hlo_cost
        corrected = hlo_cost.analyze(hlo)  # loop-aware per-device costs
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_devices": mesh.devices.size,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            "cost": {
                "flops_per_device": cost.get("flops"),
                "bytes_accessed_per_device": cost.get("bytes accessed"),
            },
            "cost_loop_aware": corrected,   # see launch/hlo_cost.py
            "collectives": coll,
            "model_flops_global": model.model_flops(cell),
            "active_params": c.active_params(),
            "total_params": c.total_params(),
        })
        # always keep the compiled HLO (gzipped): §Perf re-analysis re-derives
        # roofline terms from stored IR without recompiling
        rec["hlo_path"] = _dump_hlo(arch_id, shape_name, mesh_tag, hlo)
    except Exception as e:  # a cell failure is a bug; record it loudly
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
    return _finish(rec, save)


def _opt_state_specs(c, model, pspecs):
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    if c.optimizer == "adamw":
        f32 = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
        return optim.AdamWState(scalar, f32(pspecs), f32(pspecs))
    from repro.models.common import is_decl

    def stat(decl):
        if optim._factored(decl.shape, 128):
            return {"vr": jax.ShapeDtypeStruct(decl.shape[:-1], jnp.float32),
                    "vc": jax.ShapeDtypeStruct(decl.shape[:-2] + decl.shape[-1:],
                                               jnp.float32)}
        return {"v": jax.ShapeDtypeStruct(decl.shape, jnp.float32)}

    stats = jax.tree.map(stat, model.decls, is_leaf=is_decl)
    return optim.AdafactorState(scalar, stats)


def _dump_hlo(arch, shape, mesh_tag, hlo) -> str:
    import gzip
    d = os.path.join(ARTIFACT_DIR, mesh_tag, "hlo")
    os.makedirs(d, exist_ok=True)
    p = os.path.join(d, f"{arch}__{shape}.hlo.txt.gz")
    with gzip.open(p, "wt") as f:
        f.write(hlo)
    return p


def reanalyze(mesh_tag: str) -> int:
    """Recompute cost_loop_aware for all cells from stored HLO (no compile)."""
    import glob
    import gzip
    from repro.launch import hlo_cost
    n = 0
    for jf in glob.glob(os.path.join(ARTIFACT_DIR, mesh_tag, "*.json")):
        rec = json.load(open(jf))
        hp = rec.get("hlo_path", "")
        if rec.get("status") != "ok" or not hp or not os.path.exists(hp):
            continue
        with gzip.open(hp, "rt") as f:
            hlo = f.read()
        rec["cost_loop_aware"] = hlo_cost.analyze(hlo)
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        n += 1
    print(f"[dryrun] reanalyzed {n} cells in mesh '{mesh_tag}'")
    return n


def _finish(rec: dict, save: bool) -> dict:
    if save:
        d = os.path.join(ARTIFACT_DIR, rec["mesh"])
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{rec['arch']}__{rec['shape']}.json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = rec.get("reason") or rec.get("error", "")
    print(f"[dryrun] {rec['mesh']:6s} {rec['arch']:28s} {rec['shape']:12s} "
          f"{status:8s} {extra[:90]}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape cell (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--hlo-dump", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute costs from stored HLO without compiling")
    args = ap.parse_args(argv)
    if args.reanalyze:
        for tag in (["single", "multi"] if args.mesh == "both" else [args.mesh]):
            reanalyze(tag)
        return 0

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(configs.ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_fail = 0
    for mesh_tag, mesh in meshes:
        for a in archs:
            for s in shapes:
                out_p = os.path.join(ARTIFACT_DIR, mesh_tag, f"{a}__{s}.json")
                if args.skip_existing and os.path.exists(out_p):
                    with open(out_p) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] {mesh_tag:6s} {a:28s} {s:12s} cached")
                            continue
                rec = run_cell(a, s, mesh, mesh_tag,
                               save=True, hlo_dump=args.hlo_dump)
                n_fail += rec["status"] == "error"
    print(f"[dryrun] done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
