"""Loop-aware cost analysis over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` (XLA's HloCostAnalysis) counts
every ``while`` body ONCE, but our models lower layer stacks / grad-accum /
attention chunking to scans — on a 96-layer model the stock numbers are ~100x
low.  XLA's CPU pipeline annotates each ``while`` with
``backend_config={"known_trip_count":{"n": N}}``; this module re-aggregates
per-computation costs with those trip counts (recursively, so nested
accum(layers(chunks)) scans multiply correctly).

Cost model (per-device, post-SPMD-partitioning, post-fusion):
  flops:  dot = 2 * prod(result_dims) * prod(contracted_dims); elementwise /
          reduce ops inside fusions = prod(result_dims) each.
  bytes:  per *scheduled instruction* (fusion, dot, copy, ...) the sum of its
          operand + result buffer sizes — i.e. XLA's own bytes-accessed model
          on the post-fusion graph, which is the canonical HBM-traffic proxy.
  collective_bytes: operand bytes of all-gather / all-reduce / reduce-scatter
          / all-to-all / collective-permute, loop-scaled like everything else.

Everything is parsed from ``compiled.as_text()`` — no private APIs.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "add-dependency", "custom-call", "broadcast", "reshape",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _parse_shape(s: str) -> Tuple[int, int]:
    """'bf16[8,128]{1,0}' or '(a, b)' -> (elements, bytes) summed over tuple."""
    elems = byts = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Optional[dict] = None

    def __add__(self, o: "Cost") -> "Cost":
        merged = dict(self.collective_by_op or {})
        for k, v in (o.collective_by_op or {}).items():
            d = merged.setdefault(k, {"count": 0, "bytes": 0.0})
            d["count"] += v["count"]
            d["bytes"] += v["bytes"]
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.transcendentals + o.transcendentals,
                    self.collective_bytes + o.collective_bytes, merged)

    def scaled(self, k: float) -> "Cost":
        by = {kk: {"count": v["count"] * k, "bytes": v["bytes"] * k}
              for kk, v in (self.collective_by_op or {}).items()}
        return Cost(self.flops * k, self.bytes * k, self.transcendentals * k,
                    self.collective_bytes * k, by)


# result type is either a tuple '(...)' (may contain /*index=k*/ comments,
# never nested parens) or a scalar/array type like 'bf16[8,128]{1,0}'
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(", re.M)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of instruction lines. ENTRY keyed '__entry__'."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.endswith("{") and ("->" in ls or ls.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", ls)
            if m:
                cur = "__entry__" if ls.startswith("ENTRY") else m.group(1)
                comps[cur] = []
            continue
        if ls == "}":
            cur = None
            continue
        if cur is not None and "=" in ls:
            comps[cur].append(ls)
    return comps


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    _, name, rtype, opcode = m.groups()
    rest = line[m.end():]
    # operand list: up to the matching close paren (operands never nest parens)
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    operands_str, attrs = rest[:i], rest[i + 1:]
    operands = re.findall(r"%([\w.\-]+)", operands_str)
    return Instr(name, rtype, opcode, operands, attrs)


def _trip_count(instr: Instr, comps, shapes) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.attrs)
    if m:
        return float(m.group(1))
    # fallback: largest s32 constant in the condition computation
    mc = re.search(r"condition=%([\w.\-]+)", instr.attrs)
    if mc and mc.group(1) in comps:
        consts = [int(x) for line in comps[mc.group(1)]
                  for x in re.findall(r"constant\((\d+)\)", line)]
        if consts:
            return float(max(consts))
    return 1.0


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = _split_computations(hlo_text)
        self.instrs: Dict[str, List[Instr]] = {}
        self.shapes: Dict[Tuple[str, str], str] = {}
        for cname, lines in self.comps.items():
            out = []
            for line in lines:
                ins = _parse_instr(line)
                if ins is not None:
                    out.append(ins)
                    self.shapes[(cname, ins.name)] = ins.result_type
            self.instrs[cname] = out
        self._memo: Dict[str, Cost] = {}

    # -- shape lookup helpers --
    def _operand_type(self, cname: str, op_name: str) -> str:
        return self.shapes.get((cname, op_name), "")

    def _dot_cost(self, cname: str, ins: Instr) -> Cost:
        r_elems, r_bytes = _parse_shape(ins.result_type)
        lhs_t = self._operand_type(cname, ins.operands[0]) if ins.operands else ""
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
        k = 1
        if m and lhs_t:
            dims_m = _SHAPE_RE.search(lhs_t)
            if dims_m:
                lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for ci in (int(x) for x in m.group(1).split(",") if x):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
        ob = sum(_parse_shape(self._operand_type(cname, o))[1]
                 for o in ins.operands)
        return Cost(flops=2.0 * r_elems * k, bytes=ob + r_bytes)

    def _fusion_flops(self, called: str) -> Tuple[float, float]:
        """(elementwise flops, transcendentals) inside a fused computation."""
        fl = tr = 0.0
        for ins in self.instrs.get(called, []):
            if ins.opcode in _FREE_OPS or ins.opcode in ("fusion",):
                continue
            elems, _ = _parse_shape(ins.result_type)
            if ins.opcode == "dot":
                c = self._dot_cost(called, ins)
                fl += c.flops
                continue
            if ins.opcode in ("exponential", "tanh", "logistic", "log", "rsqrt",
                              "sqrt", "power", "cosine", "sine"):
                tr += elems
            if ins.opcode == "reduce":
                op_elems = sum(_parse_shape(self._operand_type(called, o))[0]
                               for o in ins.operands[:1])
                fl += op_elems
            else:
                fl += elems
        return fl, tr

    def _fusion_bytes(self, called: str, cname: str, ins: Instr) -> Tuple[float, float]:
        """Use-aware fusion traffic: a parameter consumed ONLY through
        dynamic-slice/gather counts its sliced bytes, not the full buffer —
        this is what makes per-layer weight slices of a stacked scan cost
        O(layer) instead of O(stack).  Same for a DUS root (in-place write)."""
        internal = self.instrs.get(called, [])
        params = [i2 for i2 in internal if i2.opcode == "parameter"]
        uses: Dict[str, List[Tuple[Instr, float]]] = {p.name: [] for p in params}
        for i2 in internal:
            for o in i2.operands:
                if o in uses:
                    _, rb2 = _parse_shape(i2.result_type)
                    uses[o].append((i2, rb2))
        ob = 0.0
        for p in params:
            full = _parse_shape(p.result_type)[1]
            u = uses.get(p.name, [])
            if u and all(i2.opcode in ("dynamic-slice", "gather") for i2, _ in u):
                ob += sum(rb2 for _, rb2 in u)   # sliced reads only
            elif u and all(i2.opcode == "dynamic-update-slice"
                           and i2.operands and i2.operands[0] == p.name
                           for i2, _ in u):
                # in-place loop-stack update: only the touched region moves
                for i2, _ in u:
                    upd = i2.operands[1] if len(i2.operands) > 1 else None
                    ub = _parse_shape(self.shapes.get((called, upd), ""))[1] \
                        if upd else 0
                    ob += ub or full
            else:
                ob += full
        # result bytes: if the root is a dynamic-update-slice, only the update
        # region is written (plus read-modify of that region)
        _, rb = _parse_shape(ins.result_type)
        root = internal[-1] if internal else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = root.operands[1] if len(root.operands) > 1 else None
            if upd:
                ub = _parse_shape(self.shapes.get((called, upd), ""))[1]
                if ub:
                    rb = ub
        return ob, rb

    def computation_cost(self, cname: str) -> Cost:
        if cname in self._memo:
            return self._memo[cname]
        total = Cost(collective_by_op={})
        for ins in self.instrs.get(cname, []):
            total = total + self.instruction_cost(cname, ins)
        self._memo[cname] = total
        return total

    def instruction_cost(self, cname: str, ins: Instr) -> Cost:
        op = ins.opcode
        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVE_OPS:
            if op.endswith("-done"):
                return Cost()
            ob = sum(_parse_shape(self._operand_type(cname, o))[1]
                     for o in ins.operands)
            if ob == 0:
                _, ob = _parse_shape(ins.result_type)
            _, rb = _parse_shape(ins.result_type)
            return Cost(bytes=0.0, collective_bytes=ob,
                        collective_by_op={base: {"count": 1, "bytes": ob}})
        if op in _FREE_OPS:
            return Cost()
        if op == "while":
            body = re.search(r"body=%([\w.\-]+)", ins.attrs)
            trips = _trip_count(ins, self.comps, self.shapes)
            c = Cost()
            if body and body.group(1) in self.comps:
                c = self.computation_cost(body.group(1)).scaled(trips)
            return c
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
            names = re.findall(r"%([\w.\-]+)", branches[0]) if branches else []
            costs = [self.computation_cost(n) for n in names if n in self.comps]
            if costs:
                worst = max(costs, key=lambda c: c.flops + c.bytes)
                return worst
            return Cost()
        if op in ("call", "async-start"):
            callee = re.search(r"(?:to_apply|calls)=%([\w.\-]+)", ins.attrs)
            if callee and callee.group(1) in self.comps:
                return self.computation_cost(callee.group(1))
            return Cost()
        if op == "dot":
            return self._dot_cost(cname, ins)
        if op == "fusion":
            callee = re.search(r"calls=%([\w.\-]+)", ins.attrs)
            fl = tr = 0.0
            if callee:
                fl, tr = self._fusion_flops(callee.group(1))
                ob, rb = self._fusion_bytes(callee.group(1), cname, ins)
            else:
                ob = sum(_parse_shape(self._operand_type(cname, o))[1]
                         for o in ins.operands)
                _, rb = _parse_shape(ins.result_type)
            return Cost(flops=fl, bytes=ob + rb, transcendentals=tr)
        if op == "convolution":
            r_elems, r_bytes = _parse_shape(ins.result_type)
            ob = sum(_parse_shape(self._operand_type(cname, o))[1]
                     for o in ins.operands)
            ke, _ = _parse_shape(self._operand_type(cname, ins.operands[1])) \
                if len(ins.operands) > 1 else (1, 0)
            return Cost(flops=2.0 * r_elems * max(1, ke // max(1, r_elems)),
                        bytes=ob + r_bytes)
        if op in ("dynamic-slice", "slice", "gather"):
            # only touched bytes count (read slice + write result)
            _, rb = _parse_shape(ins.result_type)
            return Cost(bytes=2.0 * rb)
        if op in ("dynamic-update-slice", "scatter"):
            # read update + write region; the big operand is aliased in place
            upd = ins.operands[1] if len(ins.operands) > 1 else None
            ub = _parse_shape(self._operand_type(cname, upd))[1] if upd else 0
            if ub == 0:
                _, ub = _parse_shape(ins.result_type)
                ub //= 4  # unknown update size: conservative fraction
            return Cost(bytes=2.0 * ub)
        # default data op (copy, sort, concatenate, pad, transpose, ...)
        _, rb = _parse_shape(ins.result_type)
        ob = sum(_parse_shape(self._operand_type(cname, o))[1]
                 for o in ins.operands)
        elems, _ = _parse_shape(ins.result_type)
        fl = elems if op in ("reduce", "sort", "select-and-scatter") else 0.0
        return Cost(flops=fl, bytes=ob + rb)

    def entry_cost(self) -> Cost:
        return self.computation_cost("__entry__")


def _op_label(ins: Instr) -> str:
    m = re.search(r'op_name="([^"]+)"', ins.attrs)
    if m:
        # strip jit wrapper + uniquifiers: keep the semantic path tail
        parts = m.group(1).split("/")
        keep = [p for p in parts if not p.startswith("jit(")]
        return "/".join(keep[-4:]) if keep else m.group(1)
    return ins.opcode


class _Profiler(HloCostModel):
    """Loop-scaled per-instruction attribution (the dry-run 'profile')."""

    def profile(self, top_k: int = 25):
        self.rows: Dict[str, dict] = {}
        self._walk("__entry__", 1.0)
        rows = sorted(self.rows.values(), key=lambda r: -r["bytes"])
        return rows[:top_k]

    def _walk(self, cname: str, scale: float):
        for ins in self.instrs.get(cname, []):
            if ins.opcode == "while":
                body = re.search(r"body=%([\w.\-]+)", ins.attrs)
                trips = _trip_count(ins, self.comps, self.shapes)
                if body and body.group(1) in self.comps:
                    self._walk(body.group(1), scale * trips)
                continue
            if ins.opcode in ("call", "async-start"):
                callee = re.search(r"(?:to_apply|calls)=%([\w.\-]+)", ins.attrs)
                if callee and callee.group(1) in self.comps:
                    self._walk(callee.group(1), scale)
                continue
            c = self.instruction_cost(cname, ins)
            if c.flops == 0 and c.bytes == 0 and c.collective_bytes == 0:
                continue
            key = f"{ins.opcode}|{_op_label(ins)}"
            row = self.rows.setdefault(
                key, {"op": ins.opcode, "label": _op_label(ins), "count": 0,
                      "flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                      "flash": False})
            row["count"] += scale
            row["flops"] += c.flops * scale
            row["bytes"] += c.bytes * scale
            row["collective_bytes"] += c.collective_bytes * scale
            # full-metadata scope flag (labels truncate the op_name path)
            if "flash_attn" in ins.attrs:
                row["flash"] = True


def profile(hlo_text: str, top_k: int = 25):
    return _Profiler(hlo_text).profile(top_k)


def analyze(hlo_text: str) -> dict:
    """Full loop-aware per-device cost summary as a JSON-able dict."""
    cost = HloCostModel(hlo_text).entry_cost()
    return {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "transcendentals_per_device": cost.transcendentals,
        "collective_bytes_per_device": cost.collective_bytes,
        "collectives": cost.collective_by_op or {},
    }
