"""Training launcher: ``python -m repro.launch.train --arch qwen3-1.7b ...``.

Fault-tolerance contract (exercised by tests/test_train_loop.py):
  * checkpoint every ``--ckpt-every`` steps (atomic; see train/checkpoint.py);
  * on start, auto-resume from the newest committed checkpoint;
  * ``--simulate-failure-at N`` hard-exits mid-run (os._exit) to prove the
    next launch resumes losslessly — the data pipeline is counter-based, so
    batch N after restart is bit-identical to batch N without the failure;
  * elastic restart: the checkpoint stores unsharded arrays; a restarted run
    may use a different mesh (device count) and is resharded on restore;
  * straggler mitigation at scale = synchronous SPMD + per-step watchdog: a
    step exceeding ``--step-timeout``x the median logs a straggler warning
    (on real pods this feeds the controller that evicts the slow host).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api as model_api
from repro.models.arch_config import ShapeCell
from repro.models.common import init_params
from repro.train import checkpoint as ckpt_lib
from repro.train import optim
from repro.train.data import DataConfig, make_batch
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.train_step import make_train_step


def build_trainer(c, cell, mesh=None, opt_cfg=None):
    """(model, step_fn(params,opt,batch), init_fn) triple."""
    model = model_api.build(c)
    opt_cfg = opt_cfg or optim.OptimConfig(name=c.optimizer)
    step, in_sh, out_sh, _ = make_train_step(model, opt_cfg, cell, mesh)
    if mesh is not None:
        step = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1))
    else:
        step = jax.jit(step, donate_argnums=(0, 1))

    def init_fn(seed=0):
        params = init_params(model.decls, seed=seed)
        opt_state = optim.init_opt(c.optimizer, params, opt_cfg)
        return params, opt_state

    return model, step, init_fn


def train(c, cell: ShapeCell, *, steps: int, ckpt_dir: str | None = None,
          ckpt_every: int = 0, mesh=None, seed: int = 0,
          simulate_failure_at: int = -1, step_timeout_factor: float = 5.0,
          log_every: int = 10, data_cfg: DataConfig = DataConfig()):
    model, step_fn, init_fn = build_trainer(c, cell, mesh)
    start = 0
    params = opt_state = None
    if ckpt_dir:
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            print(f"[train] resuming from checkpoint step {last}", flush=True)
            p0, o0 = init_fn(seed)
            bundle = ckpt_lib.restore(
                ckpt_dir, last, {"params": p0, "opt": o0},
                expect_config=c.to_json())
            params, opt_state = bundle["params"], bundle["opt"]
            start = last
    if params is None:
        params, opt_state = init_fn(seed)

    history = []
    durations = []
    for step in range(start, steps):
        batch_np = make_batch(c, cell, step, data_cfg)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        durations.append(dt)
        med = float(np.median(durations[-20:]))
        if len(durations) > 5 and dt > step_timeout_factor * med:
            print(f"[train] STRAGGLER step {step}: {dt:.2f}s vs median {med:.2f}s",
                  flush=True)
        history.append({"step": step, "loss": loss,
                        "grad_norm": float(metrics["grad_norm"]), "sec": dt})
        if log_every and step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s", flush=True)
        done = step + 1
        if ckpt_dir and ckpt_every and done % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, done, {"params": params, "opt": opt_state},
                          config_json=c.to_json(),
                          mesh_shape=dict(mesh.shape) if mesh else {})
        if simulate_failure_at >= 0 and done >= simulate_failure_at:
            print(f"[train] SIMULATED FAILURE at step {done}", flush=True)
            os._exit(42)
    return params, opt_state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--data", type=int, default=1, help="data-parallel size")
    ap.add_argument("--model", type=int, default=1, help="model-parallel size")
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args, unknown = ap.parse_known_args(argv)

    c = configs.get(args.arch, reduced=args.reduced)
    from repro.config import apply_overrides, parse_cli_overrides
    _, overrides = parse_cli_overrides(unknown)
    if overrides:
        c = apply_overrides(c, overrides)
    cell = ShapeCell("cli", "train", args.seq_len, args.global_batch)
    mesh = None
    if args.data * args.model > 1:
        mesh = make_host_mesh(args.data, args.model)
    rules = {"embed_act": "model"} if c.shard_residual_embed else {}
    with shd.use_mesh(mesh, rules):
        _, _, hist = train(
            c, cell, steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, mesh=mesh, seed=args.seed,
            simulate_failure_at=args.simulate_failure_at)
    print(json.dumps({"final_loss": hist[-1]["loss"] if hist else None,
                      "steps_run": len(hist)}))


if __name__ == "__main__":
    main()
