"""Jitted train/serve step construction with logical-axis shardings.

``make_train_step`` returns (step_fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)`` — shared by the real trainer and the dry-run.

Production techniques implemented here:
  * gradient accumulation (``cfg.grad_accum`` microbatches via lax.scan) —
    bounds activation memory for the 340B/400B archs;
  * f32 gradient accumulators sharded like the params (ZeRO);
  * optional int8 gradient compression for the cross-pod all-reduce
    (error-feedback-free stochastic-free deterministic quantization; opt-in,
    evaluated in §Perf);
  * donation of params/opt-state buffers (in-place update at scale).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.models.api import ModelAPI
from repro.models.arch_config import ArchConfig, ShapeCell
from repro.train import optim
from repro.launch import sharding as shd


def _batch_spec(mesh, cell: ShapeCell, arr_ndim: int) -> PS:
    """Tokens/labels: batch over ('pod','data') when divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if axes and cell.global_batch % n == 0:
        return PS(axes, *([None] * (arr_ndim - 1)))
    return PS(*([None] * arr_ndim))


def quantize_grads_int8(grads):
    """Deterministic per-tensor int8 quantization (gradient compression)."""
    def q(g):
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
        qi = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return qi.astype(jnp.float32) * scale
    return jax.tree.map(q, grads)


def make_train_step(model: ModelAPI, opt_cfg: optim.OptimConfig,
                    cell: ShapeCell, mesh=None, *,
                    compress_grads: bool = False):
    """Returns (train_step, in_shardings, out_shardings, batch_shardings)."""
    c = model.cfg
    accum = max(1, c.grad_accum)

    # Param specs captured for the gradient accumulator: constraining the f32
    # accumulator to the PARAM sharding makes XLA reduce-SCATTER each
    # microbatch's gradient contribution (bytes x (N-1)/N) instead of
    # all-reducing it (bytes x 2(N-1)/N) — §Perf iteration "grad-RS".
    if mesh is not None:
        with shd.use_mesh(mesh, _rules_for(c)):
            _grad_pspecs = shd.param_specs(model.decls)
    else:
        _grad_pspecs = None

    def loss_of(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def _constrain_grads(g):
        if _grad_pspecs is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g, _grad_pspecs)

    def train_step(params, opt_state, batch):
        b = batch["tokens"].shape[0]
        assert b % accum == 0, (b, accum)
        mb = b // accum

        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(idx):
                sl = lambda x: jax.lax.dynamic_slice_in_dim(x, idx * mb, mb, axis=0)
                return jax.tree.map(sl, batch)

            def body(carry, idx):
                acc, lsum = carry
                (l, m), g = grad_fn(params, micro(idx))
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
                acc = _constrain_grads(acc)
                return (acc, lsum + l), m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros = _constrain_grads(zeros)
            (grads, lsum), ms = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), jnp.arange(accum))
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = lsum / accum
            metrics = jax.tree.map(lambda x: x[-1], ms)

        if compress_grads:
            grads = quantize_grads_int8(grads)

        new_params, new_opt, stats = optim.apply_opt(
            c.optimizer, opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    if mesh is None:
        return train_step, None, None, None

    with shd.use_mesh(mesh, _rules_for(c)):
        pspecs = shd.param_specs(model.decls)
        param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        opt_sh = _opt_shardings(c, model, mesh, pspecs)
        batch_sh = {
            k: NamedSharding(mesh, _batch_spec(mesh, cell, len(v.shape)))
            for k, v in model.input_specs(cell).items()
        }
        scalar = NamedSharding(mesh, PS())
        in_sh = (param_sh, opt_sh, batch_sh)
        out_sh = (param_sh, opt_sh,
                  {"ce": scalar, "aux": scalar, "loss": scalar,
                   "grad_norm": scalar, "lr": scalar})
    return train_step, in_sh, out_sh, batch_sh


def _rules_for(c: ArchConfig) -> dict:
    rules = {}
    if c.shard_residual_embed:
        rules["embed_act"] = "model"
    return rules


def _opt_shardings(c: ArchConfig, model: ModelAPI, mesh, pspecs):
    """Optimizer state shardings mirror the parameter specs."""
    scalar = NamedSharding(mesh, PS())
    as_sh = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    if c.optimizer == "adamw":
        return optim.AdamWState(scalar, as_sh(pspecs), as_sh(pspecs))
    # adafactor: factored stats drop the last (or second-to-last) dim
    from repro.models.common import is_decl

    def stat_spec(decl):
        spec = shd.resolve_spec(decl.names, decl.shape)
        parts = list(spec) + [None] * (len(decl.shape) - len(spec))
        if optim._factored(decl.shape, 128):
            vr = PS(*parts[:-1])                     # mean over last dim
            vc = PS(*(parts[:-2] + parts[-1:]))      # mean over second-to-last
            return {"vr": NamedSharding(mesh, vr), "vc": NamedSharding(mesh, vc)}
        return {"v": NamedSharding(mesh, PS(*parts))}

    stats = jax.tree.map(stat_spec, model.decls, is_leaf=is_decl)
    return optim.AdafactorState(scalar, stats)


# -------------------------------------------------------------- serve steps


def make_prefill_step(model: ModelAPI, cell: ShapeCell, mesh=None):
    c = model.cfg

    def prefill_step(params, batch):
        return model.prefill_fn(params, batch)

    if mesh is None:
        return prefill_step, None, None
    with shd.use_mesh(mesh, _rules_for(c)):
        pspecs = shd.param_specs(model.decls)
        param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        batch_sh = {
            k: NamedSharding(mesh, _batch_spec(mesh, cell, len(v.shape)))
            for k, v in model.input_specs(cell).items()
        }
        logits_sh = NamedSharding(mesh, _batch_spec(mesh, cell, 3))
    return prefill_step, (param_sh, batch_sh), logits_sh


def _state_spec(mesh, cell: ShapeCell, spec: jax.ShapeDtypeStruct) -> PS:
    """Decode-state sharding: batch dim (index 1 of (L,B,...)) over data axes;
    head dim (index 2) over 'model' when divisible, else the SEQUENCE dim
    (index 3) — the flash-decode fallback for GQA archs whose few KV heads
    don't divide the TP axis (e.g. llama4's 8 kv-heads on 16-way 'model')."""
    nd = len(spec.shape)
    parts = [None] * nd
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if nd >= 2 and axes and spec.shape[1] % n == 0:
        parts[1] = axes
    tp = mesh.shape.get("model", 1)
    if nd >= 4 and tp > 1:
        if spec.shape[2] % tp == 0:
            parts[2] = "model"
        elif nd >= 5 and spec.shape[3] % tp == 0:
            parts[3] = "model"   # shard KV cache along sequence
    return PS(*parts)


def make_decode_step(model: ModelAPI, cell: ShapeCell, mesh=None):
    c = model.cfg

    def decode_step(params, token, state):
        return model.decode_fn(params, token, state)

    if mesh is None:
        return decode_step, None, None
    with shd.use_mesh(mesh, _rules_for(c)):
        pspecs = shd.param_specs(model.decls)
        param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        tok_sh = NamedSharding(mesh, _batch_spec(mesh, cell, 1))
        st_specs = model.decode_state_specs(cell)
        st_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, _state_spec(mesh, cell, s))
            if hasattr(s, "shape") and len(s.shape) > 0
            else NamedSharding(mesh, PS()),
            st_specs)
        logits_sh = NamedSharding(mesh, _batch_spec(mesh, cell, 2))
    return decode_step, (param_sh, tok_sh, st_sh), (logits_sh, st_sh)
