"""Batched serving driver: prefill + decode with slot-based batching.

A production-serving-shaped loop at laptop scale:
  * fixed decode batch of B slots; requests (prompt, max_new) occupy slots;
  * prompts are prefilled one-at-a-time into the shared KV cache slot
    (per-slot cache insertion via the decode path), decodes run batched —
    the standard continuous-batching decomposition;
  * a finished slot (EOS/max_new) is immediately recycled for the next
    queued request;
  * greedy sampling (argmax) for determinism in tests.

Families: transformer (dense/moe/vlm/audio) use the KV-cache path; ssm/hybrid
use their recurrent-state path (per-slot state reset on recycle).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api as model_api
from repro.models.arch_config import ArchConfig


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new: int = 16
    eos_id: int = -1          # -1: never stops early
    # filled by the engine:
    output: Optional[List[int]] = None
    latency_s: float = 0.0


class ServeEngine:
    """Slot-based batched decoding over a fixed batch of B slots."""

    def __init__(self, c: ArchConfig, params, *, batch_slots: int = 4,
                 max_seq: int = 512):
        self.c = c
        self.model = model_api.build(c)
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self._decode = jax.jit(self.model.decode_fn)

    # single-sequence prefill via repeated decode steps on slot 0 of a
    # one-slot state, then merged into the batch state at ``slot``.
    def _prefill_into(self, state, slot: int, prompt: Sequence[int]):
        one = self.model.init_decode_state(self.params, 1, self.max_seq)
        last_logits = None
        for t in prompt:
            tok = jnp.full((1,), t, jnp.int32)
            last_logits, one = self._decode(self.params, tok, one)
        state = jax.tree.map(
            lambda s, o: _slot_write(s, o, slot), state, one)
        return state, last_logits

    def run(self, requests: List[Request]) -> List[Request]:
        queue = list(requests)
        active: List[Optional[Request]] = [None] * self.B
        new_counts = [0] * self.B
        state = self.model.init_decode_state(self.params, self.B, self.max_seq)
        cur_tok = np.zeros((self.B,), np.int32)
        t_start = [0.0] * self.B
        done: List[Request] = []
        # KV caches carry a PER-SLOT position vector, so slots hold sequences
        # of different lengths and recycle independently (continuous batching).
        # (ssm/hybrid recurrent states are position-free by construction.)
        while queue or any(a is not None for a in active):
            for i in range(self.B):
                if active[i] is None and queue:
                    req = queue.pop(0)
                    t_start[i] = time.time()
                    state, logits = self._prefill_into(state, i, req.prompt)
                    req.output = []
                    active[i] = req
                    new_counts[i] = 0
                    cur_tok[i] = int(jnp.argmax(logits[0]))
            if not any(a is not None for a in active):
                break
            logits, state = self._decode(self.params, jnp.asarray(cur_tok), state)
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            for i in range(self.B):
                req = active[i]
                if req is None:
                    continue
                req.output.append(int(cur_tok[i]))
                new_counts[i] += 1
                if new_counts[i] >= req.max_new or int(cur_tok[i]) == req.eos_id:
                    req.latency_s = time.time() - t_start[i]
                    done.append(req)
                    active[i] = None
                else:
                    cur_tok[i] = nxt[i]
        return done


def _slot_write(batch_arr, one_arr, slot: int):
    """Write a 1-slot state leaf into batch position ``slot``.

    State leaves have the batch dim at axis 1 ((L, B, ...)) by convention;
    scalars (pos counters) pass through (shared timeline)."""
    if not hasattr(batch_arr, "ndim") or batch_arr.ndim == 0:
        return one_arr
    if batch_arr.ndim == 1 and one_arr.shape[0] == 1:
        return batch_arr.at[slot].set(one_arr[0])   # per-slot pos vector
    if batch_arr.ndim >= 2 and one_arr.shape[0] == batch_arr.shape[0] \
            and one_arr.shape[1] == 1:
        return jax.lax.dynamic_update_slice_in_dim(batch_arr, one_arr, slot, axis=1)
    return batch_arr
