"""Launch layer: mesh construction, logical-axis sharding, dry-run, train."""
