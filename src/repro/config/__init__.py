"""Dataclass-based config system with CLI overrides and serialization."""
from repro.config.base import (
    ConfigBase,
    apply_overrides,
    config_from_dict,
    config_to_dict,
    parse_cli_overrides,
)

__all__ = [
    "ConfigBase",
    "apply_overrides",
    "config_from_dict",
    "config_to_dict",
    "parse_cli_overrides",
]
