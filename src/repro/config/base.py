"""Config system: frozen dataclasses + dotted-path CLI overrides + (de)serialization.

Design goals (framework-grade, not script-grade):
  * configs are immutable dataclasses — safe to hash into jit cache keys;
  * every launcher accepts ``key=value`` / ``sub.key=value`` overrides;
  * round-trips to plain dicts (and therefore JSON) for checkpoint manifests,
    so a restart reconstructs the exact run configuration.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Tuple, Type, TypeVar, get_args, get_origin, get_type_hints

T = TypeVar("T", bound="ConfigBase")


@dataclasses.dataclass(frozen=True)
class ConfigBase:
    """Base class: all repro configs derive from this."""

    def replace(self: T, **kw) -> T:
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        return config_to_dict(self)

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls: Type[T], d: Dict[str, Any]) -> T:
        return config_from_dict(cls, d)

    @classmethod
    def from_json(cls: Type[T], s: str) -> T:
        return config_from_dict(cls, json.loads(s))


def config_to_dict(cfg: Any) -> Any:
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        return {f.name: config_to_dict(getattr(cfg, f.name)) for f in dataclasses.fields(cfg)}
    if isinstance(cfg, (list, tuple)):
        return [config_to_dict(x) for x in cfg]
    if isinstance(cfg, dict):
        return {k: config_to_dict(v) for k, v in cfg.items()}
    return cfg


def _coerce(tp: Any, value: Any) -> Any:
    """Coerce a plain value into annotated type ``tp`` (handles Optional, tuples, nested configs)."""
    origin = get_origin(tp)
    if origin is not None:
        args = get_args(tp)
        if origin in (tuple,):
            if len(args) == 2 and args[1] is Ellipsis:
                return tuple(_coerce(args[0], v) for v in value)
            return tuple(_coerce(a, v) for a, v in zip(args, value))
        if origin in (list,):
            return [_coerce(args[0], v) for v in value]
        if origin in (dict,):
            return {k: _coerce(args[1], v) for k, v in value.items()}
        # Union / Optional: try each arm
        for arm in get_args(tp):
            if arm is type(None):
                if value is None:
                    return None
                continue
            try:
                return _coerce(arm, value)
            except (TypeError, ValueError):
                continue
        return value
    if dataclasses.is_dataclass(tp) and isinstance(value, dict):
        return config_from_dict(tp, value)
    if tp in (int, float, str, bool) and value is not None:
        if tp is bool and isinstance(value, str):
            return value.lower() in ("1", "true", "yes", "on")
        return tp(value)
    return value


def config_from_dict(cls: Type[T], d: Dict[str, Any]) -> T:
    hints = get_type_hints(cls)
    kwargs = {}
    field_names = {f.name for f in dataclasses.fields(cls)}
    for k, v in d.items():
        if k not in field_names:
            raise KeyError(f"{cls.__name__} has no field '{k}'")
        kwargs[k] = _coerce(hints.get(k, Any), v)
    return cls(**kwargs)


def parse_cli_overrides(argv: List[str]) -> Tuple[List[str], Dict[str, str]]:
    """Split argv into (positional, {dotted.key: value}) for ``key=value`` tokens."""
    positional, overrides = [], {}
    for tok in argv:
        if "=" in tok and not tok.startswith("-"):
            k, v = tok.split("=", 1)
            overrides[k] = v
        else:
            positional.append(tok)
    return positional, overrides


def _parse_literal(v: str) -> Any:
    try:
        return json.loads(v)
    except json.JSONDecodeError:
        return v


def apply_overrides(cfg: T, overrides: Dict[str, str]) -> T:
    """Apply {'a.b.c': 'value'} overrides to a nested frozen dataclass."""
    for dotted, raw in overrides.items():
        cfg = _apply_one(cfg, dotted.split("."), _parse_literal(raw))
    return cfg


def _apply_one(cfg: Any, path: List[str], value: Any) -> Any:
    if not dataclasses.is_dataclass(cfg):
        raise TypeError(f"cannot descend into non-config at '{path[0]}'")
    head, rest = path[0], path[1:]
    if not hasattr(cfg, head):
        raise KeyError(f"{type(cfg).__name__} has no field '{head}'")
    if rest:
        new_sub = _apply_one(getattr(cfg, head), rest, value)
        return dataclasses.replace(cfg, **{head: new_sub})
    hints = get_type_hints(type(cfg))
    return dataclasses.replace(cfg, **{head: _coerce(hints.get(head, Any), value)})
