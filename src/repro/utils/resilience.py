"""Serving-grade resilience primitives (DESIGN.md §Resilience).

PR 7 hardened ONE call (typed taxonomy, retry ladder, guard rails); this
module holds the machinery that keeps a long-lived SERVICE healthy under
sustained faults:

* ``Deadline`` / ``call_with_deadline`` — a host-side watchdog for device
  dispatches.  JAX gives no way to cancel an in-flight execution, so the
  watchdog runs the dispatch in a daemon worker thread and ABANDONS it on
  timeout, raising a typed ``DeadlineError``: the caller is released on
  time even if the device work limps on in the background (the thread's
  eventual result is dropped).  ``timeout_s=None`` short-circuits to a
  plain call — the clean path never pays for a thread.
* ``Preempted`` — models SIGKILL/preemption at a host boundary.
  Deliberately a ``BaseException``: no retry/degradation ladder may
  swallow a kill; only the layers that genuinely survive one (the serving
  tick, the checkpoint/resume test harness) catch it by name.
* ``backoff_delays`` — deterministic jittered exponential backoff for
  transient-failure retries (seeded ``random.Random``; no global RNG, so
  schedules are reproducible in tests and benchmarks).
* ``is_retryable`` — maps the PR-7 error taxonomy onto the retry decision:
  taxonomy errors other than ``KernelError`` mean the ANSWER is unsafe
  (retrying cannot help), deadline/overload mean the BUDGET is spent;
  ``KernelError`` and non-taxonomy exceptions are transient infra.
* ``CircuitBreaker`` — per-key closed → open → half-open breaker.  A
  signature bucket that keeps failing (a poisoned capacity class
  recompiling/crashing) trips open so the service stops burning its
  deadline budget on a known-bad path and routes around it; after
  ``reset_after_s`` one half-open probe is allowed through — success
  closes the breaker, failure re-opens it.

Everything here is host-side, thread-compatible and free of JAX imports:
the serving layer composes these around the compiled programs, never
inside them.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Callable, Dict, Iterator, Optional, TypeVar

from repro.utils import telemetry
from repro.utils.errors import (CapacityError, CommunityDetectionError,
                                ConvergenceError, DeadlineError,
                                InputValidationError, KernelError,
                                NumericError, OverloadError, ShardError)

T = TypeVar("T")


class Preempted(BaseException):
    """The process was "killed" at a host boundary (fault point
    ``preempt_stage``, or a real SIGKILL in deployment modelling).

    A ``BaseException`` on purpose: the ``except Exception`` rung of the
    retry/degradation ladder must NOT absorb a preemption as a backend
    failure — it propagates until a layer that genuinely survives kills
    (the serving dispatch tick, which re-runs the batch; or a fresh
    process, which resumes from the stage checkpoint) handles it."""


# ------------------------------------------------------------------ deadlines


class Deadline:
    """A wall-clock budget anchored at construction time.

    ``clock`` is injectable for deterministic tests (defaults to
    ``time.monotonic``).  ``None`` budgets are represented by NOT creating
    a Deadline — callers pass ``Optional[Deadline]`` around.
    """

    __slots__ = ("budget_s", "_t0", "_clock")

    def __init__(self, budget_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.budget_s = float(budget_s)
        self._clock = clock
        self._t0 = clock()

    def remaining_s(self) -> float:
        return self.budget_s - (self._clock() - self._t0)

    @property
    def expired(self) -> bool:
        return self.remaining_s() <= 0.0


def min_remaining_s(deadlines) -> Optional[float]:
    """Tightest remaining budget among ``Optional[Deadline]`` members —
    the watchdog timeout of a batch that serves them all (``None`` when no
    member carries a deadline)."""
    rem = [d.remaining_s() for d in deadlines if d is not None]
    return min(rem) if rem else None


def call_with_deadline(fn: Callable[[], T],
                       timeout_s: Optional[float]) -> T:
    """Run ``fn()`` under a watchdog: raise ``DeadlineError`` if it has not
    returned within ``timeout_s`` seconds.

    ``timeout_s=None`` calls ``fn`` inline (zero overhead — the clean
    path).  Otherwise ``fn`` runs in a daemon worker thread; on timeout
    the thread is ABANDONED (its eventual result/exception is dropped) —
    JAX dispatches cannot be cancelled, only disowned.  Exceptions from
    ``fn`` (including ``BaseException`` like ``Preempted``) re-raise in
    the caller.
    """
    if timeout_s is None:
        return fn()
    if timeout_s <= 0:
        telemetry.bump("resilience.deadline_expired_preflight")
        raise DeadlineError(
            f"deadline already expired ({timeout_s:.3f}s remaining) — "
            "not dispatching")
    box: list = []

    def _run():
        try:
            box.append(("ok", fn()))
        except BaseException as err:  # noqa: BLE001 — relayed to caller
            box.append(("err", err))

    worker = threading.Thread(target=_run, daemon=True,
                              name="repro-watchdog-worker")
    worker.start()
    worker.join(timeout_s)
    if worker.is_alive():
        telemetry.bump("resilience.watchdog_fired")
        raise DeadlineError(
            f"dispatch exceeded its {timeout_s:.3f}s deadline; watchdog "
            "cancelled the wait (worker abandoned)")
    if not box:  # worker died without reporting (should not happen)
        raise KernelError("watchdog worker exited without a result")
    kind, val = box[0]
    if kind == "err":
        raise val
    return val


# -------------------------------------------------------------------- retries


def backoff_delays(attempts: int, base_s: float = 0.05, factor: float = 2.0,
                   jitter: float = 0.5, max_s: float = 2.0,
                   seed: int = 0) -> Iterator[float]:
    """Deterministic jittered exponential backoff: delay k is
    ``min(base·factor^k, max) · U[1-jitter, 1+jitter]`` with a private
    ``random.Random(seed)`` — same seed, same schedule (reproducible
    chaos runs), distinct seeds decorrelate retry storms across dispatch
    groups."""
    if jitter < 0 or jitter >= 1:
        raise ValueError(f"jitter must be in [0, 1), got {jitter}")
    rng = random.Random(seed)
    for k in range(attempts):
        d = min(base_s * (factor ** k), max_s)
        yield d * (1.0 - jitter + 2.0 * jitter * rng.random())


#: Taxonomy types whose meaning is "the ANSWER is unsafe" or "the BUDGET is
#: spent" — retrying the same inputs cannot help (DESIGN.md §Robustness).
_NON_RETRYABLE = (InputValidationError, NumericError, CapacityError,
                  ConvergenceError, ShardError, DeadlineError, OverloadError)


def is_retryable(err: BaseException) -> bool:
    """Retry decision over the PR-7 taxonomy: ``KernelError`` (a backend
    failed — the classic transient: OOM, recompile crash, lost launch) and
    non-taxonomy ``Exception``s (infra surprises) are retryable; every
    other taxonomy type, and every ``BaseException`` (kills), is not."""
    if isinstance(err, _NON_RETRYABLE):
        return False
    if isinstance(err, KernelError):
        return True
    if isinstance(err, CommunityDetectionError):
        return False
    return isinstance(err, Exception)


# ------------------------------------------------------------ circuit breaker


class _BreakerEntry:
    __slots__ = ("failures", "state", "opened_at")

    def __init__(self):
        self.failures = 0
        self.state = "closed"
        self.opened_at = 0.0


class CircuitBreaker:
    """Per-key closed → open → half-open circuit breaker.

    ``record_failure(key)`` counts CONSECUTIVE failures; at ``threshold``
    the key trips open (counter ``{name}.breaker_trip``).  While open,
    ``state(key)`` returns ``"open"`` — callers route around the protected
    path — until ``reset_after_s`` has elapsed, when it returns
    ``"half_open"``: the caller may send ONE probe through.  A recorded
    success closes the breaker (``{name}.breaker_close``, open duration
    observed as ``{name}.breaker_open_s``); a failure re-opens it for
    another full ``reset_after_s`` (counted as a new trip).

    Single-owner discipline: the serving engine is synchronous, so no
    internal locking; ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, threshold: int = 3, reset_after_s: float = 30.0,
                 name: str = "serve",
                 clock: Callable[[], float] = time.monotonic):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = int(threshold)
        self.reset_after_s = float(reset_after_s)
        self.name = name
        self._clock = clock
        self._keys: Dict[object, _BreakerEntry] = {}

    def _entry(self, key) -> _BreakerEntry:
        e = self._keys.get(key)
        if e is None:
            e = self._keys[key] = _BreakerEntry()
        return e

    def state(self, key) -> str:
        """``"closed"`` | ``"open"`` | ``"half_open"`` (open and due a
        probe)."""
        e = self._keys.get(key)
        if e is None or e.state == "closed":
            return "closed"
        if self._clock() - e.opened_at >= self.reset_after_s:
            return "half_open"
        return "open"

    def record_success(self, key) -> None:
        e = self._entry(key)
        if e.state == "open":
            telemetry.observe(f"{self.name}.breaker_open_s",
                              self._clock() - e.opened_at)
            telemetry.bump(f"{self.name}.breaker_close")
        e.state = "closed"
        e.failures = 0

    def record_failure(self, key) -> None:
        e = self._entry(key)
        e.failures += 1
        if e.state == "open" or e.failures >= self.threshold:
            # trip (or re-trip from a failed half-open probe): a fresh
            # full reset window starts now
            if e.state != "open" or self.state(key) == "half_open":
                telemetry.bump(f"{self.name}.breaker_trip")
            e.state = "open"
            e.opened_at = self._clock()

    def snapshot(self) -> Dict[str, dict]:
        """Observability view for ``stats()``: resolved state + consecutive
        failures per key."""
        return {repr(k): {"state": self.state(k), "failures": e.failures}
                for k, e in self._keys.items()}
