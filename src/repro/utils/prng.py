"""Deterministic PRNG key derivation.

Every stochastic component (init, data order, dropout, generators) derives its
key from a (seed, name, step) triple so that restarts and elastic re-shards are
bit-exact — a requirement for the fault-tolerance story (DESIGN.md §6).
"""
from __future__ import annotations

import hashlib

import jax


def named_key(seed: int, name: str, step: int = 0) -> jax.Array:
    """Stable key from (seed, name, step); independent of call order."""
    digest = hashlib.blake2b(f"{name}:{step}".encode(), digest_size=4).digest()
    fold = int.from_bytes(digest, "little")
    return jax.random.fold_in(jax.random.key(seed), fold)


def split_named(seed: int, name: str, n: int, step: int = 0) -> list[jax.Array]:
    return list(jax.random.split(named_key(seed, name, step), n))
