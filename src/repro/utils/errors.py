"""Typed failure taxonomy + run reporting for the hardened execution layer.

DESIGN.md §Robustness: every way a community-detection run can go wrong maps
to exactly one exception type below, and every run carries a ``RunReport``
describing what (if anything) was repaired, retried, or degraded on the way
to the result.  The contract enforced by ``tests/test_faults.py``: a fault
either lands on a fallback path whose result is bit-identical to the clean
oracle, or raises one of these types with a populated report — never a
silent wrong answer.

Kept in ``utils`` so every layer (graph builders, kernels, core drivers,
benchmarks) can import the taxonomy without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass
class RunReport:
    """What happened on the way to a result (attached to ``LouvainResult`` /
    ``PLPResult`` / ``DistLouvainResult`` as ``run_report``).

    * ``repairs``       — the ingest ``RepairReport`` (or None if the graph
                          came in through a non-robust entry point)
    * ``retries``       — capacity-tier retries, as
                          ``{"kind": "capacity", "from": ..., "to": ...}``
    * ``degradations``  — backend descents, as ``{"kind": "backend_descent",
                          "from": "pallas", "to": "ell", "error": ...}``
    * ``warnings``      — bounded-but-suspicious outcomes, e.g.
                          ``"watchdog:max_sweeps:level3"``,
                          ``"precision:f32_accum_risk"``
    * ``faults``        — fault-injection points active during the run
                          (``utils.faultinject``); empty in production
    """

    repairs: Optional[Any] = None
    retries: list = dataclasses.field(default_factory=list)
    degradations: list = dataclasses.field(default_factory=list)
    warnings: list = dataclasses.field(default_factory=list)
    faults: list = dataclasses.field(default_factory=list)

    @property
    def clean(self) -> bool:
        """True iff nothing was repaired, retried, degraded, or flagged."""
        return (not self.retries and not self.degradations
                and not self.warnings
                and (self.repairs is None or getattr(self.repairs, "clean", True)))

    def as_dict(self) -> dict:
        return {
            "repairs": (dataclasses.asdict(self.repairs)
                        if dataclasses.is_dataclass(self.repairs)
                        else self.repairs),
            "retries": list(self.retries),
            "degradations": list(self.degradations),
            "warnings": list(self.warnings),
            "faults": list(self.faults),
        }


class CommunityDetectionError(Exception):
    """Base of the typed failure taxonomy (DESIGN.md §Robustness).

    ``report`` carries the RunReport of the failed run so callers see what
    the degradation ladder already tried before giving up.
    """

    def __init__(self, message: str, report: Optional[RunReport] = None):
        super().__init__(message)
        self.report = report if report is not None else RunReport()


class InputValidationError(CommunityDetectionError):
    """Malformed input graph: asymmetric edges, out-of-range or negative
    endpoint ids, non-finite or negative weights, mask/count mismatches."""


class CapacityError(CommunityDetectionError):
    """A static capacity was busted (graph does not fit a stage capacity, or
    the cascade's fits-next-capacity invariant was violated)."""


class KernelError(CommunityDetectionError):
    """A compute backend failed (Pallas kernel compile/dispatch failure) and
    the backend-descent ladder is exhausted."""


class ConvergenceError(CommunityDetectionError):
    """Local-moving or the level loop failed to converge within the watchdog
    bounds AND the caller asked for strict convergence."""


class NumericError(CommunityDetectionError):
    """Non-finite values reached a result accumulator (NaN/Inf modularity,
    volume overflow) — the numeric guard rails refused the answer."""


class ShardError(CommunityDetectionError):
    """The distributed edge partition lost coverage (a dropped or corrupted
    shard): the per-shard edge counts no longer cover the graph."""


class DeadlineError(CommunityDetectionError):
    """A dispatch (or a whole request) overran its deadline and was
    cancelled by the watchdog (``utils.resilience.call_with_deadline``).
    NOT retryable: the time budget is spent — retrying can only miss
    harder.  The abandoned work may still complete in the background; the
    contract is only that the CALLER is released on time."""


class OverloadError(CommunityDetectionError):
    """Admission control shed this request: the serving queue is at its
    configured depth/cost bound (DESIGN.md §Resilience).  The typed
    backpressure signal — clients should back off and resubmit; retrying
    immediately on the same engine will meet the same bound."""
