"""Wall-clock timing helpers used by benchmarks and the phase breakdown.

The paper reports per-phase runtimes (Fig. 4 breaks Louvain into local-moving
and aggregation).  ``Timer`` accumulates named phases so the benchmark harness
can reproduce that breakdown.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator


def format_seconds(s: float) -> str:
    if s < 1e-6:
        return f"{s * 1e9:.1f}ns"
    if s < 1e-3:
        return f"{s * 1e6:.1f}us"
    if s < 1.0:
        return f"{s * 1e3:.2f}ms"
    return f"{s:.3f}s"


@dataclass
class Timer:
    """Accumulating phase timer.

    >>> t = Timer()
    >>> with t.phase("local_moving"):
    ...     pass
    >>> "local_moving" in t.totals
    True
    """

    totals: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)

    @contextlib.contextmanager
    def phase(self, name: str) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    @property
    def total(self) -> float:
        return sum(self.totals.values())

    def report(self) -> str:
        lines = []
        for name, total in sorted(self.totals.items(), key=lambda kv: -kv[1]):
            lines.append(
                f"  {name:<24s} {format_seconds(total):>10s}  (n={self.counts[name]})"
            )
        lines.append(f"  {'TOTAL':<24s} {format_seconds(self.total):>10s}")
        return "\n".join(lines)


@contextlib.contextmanager
def timed(label: str = "") -> Iterator[list]:
    """Context manager yielding a one-element list that receives the elapsed time."""
    out = [0.0]
    t0 = time.perf_counter()
    try:
        yield out
    finally:
        out[0] = time.perf_counter() - t0
        if label:
            print(f"[timed] {label}: {format_seconds(out[0])}")
