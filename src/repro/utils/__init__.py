"""Shared utilities: timing, logging, registries, pytree helpers."""
from repro.utils.timing import Timer, timed, format_seconds
from repro.utils.registry import Registry
from repro.utils.logging import get_logger
from repro.utils import tree

__all__ = ["Timer", "timed", "format_seconds", "Registry", "get_logger", "tree"]
