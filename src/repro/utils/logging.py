"""Structured logging with a consistent prefix, used across the launchers."""
from __future__ import annotations

import logging
import os
import sys

_CONFIGURED = False


def get_logger(name: str = "repro") -> logging.Logger:
    global _CONFIGURED
    if not _CONFIGURED:
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO").upper()
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(
            logging.Formatter("[%(asctime)s %(name)s %(levelname).1s] %(message)s", "%H:%M:%S")
        )
        root = logging.getLogger("repro")
        root.addHandler(handler)
        root.setLevel(level)
        root.propagate = False
        _CONFIGURED = True
    return logging.getLogger(name)
