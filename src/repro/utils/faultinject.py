"""Deterministic, env/config-gated fault injection for the robustness suite.

Each name in ``FAULT_POINTS`` is a site in the production code that, when
armed, deterministically perturbs the run in a way a real deployment could
encounter (DESIGN.md §Robustness / §Resilience):

* ``nan_weight``       — a NaN edge weight appears mid-pipeline (level 1),
                         modelling corrupt upstream data / a bad reduction.
* ``binned_overflow``  — the binned-aggregation overflow predicate is forced
                         true, modelling a hub row busting the bin width.
* ``oscillation``      — the local-move convergence signal never reports a
                         fixpoint, modelling two vertices trading labels
                         forever (Lu & Halappanavar, arXiv:1410.1237 §4).
* ``vmem_starve``      — the VMEM budget collapses to ~1KB, forcing every
                         capacity-adaptive kernel into its streamed/ref
                         regime.
* ``shard_drop``       — one device's edge shard is zeroed after
                         partitioning, modelling a lost worker.
* ``slow_dispatch``    — a batch dispatch stalls for
                         ``REPRO_SLOW_DISPATCH_S`` seconds (default 0.25)
                         before running, modelling a hung device / a
                         pathological recompile; the serving watchdog must
                         cancel it when it busts a deadline.
* ``transient_batch_fail`` — a batch dispatch raises a retryable
                         ``KernelError`` before reaching the device,
                         modelling a transient infra failure (lost RPC,
                         evicted program); the retry/backoff and circuit-
                         breaker machinery must absorb it.
* ``preempt_stage``    — the process is "killed" (a ``resilience.Preempted``
                         BaseException) at the next host boundary it
                         crosses: a cascade stage boundary in
                         ``core.louvain`` (right AFTER the stage checkpoint
                         committed) or the serving dispatch tick.  Fires
                         ONCE then self-disarms (``consume``) — a
                         preemption is an event, not a state — so the
                         retried/resumed run completes.

Arming is HOST-side only and must be captured at trace time: every
``lru_cache``/``jit`` program builder that contains an injection site takes
the active-fault frozenset as part of its cache key, so a clean-cached trace
is never reused under faults (and vice versa).  Production runs never pay
for the machinery — sites compile to nothing when their fault is off.

Gates: the ``REPRO_FAULTS`` env var (comma-separated names, read at import
AND re-read as the baseline by a bare ``disarm()``) or the ``inject()``
context manager / ``arm()``+``disarm()`` pair in tests.

Host-side sites (the serving/driver layer, never inside a trace) fire
through ``should_fire(name)`` which adds deterministic RATE control for the
chaos benchmarks: ``set_rate(name, r)`` fires the site on a Bresenham
error-accumulator schedule (exactly ⌊k·r⌋ fires after k queries — no RNG,
reproducible), ``set_burst(name, b)`` turns each scheduled fire into ``b``
CONSECUTIVE fires (modelling a poisoned recompile burst that defeats
isolated-retry absorption), and ``set_fuel(name, n)`` bounds total fires
(one-shot faults).  Defaults: rate 1.0, burst 1, unlimited fuel — armed
means fires, the historical behavior.
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict, FrozenSet, Iterator, Optional, Set

from repro.utils import telemetry

FAULT_ENV = "REPRO_FAULTS"
SLOW_DISPATCH_ENV = "REPRO_SLOW_DISPATCH_S"
DEFAULT_SLOW_DISPATCH_S = 0.25

FAULT_POINTS = (
    "nan_weight",
    "binned_overflow",
    "oscillation",
    "vmem_starve",
    "shard_drop",
    "slow_dispatch",
    "transient_batch_fail",
    "preempt_stage",
)


def _from_env() -> Set[str]:
    raw = os.environ.get(FAULT_ENV, "")
    names = {s.strip() for s in raw.split(",") if s.strip()}
    unknown = names - set(FAULT_POINTS)
    if unknown:
        raise ValueError(
            f"{FAULT_ENV} names unknown fault point(s) {sorted(unknown)}; "
            f"registry: {FAULT_POINTS}")
    return names


_active: Set[str] = _from_env()

# host-site firing schedule (should_fire); absent name == defaults
_rates: Dict[str, float] = {}
_fuel: Dict[str, int] = {}
_burst: Dict[str, int] = {}
_bres_err: Dict[str, float] = {}
_burst_left: Dict[str, int] = {}


def _check(name: str) -> None:
    if name not in FAULT_POINTS:
        raise ValueError(
            f"unknown fault point {name!r}; registry: {FAULT_POINTS}")


def active() -> FrozenSet[str]:
    """The armed fault set, for threading into jit/lru_cache keys."""
    return frozenset(_active)


def is_active(name: str) -> bool:
    _check(name)
    return name in _active


def arm(*names: str) -> None:
    for name in names:
        _check(name)
        _active.add(name)
        telemetry.bump(f"fault.armed.{name}")


def disarm(*names: str) -> None:
    """Disarm the given points; with no args, reset to the env-armed
    baseline.

    The bare form deliberately restores ``REPRO_FAULTS`` (re-read, so a
    monkeypatched env is honored) rather than clearing to empty: a test
    calling ``disarm()`` to undo its own arming must not silently switch
    off the faults a CI chaos step configured for the whole process.
    Firing-schedule state (rate/burst/fuel) is reset for the disarmed
    points either way.
    """
    if not names:
        _active.clear()
        _active.update(_from_env())
        _rates.clear()
        _fuel.clear()
        _burst.clear()
        _bres_err.clear()
        _burst_left.clear()
        return
    for name in names:
        _check(name)
        _active.discard(name)
        for d in (_rates, _fuel, _burst, _bres_err, _burst_left):
            d.pop(name, None)


def set_rate(name: str, rate: float) -> None:
    """Fire the host site on a deterministic Bresenham schedule: after k
    queries exactly ⌊k·rate⌋ have fired (rate 1.0 = every query, the
    default)."""
    _check(name)
    if not (0.0 <= rate <= 1.0):
        raise ValueError(f"rate must be in [0, 1], got {rate}")
    _rates[name] = float(rate)
    _bres_err[name] = 0.0


def set_burst(name: str, burst: int) -> None:
    """Each scheduled fire becomes ``burst`` CONSECUTIVE fires (rate counts
    burst STARTS), modelling correlated failures that defeat isolated
    retries."""
    _check(name)
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    _burst[name] = int(burst)


def set_fuel(name: str, fuel: int) -> None:
    """Bound TOTAL fires of the host site (None/absent = unlimited):
    ``set_fuel(name, 1)`` is a one-shot fault."""
    _check(name)
    if fuel < 0:
        raise ValueError(f"fuel must be >= 0, got {fuel}")
    _fuel[name] = int(fuel)


def should_fire(name: str) -> bool:
    """Host-site gate: is ``name`` armed AND scheduled to fire on THIS
    query?  Counts the query against the rate/burst/fuel schedule; never
    used inside a trace (traced sites key on ``active()`` instead)."""
    if not is_active(name):
        return False
    if _fuel.get(name) == 0:
        return False
    if _burst_left.get(name, 0) > 0:
        _burst_left[name] -= 1
        fire = True
    else:
        rate = _rates.get(name, 1.0)
        err = _bres_err.get(name, 0.0) + rate
        fire = err >= 1.0
        _bres_err[name] = err - 1.0 if fire else err
        if fire:
            _burst_left[name] = _burst.get(name, 1) - 1
    if fire:
        if name in _fuel:
            _fuel[name] -= 1
        telemetry.bump(f"fault.fired.{name}")
    return fire


def consume(name: str) -> bool:
    """One-shot host-site gate: fire per the schedule, then SELF-DISARM.

    Models event faults (a preemption happens once, then the world moves
    on): the retried/resumed attempt runs clean without the caller having
    to know a fault registry exists."""
    if should_fire(name):
        disarm(name)
        return True
    return False


def slow_dispatch_seconds() -> float:
    """Stall duration of the ``slow_dispatch`` site
    (``REPRO_SLOW_DISPATCH_S`` env override, read per fire so tests can
    monkeypatch it)."""
    env = os.environ.get(SLOW_DISPATCH_ENV)
    return float(env) if env else DEFAULT_SLOW_DISPATCH_S


@contextlib.contextmanager
def inject(*names: str) -> Iterator[None]:
    """Arm ``names`` for the duration of the block, restoring the previous
    set on exit (exception-safe); nests — each level restores exactly what
    it saw."""
    prev = set(_active)
    arm(*names)
    try:
        yield
    finally:
        _active.clear()
        _active.update(prev)
