"""Deterministic, env/config-gated fault injection for the robustness suite.

Each name in ``FAULT_POINTS`` is a site in the production code that, when
armed, deterministically perturbs the run in a way a real deployment could
encounter (DESIGN.md §Robustness):

* ``nan_weight``       — a NaN edge weight appears mid-pipeline (level 1),
                         modelling corrupt upstream data / a bad reduction.
* ``binned_overflow``  — the binned-aggregation overflow predicate is forced
                         true, modelling a hub row busting the bin width.
* ``oscillation``      — the local-move convergence signal never reports a
                         fixpoint, modelling two vertices trading labels
                         forever (Lu & Halappanavar, arXiv:1410.1237 §4).
* ``vmem_starve``      — the VMEM budget collapses to ~1KB, forcing every
                         capacity-adaptive kernel into its streamed/ref
                         regime.
* ``shard_drop``       — one device's edge shard is zeroed after
                         partitioning, modelling a lost worker.

Arming is HOST-side only and must be captured at trace time: every
``lru_cache``/``jit`` program builder that contains an injection site takes
the active-fault frozenset as part of its cache key, so a clean-cached trace
is never reused under faults (and vice versa).  Production runs never pay
for the machinery — sites compile to nothing when their fault is off.

Gates: the ``REPRO_FAULTS`` env var (comma-separated names, read at import)
or the ``inject()`` context manager / ``arm()``+``disarm()`` pair in tests.
"""
from __future__ import annotations

import contextlib
import os
from typing import FrozenSet, Iterator, Set

from repro.utils import telemetry

FAULT_ENV = "REPRO_FAULTS"

FAULT_POINTS = (
    "nan_weight",
    "binned_overflow",
    "oscillation",
    "vmem_starve",
    "shard_drop",
)


def _from_env() -> Set[str]:
    raw = os.environ.get(FAULT_ENV, "")
    names = {s.strip() for s in raw.split(",") if s.strip()}
    unknown = names - set(FAULT_POINTS)
    if unknown:
        raise ValueError(
            f"{FAULT_ENV} names unknown fault point(s) {sorted(unknown)}; "
            f"registry: {FAULT_POINTS}")
    return names


_active: Set[str] = _from_env()


def active() -> FrozenSet[str]:
    """The armed fault set, for threading into jit/lru_cache keys."""
    return frozenset(_active)


def is_active(name: str) -> bool:
    if name not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {name!r}; registry: {FAULT_POINTS}")
    return name in _active


def arm(*names: str) -> None:
    for name in names:
        if name not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {name!r}; registry: {FAULT_POINTS}")
        _active.add(name)
        telemetry.bump(f"fault.armed.{name}")


def disarm(*names: str) -> None:
    """Disarm the given points, or everything when called with no args."""
    if not names:
        _active.clear()
        return
    for name in names:
        _active.discard(name)


@contextlib.contextmanager
def inject(*names: str) -> Iterator[None]:
    """Arm ``names`` for the duration of the block, restoring the previous
    set on exit (exception-safe)."""
    prev = set(_active)
    arm(*names)
    try:
        yield
    finally:
        _active.clear()
        _active.update(prev)
