"""Pytree helpers: parameter counting, byte accounting, flat dict views."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def param_bytes(tree: Any) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def flatten_dict(tree: Any, sep: str = "/") -> Dict[str, Any]:
    """Flatten a pytree into {path: leaf} using jax key paths."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = sep.join(_key_str(k) for k in path)
        flat[key] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def unflatten_like(template: Any, flat: Dict[str, Any], sep: str = "/") -> Any:
    """Rebuild a pytree with the structure of ``template`` from a flat dict."""
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree.structure(template)
    leaves = []
    for path, _ in paths:
        key = sep.join(_key_str(k) for k in path)
        if key not in flat:
            raise KeyError(f"missing leaf '{key}' when unflattening")
        leaves.append(flat[key])
    return jax.tree.unflatten(treedef, leaves)


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, tree)


def cast_tree(tree: Any, dtype) -> Any:
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)
