"""Process-local telemetry counters for the robustness guard rails.

Counters are bumped at HOST/trace time (guard activations, fallback
engagements, fault injections) — never inside a compiled program — so they
cost nothing on the device hot path.  A counter bumped during tracing counts
compiled-program constructions, not per-call executions; that is the useful
signal for guards that are resolved statically (e.g. "the packed id scatter
was disabled for this capacity").

>>> from repro.utils import telemetry
>>> telemetry.bump("agg.pack_disabled")
>>> telemetry.get("agg.pack_disabled")
1
"""
from __future__ import annotations

import threading
from typing import Dict

_lock = threading.Lock()
_counters: Dict[str, int] = {}
_values: Dict[str, Dict[str, float]] = {}


def bump(name: str, k: int = 1) -> None:
    with _lock:
        _counters[name] = _counters.get(name, 0) + k


def get(name: str) -> int:
    with _lock:
        return _counters.get(name, 0)


def observe(name: str, value: float) -> None:
    """Record one sample of a host-side measurement (latency, backoff sleep,
    breaker-open duration, …) into a cheap running aggregate —
    count/sum/min/max/last, no per-sample storage.  Same host-only
    discipline as ``bump``: never called from inside a compiled program."""
    v = float(value)
    with _lock:
        agg = _values.get(name)
        if agg is None:
            _values[name] = {"count": 1, "sum": v, "min": v, "max": v,
                             "last": v}
        else:
            agg["count"] += 1
            agg["sum"] += v
            agg["min"] = min(agg["min"], v)
            agg["max"] = max(agg["max"], v)
            agg["last"] = v


def values() -> Dict[str, Dict[str, float]]:
    with _lock:
        return {k: dict(v) for k, v in _values.items()}


def snapshot() -> Dict[str, int]:
    with _lock:
        return dict(_counters)


def reset() -> None:
    with _lock:
        _counters.clear()
        _values.clear()
