"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.  Cross-attn image layers every 5th layer; the vision tower is a
STUB — ``input_specs`` provides precomputed patch embeddings (B, 1600, D).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    activation="swiglu", qk_norm=False, rope_theta=5e5,
    cross_attn_every=5, n_img_tokens=1600,
    optimizer="adamw", grad_accum=8, kv_repeat_to=16,
)

REDUCED = CONFIG.replace(
    name="llama-3.2-vision-11b-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, cross_attn_every=2,
    n_img_tokens=10, grad_accum=1, kv_repeat_to=1)
