"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8 (every layer).  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    n_experts=128, top_k=8, moe_every=1, d_ff_expert=768,
    activation="swiglu", qk_norm=True, rope_theta=1e6,
    optimizer="adamw", grad_accum=8, kv_repeat_to=16,
)

REDUCED = CONFIG.replace(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=32, n_experts=8, top_k=2, d_ff_expert=32,
    vocab_size=512, grad_accum=1, kv_repeat_to=1)
