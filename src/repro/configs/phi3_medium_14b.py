"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352.  RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352,
    activation="swiglu", qk_norm=False, rope_theta=1e4,
    optimizer="adamw", grad_accum=8, kv_repeat_to=16,
)

REDUCED = CONFIG.replace(
    name="phi3-medium-14b-smoke", n_layers=2, d_model=80, n_heads=5,
    n_kv_heads=5, head_dim=16, d_ff=160, vocab_size=512, grad_accum=1,
    kv_repeat_to=1)
