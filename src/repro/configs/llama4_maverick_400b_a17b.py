"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
vocab=202048, MoE 128 experts top-1, alternating dense/MoE layers
(interleave-MoE, the Llama-4 pattern), shared expert d_ff=8192, routed expert
d_ff=8192, dense layers d_ff=16384.  Totals ~400B, ~17B active.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Memory policy: adafactor + 16-way grad accumulation + SP residual sharding +
int8 KV (same rationale as nemotron-4-340b).
"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=202048,
    n_experts=128, top_k=1, moe_every=2, d_ff_expert=8192,
    shared_expert=True, d_ff_shared=8192,
    activation="swiglu", qk_norm=False, rope_theta=5e5,
    # 40 heads % 16 != 0, so KV heads stay at 8 and the decode cache shards
    # along the SEQUENCE axis over 'model' (flash-decode style) instead of
    # the head axis — see launch/train_step._state_spec.
    optimizer="adafactor", grad_accum=16, kv_repeat_to=1,
    shard_residual_embed=True, kv_cache_dtype="int8",
)

REDUCED = CONFIG.replace(
    name="llama4-maverick-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, n_experts=8, d_ff_expert=32,
    d_ff_shared=32, vocab_size=512, grad_accum=1, kv_repeat_to=1,
    shard_residual_embed=False)
