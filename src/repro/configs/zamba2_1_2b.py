"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32, full MHA) d_ff=8192,
ssm_state=64.  Mamba2 backbone + ONE shared attention block (weights tied)
invoked every 6 layers on concat(hidden, embedding).  [arXiv:2411.15242; hf]"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    shared_attn_every=6, chunk_size=128, rope_theta=1e4,
    optimizer="adamw", grad_accum=4, kv_repeat_to=16,
)

REDUCED = CONFIG.replace(
    name="zamba2-smoke", n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    shared_attn_every=3, chunk_size=8, grad_accum=1, kv_repeat_to=1)
