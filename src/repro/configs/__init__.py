"""Architecture registry: ``--arch <id>`` resolution for launchers/tests.

Each module defines CONFIG (the exact published dims) and REDUCED (a same-
family small config for CPU smoke tests).  The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct — no allocation).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.arch_config import ArchConfig, SHAPE_CELLS, SHAPES, ShapeCell, cell_applicable

_MODULES = {
    "qwen3-8b": "qwen3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen3-1.7b": "qwen3_1_7b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-large-v3": "whisper_large_v3",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str, *, reduced: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(*, reduced: bool = False) -> Dict[str, ArchConfig]:
    return {a: get(a, reduced=reduced) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "get", "all_configs", "SHAPE_CELLS", "SHAPES",
           "ShapeCell", "cell_applicable"]
