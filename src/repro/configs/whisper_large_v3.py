"""whisper-large-v3 [audio] — 32L (enc) + 32L (dec) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866.  Enc-dec; the conv frontend is a STUB — ``input_specs``
provides precomputed frame embeddings (B, 1500, D).  [arXiv:2212.04356; unverified]

vocab=51866 is not divisible by the 16-way 'model' axis; the divisibility-aware
sharding rules automatically replicate the embedding/unembedding instead
(133 MB replicated — acceptable; noted in DESIGN.md §7)."""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    head_dim=64, d_ff=5120, vocab_size=51866,
    activation="gelu", norm="layer", n_frames=1500,
    optimizer="adamw", grad_accum=4, kv_repeat_to=16,
)

REDUCED = CONFIG.replace(
    name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, n_frames=12,
    grad_accum=1, kv_repeat_to=1)
