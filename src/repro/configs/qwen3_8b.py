"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936,
    activation="swiglu", qk_norm=True, rope_theta=1e6,
    optimizer="adamw", grad_accum=8, kv_repeat_to=16,
)

REDUCED = CONFIG.replace(
    name="qwen3-8b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=512, grad_accum=1, kv_repeat_to=1)
