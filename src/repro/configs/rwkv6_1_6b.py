"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
Finch: data-dependent decay + ddlerp token shift.  [arXiv:2404.05892; unverified]"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    rwkv_head_dim=64, rwkv_lora_rank=64, chunk_size=128,
    optimizer="adamw", grad_accum=4,
)

REDUCED = CONFIG.replace(
    name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, rwkv_head_dim=16, rwkv_lora_rank=8,
    chunk_size=8, grad_accum=1)
