"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  GQA, squared-ReLU.  [arXiv:2402.16819; unverified]

Memory policy (DESIGN.md §7): adafactor (factored 2nd moment — AdamW f32
states would not fit 256 chips), 16-way grad accumulation (microbatch 1 per
data shard), residual activations sharded over 'model' (SP-style), int8 KV
for the 32k decode cells.
"""
from repro.models.arch_config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000,
    activation="squared_relu", qk_norm=False, rope_theta=1e4,
    optimizer="adafactor", grad_accum=16, kv_repeat_to=16,
    shard_residual_embed=True, kv_cache_dtype="int8",
)

REDUCED = CONFIG.replace(
    name="nemotron-4-340b-smoke", n_layers=2, d_model=96, n_heads=4,
    n_kv_heads=2, head_dim=24, d_ff=256, vocab_size=512, grad_accum=1,
    kv_repeat_to=1, shard_residual_embed=False)
