"""Multi-device community detection via shard_map (DESIGN.md §6).

Decomposition — the TPU analogue of Chapel multi-locale block distribution:
  * directed edges are sorted by destination and split into contiguous,
    edge-balanced vertex ranges (``graph.partition``); device d OWNS the
    vertices in its range and ALL edges into them, so the per-vertex GroupBy
    (``core.moves``) needs no cross-device reduction;
  * small O(n) state (labels / communities / degrees) is replicated; each
    sweep ends with a psum-merge of the disjoint per-owner updates;
  * O(n) derived state (community volumes/sizes) is recomputed redundantly on
    every device from replicated inputs — compute is cheaper than ICI.

Matching the paper's own observation (§V-B: "the aggregation phase exhibits
limited scalability due to its global communication requirements"), Louvain
aggregation is executed as a global re-shuffle: gather the moved communities,
coarsen once (jit), re-partition for the next level.

The same code runs 8 fake CPU devices (tests) or a 512-chip pod mesh
(launch/dryrun.py lowers it for the production mesh).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aggregation, moves
from repro.core.common import hash_u32
from repro.core.modularity import modularity
from repro.graph.partition import EdgePartition, partition_edges_by_dst
from repro.graph.structure import Graph
from repro.utils.timing import Timer


# ----------------------------------------------------------------- helpers


def _flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def shard_edges(p: EdgePartition, mesh: Mesh):
    """Place partition arrays on the mesh: leading axis over ALL mesh axes."""
    spec = P(_flat_axes(mesh))
    sharding = jax.NamedSharding(mesh, spec)
    dev = lambda x: jax.device_put(jnp.asarray(x), sharding)
    return dev(p.src), dev(p.dst), dev(p.w), dev(p.edge_mask)


def _merge_owner_updates(upd: jax.Array, val: jax.Array, base: jax.Array, axes):
    """Disjoint-owner merge: psum the masked updates into the replicated base."""
    contrib = jnp.where(upd, val, jnp.zeros((), val.dtype))
    total = jax.lax.psum(contrib, axes)
    any_upd = jax.lax.psum(upd.astype(jnp.int32), axes) > 0
    return jnp.where(any_upd, total, base), any_upd


# ----------------------------------------------------------------- PLP


def make_plp_sweep(mesh: Mesh, n: int, tie_eps: float = 0.25, move_prob: float = 0.75):
    """Build the jitted distributed PLP sweep for a fixed mesh/size."""
    axes = _flat_axes(mesh)
    espec = P(axes)        # edge shards
    rspec = P()            # replicated

    def worker(src, dst, w, emask, labels, active, it, seed):
        src, dst, w, emask = src[0], dst[0], w[0], emask[0]
        valid = emask & active[jnp.clip(dst, 0, n - 1)]
        best_score, best_lab, cur_score = moves.plp_best_labels(
            src, dst, w, valid, labels, n, it, seed, tie_eps
        )
        adopt = active & (best_lab >= 0) & (best_score > cur_score)
        if move_prob < 1.0:
            coin = hash_u32(
                jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(0x85EBCA6B)
                ^ hash_u32(it + seed * jnp.uint32(313))
            )
            adopt = adopt & (coin < jnp.uint32(int(move_prob * 4294967295.0)))
        new_labels, any_upd = _merge_owner_updates(adopt, best_lab, labels, axes)
        changed = any_upd & (new_labels != labels)
        # frontier propagation needs local edges only, then a max-merge
        contrib = jnp.where(emask, changed[jnp.clip(src, 0, n - 1)].astype(jnp.int32), 0)
        nbr_local = jax.ops.segment_sum(contrib, jnp.clip(dst, 0, n - 1), num_segments=n)
        nbr = jax.lax.psum(nbr_local, axes) > 0
        next_active = changed | nbr
        delta_n = jnp.sum(changed.astype(jnp.int32))
        return new_labels, next_active, delta_n

    sharded = jax.shard_map(
        worker,
        mesh=mesh,
        in_specs=(espec, espec, espec, espec, rspec, rspec, rspec, rspec),
        out_specs=(rspec, rspec, rspec),
        check_vma=False,
    )
    return jax.jit(sharded)


def distributed_plp(
    g: Graph,
    mesh: Mesh,
    max_iterations: int = 100,
    threshold: int = 0,
    seed: int = 0,
    tie_eps: float = 0.25,
    move_prob: float = 0.75,
):
    """Driver: partition, then iterate the sharded sweep."""
    n = g.n_max
    part = partition_edges_by_dst(g, mesh.devices.size)
    src, dst, w, emask = shard_edges(part, mesh)
    sweep = make_plp_sweep(mesh, n, tie_eps, move_prob)

    labels = jnp.arange(n, dtype=jnp.int32)
    active = g.vertex_mask()
    history = []
    for it in range(max_iterations):
        labels, active, dn = sweep(
            src, dst, w, emask, labels, active, jnp.uint32(it), jnp.uint32(seed)
        )
        dn = int(dn)
        history.append(dn)
        if dn <= threshold:
            break
    return np.asarray(labels), history


# ----------------------------------------------------------------- Louvain


def make_louvain_sweep(mesh: Mesh, n: int, singleton_rule: bool = True, move_prob: float = 0.5):
    axes = _flat_axes(mesh)
    espec = P(axes)
    rspec = P()

    def worker(src, dst, w, emask, com, need, deg, vol_v, n_valid, it, seed):
        src, dst, w, emask = src[0], dst[0], w[0], emask[0]
        # replicated O(n) recompute (identical on all devices, no comm)
        com_c = jnp.clip(com, 0, n - 1)
        vol_com = jax.ops.segment_sum(deg, com_c, num_segments=n)
        vmask = jnp.arange(n, dtype=jnp.int32) < n_valid
        size_com = jax.ops.segment_sum(
            jnp.where(vmask, 1, 0), com_c, num_segments=n
        )
        valid = emask & need[jnp.clip(dst, 0, n - 1)]
        best_gain, best_cand = moves.louvain_best_moves(
            src, dst, w, valid, com, deg, vol_com, size_com, vol_v, n,
            singleton_rule=singleton_rule,
        )
        move = need & (best_cand >= 0) & (best_gain > 0.0)
        if move_prob < 1.0:
            coin = hash_u32(
                jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(0x9E3779B1)
                ^ hash_u32(it + seed * jnp.uint32(101))
            )
            move = move & (coin < jnp.uint32(int(move_prob * 4294967295.0)))
        new_com, any_upd = _merge_owner_updates(move, best_cand, com, axes)
        changed = any_upd & (new_com != com)
        contrib = jnp.where(emask, changed[jnp.clip(src, 0, n - 1)].astype(jnp.int32), 0)
        nbr_local = jax.ops.segment_sum(contrib, jnp.clip(dst, 0, n - 1), num_segments=n)
        nbr = jax.lax.psum(nbr_local, axes) > 0
        return new_com, changed | nbr, jnp.sum(changed.astype(jnp.int32))

    sharded = jax.shard_map(
        worker,
        mesh=mesh,
        in_specs=(espec,) * 4 + (rspec,) * 7,
        out_specs=(rspec, rspec, rspec),
        check_vma=False,
    )
    return jax.jit(sharded)


@dataclasses.dataclass
class DistLouvainResult:
    labels: np.ndarray
    n_communities: int
    levels: int
    modularity: float
    timer: Timer


def distributed_louvain(
    g: Graph,
    mesh: Mesh,
    max_levels: int = 10,
    max_sweeps: int = 25,
    sweep_threshold: int = 0,
    seed: int = 0,
    move_prob: float = 0.5,
    singleton_rule: bool = True,
) -> DistLouvainResult:
    timer = Timer()
    n = g.n_max
    g0 = g
    assign = jnp.arange(n, dtype=jnp.int32)
    cur = g
    levels = 0

    sweep = make_louvain_sweep(mesh, n, singleton_rule, move_prob)
    for level in range(max_levels):
        with timer.phase("partition"):
            part = partition_edges_by_dst(cur, mesh.devices.size)
            src, dst, w, emask = shard_edges(part, mesh)
        com = jnp.arange(n, dtype=jnp.int32)
        need = cur.vertex_mask()
        deg = cur.weighted_degrees()
        vol_v = cur.total_volume()
        for s in range(max_sweeps):
            with timer.phase("local_moving"):
                com, need, dn = sweep(
                    src, dst, w, emask, com, need, deg, vol_v, cur.n_valid,
                    jnp.uint32(level * 1000 + s), jnp.uint32(seed),
                )
                dn = int(dn)
            if dn <= sweep_threshold:
                break
        with timer.phase("aggregation"):
            new_com, n_comm = aggregation.remap_communities(com, cur.vertex_mask())
            done = int(n_comm) == int(cur.n_valid)
            if not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_com, n_comm)
        levels = level + 1
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign))
    return DistLouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        timer=timer,
    )
