"""Multi-device community detection via shard_map (DESIGN.md §6).

Decomposition — the TPU analogue of Chapel multi-locale block distribution:
  * directed edges are sorted by destination and split into contiguous,
    edge-balanced vertex ranges (``graph.partition``); device d OWNS the
    vertices in its range and ALL edges into them, so the per-vertex GroupBy
    (``core.moves``) needs no cross-device reduction;
  * small O(n) state (labels / communities / degrees) is replicated; each
    sweep ends with a psum-merge of the disjoint per-owner updates;
  * O(n) derived state (community volumes/sizes) is recomputed redundantly on
    every device from replicated inputs — compute is cheaper than ICI.

The sweep loop itself is the shared engine's fused phase
(``core.engine.make_distributed_phase``, DESIGN.md §Engine): the
``lax.while_loop`` runs INSIDE the shard_map worker with the convergence
predicate on the replicated ΔN, so one local-moving phase is one jitted call
with zero per-sweep host syncs — the same contract as the single-device
backends.

Matching the paper's own observation (§V-B: "the aggregation phase exhibits
limited scalability due to its global communication requirements"), Louvain
aggregation comes in two flavors:

  * per-level (``pipeline_fused=False``): a global host re-shuffle — gather
    the moved communities, coarsen once (jit), re-partition for the next
    level;
  * pipeline-fused (``pipeline_fused=True``, default, DESIGN.md §Pipeline):
    the LEVEL LOOP nests around the in-shard_map sweep loop.  Level 0
    sweeps on the edge-balanced LOCAL shard (per-device compute ~m/D, same
    as the per-level driver), then the shard is all-gathered ONCE into a
    replicated list on which coarsening is a redundant groupby recompute
    and coarse levels sweep under static dst-range ownership.  The
    community count is collectively merged (``pmax``) so the Alg. 3
    convergence predicate is identical on every device, and all devices
    step through levels in lockstep with ZERO host syncs until the single
    final readback.

The same code runs 8 fake CPU devices (tests) or a 512-chip pod mesh
(launch/dryrun.py lowers it for the production mesh).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aggregation
from repro.core.engine import (EngineSpec, make_distributed_phase,
                               make_distributed_step, phase_loop,
                               shard_map_compat)
from repro.core.modularity import modularity
from repro.graph.partition import EdgePartition, partition_edges_by_dst
from repro.graph.structure import Graph
from repro.utils import faultinject, telemetry
from repro.utils.errors import RunReport, ShardError
from repro.utils.timing import Timer


# ----------------------------------------------------------------- helpers


def _flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _engine_faults(faults: frozenset) -> tuple:
    from repro.core.louvain import ENGINE_FAULTS

    return tuple(sorted(f for f in faults if f in ENGINE_FAULTS))


def _prepare_partition(g: Graph, n_devices: int) -> EdgePartition:
    """Partition + the shard-coverage guard (DESIGN.md §Robustness).

    The ``shard_drop`` fault-injection point masks out device 0's entire
    edge shard after partitioning — modelling a lost/corrupted shard.  The
    guard below re-counts the per-device masks against the graph's own
    ``m_valid`` BEFORE any compute is dispatched: losing edges here would
    otherwise just yield a quietly-worse partition (no crash, wrong
    volumes), the canonical silent-corruption outcome.
    """
    part = partition_edges_by_dst(g, n_devices)
    if faultinject.is_active("shard_drop"):
        telemetry.bump("fault.shard_drop.injected")
        emask = np.array(part.edge_mask)
        emask[0, :] = False
        part = dataclasses.replace(part, edge_mask=emask)
    covered = int(np.asarray(part.edge_mask).sum())
    expect = int(g.m_valid)
    if covered != expect:
        raise ShardError(
            f"edge partition covers {covered} directed edges, graph has "
            f"{expect}: a shard was dropped or corrupted")
    return part


def shard_edges(p: EdgePartition, mesh: Mesh):
    """Place partition arrays on the mesh: leading axis over ALL mesh axes."""
    spec = P(_flat_axes(mesh))
    sharding = jax.NamedSharding(mesh, spec)
    dev = lambda x: jax.device_put(jnp.asarray(x), sharding)
    return dev(p.src), dev(p.dst), dev(p.w), dev(p.edge_mask)


# ----------------------------------------------------------------- PLP


def distributed_plp(
    g: Graph,
    mesh: Mesh,
    max_iterations: int = 100,
    threshold: int = 0,
    seed: int = 0,
    tie_eps: float = 0.25,
    move_prob: float = 0.75,
):
    """Driver: partition once, then one fused sharded phase call."""
    n = g.n_max
    part = _prepare_partition(g, mesh.devices.size)
    src, dst, w, emask = shard_edges(part, mesh)
    spec = EngineSpec(
        evaluator="plp",
        backend="distributed",
        max_sweeps=max_iterations,
        threshold=threshold,
        tie_eps=tie_eps,
        move_prob=move_prob,
        # historical behavior of the sharded sweep: tie noise re-drawn per
        # iteration (the closest analogue of Chapel's racy move order)
        reshuffle_ties=True,
        faults=_engine_faults(faultinject.active()),
    )
    phase = make_distributed_phase(mesh, n, spec)

    labels = jnp.arange(n, dtype=jnp.int32)
    active = g.vertex_mask()
    zero = jnp.zeros((n,), jnp.float32)  # deg/vol placeholders (PLP unused)
    labels, active, sweeps, dn_hist, _ = phase(
        src, dst, w, emask, labels, active, jnp.uint32(0), jnp.uint32(seed),
        zero, jnp.float32(1.0), g.n_valid,
    )
    sweeps = int(sweeps)
    history = [int(x) for x in np.asarray(dn_hist)[:sweeps]]
    return np.asarray(labels), history


# ----------------------------------------------------------------- Louvain


@dataclasses.dataclass
class DistLouvainResult:
    labels: np.ndarray
    n_communities: int
    levels: int
    modularity: float
    timer: Timer
    sweeps_per_level: list = dataclasses.field(default_factory=list)
    n_comm_per_level: list = dataclasses.field(default_factory=list)
    # retry/degradation/watchdog accounting (DESIGN.md §Robustness)
    run_report: RunReport = dataclasses.field(default_factory=RunReport)


@lru_cache(maxsize=None)
def make_distributed_pipeline(mesh: Mesh, n: int, m_pad: int,
                              spec: EngineSpec, max_levels: int,
                              agg_method: str = "binned",
                              faults: frozenset = frozenset()):
    """Build the jitted whole-run distributed pipeline (DESIGN.md §Pipeline).

    The level loop runs INSIDE the shard_map worker, nested around the
    engine's fused sweep loop, mirroring the single-device pipeline's
    peeled-level-0 structure:

      * LEVEL 0 (the dominant level) sweeps on the device's LOCAL edge
        shard from the host edge-balanced partitioner — per-device compute
        stays ~m/D, exactly like the per-level driver;
      * the shard is then ``all_gather``-ed ONCE into the replicated
        ``m_total = D·m_pad`` edge list; aggregation reuses the one-sort
        ``aggregation.remap_and_coarsen`` on it (identical on every device,
        no re-shuffle), and coarse levels — orders of magnitude smaller —
        sweep on the replicated list masked by a static contiguous
        dst-range ownership (``ceil(n/D)`` vertices per device, so the
        per-sweep psum merge stays a disjoint union);
      * the community count is collectively merged (``lax.pmax``) so the
        Alg. 3 ``n_comm == n_valid`` predicate is bitwise-identical on all
        devices and the level loop exits in lockstep;
      * per-level sweep/community-count histories live in ``-1``-sentinel
        device buffers, read back once after the single dispatch.

    Returns ``pipeline(src, dst, w, edge_mask, seed, n_valid) ->
    (labels, n_final, levels, modularity, sweeps_hist, ncomm_hist)`` with
    ``src..edge_mask`` the (D, m_pad) partition arrays.
    """
    axes = tuple(mesh.axis_names)
    espec, rspec = P(axes), P()
    D = int(mesh.devices.size)
    m_total = D * m_pad       # static capacity of the gathered edge list
    stride = -(-n // D)       # static coarse-ownership dst-range width

    def worker(src_l, dst_l, w_l, emask_l, seed, n_valid0):
        src_l, dst_l, w_l, emask_l = (src_l[0], dst_l[0], w_l[0], emask_l[0])
        # linear device index over the (possibly multi-axis) mesh
        d = jnp.int32(0)
        for ax in axes:
            d = d * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
        lo = d * stride
        hi = jnp.minimum(lo + stride, n)
        arange_n = jnp.arange(n, dtype=jnp.int32)
        n_valid0 = n_valid0.astype(jnp.int32)

        def sweep(src, dst, w, emask, own, vmask, level_u32):
            """One fused local-moving phase over the given edge arrays."""
            w_m = jnp.where(emask, w, 0.0)
            deg = jax.lax.psum(jax.ops.segment_sum(
                jnp.where(own, w, 0.0), jnp.clip(src, 0, n - 1),
                num_segments=n), axes)
            vol_v = jnp.sum(deg)
            step = make_distributed_step(
                spec, axes, n, src, dst, w, own, deg, vol_v, vmask)
            com, _, sweeps, _dn, _act = phase_loop(
                step, arange_n, vmask, level_u32 * jnp.uint32(1000), seed,
                spec.max_sweeps, spec.threshold)
            return com, sweeps.astype(jnp.int32)

        def aggregate(cur: Graph, com, assign):
            """Sort-free (or one-sort) remap+coarsen + pmax'd convergence.

            ``com`` is replicated, so the coarsening runs identically on
            every device with no communication; only the community count is
            collectively merged for the lockstep predicate (its local value
            already equals the pmax)."""
            new_com, n_comm, cg = aggregation.remap_and_coarsen_by(
                agg_method, cur, com, faults)
            n_comm = jax.lax.pmax(n_comm, axes)  # lockstep collective merge
            done = n_comm == cur.n_valid         # Alg. 3 l.6, on device
            macro = new_com[jnp.clip(assign, 0, n - 1)]

            def advance(_):
                nown = cg.edge_mask & (cg.dst >= lo) & (cg.dst < hi)
                return (cg.src, cg.dst, cg.w, cg.edge_mask, nown,
                        n_comm, cg.m_valid, macro)

            def stay(_):
                return (cur.src, cur.dst, cur.w, cur.edge_mask,
                        jnp.zeros((m_total,), bool), cur.n_valid,
                        cur.m_valid, assign)

            nxt = jax.lax.cond(done, stay, advance, None)
            return nxt + (macro, n_comm, done)

        # ---- peeled level 0: sweep on the LOCAL edge-balanced shard
        com0, sweeps0 = sweep(src_l, dst_l, w_l, emask_l, emask_l,
                              arange_n < n_valid0, jnp.uint32(0))
        # gather the shard ONCE into the replicated full-capacity list
        gather = lambda x: jax.lax.all_gather(x, axes, tiled=True)
        src_f, dst_f, w_f, emask_f = (gather(src_l), gather(dst_l),
                                      gather(w_l), gather(emask_l))
        g_full = Graph(src=src_f, dst=dst_f, w=w_f, edge_mask=emask_f,
                       n_valid=n_valid0,
                       m_valid=jnp.sum(emask_f.astype(jnp.int32)),
                       n_max=n, m_max=m_total, sorted_by=None)
        (src, dst, w, fullmask, own, n_valid, m_valid, assign, macro,
         n_comm, done) = aggregate(g_full, com0, arange_n)

        sweeps_hist = jnp.full((max_levels,), -1, jnp.int32).at[0].set(sweeps0)
        ncomm_hist = jnp.full((max_levels,), -1, jnp.int32).at[0].set(n_comm)

        # ---- coarse levels: replicated list, dst-range ownership masks
        def cond(c):
            level, done = c[0], c[1]
            return (level < max_levels) & (~done)

        def body(c):
            (level, _done, src, dst, w, fullmask, own_l, n_valid, m_valid,
             assign, _macro, sh, nh) = c
            cur = Graph(src=src, dst=dst, w=w, edge_mask=fullmask,
                        n_valid=n_valid, m_valid=m_valid, n_max=n,
                        m_max=m_total, sorted_by=None)
            com, sweeps = sweep(src, dst, w, fullmask, own_l,
                                cur.vertex_mask(), level.astype(jnp.uint32))
            (src2, dst2, w2, fm2, own2, nv2, mv2, assign2, macro2, n_comm,
             done2) = aggregate(cur, com, assign)
            sh = sh.at[level].set(sweeps)
            nh = nh.at[level].set(n_comm)
            return (level + 1, done2, src2, dst2, w2, fm2, own2, nv2, mv2,
                    assign2, macro2, sh, nh)

        carry = (jnp.int32(1), done, src, dst, w, fullmask, own, n_valid,
                 m_valid, assign, macro, sweeps_hist, ncomm_hist)
        carry = jax.lax.while_loop(cond, body, carry)
        (levels, _, _, _, _, _, _, _, _, _, macro, sweeps_hist,
         ncomm_hist) = carry

        final, n_final = aggregation.remap_communities(
            macro, arange_n < n_valid0)
        q = modularity(g_full, final)
        return final, n_final, levels, q, sweeps_hist, ncomm_hist

    sharded = shard_map_compat(
        worker, mesh,
        in_specs=(espec,) * 4 + (rspec,) * 2,
        out_specs=(rspec,) * 6,
    )
    return jax.jit(sharded)


def distributed_louvain(
    g: Graph,
    mesh: Mesh,
    max_levels: int = 10,
    max_sweeps: int = 25,
    sweep_threshold: int = 0,
    seed: int = 0,
    move_prob: float = 0.5,
    singleton_rule: bool = True,
    pipeline_fused: bool = True,
    aggregation_method: str = "binned",
) -> DistLouvainResult:
    timer = Timer()
    n = g.n_max
    faults = frozenset(faultinject.active())
    report = RunReport(faults=sorted(faults))
    spec = EngineSpec(
        evaluator="louvain",
        backend="distributed",
        max_sweeps=max_sweeps,
        threshold=sweep_threshold,
        move_prob=move_prob,
        singleton_rule=singleton_rule,
        faults=_engine_faults(faults),
    )

    if pipeline_fused:
        with timer.phase("partition"):
            part = _prepare_partition(g, mesh.devices.size)
            src, dst, w, emask = shard_edges(part, mesh)
        pipe = make_distributed_pipeline(mesh, n, part.m_pad, spec,
                                         max_levels, aggregation_method,
                                         faults)
        with timer.phase("pipeline"):
            out = pipe(src, dst, w, emask, jnp.uint32(seed), g.n_valid)
            (final, n_final, levels, q, sweeps_hist,
             ncomm_hist) = jax.device_get(out)   # the ONE readback
        levels = int(levels)
        return DistLouvainResult(
            labels=np.asarray(final),
            n_communities=int(n_final),
            levels=levels,
            modularity=float(q),
            timer=timer,
            sweeps_per_level=[int(x) for x in sweeps_hist[:levels]],
            n_comm_per_level=[int(x) for x in ncomm_hist[:levels]],
            run_report=report,
        )

    g0 = g
    assign = jnp.arange(n, dtype=jnp.int32)
    cur = g
    levels = 0
    sweeps_per_level: list = []
    n_comm_per_level: list = []

    phase = make_distributed_phase(mesh, n, spec)
    for level in range(max_levels):
        with timer.phase("partition"):
            # the coverage guard applies per level: each re-partition is a
            # fresh opportunity to lose a shard
            part = _prepare_partition(cur, mesh.devices.size)
            src, dst, w, emask = shard_edges(part, mesh)
        com = jnp.arange(n, dtype=jnp.int32)
        need = cur.vertex_mask()
        with timer.phase("local_moving"):
            # one fused phase per level: while_loop inside the shard_map
            com, need, sweeps, _, _ = phase(
                src, dst, w, emask, com, need,
                jnp.uint32(level * 1000), jnp.uint32(seed),
                cur.weighted_degrees(), cur.total_volume(), cur.n_valid,
            )
        sweeps_per_level.append(int(sweeps))
        with timer.phase("aggregation"):
            new_com, n_comm, coarse = aggregation.remap_and_coarsen_by(
                aggregation_method, cur, com, faults)
            n_comm_per_level.append(int(n_comm))
            done = int(n_comm) == int(cur.n_valid)
            if not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = coarse
        levels = level + 1
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign))
    return DistLouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        timer=timer,
        sweeps_per_level=sweeps_per_level,
        n_comm_per_level=n_comm_per_level,
        run_report=report,
    )
