"""Multi-device community detection via shard_map (DESIGN.md §6).

Decomposition — the TPU analogue of Chapel multi-locale block distribution:
  * directed edges are sorted by destination and split into contiguous,
    edge-balanced vertex ranges (``graph.partition``); device d OWNS the
    vertices in its range and ALL edges into them, so the per-vertex GroupBy
    (``core.moves``) needs no cross-device reduction;
  * small O(n) state (labels / communities / degrees) is replicated; each
    sweep ends with a psum-merge of the disjoint per-owner updates;
  * O(n) derived state (community volumes/sizes) is recomputed redundantly on
    every device from replicated inputs — compute is cheaper than ICI.

The sweep loop itself is the shared engine's fused phase
(``core.engine.make_distributed_phase``, DESIGN.md §Engine): the
``lax.while_loop`` runs INSIDE the shard_map worker with the convergence
predicate on the replicated ΔN, so one local-moving phase is one jitted call
with zero per-sweep host syncs — the same contract as the single-device
backends.

Matching the paper's own observation (§V-B: "the aggregation phase exhibits
limited scalability due to its global communication requirements"), Louvain
aggregation is executed as a global re-shuffle: gather the moved communities,
coarsen once (jit), re-partition for the next level.

The same code runs 8 fake CPU devices (tests) or a 512-chip pod mesh
(launch/dryrun.py lowers it for the production mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aggregation
from repro.core.engine import EngineSpec, make_distributed_phase
from repro.core.modularity import modularity
from repro.graph.partition import EdgePartition, partition_edges_by_dst
from repro.graph.structure import Graph
from repro.utils.timing import Timer


# ----------------------------------------------------------------- helpers


def _flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def shard_edges(p: EdgePartition, mesh: Mesh):
    """Place partition arrays on the mesh: leading axis over ALL mesh axes."""
    spec = P(_flat_axes(mesh))
    sharding = jax.NamedSharding(mesh, spec)
    dev = lambda x: jax.device_put(jnp.asarray(x), sharding)
    return dev(p.src), dev(p.dst), dev(p.w), dev(p.edge_mask)


# ----------------------------------------------------------------- PLP


def distributed_plp(
    g: Graph,
    mesh: Mesh,
    max_iterations: int = 100,
    threshold: int = 0,
    seed: int = 0,
    tie_eps: float = 0.25,
    move_prob: float = 0.75,
):
    """Driver: partition once, then one fused sharded phase call."""
    n = g.n_max
    part = partition_edges_by_dst(g, mesh.devices.size)
    src, dst, w, emask = shard_edges(part, mesh)
    spec = EngineSpec(
        evaluator="plp",
        backend="distributed",
        max_sweeps=max_iterations,
        threshold=threshold,
        tie_eps=tie_eps,
        move_prob=move_prob,
        # historical behavior of the sharded sweep: tie noise re-drawn per
        # iteration (the closest analogue of Chapel's racy move order)
        reshuffle_ties=True,
    )
    phase = make_distributed_phase(mesh, n, spec)

    labels = jnp.arange(n, dtype=jnp.int32)
    active = g.vertex_mask()
    zero = jnp.zeros((n,), jnp.float32)  # deg/vol placeholders (PLP unused)
    labels, active, sweeps, dn_hist, _ = phase(
        src, dst, w, emask, labels, active, jnp.uint32(0), jnp.uint32(seed),
        zero, jnp.float32(1.0), g.n_valid,
    )
    sweeps = int(sweeps)
    history = [int(x) for x in np.asarray(dn_hist)[:sweeps]]
    return np.asarray(labels), history


# ----------------------------------------------------------------- Louvain


@dataclasses.dataclass
class DistLouvainResult:
    labels: np.ndarray
    n_communities: int
    levels: int
    modularity: float
    timer: Timer


def distributed_louvain(
    g: Graph,
    mesh: Mesh,
    max_levels: int = 10,
    max_sweeps: int = 25,
    sweep_threshold: int = 0,
    seed: int = 0,
    move_prob: float = 0.5,
    singleton_rule: bool = True,
) -> DistLouvainResult:
    timer = Timer()
    n = g.n_max
    g0 = g
    assign = jnp.arange(n, dtype=jnp.int32)
    cur = g
    levels = 0

    spec = EngineSpec(
        evaluator="louvain",
        backend="distributed",
        max_sweeps=max_sweeps,
        threshold=sweep_threshold,
        move_prob=move_prob,
        singleton_rule=singleton_rule,
    )
    phase = make_distributed_phase(mesh, n, spec)
    for level in range(max_levels):
        with timer.phase("partition"):
            part = partition_edges_by_dst(cur, mesh.devices.size)
            src, dst, w, emask = shard_edges(part, mesh)
        com = jnp.arange(n, dtype=jnp.int32)
        need = cur.vertex_mask()
        with timer.phase("local_moving"):
            # one fused phase per level: while_loop inside the shard_map
            com, need, _, _, _ = phase(
                src, dst, w, emask, com, need,
                jnp.uint32(level * 1000), jnp.uint32(seed),
                cur.weighted_degrees(), cur.total_volume(), cur.n_valid,
            )
        with timer.phase("aggregation"):
            new_com, n_comm = aggregation.remap_communities(com, cur.vertex_mask())
            done = int(n_comm) == int(cur.n_valid)
            if not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_com, n_comm)
        levels = level + 1
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign))
    return DistLouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        timer=timer,
    )
