"""Multi-device community detection via shard_map (DESIGN.md §6).

Decomposition — the TPU analogue of Chapel multi-locale block distribution:
  * directed edges are sorted by destination and split into contiguous,
    edge-balanced vertex ranges (``graph.partition``); device d OWNS the
    vertices in its range and ALL edges into them, so the per-vertex GroupBy
    (``core.moves``) needs no cross-device reduction;
  * small O(n) state (labels / communities / degrees) is replicated; each
    sweep ends with a psum-merge of the disjoint per-owner updates;
  * O(n) derived state (community volumes/sizes) is recomputed redundantly on
    every device from replicated inputs — compute is cheaper than ICI.

The sweep loop itself is the shared engine's fused phase
(``core.engine.make_distributed_phase``, DESIGN.md §Engine): the
``lax.while_loop`` runs INSIDE the shard_map worker with the convergence
predicate on the replicated ΔN, so one local-moving phase is one jitted call
with zero per-sweep host syncs — the same contract as the single-device
backends.

Matching the paper's own observation (§V-B: "the aggregation phase exhibits
limited scalability due to its global communication requirements"), Louvain
aggregation comes in three flavors:

  * per-level (``pipeline_fused=False``): a global host re-shuffle — gather
    the moved communities, coarsen once (jit), re-partition for the next
    level;
  * fused + SHARD-LOCAL coarsening (``pipeline_fused=True,
    coarsening="shard_local"``, the default): the level loop nests around
    the in-shard_map sweep loop and each device coarsens ONLY its owned
    edge shard with the sort-free binned kernel.  Community ids are
    contiguized by a two-phase scheme (per-device presence-bitmap stripe +
    exclusive prefix over per-shard counts), and the per-shard partial
    coarse lists — bounded by the static ``halo_cap``
    (``kernels.common.pick_halo_cap``) — are exchanged in ONE tiled
    all_gather and merged by a second groupby pass.  The per-level
    collective payload is O(communities + cross-shard community pairs),
    never O(m); a psum'd overflow flag sends the rare cap-busting level to
    the host degradation ladder (retry with replicated coarsening);
  * fused + REPLICATED coarsening (``coarsening="replicated"``): the
    retired gather-then-replicate loop, kept as the selectable parity
    ORACLE — one full-shard all_gather after level 0, then replicated
    groupby recompute on every device.  Shard-local must match it (and the
    single-device fused driver) bit-for-bit on every mesh size
    (tests/test_distributed.py).

Bitwise parity of partial-then-merge coarsening rests on the same
integer-exactness condition as the rest of the repo (DESIGN.md §Numerics):
coarse edge weights are sums of input weights, exact in f32 below
``kernels.common.F32_ACCUM_SAFE``, so per-shard partial sums followed by the
merge groupby reassociate freely; group ORDER is canonical ((cs, cd)
ascending, front-compacted) and therefore shard-count independent.

The same code runs 8 fake CPU devices (tests) or a 512-chip pod mesh
(launch/dryrun.py lowers it for the production mesh).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aggregation
from repro.core.engine import (EngineSpec, make_distributed_phase,
                               make_distributed_step, phase_loop,
                               shard_map_compat)
from repro.core.modularity import modularity
from repro.graph.partition import (EdgePartition, build_halo,
                                   partition_edges_by_dst, partition_quality)
from repro.graph.structure import Graph
from repro.kernels.aggregation import binned_coarsen
from repro.kernels.common import (EDGE_WIRE_BYTES, LABEL_WIRE_BYTES,
                                  accum_dtype, accum_needs_promotion,
                                  dist_comm_bytes_per_level, pick_halo_cap)
from repro.utils import faultinject, telemetry
from repro.utils.errors import RunReport, ShardError
from repro.utils.timing import Timer

COARSENING_MODES = ("shard_local", "replicated")


# ----------------------------------------------------------------- helpers


def _flat_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _engine_faults(faults: frozenset) -> tuple:
    from repro.core.louvain import ENGINE_FAULTS

    return tuple(sorted(f for f in faults if f in ENGINE_FAULTS))


def _prepare_partition(g: Graph, n_devices: int) -> EdgePartition:
    """Partition + the shard-coverage guard (DESIGN.md §Robustness).

    The ``shard_drop`` fault-injection point masks out device 0's entire
    edge shard after partitioning — modelling a lost/corrupted shard.  The
    guard below re-counts the per-device masks against the graph's own
    ``m_valid`` BEFORE any compute is dispatched: losing edges here would
    otherwise just yield a quietly-worse partition (no crash, wrong
    volumes), the canonical silent-corruption outcome.
    """
    part = partition_edges_by_dst(g, n_devices)
    if faultinject.is_active("shard_drop"):
        telemetry.bump("fault.shard_drop.injected")
        emask = np.array(part.edge_mask)
        emask[0, :] = False
        part = dataclasses.replace(part, edge_mask=emask)
    covered = int(np.asarray(part.edge_mask).sum())
    expect = int(g.m_valid)
    if covered != expect:
        raise ShardError(
            f"edge partition covers {covered} directed edges, graph has "
            f"{expect}: a shard was dropped or corrupted")
    return part


def shard_edges(p: EdgePartition, mesh: Mesh):
    """Place partition arrays on the mesh: leading axis over ALL mesh axes."""
    spec = P(_flat_axes(mesh))
    sharding = jax.NamedSharding(mesh, spec)
    dev = lambda x: jax.device_put(jnp.asarray(x), sharding)
    return dev(p.src), dev(p.dst), dev(p.w), dev(p.edge_mask)


# ----------------------------------------------------------------- PLP


def distributed_plp(
    g: Graph,
    mesh: Mesh,
    max_iterations: int = 100,
    threshold: int = 0,
    seed: int = 0,
    tie_eps: float = 0.25,
    move_prob: float = 0.75,
):
    """Driver: partition once, then one fused sharded phase call."""
    n = g.n_max
    part = _prepare_partition(g, mesh.devices.size)
    src, dst, w, emask = shard_edges(part, mesh)
    spec = EngineSpec(
        evaluator="plp",
        backend="distributed",
        max_sweeps=max_iterations,
        threshold=threshold,
        tie_eps=tie_eps,
        move_prob=move_prob,
        # historical behavior of the sharded sweep: tie noise re-drawn per
        # iteration (the closest analogue of Chapel's racy move order)
        reshuffle_ties=True,
        faults=_engine_faults(faultinject.active()),
    )
    phase = make_distributed_phase(mesh, n, spec)

    labels = jnp.arange(n, dtype=jnp.int32)
    active = g.vertex_mask()
    zero = jnp.zeros((n,), jnp.float32)  # deg/vol placeholders (PLP unused)
    labels, active, sweeps, dn_hist, _ = phase(
        src, dst, w, emask, labels, active, jnp.uint32(0), jnp.uint32(seed),
        zero, jnp.float32(1.0), g.n_valid,
    )
    sweeps = int(sweeps)
    history = [int(x) for x in np.asarray(dn_hist)[:sweeps]]
    return np.asarray(labels), history


# ----------------------------------------------------------------- Louvain


@dataclasses.dataclass
class DistLouvainResult:
    labels: np.ndarray
    n_communities: int
    levels: int
    modularity: float
    timer: Timer
    sweeps_per_level: list = dataclasses.field(default_factory=list)
    n_comm_per_level: list = dataclasses.field(default_factory=list)
    modularity_history: list = dataclasses.field(default_factory=list)
    delta_n_per_level: list = dataclasses.field(default_factory=list)
    # which coarsening mode actually produced the answer ("shard_local",
    # "replicated", or "per_level"), after any overflow degradation
    coarsening: str = "replicated"
    # partition health (graph.partition.partition_quality._asdict()) and the
    # per-level collective-payload accounting of the fused pipeline
    partition_stats: dict = dataclasses.field(default_factory=dict)
    comm_stats: dict = dataclasses.field(default_factory=dict)
    # retry/degradation/watchdog accounting (DESIGN.md §Robustness)
    run_report: RunReport = dataclasses.field(default_factory=RunReport)


@lru_cache(maxsize=None)
def make_distributed_pipeline(mesh: Mesh, n: int, m_pad: int,
                              spec: EngineSpec, max_levels: int,
                              agg_method: str = "binned",
                              faults: frozenset = frozenset(),
                              coarsening: str = "shard_local",
                              halo_cap: int = 0,
                              refine_sweeps: int = 0,
                              track_modularity: bool = True,
                              promote: bool = False):
    """Build the jitted whole-run distributed pipeline (DESIGN.md §Pipeline).

    The level loop runs INSIDE the shard_map worker, nested around the
    engine's fused sweep loop, mirroring the single-device pipeline's
    peeled-level-0 structure:

      * LEVEL 0 (the dominant level) sweeps on the device's LOCAL edge
        shard from the host edge-balanced partitioner — per-device compute
        stays ~m/D, exactly like the per-level driver;
      * ``coarsening="shard_local"``: community ids are contiguized by the
        TWO-PHASE scheme (each device scans its ``ceil(n/D)`` stripe of the
        presence bitmap; an all_gather of per-stripe counts provides the
        exclusive prefix that makes local ranks globally dense — bitwise
        equal to ``aggregation.remap_communities``, no sort); each device
        then coarsens ONLY its owned edges with the binned kernel and ships
        the first ``halo_cap`` partial groups through one tiled all_gather;
        a second (identity-map) groupby merges cross-shard duplicates into
        the canonical coarse graph at the REDUCED static capacity
        ``D·halo_cap``.  A psum'd flag records any shard whose partial list
        overflowed the cap — results of an overflowed run are refused by
        the driver, which retries replicated;
      * ``coarsening="replicated"``: the parity oracle — the shard is
        all_gather-ed ONCE into the replicated ``D·m_pad`` list, and
        aggregation is a redundant identical groupby on every device;
      * coarse levels — orders of magnitude smaller — sweep on the (merged,
        replicated) coarse list masked by a static contiguous dst-range
        ownership (``ceil(n/D)`` vertices per device, so the per-sweep psum
        merge stays a disjoint union); shard-local coarsening keeps
        applying per level with the same dst-range ownership;
      * ``refine_sweeps > 0`` enables Leiden refinement: after the macro
        phase, a threshold-0 phase re-runs from singletons restricted to
        macro communities, aggregation groups by the REFINED partition, and
        the next level's local-moving is seeded with each super-vertex's
        macro id — mirroring ``core.louvain`` exactly.  The refine phase
        contains collectives, so it runs UNCONDITIONALLY (uniform across
        devices) and its outputs are simply dead when the level converged —
        bitwise identical to the local driver's cond-gated refinement;
      * per-level modularity (and the final Q) use a psum decomposition of
        ``core.modularity`` over the level-0 shards: per-shard partial
        intra-weight/degree sums are exact in f32 for integer-valued
        weights (F32_ACCUM_SAFE), so the distributed Q is bitwise equal to
        the local oracle's;
      * histories live in sentinel device buffers (``-1`` for counts, NaN
        for modularity — the PR-1 convention), read back once.

    Returns ``pipeline(src, dst, w, edge_mask, seed, n_valid) ->
    (labels, n_final, levels, modularity, sweeps_hist, ncomm_hist,
    mod_hist, dn_hist, pgroups_hist, overflow)`` with ``src..edge_mask``
    the (D, m_pad) partition arrays.  ``pgroups_hist`` counts the gathered
    partial groups per level (-1 where not applicable) — the actual
    shard-local collective payload; ``overflow`` is the psum'd halo-cap
    flag.
    """
    from repro.core.louvain import LEVEL_IT_STRIDE, REFINE_IT_OFFSET

    if coarsening not in COARSENING_MODES:
        raise ValueError(f"coarsening must be one of {COARSENING_MODES}, "
                         f"got {coarsening!r}")
    axes = tuple(mesh.axis_names)
    espec, rspec = P(axes), P()
    D = int(mesh.devices.size)
    stride = -(-n // D)       # static coarse-ownership dst-range width
    n_pad_c = D * stride - n  # stripe padding of the presence bitmap
    if coarsening == "shard_local":
        h_cap = int(halo_cap) if halo_cap else pick_halo_cap(m_pad, D)
        h_cap = min(h_cap, m_pad)
        m_c = D * h_cap       # static capacity of the merged coarse list
    else:
        h_cap = 0
        m_c = D * m_pad       # static capacity of the gathered edge list
    refine = refine_sweeps > 0
    refine_spec = (dataclasses.replace(spec, max_sweeps=refine_sweeps,
                                       threshold=0) if refine else None)
    force_overflow = "binned_overflow" in faults
    max_sweeps = spec.max_sweeps
    acc = accum_dtype(promote)

    def worker(src_l, dst_l, w_l, emask_l, seed, n_valid0):
        src_l, dst_l, w_l, emask_l = (src_l[0], dst_l[0], w_l[0], emask_l[0])
        # linear device index over the (possibly multi-axis) mesh
        d = jnp.int32(0)
        for ax in axes:
            d = d * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
        lo = d * stride
        hi = jnp.minimum(lo + stride, n)
        arange_n = jnp.arange(n, dtype=jnp.int32)
        n_valid0 = n_valid0.astype(jnp.int32)
        sentinel = jnp.int32(n)
        gather = lambda x: jax.lax.all_gather(x, axes, tiled=True)

        def sweep(sp, src, dst, w, own, vmask, init_com, it0, restrict=None):
            """One fused local-moving phase over the given edge arrays."""
            deg = jax.lax.psum(jax.ops.segment_sum(
                jnp.where(own, w, 0.0), jnp.clip(src, 0, n - 1),
                num_segments=n), axes)
            vol_v = jnp.sum(deg)
            step = make_distributed_step(
                sp, axes, n, src, dst, w, own, deg, vol_v, vmask, restrict)
            com, _, sweeps, dn_h, _act = phase_loop(
                step, init_com, vmask, it0, seed, sp.max_sweeps, sp.threshold)
            return com, sweeps.astype(jnp.int32), dn_h

        def dist_q(com):
            """psum decomposition of ``core.modularity`` over level-0 shards.

            Each partial sum (per-shard intra weight, per-vertex degree) is
            an exact integer in f32 below F32_ACCUM_SAFE, so the psum
            reassociation is bitwise equal to the local single-pass sums;
            the replicated tail (vol_c scatter + Σ(vol_c/vol)² ) runs on
            identical arrays and is deterministic by shape.
            """
            wm = jnp.where(emask_l, w_l, 0.0).astype(acc)
            vol_v = jax.lax.psum(jnp.sum(wm), axes)
            same = com[src_l] == com[dst_l]
            w_in = jax.lax.psum(
                jnp.sum(jnp.where(same, wm, jnp.zeros((), acc))), axes)
            deg = jax.lax.psum(
                jax.ops.segment_sum(wm, src_l, num_segments=n), axes)
            vol_c = jax.ops.segment_sum(deg, com, num_segments=n)
            safe = jnp.where(vol_v > 0, vol_v, jnp.ones((), vol_v.dtype))
            q = w_in / safe - jnp.sum((vol_c / safe) ** 2)
            return jnp.where(vol_v > 0, q,
                             jnp.zeros((), q.dtype)).astype(jnp.float32)

        def contiguize(com, vmask):
            """Two-phase contiguization ≡ ``aggregation.remap_communities``.

            Phase 1: every device scans ITS ``stride``-wide stripe of the
            presence bitmap and ranks its ids locally (one cumsum).
            Phase 2: one all_gather of the D stripe counts gives the
            exclusive prefix; local rank + stripe offset is the globally
            dense id, and a tiled all_gather of the stripe tables yields
            the replicated remap table.  All-int32 arithmetic — bitwise
            equal to the single-pass ``contiguize_ids`` on every mesh.
            """
            idx = jnp.clip(jnp.where(vmask, com, sentinel), 0, n)
            p = jnp.zeros((n + 1,), jnp.int32).at[idx].set(1)[:n]
            if n_pad_c:
                p = jnp.concatenate([p, jnp.zeros((n_pad_c,), jnp.int32)])
            p_d = jax.lax.dynamic_slice(p, (lo,), (stride,))
            counts = jax.lax.all_gather(
                jnp.sum(p_d), axes, tiled=False).reshape(-1)      # (D,)
            off_d = jnp.take(jnp.cumsum(counts) - counts, d)
            t_d = jnp.where(p_d == 1, off_d + jnp.cumsum(p_d) - 1, sentinel)
            table = jax.lax.all_gather(t_d, axes, tiled=True)[:n]
            n_comm = jnp.sum(counts)
            new_com = jnp.where(vmask, table[jnp.clip(com, 0, n - 1)],
                                sentinel)
            return new_com, n_comm

        def coarsen_by(gl, new_com, n_comm):
            if agg_method == "sort":
                return aggregation.coarsen_graph(gl, new_com, n_comm)
            return binned_coarsen(gl, new_com, n_comm,
                                  force_overflow=force_overflow)

        def aggregate_shard_local(a, n_valid, com, vmask, m_cap):
            """Partial per-shard coarsen → halo exchange → collective merge.

            Each device groups ONLY its owned edges (a disjoint cover of the
            level's edge list), ships the first ``h_cap`` partial groups,
            and every device merges the gathered lists with an identity-map
            groupby.  Weight sums are exact integers, and both groupby
            passes emit canonically ordered front-compacted groups, so the
            merged coarse graph is bitwise identical to the replicated
            single-pass oracle.  The collective payload is the contiguize
            table + D·h_cap partial groups — O(communities + cross-shard
            pairs), never O(m).
            """
            a_src, a_dst, a_w, a_own = a
            new_com, n_comm = contiguize(com, vmask)
            gl = Graph(src=a_src, dst=a_dst, w=a_w, edge_mask=a_own,
                       n_valid=n_valid,
                       m_valid=jnp.sum(a_own.astype(jnp.int32)),
                       n_max=n, m_max=m_cap, sorted_by=None)
            part = coarsen_by(gl, new_com, n_comm)
            over = jax.lax.psum(
                (part.m_valid > jnp.int32(h_cap)).astype(jnp.int32),
                axes) > 0
            pgroups = jax.lax.psum(
                jnp.minimum(part.m_valid, jnp.int32(h_cap)), axes)
            gs, gd, gw, gm = (gather(part.src[:h_cap]),
                              gather(part.dst[:h_cap]),
                              gather(part.w[:h_cap]),
                              gather(part.edge_mask[:h_cap]))
            g_part = Graph(src=gs, dst=gd, w=gw, edge_mask=gm,
                           n_valid=n_comm,
                           m_valid=jnp.sum(gm.astype(jnp.int32)),
                           n_max=n, m_max=m_c, sorted_by=None)
            cg = coarsen_by(g_part, arange_n, n_comm)
            return new_com, n_comm, cg, over, pgroups

        def aggregate_replicated(a, n_valid, com):
            """The parity oracle: identical redundant groupby per device."""
            a_src, a_dst, a_w, a_mask = a
            cur = Graph(src=a_src, dst=a_dst, w=a_w, edge_mask=a_mask,
                        n_valid=n_valid,
                        m_valid=jnp.sum(a_mask.astype(jnp.int32)),
                        n_max=n, m_max=m_c, sorted_by=None)
            new_com, n_comm, cg = aggregation.remap_and_coarsen_by(
                agg_method, cur, com, faults)
            n_comm = jax.lax.pmax(n_comm, axes)  # lockstep collective merge
            return new_com, n_comm, cg, jnp.bool_(False), jnp.int32(-1)

        def aggregate(a, n_valid, com, vmask, m_cap):
            if coarsening == "shard_local":
                return aggregate_shard_local(a, n_valid, com, vmask, m_cap)
            return aggregate_replicated(a, n_valid, com)

        def run_level(s, a, n_valid, level_u32, init_com, assign, m_cap):
            """One level: fused local-moving → (refine) → remap+coarsen.

            ``s`` = (src, dst, w, own) sweep arrays (always the local view);
            ``a`` = aggregation arrays at static capacity ``m_cap`` (the
            local shard under shard-local coarsening, the replicated list
            under the oracle).  Mirrors ``core.louvain``'s ``run_level``
            exactly; collectives make every branch run unconditionally,
            with the results dead (never consumed) once the level loop
            exits.
            """
            s_src, s_dst, s_w, s_own = s
            vmask = arange_n < n_valid
            it0 = level_u32 * jnp.uint32(LEVEL_IT_STRIDE)
            com, sweeps, dn_h = sweep(spec, s_src, s_dst, s_w, s_own, vmask,
                                      init_com, it0)
            if not refine:
                new_com, n_comm, cg, over, pgroups = aggregate(
                    a, n_valid, com, vmask, m_cap)
                macro = new_com[jnp.clip(assign, 0, n - 1)]
                assign2, init2, nv2 = macro, arange_n, n_comm
            else:
                # Leiden: macro remap only; aggregation groups by the
                # REFINED partition and the next level's local-moving is
                # seeded with each super-vertex's macro id
                if coarsening == "shard_local":
                    new_com, n_comm = contiguize(com, vmask)
                else:
                    new_com, n_comm = aggregation.remap_communities(
                        com, vmask)
                macro = new_com[jnp.clip(assign, 0, n - 1)]
                ref, _sw_r, _dn_r = sweep(
                    refine_spec, s_src, s_dst, s_w, s_own, vmask, arange_n,
                    it0 + jnp.uint32(REFINE_IT_OFFSET), restrict=com)
                new_ref, n_ref, cg, over, pgroups = aggregate(
                    a, n_valid, ref, vmask, m_cap)
                macro_of_ref = jax.ops.segment_max(
                    jnp.where(vmask, new_com, -1),
                    jnp.clip(new_ref, 0, n - 1), num_segments=n)
                init2 = jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32)
                assign2 = new_ref[jnp.clip(assign, 0, n - 1)]
                nv2 = n_ref
            done = n_comm == n_valid             # Alg. 3 l.6 convergence
            q = dist_q(macro) if track_modularity else jnp.float32(0.0)
            nown = cg.edge_mask & (cg.dst >= lo) & (cg.dst < hi)
            return (cg.src, cg.dst, cg.w, cg.edge_mask, nown, nv2, assign2,
                    init2, macro, sweeps, dn_h, n_comm, q, over, pgroups,
                    done)

        # ---- peeled level 0: sweep on the LOCAL edge-balanced shard
        s0 = (src_l, dst_l, w_l, emask_l)
        if coarsening == "replicated":
            # gather the shard ONCE into the replicated full-capacity list
            a0 = (gather(src_l), gather(dst_l), gather(w_l), gather(emask_l))
            m_cap0 = m_c
        else:
            a0, m_cap0 = s0, m_pad
        (csrc, cdst, cw, cmask, own, n_valid, assign, init_com, macro,
         sweeps0, dn0, n_comm0, q0, over, pg0, done) = run_level(
            s0, a0, n_valid0, jnp.uint32(0), arange_n, arange_n, m_cap0)

        mod_hist = jnp.full((max_levels,), jnp.nan, jnp.float32).at[0].set(q0)
        sweeps_hist = jnp.full((max_levels,), -1, jnp.int32).at[0].set(sweeps0)
        ncomm_hist = jnp.full((max_levels,), -1, jnp.int32).at[0].set(n_comm0)
        dn_hist = jnp.full((max_levels, max_sweeps), -1,
                           jnp.int32).at[0].set(dn0)
        pg_hist = jnp.full((max_levels,), -1, jnp.int32).at[0].set(pg0)

        # ---- coarse levels: merged (replicated) list, dst-range ownership
        def cond(c):
            level, done = c[0], c[1]
            return (level < max_levels) & (~done)

        def body(c):
            (level, _done, csrc, cdst, cw, cmask, own_l, n_valid, assign,
             init_com, _macro, mh, sh, nh, dh, ph, ov) = c
            amask = own_l if coarsening == "shard_local" else cmask
            (csrc2, cdst2, cw2, cmask2, own2, nv2, assign2, init2, macro2,
             sweeps, dn_h, n_comm, q, over2, pg, done2) = run_level(
                (csrc, cdst, cw, own_l), (csrc, cdst, cw, amask), n_valid,
                level.astype(jnp.uint32), init_com, assign, m_c)
            mh = mh.at[level].set(q)
            sh = sh.at[level].set(sweeps)
            nh = nh.at[level].set(n_comm)
            dh = dh.at[level].set(dn_h)
            ph = ph.at[level].set(pg)
            return (level + 1, done2, csrc2, cdst2, cw2, cmask2, own2, nv2,
                    assign2, init2, macro2, mh, sh, nh, dh, ph, ov | over2)

        carry = (jnp.int32(1), done, csrc, cdst, cw, cmask, own, n_valid,
                 assign, init_com, macro, mod_hist, sweeps_hist, ncomm_hist,
                 dn_hist, pg_hist, over)
        carry = jax.lax.while_loop(cond, body, carry)
        (levels, _, _, _, _, _, _, _, _, _, macro, mod_hist, sweeps_hist,
         ncomm_hist, dn_hist, pg_hist, overflow) = carry

        final, n_final = aggregation.remap_communities(
            macro, arange_n < n_valid0)
        q = dist_q(final)
        return (final, n_final, levels, q, sweeps_hist, ncomm_hist,
                mod_hist, dn_hist, pg_hist, overflow)

    sharded = shard_map_compat(
        worker, mesh,
        in_specs=(espec,) * 4 + (rspec,) * 2,
        out_specs=(rspec,) * 10,
    )
    return jax.jit(sharded)


def _resolve_halo_cap(halo_cap, m_pad: int, n_devices: int) -> int:
    cap = int(halo_cap) if halo_cap else pick_halo_cap(m_pad, n_devices)
    return min(cap, int(m_pad))


def distributed_louvain(
    g: Graph,
    mesh: Mesh,
    max_levels: int = 10,
    max_sweeps: int = 25,
    sweep_threshold: int = 0,
    seed: int = 0,
    move_prob: float = 0.5,
    singleton_rule: bool = True,
    pipeline_fused: bool = True,
    aggregation_method: str = "binned",
    coarsening: str = "shard_local",
    halo_cap: int | None = None,
    refine: bool = False,
    refine_sweeps: int = 8,
    track_modularity: bool = True,
) -> DistLouvainResult:
    """Distributed Louvain/Leiden driver (DESIGN.md §6).

    ``coarsening`` selects the fused pipeline's aggregation layout:
    ``"shard_local"`` (default — per-device partial coarsen + halo-capped
    collective merge) or ``"replicated"`` (the gather-then-replicate parity
    oracle).  Both are bit-identical; a shard whose partial coarse list
    overflows the static ``halo_cap`` flags the run and the driver retries
    replicated, recording the degradation in ``run_report``.  ``refine``
    enables Leiden refinement (fused pipeline only).
    """
    if coarsening not in COARSENING_MODES:
        raise ValueError(f"coarsening must be one of {COARSENING_MODES}, "
                         f"got {coarsening!r}")
    if refine and not pipeline_fused:
        raise ValueError("Leiden refinement (refine=True) requires "
                         "pipeline_fused=True")
    timer = Timer()
    n = g.n_max
    D = int(mesh.devices.size)
    faults = frozenset(faultinject.active())
    report = RunReport(faults=sorted(faults))
    promote = accum_needs_promotion(g.m_max)
    spec = EngineSpec(
        evaluator="louvain",
        backend="distributed",
        max_sweeps=max_sweeps,
        threshold=sweep_threshold,
        move_prob=move_prob,
        singleton_rule=singleton_rule,
        faults=_engine_faults(faults),
    )

    if pipeline_fused:
        with timer.phase("partition"):
            part = _prepare_partition(g, D)
            src, dst, w, emask = shard_edges(part, mesh)
            halo = build_halo(part)
            pq = partition_quality(part, halo)
        h_cap = _resolve_halo_cap(halo_cap, part.m_pad, D)
        used = coarsening
        rs = refine_sweeps if refine else 0
        pipe = make_distributed_pipeline(
            mesh, n, part.m_pad, spec, max_levels, aggregation_method,
            faults, used, h_cap, rs, track_modularity, promote)
        with timer.phase("pipeline"):
            out = pipe(src, dst, w, emask, jnp.uint32(seed), g.n_valid)
            (final, n_final, levels, q, sweeps_hist, ncomm_hist, mod_hist,
             dn_hist, pg_hist, overflow) = jax.device_get(out)  # ONE readback
        if bool(overflow) and used == "shard_local":
            # degradation ladder: a partial coarse list busted the halo cap
            # somewhere in the level loop — the merged graph may have lost
            # groups, so the whole answer is refused and re-run replicated
            telemetry.bump("dist.halo_overflow_retry")
            report.degradations.append({
                "kind": "halo_overflow", "from": "shard_local",
                "to": "replicated",
                "error": f"partial coarse list overflowed halo_cap={h_cap}"})
            used = "replicated"
            pipe = make_distributed_pipeline(
                mesh, n, part.m_pad, spec, max_levels, aggregation_method,
                faults, used, h_cap, rs, track_modularity, promote)
            with timer.phase("pipeline"):
                out = pipe(src, dst, w, emask, jnp.uint32(seed), g.n_valid)
                (final, n_final, levels, q, sweeps_hist, ncomm_hist,
                 mod_hist, dn_hist, pg_hist, overflow) = jax.device_get(out)
        levels = int(levels)
        sweeps_list = [int(x) for x in sweeps_hist[:levels]]
        gathered = [int(x) for x in pg_hist[:levels]]
        model = dist_comm_bytes_per_level(n, part.m_pad, h_cap, D)
        table_bytes = (n + D) * LABEL_WIRE_BYTES
        comm_stats = {
            "mode": used,
            "requested": coarsening,
            "n_devices": D,
            "m_pad": int(part.m_pad),
            "halo_cap": h_cap,
            "bytes_per_level_model": model,
            "gathered_groups_per_level": gathered,
            "actual_bytes_per_level": [
                (table_bytes + gct * EDGE_WIRE_BYTES) if gct >= 0
                else model["replicated"] for gct in gathered],
            "halo_labels": int(pq.total_ghosts),
        }
        return DistLouvainResult(
            labels=np.asarray(final),
            n_communities=int(n_final),
            levels=levels,
            modularity=float(q),
            timer=timer,
            sweeps_per_level=sweeps_list,
            n_comm_per_level=[int(x) for x in ncomm_hist[:levels]],
            modularity_history=([float(x) for x in mod_hist[:levels]]
                                if track_modularity else []),
            delta_n_per_level=[[int(x) for x in row[:s]]
                               for row, s in zip(dn_hist[:levels],
                                                 sweeps_list)],
            coarsening=used,
            partition_stats=dict(pq._asdict()),
            comm_stats=comm_stats,
            run_report=report,
        )

    from repro.core.louvain import LEVEL_IT_STRIDE

    g0 = g
    assign = jnp.arange(n, dtype=jnp.int32)
    cur = g
    levels = 0
    sweeps_per_level: list = []
    n_comm_per_level: list = []
    partition_stats: dict = {}

    phase = make_distributed_phase(mesh, n, spec)
    for level in range(max_levels):
        with timer.phase("partition"):
            # the coverage guard applies per level: each re-partition is a
            # fresh opportunity to lose a shard
            part = _prepare_partition(cur, D)
            src, dst, w, emask = shard_edges(part, mesh)
        if level == 0:
            partition_stats = dict(partition_quality(part)._asdict())
        com = jnp.arange(n, dtype=jnp.int32)
        need = cur.vertex_mask()
        with timer.phase("local_moving"):
            # one fused phase per level: while_loop inside the shard_map
            com, need, sweeps, _, _ = phase(
                src, dst, w, emask, com, need,
                jnp.uint32(level * LEVEL_IT_STRIDE), jnp.uint32(seed),
                cur.weighted_degrees(), cur.total_volume(), cur.n_valid,
            )
        sweeps_per_level.append(int(sweeps))
        with timer.phase("aggregation"):
            new_com, n_comm, coarse = aggregation.remap_and_coarsen_by(
                aggregation_method, cur, com, faults)
            n_comm_per_level.append(int(n_comm))
            done = int(n_comm) == int(cur.n_valid)
            if not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = coarse
        levels = level + 1
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign, promote=promote))
    return DistLouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        timer=timer,
        sweeps_per_level=sweeps_per_level,
        n_comm_per_level=n_comm_per_level,
        coarsening="per_level",
        partition_stats=partition_stats,
        run_report=report,
    )


def distributed_leiden(g: Graph, mesh: Mesh, **kwargs) -> DistLouvainResult:
    """Leiden = Louvain + the refinement phase between move and aggregate
    (fused distributed pipeline only) — mirrors ``core.louvain.leiden``."""
    kwargs.setdefault("refine", True)
    return distributed_louvain(g, mesh, **kwargs)
