"""The paper's primary contribution: parallel community detection.

* ``plp``      — Parallel Label Propagation (paper Alg. 1)
* ``louvain``  — Parallel Louvain: local-moving (Alg. 2) + aggregation (Alg. 3)
* ``modularity`` — §II-C metric + Eq. 1 move gain
* ``baselines`` — sequential/NetworkX comparison tier (paper §V)
* ``engine``   — unified device-resident sweep engine (DESIGN.md §Engine)
* ``distributed`` — shard_map multi-device variants (DESIGN.md §6)
"""
from repro.core.engine import EngineSpec, PhaseResult, SweepEngine
from repro.core.plp import PLPConfig, PLPResult, plp
from repro.core.louvain import LouvainConfig, LouvainResult, louvain, leiden
from repro.core.modularity import modularity, community_volumes, delta_q_from_score
from repro.core import aggregation, baselines

__all__ = [
    "EngineSpec",
    "PhaseResult",
    "SweepEngine",
    "leiden",
    "PLPConfig",
    "PLPResult",
    "plp",
    "LouvainConfig",
    "LouvainResult",
    "louvain",
    "modularity",
    "community_volumes",
    "delta_q_from_score",
    "aggregation",
    "baselines",
]
