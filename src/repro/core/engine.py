"""Unified device-resident sweep engine (DESIGN.md §Engine).

One abstraction replaces the six near-identical local-moving sweeps that used
to live in ``core/plp.py``, ``core/louvain.py`` and ``core/distributed.py``:

  evaluator  ×  backend
  ---------     -------
  ``plp``       ``segment``      sort + segment GroupBy over the edge list
  ``louvain``   ``ell``          degree-bucketed dense tiles (jnp oracle)
                ``pallas``       same tiles through the Pallas kernels
                ``distributed``  shard_map over edge-partitioned shards

An evaluator proposes moves — ``(proposal[n], propose[n])`` per vertex — and
the engine owns everything around it: the Luby move-probability coin, the
adopt/changed bookkeeping, ΔN accounting, and active-frontier propagation.

The per-level sweep loop is a ``jax.lax.while_loop`` with on-device
``ΔN ≤ threshold`` convergence, so an entire local-moving phase (all sweeps of
one level) is ONE jitted call: no per-sweep host round-trip, no per-sweep
dispatch.  Per-sweep ΔN / active-count histories are written into fixed-size
on-device buffers and read back once per phase.  Label/frontier buffers are
donated to the fused call on accelerator backends.

``fused=False`` drives the SAME step function from a Python loop (one jitted
call per sweep) — the stepwise reference used by the parity tests and the
``benchmarks`` fused-vs-stepwise comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core import moves
from repro.core.common import luby_move_gate, neighbor_or_self_changed
from repro.core.progcache import program_cache
from repro.graph.structure import Graph

# Per-evaluator Luby coin stream constants (kept distinct so PLP and Louvain
# draw decorrelated move coins; values match the original sweep code).
_GATE_CONST = {"plp": (0x85EBCA6B, 313), "louvain": (0x9E3779B1, 101)}

EVALUATORS = ("plp", "louvain")
BACKENDS = ("segment", "ell", "pallas", "distributed")


@dataclasses.dataclass(frozen=True)
class EngineSpec(ConfigBase):
    """Static (hashable) sweep configuration — the jit cache key.

    ``threshold``/``max_sweeps`` define the fused convergence contract: the
    loop runs while ``sweep < max_sweeps and ΔN > threshold``, evaluated
    on device.
    """

    evaluator: str = "plp"       # plp | louvain
    backend: str = "segment"     # segment | ell | pallas | distributed
    max_sweeps: int = 100
    threshold: int = 0           # paper's ΔN threshold θ
    tie_eps: float = 0.25        # PLP tie noise amplitude
    move_prob: float = 1.0       # Luby move gate (1.0 = pure Jacobi)
    use_frontier: bool = True    # paper's active-vertex optimization
    reshuffle_ties: bool = False # PLP: re-draw tie noise each sweep
    singleton_rule: bool = True  # Louvain: Lu et al. swap suppression
    # ell/pallas table layout (DESIGN.md §Kernels): VMEM-resident tables vs
    # per-row-block windowed streaming; "auto" resolves from the VMEM byte
    # budget (kernels.common) at trace time.
    table_mode: str = "auto"     # auto | resident | streamed
    # ell/pallas with NO host-built layout: rebuild a single-bucket ELL tile
    # of this static width per level inside the trace (the cascade's coarse
    # levels, DESIGN.md §Pipeline).  0 = host-built DeviceEll required.
    ell_width: int = 0
    # Armed fault-injection points relevant to the sweep trace (DESIGN.md
    # §Robustness): "oscillation" pins the reported ΔN above the threshold,
    # "vmem_starve" is read by the VMEM budget policy at trace time.  Part
    # of the spec BECAUSE the spec is the jit/lru_cache key — fault state
    # outside the key would let clean traces be reused under faults.
    faults: tuple = ()

    def __post_init__(self):
        from repro.kernels.common import TABLE_MODES
        from repro.utils.faultinject import FAULT_POINTS

        if self.evaluator not in EVALUATORS:
            raise ValueError(f"unknown evaluator {self.evaluator!r}")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.table_mode not in TABLE_MODES:
            raise ValueError(f"unknown table_mode {self.table_mode!r}")
        if any(f not in FAULT_POINTS for f in self.faults):
            raise ValueError(f"unknown fault point(s) in {self.faults!r}")
        if self.ell_width < 0:
            raise ValueError(f"ell_width must be >= 0, got {self.ell_width}")
        if self.ell_width > 0 and self.backend not in ("ell", "pallas"):
            raise ValueError(
                "ell_width (traced re-bucketing) requires the ell or pallas "
                f"backend, not {self.backend!r}")


@dataclasses.dataclass
class PhaseResult:
    """Result of one local-moving phase (all sweeps of one level)."""

    labels: jax.Array            # device-resident — no forced host copy
    active: jax.Array
    sweeps: int
    delta_n_history: list
    active_history: list


# ----------------------------------------------------------------- evaluators


def _evaluate_segment(spec: EngineSpec, g: Graph, labels, active, it, seed,
                      restrict):
    """Sort+segment evaluator over the full (single-device) edge list."""
    n = g.n_max
    valid = g.edge_mask & active[jnp.clip(g.dst, 0, n - 1)]
    if spec.evaluator == "plp":
        noise_it = it if spec.reshuffle_ties else jnp.uint32(0)
        best_score, best_lab, cur_score = moves.plp_best_labels(
            g.src, g.dst, g.w, valid, labels, n, noise_it, seed, spec.tie_eps
        )
        propose = active & (best_lab >= 0) & (best_score > cur_score)
        return best_lab, propose

    vmask = g.vertex_mask()
    deg = g.weighted_degrees()              # loop-invariant: hoisted by XLA
    vol_v = g.total_volume()
    vol_com, size_com = moves.community_aux(labels, deg, vmask, n)
    if restrict is not None:
        # Leiden refinement: moves never leave the enclosing macro community
        same_macro = (restrict[jnp.clip(g.src, 0, n - 1)]
                      == restrict[jnp.clip(g.dst, 0, n - 1)])
        valid = valid & same_macro
    best_gain, best_cand = moves.louvain_best_moves(
        g.src, g.dst, g.w, valid, labels, deg, vol_com, size_com, vol_v, n,
        singleton_rule=spec.singleton_rule,
    )
    propose = vmask & active & (best_cand >= 0) & (best_gain > 0.0)
    return best_cand, propose


def _grid_propose(ell, active, n: int, eval_bucket):
    """Shared ELL bucket plumbing: run ``eval_bucket(rows, nbr, w, windows)
    -> (best[R], propose[R])`` once per degree bucket over ALL of its chunks
    at a time (one Pallas grid dispatch on the pallas backend, one vectorized
    jnp call on the ell backend — no lax.scan chain), scattering per-row
    proposals into per-vertex arrays.  Slot n is the write sink for padding /
    non-proposing rows, so real rows (unique across buckets) never collide.
    ``windows`` is the bucket's table-window metadata for the streamed
    (beyond-VMEM) table layout — see DESIGN.md §Kernels."""
    from repro.graph.ell import grid_view

    proposal_ext = jnp.full((n + 1,), -1, jnp.int32)
    propose_ext = jnp.zeros((n + 1,), bool)
    for b in ell.buckets:
        if b.n_rows_valid == 0:
            continue  # statically empty bucket: pure-padding tiles, no work
        rows, nbr, w = grid_view(b)
        best, good = eval_bucket(rows, nbr, w, b.windows)
        row_ok = (rows < n) & active[jnp.clip(rows, 0, n - 1)]
        row_prop = row_ok & good
        idx = jnp.where(row_prop, jnp.clip(rows, 0, n - 1), n)
        proposal_ext = proposal_ext.at[idx].set(jnp.where(row_prop, best, -1))
        propose_ext = propose_ext.at[idx].set(row_prop)
    return proposal_ext[:n], propose_ext[:n]


def _ell_evaluators(spec: EngineSpec, g: Graph, labels, it, seed,
                    use_pallas: bool, table_mode: str):
    """Per-sweep closure pair ``(eval_bucket, eval_tail)`` shared by the
    host-built bucket evaluator and the traced coarse-level evaluator.

    The per-vertex tables (labels for PLP; community/volume/size/degree for
    Louvain) are built ONCE here per sweep; ``eval_bucket(rows, nbr, w,
    windows)`` hands them whole to the ``local_move`` kernel family (gathers
    in-kernel), ``eval_tail(src, dst, w, valid) -> (best[n], good[n])``
    scores an edge list off the SAME extended tables (``moves.*_tables``)."""
    from repro.kernels.local_move import ops as lm_ops

    n = g.n_max

    if spec.evaluator == "plp":
        labels_ext = jnp.concatenate([labels, jnp.int32([n])])
        noise_it = it if spec.reshuffle_ties else jnp.uint32(0)
        noise_seed = seed.astype(jnp.uint32) + noise_it

        def eval_bucket(rows, nbr, w, windows):
            return lm_ops.local_move_plp(
                rows, nbr, w, labels_ext, noise_seed,
                tie_eps=spec.tie_eps, sentinel=n, use_pallas=use_pallas,
                windows=windows, table_mode=table_mode,
            )

        def eval_tail(tail_src, tail_dst, tail_w, valid_t):
            best_score, best_lab, cur_score = moves.plp_best_labels_tables(
                tail_src, tail_dst, tail_w, valid_t, labels_ext,
                n, noise_it, seed, spec.tie_eps,
            )
            return best_lab, (best_lab >= 0) & (best_score > cur_score)

    else:  # louvain
        vmask = g.vertex_mask()
        deg = g.weighted_degrees()
        vol_v = g.total_volume()
        vol_com, size_com = moves.community_aux(labels, deg, vmask, n)
        com_ext = jnp.concatenate([labels, jnp.int32([n])])
        vol_ext = jnp.concatenate([vol_com, jnp.zeros((1,), vol_com.dtype)])
        size_ext = jnp.concatenate([size_com, jnp.zeros((1,), size_com.dtype)])
        deg_ext = jnp.concatenate([deg, jnp.zeros((1,), deg.dtype)])
        # per-VERTEX composed tables, built ONCE per sweep and shared by
        # every bucket dispatch (ref.compose_louvain_tables)
        composed = lm_ops.compose_louvain_tables(
            com_ext, vol_ext.astype(jnp.float32), size_ext,
            deg_ext.astype(jnp.float32), n)

        def eval_bucket(rows, nbr, w, windows):
            return lm_ops.local_move_louvain(
                rows, nbr, w, com_ext, vol_ext, size_ext, deg_ext, vol_v,
                sentinel=n, singleton_rule=spec.singleton_rule,
                use_pallas=use_pallas,
                windows=windows, table_mode=table_mode,
                composed=composed,
            )

        def eval_tail(tail_src, tail_dst, tail_w, valid_t):
            best_gain, best_cand = moves.louvain_best_moves_tables(
                tail_src, tail_dst, tail_w, valid_t,
                com_ext, vol_ext, size_ext, deg_ext, vol_v, n,
                singleton_rule=spec.singleton_rule,
            )
            return best_cand, vmask & (best_cand >= 0) & (best_gain > 0.0)

    return eval_bucket, eval_tail


def _evaluate_ell(spec: EngineSpec, g: Graph, ell, labels, active, it, seed,
                  use_pallas: bool):
    """Degree-bucketed fused-gather evaluator (DESIGN.md §Kernels) over a
    host-built ``DeviceEll``; ``spec.table_mode`` picks VMEM-resident tables
    vs per-row-block windowed streaming.  ``ell`` routes through the
    pure-jnp oracle, ``pallas`` through the fused kernel.  Tail
    (above-widest-bucket) vertices go through the tables tail evaluator on
    the pre-extracted tail edges — the tail's per-sweep lexsort result is
    scored off the one shared per-sweep table build."""
    n = g.n_max
    eval_bucket, eval_tail = _ell_evaluators(
        spec, g, labels, it, seed, use_pallas, spec.table_mode)
    proposal, propose = _grid_propose(ell, active, n, eval_bucket)
    if ell.has_tail:
        valid_t = ((ell.tail_src < n) & (ell.tail_dst < n)
                   & active[jnp.clip(ell.tail_dst, 0, n - 1)])
        best, good = eval_tail(ell.tail_src, ell.tail_dst, ell.tail_w,
                               valid_t)
        tail_prop = ell.is_tail & active & good
        proposal = jnp.where(tail_prop, best, proposal)
        propose = propose | tail_prop
    return proposal, propose


def _evaluate_ell_traced(spec: EngineSpec, g: Graph, tile, labels, active,
                         it, seed):
    """Coarse-level fused-kernel evaluator with NO host-built layout
    (DESIGN.md §Pipeline): the ELL tile is re-bucketed from the src-sorted
    coarse edge list inside the trace (``graph/ell.traced_ell_tile``,
    hoisted to ``make_step`` so one level's sweeps share a single build) at
    the static per-stage width ``spec.ell_width``, then scored through the
    SAME ``local_move`` kernel family as level 0 (``ell`` = jnp oracle,
    ``pallas`` = fused kernel).  Rows are vertex-aligned, so the bucket
    scatter of ``_grid_propose`` reduces to a ``where``.  Vertices wider
    than the tile fall back to the tables tail evaluator over the FULL edge
    list, gated by ``lax.cond`` so hub-free levels skip the per-sweep sort
    entirely.  Tables are forced resident: coarse tables are small by
    construction and streaming needs host-side window metadata."""
    n = g.n_max
    rows, nbr, w_t, is_tail = tile
    eval_bucket, eval_tail = _ell_evaluators(
        spec, g, labels, it, seed, use_pallas=(spec.backend == "pallas"),
        table_mode="resident")
    best, good = eval_bucket(rows, nbr, w_t, None)
    row_prop = (rows < n) & active & good
    proposal = jnp.where(row_prop, best, -1)
    propose = row_prop

    def with_tail(args):
        proposal, propose = args
        dstc = jnp.clip(g.dst, 0, n - 1)
        valid_t = g.edge_mask & is_tail[dstc] & active[dstc]
        best_t, good_t = eval_tail(g.src, g.dst, g.w, valid_t)
        tail_prop = is_tail & active & good_t
        return jnp.where(tail_prop, best_t, proposal), propose | tail_prop

    return jax.lax.cond(jnp.any(is_tail), with_tail, lambda args: args,
                        (proposal, propose))


# ----------------------------------------------------------------- step / loop


def make_step(spec: EngineSpec, g: Graph, ell, restrict):
    """Build the shared sweep step: evaluate → gate → adopt → frontier."""
    n = g.n_max
    mult, salt = _GATE_CONST[spec.evaluator]
    tile = None
    if spec.backend != "segment" and ell is None and spec.ell_width > 0:
        from repro.graph.ell import traced_ell_tile

        # loop-invariant within a level: built once per phase, shared by
        # every sweep of the fused while_loop
        tile = traced_ell_tile(g, spec.ell_width)

    def step(labels, active, it, seed):
        if spec.backend == "segment":
            proposal, propose = _evaluate_segment(
                spec, g, labels, active, it, seed, restrict)
        elif tile is not None:
            proposal, propose = _evaluate_ell_traced(
                spec, g, tile, labels, active, it, seed)
        else:
            proposal, propose = _evaluate_ell(
                spec, g, ell, labels, active, it, seed,
                use_pallas=(spec.backend == "pallas"))
        adopt = propose
        if spec.move_prob < 1.0:
            adopt = adopt & luby_move_gate(n, it, seed, spec.move_prob, mult, salt)
        new_labels = jnp.where(adopt, proposal, labels)
        changed = adopt & (new_labels != labels)
        delta_n = jnp.sum(changed.astype(jnp.int32))
        if "oscillation" in spec.faults:
            # fault injection: the convergence signal never reports a
            # fixpoint (two vertices trading labels forever, Lu &
            # Halappanavar §4).  Labels and frontier are NOT perturbed —
            # only the reported ΔN — so the phase runs to the max_sweeps
            # watchdog bound and, at move_prob=1.0, returns bit-identical
            # labels (a Jacobi fixpoint re-sweeps to itself).
            delta_n = jnp.maximum(delta_n, jnp.int32(spec.threshold) + 1)
        if spec.use_frontier:
            next_active = neighbor_or_self_changed(g, changed)
        else:
            next_active = g.vertex_mask()
        return new_labels, next_active, delta_n

    return step


def phase_loop(step, labels, active, it0, seed, max_sweeps: int, threshold: int):
    """The fused convergence loop: run ``step`` until ΔN ≤ threshold or the
    sweep budget is exhausted, entirely on device.  Returns
    (labels, active, sweeps, dn_hist[max_sweeps], act_hist[max_sweeps])."""

    def cond(carry):
        s, dn, _, _, _, _ = carry
        return (s < jnp.uint32(max_sweeps)) & (dn > jnp.int32(threshold))

    def body(carry):
        s, _, labels, active, dn_hist, act_hist = carry
        labels, active, dn = step(labels, active, it0 + s, seed)
        dn_hist = dn_hist.at[s].set(dn)
        act_hist = act_hist.at[s].set(jnp.sum(active.astype(jnp.int32)))
        return s + jnp.uint32(1), dn, labels, active, dn_hist, act_hist

    init = (
        jnp.uint32(0),
        jnp.int32(threshold) + jnp.int32(1),
        labels,
        active,
        jnp.full((max_sweeps,), -1, jnp.int32),
        jnp.full((max_sweeps,), -1, jnp.int32),
    )
    s, _, labels, active, dn_hist, act_hist = jax.lax.while_loop(cond, body, init)
    return labels, active, s, dn_hist, act_hist


def device_phase(spec: EngineSpec, g: Graph, ell, labels, active, it0, seed,
                 restrict=None):
    """Trace one fused local-moving phase for embedding in a LARGER jitted
    program (e.g. the multi-level pipeline, DESIGN.md §Pipeline).

    Must be called under an enclosing trace/jit; returns the raw loop outputs
    ``(labels, active, sweeps, dn_hist, act_hist)`` with everything device-
    resident.  ``SweepEngine.run_phase`` is the standalone-dispatch wrapper
    around the same loop.
    """
    step = make_step(spec, g, ell, restrict)
    return phase_loop(step, labels, active, it0, seed,
                      spec.max_sweeps, spec.threshold)


def _donate_labels() -> bool:
    """Buffer donation for the label/frontier arrays in the fused call.

    Skipped on CPU, where XLA does not implement donation (the warning would
    drown test output); on TPU/GPU the phase reuses the input buffers."""
    return jax.default_backend() != "cpu"


@program_cache("engine.fused_phase", maxsize=128)
def _fused_phase_fn(spec: EngineSpec, donate: bool):
    def phase(g, ell, labels, active, it0, seed, restrict):
        return device_phase(spec, g, ell, labels, active, it0, seed, restrict)

    return jax.jit(phase, donate_argnums=(2, 3) if donate else ())


@program_cache("engine.step", maxsize=128)
def _step_fn(spec: EngineSpec):
    def one_sweep(g, ell, labels, active, it, seed, restrict):
        return make_step(spec, g, ell, restrict)(labels, active, it, seed)

    return jax.jit(one_sweep)


# ----------------------------------------------------------------- engine


class SweepEngine:
    """Local-moving sweep engine for one graph (one coarsening level).

    >>> eng = SweepEngine(g, EngineSpec(evaluator="plp", max_sweeps=50))
    >>> res = eng.run_phase(*eng.singleton_state(), seed=0)
    """

    def __init__(self, g: Graph, spec: EngineSpec, ell=None):
        if spec.backend == "distributed":
            raise ValueError(
                "use make_distributed_phase() for the distributed backend")
        self.g = g
        self.spec = spec
        self.ell = None
        if spec.backend in ("ell", "pallas") and spec.ell_width == 0:
            from repro.graph import ell as ell_mod

            if ell is None:
                ell = ell_mod.build_device_ell(g)
            elif isinstance(ell, ell_mod.EllGraph):
                ell = ell_mod.to_device(g, ell)
            self.ell = ell

    def singleton_state(self) -> Tuple[jax.Array, jax.Array]:
        """(labels, active): singleton init + full active set (Alg. 1 l.4-5)."""
        return (jnp.arange(self.g.n_max, dtype=jnp.int32),
                self.g.vertex_mask())

    def run_phase(
        self,
        labels: jax.Array,
        active: jax.Array,
        *,
        it0: int = 0,
        seed: int = 0,
        restrict: Optional[jax.Array] = None,
        fused: bool = True,
    ) -> PhaseResult:
        """Run one local-moving phase to convergence.

        fused=True:  ONE jitted lax.while_loop call; the only host transfer
                     is reading back (sweeps, ΔN history, active history).
        fused=False: stepwise reference — the same step function driven from
                     Python, one jitted call + one ΔN transfer per sweep.
        """
        spec = self.spec
        if restrict is not None and spec.backend != "segment":
            raise ValueError(
                "restrict (Leiden macro confinement) is only implemented for "
                f"the segment backend, not {spec.backend!r}")
        it0_a = jnp.uint32(it0)
        seed_a = jnp.uint32(seed)
        if fused:
            phase = _fused_phase_fn(spec, _donate_labels())
            labels, active, s, dn_hist, act_hist = phase(
                self.g, self.ell, labels, active, it0_a, seed_a, restrict)
            s, dn_hist, act_hist = jax.device_get((s, dn_hist, act_hist))
            s = int(s)
            return PhaseResult(labels, active, s,
                               [int(x) for x in dn_hist[:s]],
                               [int(x) for x in act_hist[:s]])

        step = _step_fn(spec)
        dn_hist, act_hist = [], []
        s = 0
        while s < spec.max_sweeps:
            labels, active, dn = step(
                self.g, self.ell, labels, active, it0_a + jnp.uint32(s),
                seed_a, restrict)
            dn = int(dn)
            dn_hist.append(dn)
            act_hist.append(int(jnp.sum(active.astype(jnp.int32))))
            s += 1
            if dn <= spec.threshold:
                break
        return PhaseResult(labels, active, s, dn_hist, act_hist)


# ----------------------------------------------------------------- distributed


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (check_vma vs check_rep spelling)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_distributed_step(spec: EngineSpec, axes, n: int, src, dst, w, emask,
                          deg, vol_v, vmask, restrict=None):
    """Build one sweep step over a LOCAL edge shard (for use inside a
    shard_map worker): evaluate on local in-edges, psum-merge the disjoint
    per-owner proposals, gate, adopt, frontier.

    ``emask`` is the per-device ownership mask: every vertex's in-edges must
    be owned by exactly one device (dst-disjoint ownership), so the psum
    merge is a pure union.  ``deg``/``vol_v`` are the per-level Louvain
    invariants (ignored by PLP).  ``restrict`` (replicated int32[n] or None)
    confines Louvain moves to vertices sharing its value — the Leiden
    refinement mask, mirroring ``_evaluate_segment``.  Reused by both the
    per-level distributed phase and the fused multi-level pipeline
    (DESIGN.md §Pipeline).
    """
    mult, salt = _GATE_CONST[spec.evaluator]

    def evaluate(labels, active, it, seed):
        valid = emask & active[jnp.clip(dst, 0, n - 1)]
        if spec.evaluator == "plp":
            noise_it = it if spec.reshuffle_ties else jnp.uint32(0)
            best_score, best_lab, cur_score = moves.plp_best_labels(
                src, dst, w, valid, labels, n, noise_it, seed, spec.tie_eps)
            propose_l = active & (best_lab >= 0) & (best_score > cur_score)
            proposal_l = best_lab
        else:
            # replicated O(n) recompute — identical on all devices, no comm
            vol_com, size_com = moves.community_aux(labels, deg, vmask, n)
            if restrict is not None:
                same_macro = (restrict[jnp.clip(src, 0, n - 1)]
                              == restrict[jnp.clip(dst, 0, n - 1)])
                valid = valid & same_macro
            best_gain, best_cand = moves.louvain_best_moves(
                src, dst, w, valid, labels, deg, vol_com, size_com, vol_v,
                n, singleton_rule=spec.singleton_rule)
            propose_l = active & (best_cand >= 0) & (best_gain > 0.0)
            proposal_l = best_cand
        # disjoint-owner merge: every vertex's in-edges live on one device
        merged = jax.lax.psum(
            jnp.where(propose_l, proposal_l, 0).astype(jnp.int32), axes)
        propose = jax.lax.psum(propose_l.astype(jnp.int32), axes) > 0
        return jnp.where(propose, merged, -1), propose

    def frontier(changed):
        contrib = jnp.where(
            emask, changed[jnp.clip(src, 0, n - 1)].astype(jnp.int32), 0)
        nbr_local = jax.ops.segment_sum(
            contrib, jnp.clip(dst, 0, n - 1), num_segments=n)
        return changed | (jax.lax.psum(nbr_local, axes) > 0)

    def step(labels, active, it, seed):
        proposal, propose = evaluate(labels, active, it, seed)
        adopt = propose
        if spec.move_prob < 1.0:
            adopt = adopt & luby_move_gate(
                n, it, seed, spec.move_prob, mult, salt)
        new_labels = jnp.where(adopt, proposal, labels)
        changed = adopt & (new_labels != labels)
        delta_n = jnp.sum(changed.astype(jnp.int32))
        next_active = frontier(changed) if spec.use_frontier else vmask
        return new_labels, next_active, delta_n

    return step


@program_cache("engine.distributed_phase", maxsize=32)
def make_distributed_phase(mesh, n: int, spec: EngineSpec):
    """Build the jitted fused phase for edge-partitioned shards.

    The while_loop runs INSIDE the shard_map worker: small O(n) state is
    replicated, each sweep psum-merges the disjoint per-owner proposals, and
    the convergence predicate is evaluated on the replicated ΔN — identical
    on every device, so the loop exits in lockstep with zero host syncs.

    Returns ``phase(src, dst, w, emask, labels, active, it0, seed, deg,
    vol_v, n_valid) -> (labels, active, sweeps, dn_hist, act_hist)``.
    ``deg``/``vol_v`` are the per-level Louvain invariants (ignored by PLP).
    Cached per (mesh, n, spec) so repeated driver calls reuse the compiled
    phase instead of retracing a fresh closure.
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    espec, rspec = P(axes), P()

    def worker(src, dst, w, emask, labels, active, it0, seed, deg, vol_v,
               n_valid):
        src, dst, w, emask = src[0], dst[0], w[0], emask[0]
        vmask = jnp.arange(n, dtype=jnp.int32) < n_valid
        step = make_distributed_step(
            spec, axes, n, src, dst, w, emask, deg, vol_v, vmask)
        return phase_loop(step, labels, active, it0, seed,
                          spec.max_sweeps, spec.threshold)

    sharded = shard_map_compat(
        worker, mesh,
        in_specs=(espec,) * 4 + (rspec,) * 7,
        out_specs=(rspec,) * 5,
    )
    return jax.jit(sharded)
