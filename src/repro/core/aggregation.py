"""Louvain aggregation phase (paper Alg. 3 l.13-17, §III-B2) — jit-native.

Steps, exactly as the paper describes, re-expressed for XLA:
  1. *Remap* community IDs to a contiguous [0, n_comm) range
     (sort + run-detect + scatter — Arkouda ``GroupBy`` keys);
  2. *Rewrite* edge endpoints through the remap;
  3. *Merge* parallel edges with weight summation
     (``GroupBy((src,dst)).sum(w)`` + ``Broadcast`` ≙ ``groupby_sum``).

Intra-community edges collapse onto self-loops whose (single, doubled) weight
equals the directed intra weight — preserving vol/deg/modularity invariants
(see tests/test_louvain.py::test_coarsen_preserves_modularity).

All outputs reuse the level-0 static capacities (n_max, m_max) with masks, so
every coarsening level runs under the same compiled program.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph import segment as seg
from repro.graph.structure import Graph


@jax.jit
def remap_communities(com: jax.Array, vertex_mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Contiguize community ids.

    Returns (new_com, n_comm): ``new_com[v] ∈ [0, n_comm)`` for valid v,
    ``n_max`` sentinel for invalid v.  Ordering is by old community id
    (deterministic).
    """
    n = com.shape[0]
    sentinel = jnp.int32(n)
    key = jnp.where(vertex_mask, com, sentinel)
    (sk,), (pidx,) = seg.sort_by_keys((key,), (jnp.arange(n, dtype=jnp.int32),))
    starts_all = seg.run_starts(sk)
    rid = seg.run_ids(starts_all)
    n_comm = jnp.sum((starts_all & (sk < sentinel)).astype(jnp.int32))
    new_com = jnp.zeros((n,), jnp.int32).at[pidx].set(rid)
    new_com = jnp.where(vertex_mask, new_com, sentinel)
    return new_com, n_comm


@jax.jit
def coarsen_graph(g: Graph, new_com: jax.Array, n_comm: jax.Array) -> Graph:
    """Build the super-vertex graph for contiguous community ids ``new_com``."""
    n, m = g.n_max, g.m_max
    sentinel = jnp.int32(n)
    csrc = jnp.where(g.edge_mask, new_com[jnp.clip(g.src, 0, n - 1)], sentinel)
    cdst = jnp.where(g.edge_mask, new_com[jnp.clip(g.dst, 0, n - 1)], sentinel)
    w = jnp.where(g.edge_mask, g.w, 0.0)
    (gk, gs, gvalid, n_groups) = seg.groupby_sum((csrc, cdst), w, valid=g.edge_mask)
    gsrc, gdst = gk
    grp_ok = gvalid & (gsrc < sentinel)
    return Graph(
        src=jnp.where(grp_ok, gsrc, sentinel),
        dst=jnp.where(grp_ok, gdst, sentinel),
        w=jnp.where(grp_ok, gs, 0.0),
        edge_mask=grp_ok,
        n_valid=n_comm.astype(jnp.int32),
        m_valid=jnp.sum(grp_ok.astype(jnp.int32)),
        n_max=n,
        m_max=m,
        sorted_by="src",
    )
