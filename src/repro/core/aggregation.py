"""Louvain aggregation phase (paper Alg. 3 l.13-17, §III-B2) — jit-native.

Steps, exactly as the paper describes, re-expressed for XLA:
  1. *Remap* community IDs to a contiguous [0, n_comm) range
     (sort + run-detect + scatter — Arkouda ``GroupBy`` keys);
  2. *Rewrite* edge endpoints through the remap;
  3. *Merge* parallel edges with weight summation
     (``GroupBy((src,dst)).sum(w)`` + ``Broadcast`` ≙ ``groupby_sum``).

Intra-community edges collapse onto self-loops whose (single, doubled) weight
equals the directed intra weight — preserving vol/deg/modularity invariants
(see tests/test_louvain.py::test_coarsen_preserves_modularity).

Outputs keep static capacities with masks, so every coarsening level runs
under one compiled program per capacity.  Three coarsening paths exist:

* ``remap_and_coarsen_binned`` (default in both louvain drivers, via the
  ``remap_and_coarsen_by`` dispatch): NO sort anywhere — the sort-free
  invariant of DESIGN.md §Pipeline.  The remap is a presence bitmap +
  ``cumsum`` (``graph/segment.py contiguize_ids``) and the parallel-edge
  merge scatter-accumulates weights into dense per-src-community bin rows
  (``kernels/aggregation``), with a ``lax.cond``-gated fallback onto the
  one-sort path for rows over the static bin width.
* ``remap_and_coarsen`` (``LouvainConfig.aggregation="sort"``): steps 1-3
  fused into ONE ``lax.sort`` over the combined (m edges + n vertices)
  entry list — the retired default, kept as the binned path's parity
  ORACLE.  Vertex entries (sorted ahead of their community's edges via a
  -1 dst key) enumerate the contiguous ids; edge runs are grouped, summed
  and scatter-compacted off the SAME sorted order.
* ``remap_communities_sorted`` + ``coarsen_graph``: the two-step reference
  path (one n-sort + one m-sort), the original oracle.

All three produce bit-for-bit identical coarse graphs, including the
unspecified-slot conventions (tests/test_aggregation.py), so
``shrink_graph`` and the cascade boundary sync are agnostic to the path.

``shrink_graph`` compacts a coarsened graph into smaller static capacities
for the capacity-scheduled cascade (DESIGN.md §Pipeline): coarsening output
is front-compacted and src-sorted by construction, so the capacity change is
a static slice + sentinel rewrite, entirely on device.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph import segment as seg
from repro.graph.structure import Graph
from repro.kernels.aggregation import binned_coarsen

AGGREGATION_METHODS = ("binned", "sort")


@jax.jit
def remap_communities(com: jax.Array, vertex_mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Contiguize community ids — sort-free.

    Presence bitmap + ``cumsum`` (``graph/segment.py contiguize_ids``); the
    historical sorted version survives as ``remap_communities_sorted`` and
    the two agree bitwise (tests/test_aggregation.py).

    Returns (new_com, n_comm): ``new_com[v] ∈ [0, n_comm)`` for valid v,
    ``n_max`` sentinel for invalid v.  Ordering is by old community id
    (deterministic).
    """
    n = com.shape[0]
    sentinel = jnp.int32(n)
    table, n_comm = seg.contiguize_ids(com, vertex_mask, n)
    new_com = jnp.where(vertex_mask, table[jnp.clip(com, 0, n - 1)], sentinel)
    return new_com, n_comm


@jax.jit
def remap_communities_sorted(com: jax.Array, vertex_mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sorted contiguize oracle (the pre-sort-free ``remap_communities``):
    one n-sort + run-detect + scatter, Arkouda ``GroupBy`` keys."""
    n = com.shape[0]
    sentinel = jnp.int32(n)
    key = jnp.where(vertex_mask, com, sentinel)
    (sk,), (pidx,) = seg.sort_by_keys((key,), (jnp.arange(n, dtype=jnp.int32),))
    starts_all = seg.run_starts(sk)
    rid = seg.run_ids(starts_all)
    n_comm = jnp.sum((starts_all & (sk < sentinel)).astype(jnp.int32))
    new_com = jnp.zeros((n,), jnp.int32).at[pidx].set(rid)
    new_com = jnp.where(vertex_mask, new_com, sentinel)
    return new_com, n_comm


@jax.jit
def remap_and_coarsen(
    g: Graph, com: jax.Array
) -> Tuple[jax.Array, jax.Array, Graph]:
    """Fused remap + coarsen: ONE ``lax.sort`` per aggregation.

    Equivalent to ``remap_communities`` followed by ``coarsen_graph`` —
    bit-for-bit, including unspecified-slot conventions — but the standalone
    vertex-side sort is folded into the edge-grouping sort: the combined
    (m + n)-entry list carries one entry per edge keyed by its RAW
    (com[src], com[dst]) pair and one entry per vertex keyed by
    (com[v], -1), so within each source community the vertex entries sort
    first.  Runs of the first key enumerate communities in ascending raw-id
    order (every valid community owns at least one vertex entry), which is
    exactly ``remap_communities``'s ordering; because the raw→contiguous map
    is monotone, edge runs also appear in the two-step path's group order,
    so group sums accumulate in the same element order (bitwise-equal
    floats) and the scatter compaction lands them in the same slots.

    Returns ``(new_com, n_comm, coarse_graph)``.
    """
    n, m = g.n_max, g.m_max
    sentinel = jnp.int32(n)
    vmask = g.vertex_mask()
    com_c = jnp.clip(com, 0, n - 1)

    # combined entry list: m edge entries then n vertex entries
    flag = jnp.concatenate([
        jnp.where(g.edge_mask, 0, 1),
        jnp.where(vmask, 0, 1),
    ]).astype(jnp.int32)
    a = jnp.concatenate([
        jnp.where(g.edge_mask, com_c[jnp.clip(g.src, 0, n - 1)], sentinel),
        jnp.where(vmask, com, sentinel),
    ]).astype(jnp.int32)
    b = jnp.concatenate([
        jnp.where(g.edge_mask, com_c[jnp.clip(g.dst, 0, n - 1)], sentinel),
        jnp.full((n,), -1, jnp.int32),          # vertices ahead of edges
    ])
    wv = jnp.concatenate([
        jnp.where(g.edge_mask, g.w, 0.0),
        jnp.zeros((n,), g.w.dtype),
    ])
    payload = jnp.concatenate([
        jnp.full((m,), n, jnp.int32),           # edge entries: sink id
        jnp.arange(n, dtype=jnp.int32),         # vertex entries: vertex id
    ])
    (sflag, sa, sb), (sw, spay) = seg.sort_by_keys((flag, a, b), (wv, payload))
    svalid = sflag == 0
    is_vtx = sb == jnp.int32(-1)
    total = m + n

    # community enumeration: runs of (flag, a); the j-th valid run is the
    # j-th distinct live community in ascending raw-id order
    a_starts = seg.run_starts(sflag, sa)
    a_rid = seg.run_ids(a_starts)
    n_comm = jnp.sum((a_starts & svalid).astype(jnp.int32))

    # new_com per vertex: scatter each vertex entry's community run id back
    # to its vertex slot (slot n is the sink for non-vertex entries)
    vpos = jnp.where(svalid & is_vtx, spay, n)
    new_com = (jnp.full((n + 1,), sentinel, jnp.int32)
               .at[vpos].set(a_rid)[:n])
    new_com = jnp.where(vmask, new_com, sentinel)
    # raw community id -> contiguous id table (for the dst rewrite); every
    # valid raw id is written (identically) by each of its vertex entries
    vkey = jnp.where(svalid & is_vtx, sa, n)
    raw2new = (jnp.full((n + 1,), sentinel, jnp.int32)
               .at[vkey].set(a_rid))

    # edge grouping: runs of (flag, a, b) restricted to valid edge entries
    starts_all = seg.run_starts(sflag, sa, sb)
    rid = seg.run_ids(starts_all)
    sums = jax.ops.segment_sum(
        jnp.where(svalid & ~is_vtx, sw, 0.0), rid, num_segments=total)
    e_starts = starts_all & svalid & (~is_vtx)
    e_rid = jnp.cumsum(e_starts.astype(jnp.int32)) - 1
    n_groups = jnp.sum(e_starts.astype(jnp.int32))

    # scatter-compact group representatives to the front (graph/segment.py's
    # run-detect/scatter machinery, no second sort); slots >= n_groups are
    # masked, matching coarsen_graph's contract
    pos = jnp.where(e_starts, e_rid, total)
    idx = (jnp.zeros((total + 1,), jnp.int32)
           .at[pos].set(jnp.arange(total, dtype=jnp.int32))[:m])
    grp_ok = jnp.arange(m, dtype=jnp.int32) < n_groups
    gsrc = jnp.where(grp_ok, a_rid[idx], sentinel)
    gdst = jnp.where(grp_ok, raw2new[jnp.clip(sb[idx], 0, n)], sentinel)
    gw = jnp.where(grp_ok, sums[rid[idx]], 0.0)
    cg = Graph(
        src=gsrc,
        dst=gdst,
        w=gw,
        edge_mask=grp_ok,
        n_valid=n_comm.astype(jnp.int32),
        m_valid=n_groups,
        n_max=n,
        m_max=m,
        sorted_by="src",
    )
    return new_com, n_comm, cg


@partial(jax.jit, static_argnames=("width", "impl", "force_overflow"))
def remap_and_coarsen_binned(
    g: Graph, com: jax.Array, *, width: int | None = None, impl: str = "auto",
    force_overflow: bool = False
) -> Tuple[jax.Array, jax.Array, Graph]:
    """Sort-free remap + coarsen (DESIGN.md §Aggregation kernel).

    Bitmap-``cumsum`` remap followed by the binned scatter merge
    (``kernels/aggregation.binned_coarsen``); bit-for-bit identical to the
    one-sort ``remap_and_coarsen`` oracle, including unspecified-slot
    conventions, so downstream ``shrink_graph`` / cascade boundary sync run
    unchanged.  ``width`` defaults to the capacity-derived
    ``kernels.common.pick_bin_width`` menu pick (static at trace time).

    Returns ``(new_com, n_comm, coarse_graph)``.

    ``force_overflow`` (static, part of the jit cache key) is the
    ``binned_overflow`` fault-injection point — see
    ``kernels.aggregation.binned_coarsen``.
    """
    new_com, n_comm = remap_communities(com, g.vertex_mask())
    cg = binned_coarsen(g, new_com, n_comm, width=width, impl=impl,
                        force_overflow=force_overflow)
    return new_com, n_comm, cg


def remap_and_coarsen_by(
    method: str, g: Graph, com: jax.Array, faults=()
) -> Tuple[jax.Array, jax.Array, Graph]:
    """Dispatch one aggregation step by method name.

    ``"binned"`` (the default everywhere) runs the sort-free path;
    ``"sort"`` keeps the one-sort fused path selectable as the documented
    oracle (``LouvainConfig.aggregation``).

    ``faults`` is the armed fault-point collection threaded down from the
    driver (``utils.faultinject``): passing it explicitly (instead of
    reading the global registry here, possibly mid-trace) keeps every
    enclosing jit/lru_cache program keyed on the fault state, so a
    clean-cached trace is never reused under faults or vice versa.
    """
    if method not in AGGREGATION_METHODS:
        raise ValueError(
            f"unknown aggregation {method!r}, want one of {AGGREGATION_METHODS}")
    if method == "sort":
        return remap_and_coarsen(g, com)
    return remap_and_coarsen_binned(
        g, com, force_overflow="binned_overflow" in faults)


def shrink_graph(g: Graph, n_max: int, m_max: int) -> Graph:
    """Compact a coarsened graph into smaller static capacities (on device).

    Requires ``n_valid <= n_max``, ``m_valid <= m_max`` and valid edges
    front-compacted (both hold for ``remap_and_coarsen``/``coarsen_graph``
    output — the capacity-scheduled cascade checks the counts host-side
    before descending).  Pure slice + sentinel rewrite: vertex ids are
    already contiguous in [0, n_valid), so only the padding sentinel value
    changes with the capacity.
    """
    sent = jnp.int32(n_max)
    em = g.edge_mask[:m_max]
    return Graph(
        src=jnp.where(em, g.src[:m_max], sent),
        dst=jnp.where(em, g.dst[:m_max], sent),
        w=jnp.where(em, g.w[:m_max], 0.0),
        edge_mask=em,
        n_valid=g.n_valid,
        m_valid=g.m_valid,
        n_max=int(n_max),
        m_max=int(m_max),
        sorted_by=g.sorted_by,
    )


@jax.jit
def coarsen_graph(g: Graph, new_com: jax.Array, n_comm: jax.Array) -> Graph:
    """Build the super-vertex graph for contiguous community ids ``new_com``.

    Two-step reference path (with ``remap_communities``): kept as the
    documented oracle for ``remap_and_coarsen``, which fuses the remap sort
    into this GroupBy's sort."""
    n, m = g.n_max, g.m_max
    sentinel = jnp.int32(n)
    csrc = jnp.where(g.edge_mask, new_com[jnp.clip(g.src, 0, n - 1)], sentinel)
    cdst = jnp.where(g.edge_mask, new_com[jnp.clip(g.dst, 0, n - 1)], sentinel)
    w = jnp.where(g.edge_mask, g.w, 0.0)
    (gk, gs, gvalid, n_groups) = seg.groupby_sum((csrc, cdst), w, valid=g.edge_mask)
    gsrc, gdst = gk
    grp_ok = gvalid & (gsrc < sentinel)
    return Graph(
        src=jnp.where(grp_ok, gsrc, sentinel),
        dst=jnp.where(grp_ok, gdst, sentinel),
        w=jnp.where(grp_ok, gs, 0.0),
        edge_mask=grp_ok,
        n_valid=n_comm.astype(jnp.int32),
        m_valid=jnp.sum(grp_ok.astype(jnp.int32)),
        n_max=n,
        m_max=m,
        sorted_by="src",
    )
