"""Parallel Label Propagation (paper Alg. 1) — TPU-native.

Faithful structure:
  * singleton initialization (l.4)
  * active-vertex set with deactivate-on-stable / reactivate-on-neighbor-change
    (l.5, l.19-20, l.25) — realized as a boolean frontier mask
  * per-iteration move: every active vertex adopts
    argmax_c Σ_{u∈N(v): C(u)=c} w(v,u)   (l.18)
  * termination: ΔN ≤ threshold or maxIteration (l.7-11)

Adaptation (DESIGN.md §2): the paper's asynchronous shared-array update with
benign races becomes a synchronous Jacobi sweep; thread-race tie randomization
becomes seeded hash noise (``tie_noise``).  The sweep itself lives in the
shared ``core.engine`` (DESIGN.md §Engine): this module only configures the
``plp`` evaluator and packages results.  With ``fused=True`` (default) the
whole label-propagation run is ONE jitted ``lax.while_loop`` call with
on-device convergence; ``fused=False`` is the stepwise reference.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core.engine import EngineSpec, SweepEngine
from repro.graph.structure import Graph
from repro.utils.timing import Timer


@dataclasses.dataclass(frozen=True)
class PLPConfig(ConfigBase):
    max_iterations: int = 100
    threshold: int = 0          # paper's ΔN threshold θ
    seed: int = 0
    tie_eps: float = 0.25       # < min weight gap on unit-weight graphs
    use_frontier: bool = True   # the paper's active-vertex optimization
    backend: str = "segment"    # segment | ell | pallas
    # Re-draw tie noise each iteration (closest to the paper's thread-race
    # randomization but can stall convergence on tie-rich graphs) vs a fixed
    # random preference per (vertex,label) pair (converges; default).
    reshuffle_ties: bool = False
    move_prob: float = 0.75     # Luby-style move gating (1.0 = pure Jacobi)
    fused: bool = True          # one while_loop call vs per-sweep dispatch
    # ell/pallas table layout: VMEM-resident vs windowed streaming; "auto"
    # resolves from the VMEM byte budget (DESIGN.md §Kernels)
    table_mode: str = "auto"    # auto | resident | streamed


@dataclasses.dataclass
class PLPResult:
    labels: np.ndarray
    iterations: int
    delta_n_history: list
    active_history: list
    timer: Timer


def engine_spec(cfg: PLPConfig) -> EngineSpec:
    return EngineSpec(
        evaluator="plp",
        backend=cfg.backend,
        max_sweeps=cfg.max_iterations,
        threshold=cfg.threshold,
        tie_eps=float(cfg.tie_eps),
        move_prob=float(cfg.move_prob),
        use_frontier=cfg.use_frontier,
        reshuffle_ties=cfg.reshuffle_ties,
        table_mode=cfg.table_mode,
    )


def plp(g: Graph, cfg: PLPConfig = PLPConfig(), ell_graph=None) -> PLPResult:
    """Run Parallel Label Propagation; returns final labels + history."""
    timer = Timer()
    with timer.phase("ell_build") if cfg.backend in ("ell", "pallas") \
            else contextlib.nullcontext():
        engine = SweepEngine(g, engine_spec(cfg), ell=ell_graph)

    labels, active = engine.singleton_state()
    with timer.phase("move"):
        res = engine.run_phase(labels, active, seed=cfg.seed, fused=cfg.fused)
    return PLPResult(
        labels=np.asarray(res.labels),
        iterations=res.sweeps,
        delta_n_history=res.delta_n_history,
        active_history=res.active_history,
        timer=timer,
    )
