"""Parallel Label Propagation (paper Alg. 1) — TPU-native.

Faithful structure:
  * singleton initialization (l.4)
  * active-vertex set with deactivate-on-stable / reactivate-on-neighbor-change
    (l.5, l.19-20, l.25) — realized as a boolean frontier mask
  * per-iteration move: every active vertex adopts
    argmax_c Σ_{u∈N(v): C(u)=c} w(v,u)   (l.18)
  * termination: ΔN ≤ threshold or maxIteration (l.7-11)

Adaptation (DESIGN.md §2): the paper's asynchronous shared-array update with
benign races becomes a synchronous Jacobi sweep; thread-race tie randomization
becomes seeded hash noise (``tie_noise``).  The sweep itself lives in the
shared ``core.engine`` (DESIGN.md §Engine): this module only configures the
``plp`` evaluator and packages results.  With ``fused=True`` (default) the
whole label-propagation run is ONE jitted ``lax.while_loop`` call with
on-device convergence; ``fused=False`` is the stepwise reference.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core.engine import EngineSpec, SweepEngine
from repro.graph.structure import Graph
from repro.utils import faultinject, telemetry
from repro.utils.errors import (CommunityDetectionError, KernelError,
                                RunReport)
from repro.utils.timing import Timer


@dataclasses.dataclass(frozen=True)
class PLPConfig(ConfigBase):
    max_iterations: int = 100
    threshold: int = 0          # paper's ΔN threshold θ
    seed: int = 0
    tie_eps: float = 0.25       # < min weight gap on unit-weight graphs
    use_frontier: bool = True   # the paper's active-vertex optimization
    backend: str = "segment"    # segment | ell | pallas
    # Re-draw tie noise each iteration (closest to the paper's thread-race
    # randomization but can stall convergence on tie-rich graphs) vs a fixed
    # random preference per (vertex,label) pair (converges; default).
    reshuffle_ties: bool = False
    move_prob: float = 0.75     # Luby-style move gating (1.0 = pure Jacobi)
    fused: bool = True          # one while_loop call vs per-sweep dispatch
    # ell/pallas table layout: VMEM-resident vs windowed streaming; "auto"
    # resolves from the VMEM byte budget (DESIGN.md §Kernels)
    table_mode: str = "auto"    # auto | resident | streamed


@dataclasses.dataclass
class PLPResult:
    labels: np.ndarray
    iterations: int
    delta_n_history: list
    active_history: list
    timer: Timer
    # retry/degradation/watchdog accounting (DESIGN.md §Robustness)
    run_report: RunReport = dataclasses.field(default_factory=RunReport)


def engine_spec(cfg: PLPConfig,
                faults: frozenset = frozenset()) -> EngineSpec:
    from repro.core.louvain import ENGINE_FAULTS

    return EngineSpec(
        evaluator="plp",
        backend=cfg.backend,
        max_sweeps=cfg.max_iterations,
        threshold=cfg.threshold,
        tie_eps=float(cfg.tie_eps),
        move_prob=float(cfg.move_prob),
        use_frontier=cfg.use_frontier,
        reshuffle_ties=cfg.reshuffle_ties,
        table_mode=cfg.table_mode,
        faults=tuple(sorted(f for f in faults if f in ENGINE_FAULTS)),
    )


def _plp_once(g: Graph, cfg: PLPConfig, ell_graph,
              faults: frozenset) -> PLPResult:
    timer = Timer()
    with timer.phase("ell_build") if cfg.backend in ("ell", "pallas") \
            else contextlib.nullcontext():
        engine = SweepEngine(g, engine_spec(cfg, faults), ell=ell_graph)

    labels, active = engine.singleton_state()
    with timer.phase("move"):
        res = engine.run_phase(labels, active, seed=cfg.seed, fused=cfg.fused)
    return PLPResult(
        labels=np.asarray(res.labels),
        iterations=res.sweeps,
        delta_n_history=res.delta_n_history,
        active_history=res.active_history,
        timer=timer,
    )


def plp(g: Graph, cfg: PLPConfig = PLPConfig(), ell_graph=None) -> PLPResult:
    """Run Parallel Label Propagation; returns final labels + history.

    Hardened like ``core.louvain.louvain``: non-taxonomy backend failures
    descend the ``pallas → ell → segment`` ladder (bit-identical on clean
    input), iteration-budget exhaustion is flagged as a watchdog warning,
    and everything attempted lands in ``result.run_report``."""
    from repro.core.louvain import BACKEND_DESCENT

    report = RunReport(faults=sorted(faultinject.active()))
    if g.n_max == 0:
        return PLPResult(labels=np.zeros((0,), np.int32), iterations=0,
                         delta_n_history=[], active_history=[], timer=Timer(),
                         run_report=report)
    faults = frozenset(faultinject.active())
    cfg_try = cfg
    while True:
        try:
            res = _plp_once(g, cfg_try, ell_graph, faults)
            break
        except CommunityDetectionError as err:
            err.report = report
            raise
        except Exception as err:  # noqa: BLE001 — the backend-descent rung
            nxt = BACKEND_DESCENT.get(cfg_try.backend)
            if nxt is None:
                raise KernelError(
                    f"backend {cfg_try.backend!r} failed with no descent "
                    f"left: {type(err).__name__}: {err}",
                    report=report) from err
            telemetry.bump("ladder.backend_descent")
            report.degradations.append({
                "kind": "backend_descent",
                "from": cfg_try.backend, "to": nxt,
                "error": f"{type(err).__name__}: {err}"})
            # a descended run no longer uses the caller's ELL layout
            ell_graph = None
            cfg_try = cfg_try.replace(backend=nxt)
    if res.iterations >= cfg_try.max_iterations:
        report.warnings.append("watchdog:max_iterations")
    res.run_report = report
    return res
