"""Parallel Label Propagation (paper Alg. 1) — TPU-native.

Faithful structure:
  * singleton initialization (l.4)
  * active-vertex set with deactivate-on-stable / reactivate-on-neighbor-change
    (l.5, l.19-20, l.25) — realized as a boolean frontier mask
  * per-iteration move: every active vertex adopts
    argmax_c Σ_{u∈N(v): C(u)=c} w(v,u)   (l.18)
  * termination: ΔN ≤ threshold or maxIteration (l.7-11)

Adaptation (DESIGN.md §2): the paper's asynchronous shared-array update with
benign races becomes a synchronous Jacobi sweep; thread-race tie randomization
becomes seeded hash noise (``tie_noise``).  Two interchangeable move backends:

  * ``segment`` — lax.sort + segment reductions (Arkouda GroupBy analogue);
  * ``pallas``/``ell``   — degree-bucketed ELL tiles through the
    ``kernels/label_argmax`` Pallas kernel (or its jnp oracle).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core.common import neighbor_or_self_changed, tie_noise
from repro.graph import segment as seg
from repro.graph.structure import Graph
from repro.utils.timing import Timer


@dataclasses.dataclass(frozen=True)
class PLPConfig(ConfigBase):
    max_iterations: int = 100
    threshold: int = 0          # paper's ΔN threshold θ
    seed: int = 0
    tie_eps: float = 0.25       # < min weight gap on unit-weight graphs
    use_frontier: bool = True   # the paper's active-vertex optimization
    backend: str = "segment"    # segment | ell | pallas
    # Re-draw tie noise each iteration (closest to the paper's thread-race
    # randomization but can stall convergence on tie-rich graphs) vs a fixed
    # random preference per (vertex,label) pair (converges; default).
    reshuffle_ties: bool = False
    move_prob: float = 0.75     # Luby-style move gating (1.0 = pure Jacobi)


@dataclasses.dataclass
class PLPResult:
    labels: np.ndarray
    iterations: int
    delta_n_history: list
    active_history: list
    timer: Timer


# ---------------------------------------------------------------- segment path


@partial(jax.jit, static_argnames=("tie_eps", "move_prob"))
def _plp_sweep_segment(
    g: Graph,
    labels: jax.Array,
    active: jax.Array,
    it: jax.Array,
    tie_eps: float,
    seed: jax.Array,
    sweep_idx: jax.Array = jnp.uint32(0),
    move_prob: float = 1.0,
):
    """One synchronous PLP move over all active vertices."""
    from repro.core import moves

    n = g.n_max
    valid = g.edge_mask & active[jnp.clip(g.dst, 0, n - 1)]
    best_score, best_lab, cur_score = moves.plp_best_labels(
        g.src, g.dst, g.w, valid, labels, n, it.astype(jnp.uint32), seed, tie_eps
    )
    adopt = active & (best_lab >= 0) & (best_score > cur_score)
    if move_prob < 1.0:
        # Luby-style gating: emulates the paper's async move order, breaks
        # synchronous two-cycles (see DESIGN.md §2).
        from repro.core.common import hash_u32

        coin = hash_u32(
            jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(0x85EBCA6B)
            ^ hash_u32(sweep_idx + seed * jnp.uint32(313))
        )
        adopt = adopt & (coin < jnp.uint32(int(move_prob * 4294967295.0)))
    new_labels = jnp.where(adopt, best_lab, labels)
    changed = adopt & (new_labels != labels)
    delta_n = jnp.sum(changed.astype(jnp.int32))

    next_active = neighbor_or_self_changed(g, changed)
    return new_labels, next_active, delta_n


# ---------------------------------------------------------------- ELL/Pallas path


def _plp_sweep_ell(g, ell_graph, labels, active, it, tie_eps, seed, use_pallas,
                   sweep_idx=0, move_prob=1.0):
    """Move step over degree-bucketed dense tiles (kernel or jnp oracle)."""
    from repro.kernels.label_argmax import ops as la_ops

    n = g.n_max
    new_labels = labels
    changed = jnp.zeros((n,), dtype=bool)
    labels_ext = jnp.concatenate([labels, jnp.int32([n])])  # sentinel slot

    for b in ell_graph.buckets:
        rows = jnp.asarray(b.rows)
        nbr = jnp.asarray(b.nbr)
        w = jnp.asarray(b.w)
        nbr_lab = labels_ext[jnp.clip(nbr, 0, n)]
        nbr_lab = jnp.where(nbr < n, nbr_lab, n)  # sentinel label for padding
        row_ok = rows < n
        cur_lab = labels_ext[jnp.clip(rows, 0, n)]
        best_lab, best_score, cur_score = la_ops.label_argmax(
            nbr_lab,
            w,
            cur_lab,
            jnp.where(rows < n, rows, n),
            jnp.uint32(seed) + jnp.uint32(it),
            tie_eps=tie_eps,
            sentinel=n,
            use_pallas=use_pallas,
        )
        row_active = active[jnp.clip(rows, 0, n - 1)] & row_ok
        adopt = row_active & (best_lab >= 0) & (best_score > cur_score)
        if move_prob < 1.0:
            from repro.core.common import hash_u32

            coin = hash_u32(
                jnp.clip(rows, 0, n - 1).astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
                ^ hash_u32(jnp.uint32(sweep_idx) + jnp.uint32(seed) * jnp.uint32(313))
            )
            adopt = adopt & (coin < jnp.uint32(int(move_prob * 4294967295.0)))
        upd_idx = jnp.where(adopt, rows, n)
        new_labels = new_labels.at[jnp.clip(upd_idx, 0, n - 1)].set(
            jnp.where(adopt, best_lab, new_labels[jnp.clip(upd_idx, 0, n - 1)])
        )
        did = adopt & (best_lab != cur_lab)
        changed = changed.at[jnp.clip(upd_idx, 0, n - 1)].max(
            jnp.where(upd_idx < n, did, False)
        )

    # tail vertices (deg > max bucket width): segment path on their edges
    if ell_graph.has_tail:
        tail_new, tail_changed = _tail_move(g, ell_graph, labels, active, it, tie_eps, seed)
        new_labels = jnp.where(tail_changed, tail_new, new_labels)
        changed = changed | tail_changed

    delta_n = jnp.sum(changed.astype(jnp.int32))
    next_active = neighbor_or_self_changed(g, changed)
    return new_labels, next_active, delta_n


def _tail_move(g, ell_graph, labels, active, it, tie_eps, seed):
    n = g.n_max
    idx = jnp.asarray(ell_graph.tail_edge_idx)
    # src/dst arrays of g are in dst-undefined order; tail_edge_idx indexes the
    # dst-sorted view built in ell.py, so re-sort here to match.
    order = jnp.lexsort((g.src, g.dst))
    src_s, dst_s, w_s = g.src[order], g.dst[order], g.w[order]
    tsrc, tdst, tw = src_s[idx], dst_s[idx], w_s[idx]
    valid = (tsrc < n) & (tdst < n) & (tsrc != tdst)
    lab_k = jnp.where(valid, labels[jnp.clip(tsrc, 0, n - 1)], n)
    dst_k = jnp.where(valid, tdst, n)
    (gk, gs, gvalid, _) = seg.groupby_sum((dst_k, lab_k), jnp.where(valid, tw, 0.0))
    gdst, glab = gk
    grp_ok = gvalid & (gdst < n) & (glab < n)
    noise = tie_noise(gdst, glab, jnp.uint32(seed) + jnp.uint32(it), tie_eps)
    score = jnp.where(grp_ok, gs + noise, -jnp.inf)
    seg_ids = jnp.where(grp_ok, gdst, n)
    best_score, best_lab = seg.segment_argmax(score, glab, seg_ids, n + 1, valid=grp_ok)
    best_score, best_lab = best_score[:n], best_lab[:n]
    cur_match = grp_ok & (glab == labels[jnp.clip(gdst, 0, n - 1)])
    cur_score = jax.ops.segment_sum(
        jnp.where(cur_match, score, 0.0), seg_ids, num_segments=n + 1
    )[:n]
    is_tail = jnp.zeros((n,), bool).at[jnp.asarray(ell_graph.tail_vertices)].set(True)
    adopt = is_tail & active & (best_lab >= 0) & (best_score > cur_score)
    new_labels = jnp.where(adopt, best_lab, labels)
    return new_labels, adopt & (new_labels != labels)


# ---------------------------------------------------------------- driver


def plp(g: Graph, cfg: PLPConfig = PLPConfig(), ell_graph=None) -> PLPResult:
    """Run Parallel Label Propagation; returns final labels + history."""
    timer = Timer()
    n = g.n_max
    labels = jnp.arange(n, dtype=jnp.int32)       # singleton init (l.4)
    active = g.vertex_mask()                       # V_active = V (l.5)
    if not cfg.use_frontier:
        always_active = g.vertex_mask()

    if cfg.backend in ("ell", "pallas") and ell_graph is None:
        from repro.graph.ell import build_ell

        with timer.phase("ell_build"):
            ell_graph = build_ell(g)

    dn_hist, act_hist = [], []
    it_done = 0
    for it in range(cfg.max_iterations):
        noise_it = it if cfg.reshuffle_ties else 0
        with timer.phase("move"):
            if cfg.backend == "segment":
                labels, active, dn = _plp_sweep_segment(
                    g,
                    labels,
                    active,
                    jnp.uint32(noise_it),
                    float(cfg.tie_eps),
                    jnp.uint32(cfg.seed),
                    sweep_idx=jnp.uint32(it),
                    move_prob=float(cfg.move_prob),
                )
            else:
                labels, active, dn = _plp_sweep_ell(
                    g,
                    ell_graph,
                    labels,
                    active,
                    noise_it,
                    cfg.tie_eps,
                    cfg.seed,
                    use_pallas=(cfg.backend == "pallas"),
                    sweep_idx=it,
                    move_prob=float(cfg.move_prob),
                )
            if not cfg.use_frontier:
                active = always_active
            dn = int(dn)
        dn_hist.append(dn)
        act_hist.append(int(jnp.sum(active.astype(jnp.int32))))
        it_done = it + 1
        if dn <= cfg.threshold:   # paper l.9
            break
    return PLPResult(
        labels=np.asarray(labels),
        iterations=it_done,
        delta_n_history=dn_hist,
        active_history=act_hist,
        timer=timer,
    )
