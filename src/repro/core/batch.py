"""Batched many-graph engine (DESIGN.md §Serving).

PRs 1-7 made ONE graph fast: a whole Louvain run is one dispatch + one
readback.  The serving workload is the opposite shape — millions of small
graphs (ego-nets, session graphs), where per-graph DISPATCH dominates once
the kernels are fast: a 300-vertex graph pays the same Python-driver + jit
launch + readback latency as a 300k-vertex one.  ``louvain_batch`` /
``plp_batch`` amortize it:

  1. **bucket** incoming graphs by ``kernels.common.capacity_signature`` —
     capacities quantize onto a doubling menu with ego-net-scale floors
     (padding waste bounded <2×), so arbitrarily-sized traffic lands on a
     handful of buckets;
  2. **pack** each bucket along a new leading batch axis
     (``graph.packing``): capacity-padded arrays stack for free, the batch
     is padded to a power-of-two slot count with fully-masked empty-slot
     graphs so steady-state traffic reuses a handful of compiled shapes;
  3. **dispatch** the existing fused stage program under ``jax.vmap``: the
     same ``louvain._build_stage`` closure the single-graph cascade jits is
     lifted over the batch axis, so ONE dispatch serves up to ``max_slots``
     graphs of a bucket (the dispatch-width bound caps vmap-lockstep waste
     — see ``MAX_SLOTS``) and per-slot results are bit-identical to the
     unbatched driver by the capacity-portability contract
     (tests/test_batch.py).

Backend notes: the ``segment`` evaluator vmaps directly.  ``ell`` uses the
traced per-level re-bucketing at the signature's static menu width (the
cascade's coarse-level machinery — no host-built layout, pure jnp, vmaps
directly).  ``pallas`` falls back to ``ell`` under vmap — the documented
vmap-of-ref fallback: the kernels' jnp oracle is bit-identical by the
parity contracts, so batching trades the fused-kernel speedup for the
dispatch amortization without touching results; a batch-grid dimension
through the Pallas kernels can lift that later where the kernels permit.
Graphs without the ``sorted_by == "src"`` invariant fall back to the
segment evaluator (also bit-identical).

Compiled programs are memoized in a bounded LRU keyed on the capacity
signature (``progcache.program_cache``), mirroring the cascade's
≤4-stage-program discipline: steady-state traffic incurs ZERO recompiles
(asserted by the ``batch_serve`` benchmark).

Per-graph ``RunReport`` discipline (DESIGN.md §Robustness) is preserved:
empty (zero-capacity) inputs short-circuit to the PR-7 trivial result
without occupying a batch slot, the per-level non-finite-weight guard rides
the batched readback per slot and poisons ONLY the offending graphs
(``NumericError`` names them; clean slots are unaffected), and watchdog /
precision warnings are recorded per slot.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineSpec, device_phase
# NB: ``repro.core``'s package namespace rebinds the names ``louvain``/
# ``plp`` to the driver FUNCTIONS, so the submodules are imported by the
# names we need rather than as module objects.
from repro.core.louvain import (LouvainConfig, LouvainResult, _build_stage,
                                _coarse_backend, _finalize_report, _readback,
                                _refine_spec, _trivial_result)
from repro.core.louvain import engine_spec as louvain_engine_spec
from repro.core.plp import PLPConfig, PLPResult
from repro.core.plp import engine_spec as plp_engine_spec
from repro.core.progcache import program_cache
from repro.graph import packing
from repro.graph.structure import Graph
from repro.kernels.common import (CapacitySignature, accum_needs_promotion,
                                  capacity_signature)
from repro.utils import faultinject, resilience, telemetry
from repro.utils.errors import DeadlineError, KernelError, NumericError, RunReport
from repro.utils.timing import Timer


def _dispatch_guarded(run, deadline: Optional[resilience.Deadline]):
    """Execute one bucket dispatch under the serving resilience contract
    (DESIGN.md §Resilience): the chaos fault sites fire HERE (inside the
    watchdogged callable, so a stalled dispatch is indistinguishable from
    a hung device) and, when a deadline rides the call, the whole thing
    runs under ``resilience.call_with_deadline`` — on overrun the wait is
    cancelled with a typed ``DeadlineError`` and the worker abandoned.
    ``deadline=None`` is the clean path: a plain inline call, no thread."""

    def attempt():
        if faultinject.should_fire("slow_dispatch"):
            # models a hung device / pathological recompile: stall inside
            # the watchdog window
            time.sleep(faultinject.slow_dispatch_seconds())
        if faultinject.should_fire("transient_batch_fail"):
            raise KernelError(
                "injected transient batch dispatch failure "
                "(fault point: transient_batch_fail)")
        return run()

    return resilience.call_with_deadline(
        attempt, deadline.remaining_s() if deadline is not None else None)


def pick_batch_slots(n_graphs: int) -> int:
    """Pad the batch to the next power of two (min 1).

    Slot counts are jit shape inputs: quantizing them bounds the compiled
    programs per signature to log2(max batch) instead of one per distinct
    request-group size.  Padding slots are fully-masked empty graphs — inert
    vmap lanes (``graph.packing.empty_slot``).
    """
    if n_graphs < 1:
        raise ValueError(f"need at least one graph, got {n_graphs}")
    return 1 << (n_graphs - 1).bit_length()


def _resolve_batch_backend(backend: str, sorted_ok: bool) -> str:
    """Static backend resolution for the batched path (module docstring):
    ``pallas`` → ``ell`` (vmap-of-ref fallback), and ``ell`` → ``segment``
    when the bucket lacks the src-sorted invariant the traced re-bucketing
    needs.  Every step is bit-identical by the parity contracts."""
    if backend == "pallas":
        telemetry.bump("batch.pallas_vmap_fallback")
        backend = "ell"
    if backend == "ell" and not sorted_ok:
        telemetry.bump("batch.unsorted_segment_fallback")
        backend = "segment"
    return backend


# ------------------------------------------------------------------- louvain


@program_cache("batch.louvain", maxsize=32)
def _louvain_batch_fn(sig: CapacitySignature, spec0: EngineSpec,
                      spec_coarse: EngineSpec,
                      refine_spec: Optional[EngineSpec], max_levels: int,
                      track_modularity: bool, agg_method: str,
                      faults: frozenset, promote: bool):
    """One compiled batch program per capacity signature (and spec set):
    the single-capacity whole-run stage (``_build_stage`` with
    ``next_caps=None`` — the cascade's parity oracle) lifted through
    ``jax.vmap`` over the leading batch axis.  ``sig`` pins the static
    shapes in the cache key; the jit beneath retraces only when the slot
    count changes (bounded by ``pick_batch_slots``)."""
    stage = _build_stage(
        spec0, spec_coarse, refine_spec, max_levels, track_modularity,
        None, agg_method, faults, promote)
    max_sweeps = spec0.max_sweeps

    def run(g: Graph, seed):
        n = g.n_max
        ar = jnp.arange(n, dtype=jnp.int32)
        hists = (jnp.full((max_levels,), jnp.nan, jnp.float32),
                 jnp.full((max_levels,), -1, jnp.int32),
                 jnp.full((max_levels,), -1, jnp.int32),
                 jnp.full((max_levels, max_sweeps), -1, jnp.int32),
                 jnp.bool_(False))
        (_arrays, _assign, _init, _macro, hists, level, _done, _nv, _mv,
         _max_deg, final_assign, n_final, q_final) = stage(
            g, None, g, seed, ar, ar, ar, jnp.int32(0), hists)
        mod_h, sw_h, nc_h, dn_h, bad_w = hists
        return (final_assign, n_final, level, q_final,
                mod_h, sw_h, nc_h, dn_h, bad_w)

    return jax.jit(jax.vmap(run, in_axes=(0, None)))


def _louvain_specs(cfg: LouvainConfig, sig: CapacitySignature,
                   backend: str, faults: frozenset):
    spec0 = louvain_engine_spec(cfg, backend=backend, faults=faults)
    if backend == "ell":
        # no host-built layout in the batched path: level 0 uses the traced
        # re-bucketing at the signature's static menu width
        spec0 = spec0.replace(ell_width=sig.ell_width)
    # coarse levels mirror the single-capacity parity oracle exactly
    # (schedule="none" semantics): segment evaluator beyond level 0
    spec_coarse = louvain_engine_spec(
        cfg, backend=_coarse_backend(backend), faults=faults)
    refine_spec = (_refine_spec(cfg, faults)
                   if cfg.refine else None)
    return spec0, spec_coarse, refine_spec


def _unpack_labels(final_assign: np.ndarray, g: Graph, n_cap: int) -> np.ndarray:
    """Slot labels at bucket capacity → the graph's own capacity: slice to
    ``n_max`` and rewrite the contiguize sentinel (``n_cap`` → ``n_max``).
    Valid labels are < n_valid <= n_max, so only sentinels can equal
    ``n_cap`` — no device sync needed."""
    lab = np.asarray(final_assign[:g.n_max])
    if n_cap != g.n_max:
        lab = np.where(lab == n_cap, g.n_max, lab).astype(np.int32)
    return lab


#: Default dispatch-width bound.  A vmapped while_loop runs every lane
#: until the SLOWEST lane converges, so unbounded batches pay worst-case
#: sweep/level counts for all slots; chunking a bucket into ≤MAX_SLOTS
#: dispatches caps that lockstep waste (and the packed-batch memory
#: footprint) while chunks of one size share one compiled program.
#: 8 is the measured CPU-serving optimum for both drivers (the sweep in
#: BENCH_batch_serve.json's PR notes); raise it on accelerators with
#: parallel lanes to spare.
MAX_SLOTS = 8


def _chunks(idxs: List[int], max_slots: int):
    if max_slots < 1:
        raise ValueError(f"max_slots must be >= 1, got {max_slots}")
    for k in range(0, len(idxs), max_slots):
        yield idxs[k:k + max_slots]


def _schedule_lanes(graphs, idxs: List[int]) -> List[int]:
    """Order a bucket's lanes by predicted sweep count before chunking.

    A vmapped while_loop runs every lane of a chunk until its SLOWEST lane
    converges, so mixing one dense graph with seven sparse ones makes the
    sparse lanes idle through the dense lane's extra sweeps/levels.  Sweep
    and level counts grow with edge count (and, secondarily, vertex count),
    so sorting a bucket descending by ``(m_valid, n_valid)`` packs
    similar-cost graphs into the same ``max_slots`` chunk and confines the
    lockstep waste to the one chunk that actually holds the heavy graphs.
    Pure reordering of which chunk a graph lands in: per-graph results are
    positionally realigned by index and bit-identical either way
    (tests/test_batch.py).
    """
    return sorted(idxs, key=lambda i: (-int(graphs[i].m_valid),
                                       -int(graphs[i].n_valid), i))


def louvain_batch(graphs: Sequence[Graph],
                  cfg: LouvainConfig = LouvainConfig(),
                  max_slots: int = MAX_SLOTS,
                  deadline_s: Optional[float] = None,
                  lane_schedule: bool = True) -> List[LouvainResult]:
    """Run Louvain over many graphs with one dispatch per capacity bucket
    (buckets wider than ``max_slots`` are chunked — see ``MAX_SLOTS``;
    ``lane_schedule`` orders lanes by predicted sweep count first — see
    ``_schedule_lanes`` — without affecting per-graph results).

    Results are positionally aligned with ``graphs`` and bit-identical to
    ``louvain(g, cfg)`` per graph (the parity contract the batch tests
    enforce).  Zero-capacity graphs return the trivial result without
    occupying a slot; if the per-level numeric guard flags non-finite
    weights in some slots, ``NumericError`` names those graph indices —
    clean graphs in the same batch are unaffected (their results would be
    returned on a retry without the poisoned inputs).

    ``deadline_s`` bounds the WHOLE call (DESIGN.md §Resilience): each
    bucket dispatch runs under the remaining-budget watchdog and overrun
    raises a typed ``DeadlineError`` — per-request deadline splitting
    (fail only the expired requests, re-run the rest) is the serving
    layer's job, which knows who owns which deadline.  ``None`` is the
    clean path: no watchdog thread, behavior unchanged.
    """
    graphs = list(graphs)
    results: List[Optional[LouvainResult]] = [None] * len(graphs)
    active_faults = sorted(faultinject.active())
    faults = frozenset(active_faults)
    deadline = (resilience.Deadline(deadline_s)
                if deadline_s is not None else None)

    buckets: Dict[Tuple, List[int]] = {}
    for i, g in enumerate(graphs):
        if g.n_max == 0:
            results[i] = _trivial_result(
                RunReport(faults=active_faults))
            continue
        sig = capacity_signature(g.n_max, g.m_max)
        buckets.setdefault((sig, g.sorted_by), []).append(i)

    bad_slots: List[int] = []
    for (sig, sorted_by), idxs in buckets.items():
        if lane_schedule and len(idxs) > max_slots:
            telemetry.bump("batch.lane_scheduled_buckets")
            idxs = _schedule_lanes(graphs, idxs)
        for chunk in _chunks(idxs, max_slots):
            if deadline is not None and deadline.expired:
                raise DeadlineError(
                    f"batch deadline ({deadline_s:.3f}s) expired with "
                    "bucket dispatches still pending")
            bad_slots += _run_louvain_bucket(
                graphs, chunk, sig, sorted_by, cfg, faults, active_faults,
                results, deadline)
    if bad_slots:
        raise NumericError(
            "non-finite edge weight detected inside the fused level loop "
            f"for graph(s) {sorted(bad_slots)}")
    return results  # type: ignore[return-value]


def _run_louvain_bucket(graphs, idxs, sig: CapacitySignature,
                        sorted_by, cfg: LouvainConfig, faults: frozenset,
                        active_faults, results,
                        deadline: Optional[resilience.Deadline] = None,
                        ) -> List[int]:
    timer = Timer()
    backend = _resolve_batch_backend(cfg.backend, sorted_by == "src")
    spec0, spec_coarse, refine_spec = _louvain_specs(cfg, sig, backend,
                                                     faults)
    promote = accum_needs_promotion(sig.m_cap)

    with timer.phase("pack"):
        padded = [packing.pad_graph(graphs[i], sig.n_cap, sig.m_cap)
                  for i in idxs]
        slots = pick_batch_slots(len(padded))
        filler = packing.empty_slot(sig.n_cap, sig.m_cap)
        if filler.sorted_by != sorted_by:
            filler = dataclasses.replace(filler, sorted_by=sorted_by)
        padded += [filler] * (slots - len(padded))
        gb = packing.stack_graphs(padded)

    fn = _louvain_batch_fn(sig, spec0, spec_coarse, refine_spec,
                           cfg.max_levels, cfg.track_modularity,
                           cfg.aggregation, faults, promote)
    with timer.phase("pipeline"):
        # ONE bulk transfer per bucket; under a deadline the dispatch +
        # readback run watchdogged (fault sites fire inside the window)
        host = _dispatch_guarded(
            lambda: _readback(fn(gb, jnp.uint32(cfg.seed))), deadline)
    (final_assign, n_final, level, q_final,
     mod_h, sw_h, nc_h, dn_h, bad_w) = host
    telemetry.bump("batch.louvain_dispatches")
    telemetry.bump("batch.louvain_graphs", len(idxs))

    bad_slots: List[int] = []
    for b, i in enumerate(idxs):
        if bool(bad_w[b]):
            bad_slots.append(i)
            continue
        report = RunReport(faults=list(active_faults))
        if promote:
            report.warnings.append("precision:f32_accum_risk"
                                   if not jax.config.jax_enable_x64
                                   else "precision:promoted_f64")
        levels = int(level[b])
        sweeps_per_level = [int(s) for s in sw_h[b][:levels]]
        res = LouvainResult(
            labels=_unpack_labels(final_assign[b], graphs[i], sig.n_cap),
            n_communities=int(n_final[b]),
            levels=levels,
            modularity=float(q_final[b]),
            modularity_history=(
                [float(x) for x in mod_h[b][:levels]]
                if cfg.track_modularity else []),
            sweeps_per_level=sweeps_per_level,
            timer=timer,
            n_comm_per_level=[int(x) for x in nc_h[b][:levels]],
            delta_n_per_level=[
                [int(x) for x in row[:s]]
                for row, s in zip(dn_h[b][:levels], sweeps_per_level)],
            cascade_stages=[(sig.n_cap, sig.m_cap)],
        )
        results[i] = _finalize_report(res, cfg, report)
    return bad_slots


# ----------------------------------------------------------------------- plp


@program_cache("batch.plp", maxsize=32)
def _plp_batch_fn(sig: CapacitySignature, spec: EngineSpec):
    """One compiled PLP batch program per capacity signature: the fused
    phase loop (``engine.device_phase`` — singleton init, on-device
    convergence) lifted through ``jax.vmap``."""

    def run(g: Graph, seed):
        labels = jnp.arange(g.n_max, dtype=jnp.int32)
        active = g.vertex_mask()
        labels, active, s, dn_hist, act_hist = device_phase(
            spec, g, None, labels, active, jnp.uint32(0), seed)
        return labels, s, dn_hist, act_hist

    return jax.jit(jax.vmap(run, in_axes=(0, None)))


def plp_batch(graphs: Sequence[Graph],
              cfg: PLPConfig = PLPConfig(),
              max_slots: int = MAX_SLOTS,
              deadline_s: Optional[float] = None,
              lane_schedule: bool = True) -> List[PLPResult]:
    """Run PLP over many graphs with one dispatch per capacity bucket —
    ``louvain_batch``'s contract (positional results, per-graph bitwise
    parity with ``plp(g, cfg)``, trivial result for zero-capacity inputs,
    per-slot RunReport, ``max_slots`` dispatch-width bound,
    ``deadline_s`` whole-call watchdog, ``lane_schedule`` sweep-count
    ordering) for the label-propagation evaluator."""
    graphs = list(graphs)
    results: List[Optional[PLPResult]] = [None] * len(graphs)
    active_faults = sorted(faultinject.active())
    faults = frozenset(active_faults)
    deadline = (resilience.Deadline(deadline_s)
                if deadline_s is not None else None)

    buckets: Dict[Tuple, List[int]] = {}
    for i, g in enumerate(graphs):
        if g.n_max == 0:
            results[i] = PLPResult(
                labels=np.zeros((0,), np.int32), iterations=0,
                delta_n_history=[], active_history=[], timer=Timer(),
                run_report=RunReport(faults=active_faults))
            continue
        sig = capacity_signature(g.n_max, g.m_max)
        buckets.setdefault((sig, g.sorted_by), []).append(i)

    for (sig, sorted_by), bucket_idxs in buckets.items():
        if lane_schedule and len(bucket_idxs) > max_slots:
            telemetry.bump("batch.lane_scheduled_buckets")
            bucket_idxs = _schedule_lanes(graphs, bucket_idxs)
        for idxs in _chunks(bucket_idxs, max_slots):
            if deadline is not None and deadline.expired:
                raise DeadlineError(
                    f"batch deadline ({deadline_s:.3f}s) expired with "
                    "bucket dispatches still pending")
            _run_plp_bucket(graphs, idxs, sig, sorted_by, cfg, faults,
                            active_faults, results, deadline)
    return results  # type: ignore[return-value]


def _run_plp_bucket(graphs, idxs, sig: CapacitySignature, sorted_by,
                    cfg: PLPConfig, faults: frozenset, active_faults,
                    results, deadline: Optional[resilience.Deadline] = None,
                    ) -> None:
    timer = Timer()
    backend = _resolve_batch_backend(cfg.backend, sorted_by == "src")
    spec = plp_engine_spec(cfg, faults).replace(backend=backend)
    if backend == "ell":
        spec = spec.replace(ell_width=sig.ell_width)

    with timer.phase("pack"):
        padded = [packing.pad_graph(graphs[i], sig.n_cap, sig.m_cap)
                  for i in idxs]
        slots = pick_batch_slots(len(padded))
        filler = packing.empty_slot(sig.n_cap, sig.m_cap)
        if filler.sorted_by != sorted_by:
            filler = dataclasses.replace(filler, sorted_by=sorted_by)
        padded += [filler] * (slots - len(padded))
        gb = packing.stack_graphs(padded)

    fn = _plp_batch_fn(sig, spec)
    with timer.phase("move"):
        labels, s, dn_hist, act_hist = _dispatch_guarded(
            lambda: jax.device_get(fn(gb, jnp.uint32(cfg.seed))), deadline)
    telemetry.bump("batch.plp_dispatches")
    telemetry.bump("batch.plp_graphs", len(idxs))

    for b, i in enumerate(idxs):
        report = RunReport(faults=list(active_faults))
        its = int(s[b])
        if its >= cfg.max_iterations:
            report.warnings.append("watchdog:max_iterations")
        results[i] = PLPResult(
            labels=np.asarray(labels[b][:graphs[i].n_max]),
            iterations=its,
            delta_n_history=[int(x) for x in dn_hist[b][:its]],
            active_history=[int(x) for x in act_hist[b][:its]],
            timer=timer,
            run_report=report)
