"""Shared pieces of the two community-detection algorithms."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph


def hash_u32(x: jax.Array) -> jax.Array:
    """splitmix32-style avalanche hash on uint32 (wraps mod 2^32)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def tie_noise(a: jax.Array, b: jax.Array, seed: jax.Array, eps: float) -> jax.Array:
    """Deterministic pseudo-random tie-break noise in [0, eps).

    Stands in for the paper's "inherent randomization provided by thread
    execution" (§III-A2): the asynchronous Chapel version breaks label-score
    ties through racy scheduling; the synchronous TPU version breaks them with
    a seeded hash of (vertex, candidate, iteration) — reproducible, and
    statistically equivalent for community quality.
    """
    h = hash_u32(
        a.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
        ^ hash_u32(b.astype(jnp.uint32) + seed.astype(jnp.uint32))
    )
    return h.astype(jnp.float32) * jnp.float32(eps / 4294967296.0)


def luby_move_gate(
    n: int,
    sweep_key: jax.Array,
    seed: jax.Array,
    move_prob: float,
    mult: int,
    salt: int,
) -> jax.Array:
    """bool[n]: Luby-style per-vertex move coin for one synchronous sweep.

    Emulates the paper's asynchronous move order (DESIGN.md §2): moving a
    random ``move_prob`` fraction of intenders per sweep breaks synchronous
    two-cycles.  ``mult``/``salt`` are per-evaluator stream constants so PLP
    and Louvain draw from decorrelated coin sequences.
    """
    coin = hash_u32(
        jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(mult)
        ^ hash_u32(sweep_key.astype(jnp.uint32) + seed.astype(jnp.uint32) * jnp.uint32(salt))
    )
    return coin < jnp.uint32(int(move_prob * 4294967295.0))


def neighbor_or_self_changed(g: Graph, changed: jax.Array) -> jax.Array:
    """Active-set propagation (Alg. 1 l.25 / Alg. 2 l.21): a vertex needs
    re-checking iff it changed or any neighbor changed."""
    contrib = jnp.where(g.edge_mask, changed[g.src].astype(jnp.int32), 0)
    nbr = jax.ops.segment_max(contrib, g.dst, num_segments=g.n_max) > 0
    return changed | nbr


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["labels", "iterations", "delta_n", "active_count"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class SweepHistory:
    labels: jax.Array
    iterations: jax.Array
    delta_n: jax.Array
    active_count: jax.Array
