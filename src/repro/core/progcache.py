"""Bounded compiled-program caches (DESIGN.md §Serving).

Every jitted-program factory on the hot path (`engine._fused_phase_fn`,
`louvain._stage_fn`, the batch-engine programs, ...) is memoized so repeated
driver calls reuse compiled programs instead of retracing fresh closures.
Unbounded memoization is fine for a single run but a LEAK in a long-lived
serving process: config churn (changing seeds live in the jit key via the
spec, fault tuples, capacity signatures) would accumulate compiled programs
without bound.  This module is the one place those caches are created, so
they are all

  * bounded — an explicit ``maxsize`` per cache, sized to the static menus
    that feed its key (capacity signatures, width menus, cascade stages);
    steady-state traffic therefore stays at 100% hits while a pathological
    key churn evicts LRU programs instead of growing forever;
  * observable — ``cache_stats()`` reports hits/misses/size per cache (the
    cache-stats hook), and the serving layer exposes it per engine.

The wrapped functions keep the full ``functools.lru_cache`` interface
(``cache_info()`` / ``cache_clear()``), so existing test hooks like
``louvain._stage_fn.cache_info().misses`` are unchanged.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

# name -> lru-wrapped factory; insertion-ordered, names are dotted paths
_REGISTRY: Dict[str, Callable] = {}


def program_cache(name: str, maxsize: int):
    """``functools.lru_cache(maxsize=...)`` that self-registers for stats.

    ``name`` must be unique (it is the stats key); re-decorating under an
    existing name (module reload in tests) simply replaces the entry.
    """

    def deco(fn):
        wrapped = functools.lru_cache(maxsize=maxsize)(fn)
        _REGISTRY[name] = wrapped
        return wrapped

    return deco


def cache_stats() -> dict:
    """{name: {hits, misses, maxsize, currsize}} for every program cache."""
    return {
        name: dict(c.cache_info()._asdict())
        for name, c in sorted(_REGISTRY.items())
    }


def clear_caches() -> None:
    """Drop every cached program (test hook; frees the compiled executables
    once JAX's own jit cache releases them)."""
    for c in _REGISTRY.values():
        c.cache_clear()
