"""Sequential baselines — the paper's comparison class (NetworkX / igraph tier).

The paper benchmarks Arachne against NetworkX, igraph and NetworKit.  Offline
we provide:
  * ``seq_lpa`` / ``seq_louvain`` — faithful single-threaded pure-Python
    implementations (the igraph/NetworkX algorithmic tier) that double as
    correctness oracles;
  * ``nx_lpa`` / ``nx_louvain`` — the actual NetworkX implementations
    (networkx ships in this container), the paper's headline baseline.
"""
from __future__ import annotations

import random
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graph.structure import Graph


def _adjacency(g: Graph) -> Tuple[List[List[Tuple[int, float]]], np.ndarray, float]:
    """(adj[v] = [(u, w)...] excluding loops, deg_w incl doubled loops, vol)."""
    src, dst, w = g.to_numpy_edges()
    n = int(g.n_valid)
    adj: List[List[Tuple[int, float]]] = [[] for _ in range(n)]
    deg_w = np.zeros(n, dtype=np.float64)
    for s, d, x in zip(src.tolist(), dst.tolist(), w.tolist()):
        deg_w[s] += x
        if s != d:
            adj[d].append((s, x))  # in-edges == out-edges by symmetry
    return adj, deg_w, float(deg_w.sum())


def seq_lpa(g: Graph, max_iterations: int = 100, seed: int = 0) -> np.ndarray:
    """Sequential asynchronous LPA (Raghavan et al.), random vertex order."""
    adj, _, _ = _adjacency(g)
    n = len(adj)
    rng = random.Random(seed)
    labels = list(range(n))
    order = list(range(n))
    for _ in range(max_iterations):
        rng.shuffle(order)
        changed = 0
        for v in order:
            if not adj[v]:
                continue
            score: Dict[int, float] = defaultdict(float)
            for u, x in adj[v]:
                score[labels[u]] += x
            best = max(score.values())
            cands = [c for c, s in score.items() if s == best]
            new = rng.choice(cands)
            if new != labels[v] and score.get(labels[v], 0.0) < best:
                labels[v] = new
                changed += 1
        if changed == 0:
            break
    return np.asarray(labels)


def seq_louvain(
    g: Graph, max_levels: int = 10, max_sweeps: int = 50, seed: int = 0
) -> np.ndarray:
    """Sequential Louvain (Blondel et al.) with real-time volume updates.

    Vertex-at-a-time Gauss–Seidel — the quality reference the paper compares
    its parallel implementation against (Fig. 3).
    """
    src0, dst0, w0 = g.to_numpy_edges()
    n0 = int(g.n_valid)
    assign = np.arange(n0)

    src, dst, w = src0.tolist(), dst0.tolist(), w0.tolist()
    n = n0
    rng = random.Random(seed)

    for _level in range(max_levels):
        adj: List[List[Tuple[int, float]]] = [[] for _ in range(n)]
        deg_w = np.zeros(n, dtype=np.float64)
        loop_w = np.zeros(n, dtype=np.float64)
        for s, d, x in zip(src, dst, w):
            deg_w[s] += x
            if s == d:
                loop_w[s] += x
            else:
                adj[d].append((s, x))
        vol_v = float(deg_w.sum())
        com = list(range(n))
        vol_com = deg_w.copy()

        improved_any = False
        for _sweep in range(max_sweeps):
            moved = 0
            order = list(range(n))
            rng.shuffle(order)
            for v in order:
                if not adj[v]:
                    continue
                a = com[v]
                kvc: Dict[int, float] = defaultdict(float)
                for u, x in adj[v]:
                    kvc[com[u]] += x
                vol_com[a] -= deg_w[v]
                base = kvc.get(a, 0.0) - deg_w[v] * vol_com[a] / vol_v
                best_c, best_gain = a, 0.0
                for c, k in kvc.items():
                    if c == a:
                        continue
                    gain = (k - deg_w[v] * vol_com[c] / vol_v) - base
                    if gain > best_gain + 1e-12 or (
                        abs(gain - best_gain) <= 1e-12 and best_c != a and c < best_c
                    ):
                        best_gain, best_c = gain, c
                com[v] = best_c
                vol_com[best_c] += deg_w[v]
                if best_c != a:
                    moved += 1
            if moved == 0:
                break
            improved_any = True

        # contiguous remap
        uniq = sorted(set(com))
        remap = {c: i for i, c in enumerate(uniq)}
        com_arr = np.asarray([remap[c] for c in com])
        n_comm = len(uniq)
        if n_comm == n or not improved_any:
            break
        assign = com_arr[assign]
        # aggregate
        agg: Dict[Tuple[int, int], float] = defaultdict(float)
        for s, d, x in zip(src, dst, w):
            agg[(int(com_arr[s]), int(com_arr[d]))] += x
        src = [k[0] for k in agg]
        dst = [k[1] for k in agg]
        w = [agg[k] for k in agg]
        n = n_comm
    # final contiguous ids
    uniq = sorted(set(assign.tolist()))
    remap = {c: i for i, c in enumerate(uniq)}
    return np.asarray([remap[c] for c in assign.tolist()])


# ------------------------------------------------------------ networkx tier


def _to_networkx(g: Graph):
    import networkx as nx

    src, dst, w = g.to_numpy_edges()
    G = nx.Graph()
    G.add_nodes_from(range(int(g.n_valid)))
    for s, d, x in zip(src.tolist(), dst.tolist(), w.tolist()):
        if s <= d:
            G.add_edge(s, d, weight=(x / 2.0 if s == d else x))
    return G


def nx_lpa(g: Graph, seed: int = 0) -> np.ndarray:
    import networkx as nx

    G = _to_networkx(g)
    labels = np.arange(int(g.n_valid))
    for i, comm in enumerate(
        nx.algorithms.community.asyn_lpa_communities(G, weight="weight", seed=seed)
    ):
        for v in comm:
            labels[v] = i
    return labels


def nx_louvain(g: Graph, seed: int = 0) -> np.ndarray:
    import networkx as nx

    G = _to_networkx(g)
    labels = np.arange(int(g.n_valid))
    for i, comm in enumerate(
        nx.algorithms.community.louvain_communities(G, weight="weight", seed=seed)
    ):
        for v in comm:
            labels[v] = i
    return labels


def nx_modularity(g: Graph, labels: np.ndarray) -> float:
    import networkx as nx

    G = _to_networkx(g)
    groups: Dict[int, set] = defaultdict(set)
    for v, c in enumerate(np.asarray(labels)[: int(g.n_valid)].tolist()):
        groups[c].add(v)
    return float(
        nx.algorithms.community.modularity(G, list(groups.values()), weight="weight")
    )
