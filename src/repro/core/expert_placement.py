"""Expert placement via community detection — the paper's technique applied
INSIDE the training framework (beyond-paper integration, DESIGN.md §9).

Problem: MoE all-to-all traffic depends on which experts co-fire for the same
token (top-k>1) or for adjacent tokens in a sequence.  Placing co-activated
experts on the same device group turns cross-device dispatch into local
dispatch for the correlated fraction of traffic.

Method: build the expert co-activation graph (edge weight = how often experts
i,j are routed together), run THE PAPER'S parallel Louvain on it, then pack
communities onto device groups greedily (balanced, capacity = experts-per-
device).  This is exactly the Arachne pipeline — GroupBy-style aggregation +
modularity maximization — reused as a systems optimization.

API:
  coactivation_graph(routing)      (T, k) int32 -> Graph over E experts
  louvain_placement(g, n_experts, n_groups) -> (E,) int32 device-group ids
  placement_traffic(routing, placement, n_groups) -> cross-group assignment frac
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.louvain import LouvainConfig, louvain
from repro.graph.builders import from_numpy_edges
from repro.graph.structure import Graph


def coactivation_graph(routing: np.ndarray, n_experts: int) -> Graph:
    """routing: (T, k) int32 expert ids per token -> co-activation Graph."""
    routing = np.asarray(routing)
    t, k = routing.shape
    if k < 2:
        # top-1: co-activation across ADJACENT tokens (sequence locality)
        a = routing[:-1, 0]
        b = routing[1:, 0]
    else:
        pairs = []
        for i in range(k):
            for j in range(i + 1, k):
                pairs.append((routing[:, i], routing[:, j]))
        a = np.concatenate([p[0] for p in pairs])
        b = np.concatenate([p[1] for p in pairs])
    keep = a != b
    a, b = a[keep], b[keep]
    # aggregate parallel edges (GroupBy.sum — same primitive as aggregation)
    key = a.astype(np.int64) * n_experts + b.astype(np.int64)
    uniq, counts = np.unique(key, return_counts=True)
    u = (uniq // n_experts).astype(np.int64)
    v = (uniq % n_experts).astype(np.int64)
    return from_numpy_edges(u, v, counts.astype(np.float64), n=n_experts)


def louvain_placement(g: Graph, n_experts: int, n_groups: int,
                      seed: int = 0) -> np.ndarray:
    """Louvain communities -> balanced device-group assignment (E,) int32."""
    res = louvain(g, LouvainConfig(seed=seed, track_modularity=False))
    com = np.asarray(res.labels)[:n_experts]
    cap = (n_experts + n_groups - 1) // n_groups
    # pack communities (largest first) into groups with capacity `cap`
    order = sorted(np.unique(com), key=lambda c: -(com == c).sum())
    load = np.zeros(n_groups, dtype=np.int64)
    placement = np.zeros(n_experts, dtype=np.int32)
    for c in order:
        members = np.where(com == c)[0]
        # fill the least-loaded groups, splitting if the community overflows
        while members.size:
            gidx = int(np.argmin(load))
            take = min(members.size, cap - int(load[gidx]))
            if take <= 0:
                cap += 1  # all groups full at current cap: relax
                continue
            placement[members[:take]] = gidx
            load[gidx] += take
            members = members[take:]
    return placement


def placement_traffic(routing: np.ndarray, placement: np.ndarray,
                      n_groups: int) -> float:
    """Fraction of co-routed expert pairs that cross device groups
    (a proxy for all-to-all bytes; lower is better)."""
    routing = np.asarray(routing)
    t, k = routing.shape
    if k < 2:
        a, b = routing[:-1, 0], routing[1:, 0]
    else:
        pa, pb = [], []
        for i in range(k):
            for j in range(i + 1, k):
                pa.append(routing[:, i])
                pb.append(routing[:, j])
        a, b = np.concatenate(pa), np.concatenate(pb)
    keep = a != b
    a, b = a[keep], b[keep]
    if a.size == 0:
        return 0.0
    cross = placement[a] != placement[b]
    return float(cross.mean())


def random_placement(n_experts: int, n_groups: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = np.repeat(np.arange(n_groups), (n_experts + n_groups - 1) // n_groups)
    return rng.permutation(base[:n_experts]).astype(np.int32)
