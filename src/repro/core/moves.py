"""Move-evaluation primitives shared by single-device and shard_map sweeps.

Both PLP (Alg. 1 l.18) and Louvain local-moving (Alg. 2 l.13-16) reduce to:
  "for every destination vertex, group incident edges by a per-edge candidate
   key, sum weights per group, then argmax a per-group score"
— the sort+segment GroupBy pattern.  The distributed sweeps call these on
*local* edge shards (each vertex's in-edges live on its owner device), so the
same code serves 1 device or a 512-chip mesh.

``core.engine`` composes these evaluators with shared move-gating / frontier
plumbing into the fused per-level sweep loop (DESIGN.md §Engine).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.common import tie_noise
from repro.graph import segment as seg


def plp_best_labels(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    labels: jax.Array,
    n: int,
    it: jax.Array,
    seed: jax.Array,
    tie_eps: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(best_score[n], best_label[n], cur_score[n]) for the PLP move.

    ``labels`` is the full (replicated) label array; edge arrays may be any
    static length (a local shard).  Vertices with no valid incident edge get
    best_score = -inf, best_label = -1.

    Thin wrapper over ``plp_best_labels_tables`` (ONE implementation of the
    scoring math): extending ``labels`` with the sentinel sink slot changes
    no output — every read that could hit the sink is masked by edge/group
    validity before use.
    """
    labels_ext = jnp.concatenate([labels, jnp.full((1,), n, labels.dtype)])
    return plp_best_labels_tables(
        src, dst, w, valid, labels_ext, n, it, seed, tie_eps)


def plp_best_labels_tables(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    labels_ext: jax.Array,   # (n+1,) labels table, labels_ext[n] = n
    n: int,
    it: jax.Array,
    seed: jax.Array,
    tie_eps: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``plp_best_labels`` on the once-per-sweep EXTENDED label table.

    Used by the ELL evaluator's high-degree tail (DESIGN.md §Kernels): the
    fused bucket path already built ``labels_ext`` for this sweep, so the
    tail's per-edge gathers index the same array (slot n is the sink —
    ids in [0, n] need no clip guard) instead of re-deriving them from the
    raw ``labels``.  Outputs are bit-identical to ``plp_best_labels``: every
    place the sink value can differ from the raw array's clipped read is
    masked by ``valid`` / group-validity before use.
    """
    sentinel = jnp.int32(n)
    cand_valid = valid & (src != dst)
    dst_k = jnp.where(cand_valid, dst, sentinel)
    lab_k = jnp.where(cand_valid, labels_ext[jnp.clip(src, 0, n)], sentinel)
    w_v = jnp.where(cand_valid, w, 0.0)

    (gk, gs, gvalid, _) = seg.groupby_sum((dst_k, lab_k), w_v)
    gdst, glab = gk
    grp_ok = gvalid & (gdst < sentinel) & (glab < sentinel)

    noise = tie_noise(gdst, glab, seed + it, tie_eps)
    score = jnp.where(grp_ok, gs + noise, -jnp.inf)
    seg_ids = jnp.where(grp_ok, gdst, n)
    best_score, best_lab = seg.segment_argmax(
        score, glab, seg_ids, num_segments=n + 1, valid=grp_ok
    )
    cur_match = grp_ok & (glab == labels_ext[jnp.clip(gdst, 0, n)])
    cur_score = jax.ops.segment_sum(
        jnp.where(cur_match, score, 0.0), seg_ids, num_segments=n + 1
    )
    return best_score[:n], best_lab[:n], cur_score[:n]


def community_aux(
    com: jax.Array,
    deg: jax.Array,
    vmask: jax.Array,
    n: int,
) -> Tuple[jax.Array, jax.Array]:
    """(vol_com[n], size_com[n]) — the replicated per-sweep Louvain state.

    Stands in for the paper's atomically-maintained volCom array (Alg. 2
    l.18-19): the synchronous sweep recomputes it from scratch, which is
    cheap, deterministic, and needs no cross-device communication when
    ``com``/``deg`` are replicated.
    """
    com_c = jnp.clip(com, 0, n - 1)
    vol_com = jax.ops.segment_sum(deg, com_c, num_segments=n)
    size_com = jax.ops.segment_sum(
        jnp.where(vmask, 1, 0), com_c, num_segments=n
    )
    return vol_com, size_com


def louvain_best_moves(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    com: jax.Array,
    deg: jax.Array,
    vol_com: jax.Array,
    size_com: jax.Array,
    vol_v: jax.Array,
    n: int,
    singleton_rule: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """(best_gain[n], best_community[n]) for the Louvain local move (Eq. 1).

    gain is Eq. 1 rescaled by 1/vol(V):  ΔQ = 2·gain/vol(V).
    ``com``/``deg``/``vol_com``/``size_com`` are full replicated arrays.

    Thin wrapper over ``louvain_best_moves_tables`` (ONE implementation of
    the Eq. 1 math): extending the arrays with the sentinel sink slot
    changes no output — sink reads only occur for groups masked to -inf
    before the argmax either way.
    """
    com_ext = jnp.concatenate([com, jnp.full((1,), n, com.dtype)])
    vol_ext = jnp.concatenate([vol_com, jnp.zeros((1,), vol_com.dtype)])
    size_ext = jnp.concatenate([size_com, jnp.zeros((1,), size_com.dtype)])
    deg_ext = jnp.concatenate([deg, jnp.zeros((1,), deg.dtype)])
    return louvain_best_moves_tables(
        src, dst, w, valid, com_ext, vol_ext, size_ext, deg_ext, vol_v, n,
        singleton_rule=singleton_rule)


def louvain_best_moves_tables(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    com_ext: jax.Array,    # (n+1,) community table, com_ext[n] = n
    vol_ext: jax.Array,    # (n+1,) community volumes, vol_ext[n] = 0
    size_ext: jax.Array,   # (n+1,) community sizes, size_ext[n] = 0
    deg_ext: jax.Array,    # (n+1,) weighted degrees, deg_ext[n] = 0
    vol_v: jax.Array,
    n: int,
    singleton_rule: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """``louvain_best_moves`` on the once-per-sweep EXTENDED tables.

    Used by the ELL evaluator's high-degree tail (DESIGN.md §Kernels): the
    fused bucket path already built com/vol/size/deg_ext for this sweep, so
    the tail's gathers index the same arrays (sink slot n) instead of the
    raw com/vol_com/size_com/deg with clip guards.  Bit-identical to
    ``louvain_best_moves``: sink reads only occur for invalid groups, whose
    gain is masked to -inf before the argmax either way.
    """
    sentinel = jnp.int32(n)
    cand_valid = valid & (src != dst)
    dst_k = jnp.where(cand_valid, dst, sentinel)
    cand_k = jnp.where(cand_valid, com_ext[jnp.clip(src, 0, n)], sentinel)
    w_v = jnp.where(cand_valid, w, 0.0)

    (gk, gs, gvalid, _) = seg.groupby_sum((dst_k, cand_k), w_v)
    gdst, gcand = gk
    grp_ok = gvalid & (gdst < sentinel) & (gcand < sentinel)

    gdst_e = jnp.clip(gdst, 0, n)
    seg_ids = jnp.where(grp_ok, gdst, n)
    A = com_ext[gdst_e]
    deg_d = deg_ext[gdst_e]
    s_to_A = jax.ops.segment_sum(
        jnp.where(grp_ok & (gcand == A), gs, 0.0), seg_ids, num_segments=n + 1
    )[:n]

    cand_e = jnp.clip(gcand, 0, n)
    A_e = jnp.clip(A, 0, n)
    vol_B_minus = vol_ext[cand_e] - jnp.where(gcand == A, deg_d, 0.0)
    vol_A_minus = vol_ext[A_e] - deg_d
    gain = (gs - s_to_A[jnp.clip(gdst, 0, n - 1)]
            ) - deg_d * (vol_B_minus - vol_A_minus) / vol_v

    if singleton_rule:
        both_single = (size_ext[A_e] == 1) & (size_ext[cand_e] == 1)
        gain = jnp.where(both_single & (gcand > A), -jnp.inf, gain)

    gain = jnp.where(grp_ok & (gcand != A), gain, -jnp.inf)
    best_gain, best_cand = seg.segment_argmax(
        gain, gcand, seg_ids, num_segments=n + 1, valid=grp_ok
    )
    return best_gain[:n], best_cand[:n]
