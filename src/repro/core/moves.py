"""Move-evaluation primitives shared by single-device and shard_map sweeps.

Both PLP (Alg. 1 l.18) and Louvain local-moving (Alg. 2 l.13-16) reduce to:
  "for every destination vertex, group incident edges by a per-edge candidate
   key, sum weights per group, then argmax a per-group score"
— the sort+segment GroupBy pattern.  The distributed sweeps call these on
*local* edge shards (each vertex's in-edges live on its owner device), so the
same code serves 1 device or a 512-chip mesh.

``core.engine`` composes these evaluators with shared move-gating / frontier
plumbing into the fused per-level sweep loop (DESIGN.md §Engine).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.common import tie_noise
from repro.graph import segment as seg


def plp_best_labels(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    labels: jax.Array,
    n: int,
    it: jax.Array,
    seed: jax.Array,
    tie_eps: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(best_score[n], best_label[n], cur_score[n]) for the PLP move.

    ``labels`` is the full (replicated) label array; edge arrays may be any
    static length (a local shard).  Vertices with no valid incident edge get
    best_score = -inf, best_label = -1.
    """
    sentinel = jnp.int32(n)
    cand_valid = valid & (src != dst)
    dst_k = jnp.where(cand_valid, dst, sentinel)
    lab_k = jnp.where(cand_valid, labels[jnp.clip(src, 0, n - 1)], sentinel)
    w_v = jnp.where(cand_valid, w, 0.0)

    (gk, gs, gvalid, _) = seg.groupby_sum((dst_k, lab_k), w_v)
    gdst, glab = gk
    grp_ok = gvalid & (gdst < sentinel) & (glab < sentinel)

    noise = tie_noise(gdst, glab, seed + it, tie_eps)
    score = jnp.where(grp_ok, gs + noise, -jnp.inf)
    seg_ids = jnp.where(grp_ok, gdst, n)
    best_score, best_lab = seg.segment_argmax(
        score, glab, seg_ids, num_segments=n + 1, valid=grp_ok
    )
    cur_match = grp_ok & (glab == labels[jnp.clip(gdst, 0, n - 1)])
    cur_score = jax.ops.segment_sum(
        jnp.where(cur_match, score, 0.0), seg_ids, num_segments=n + 1
    )
    return best_score[:n], best_lab[:n], cur_score[:n]


def community_aux(
    com: jax.Array,
    deg: jax.Array,
    vmask: jax.Array,
    n: int,
) -> Tuple[jax.Array, jax.Array]:
    """(vol_com[n], size_com[n]) — the replicated per-sweep Louvain state.

    Stands in for the paper's atomically-maintained volCom array (Alg. 2
    l.18-19): the synchronous sweep recomputes it from scratch, which is
    cheap, deterministic, and needs no cross-device communication when
    ``com``/``deg`` are replicated.
    """
    com_c = jnp.clip(com, 0, n - 1)
    vol_com = jax.ops.segment_sum(deg, com_c, num_segments=n)
    size_com = jax.ops.segment_sum(
        jnp.where(vmask, 1, 0), com_c, num_segments=n
    )
    return vol_com, size_com


def louvain_best_moves(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    valid: jax.Array,
    com: jax.Array,
    deg: jax.Array,
    vol_com: jax.Array,
    size_com: jax.Array,
    vol_v: jax.Array,
    n: int,
    singleton_rule: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """(best_gain[n], best_community[n]) for the Louvain local move (Eq. 1).

    gain is Eq. 1 rescaled by 1/vol(V):  ΔQ = 2·gain/vol(V).
    ``com``/``deg``/``vol_com``/``size_com`` are full replicated arrays.
    """
    sentinel = jnp.int32(n)
    cand_valid = valid & (src != dst)
    dst_k = jnp.where(cand_valid, dst, sentinel)
    cand_k = jnp.where(cand_valid, com[jnp.clip(src, 0, n - 1)], sentinel)
    w_v = jnp.where(cand_valid, w, 0.0)

    (gk, gs, gvalid, _) = seg.groupby_sum((dst_k, cand_k), w_v)
    gdst, gcand = gk
    grp_ok = gvalid & (gdst < sentinel) & (gcand < sentinel)

    gdst_c = jnp.clip(gdst, 0, n - 1)
    seg_ids = jnp.where(grp_ok, gdst, n)
    A = com[gdst_c]
    deg_d = deg[gdst_c]
    s_to_A = jax.ops.segment_sum(
        jnp.where(grp_ok & (gcand == A), gs, 0.0), seg_ids, num_segments=n + 1
    )[:n]

    cand_c = jnp.clip(gcand, 0, n - 1)
    vol_B_minus = vol_com[cand_c] - jnp.where(gcand == A, deg_d, 0.0)
    vol_A_minus = vol_com[jnp.clip(A, 0, n - 1)] - deg_d
    gain = (gs - s_to_A[gdst_c]) - deg_d * (vol_B_minus - vol_A_minus) / vol_v

    if singleton_rule:
        both_single = (size_com[jnp.clip(A, 0, n - 1)] == 1) & (size_com[cand_c] == 1)
        gain = jnp.where(both_single & (gcand > A), -jnp.inf, gain)

    gain = jnp.where(grp_ok & (gcand != A), gain, -jnp.inf)
    best_gain, best_cand = seg.segment_argmax(
        gain, gcand, seg_ids, num_segments=n + 1, valid=grp_ok
    )
    return best_gain[:n], best_cand[:n]
