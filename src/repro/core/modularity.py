"""Modularity (§II-C) and the move gain Δ𝑄 (Eq. 1) on the directed-symmetric form.

With the Graph convention (self-loops stored once with doubled weight):

    Q(C) = Σ_c  w_in(c)/vol(V)  −  (vol_w(c)/vol(V))²

where ``w_in(c)`` counts directed intra-community weight (loops enter once but
carry doubled weight — i.e. exactly twice the undirected intra weight), and
``vol(V) = Σ_v deg_w(v) = 2W``.  On loop-free graphs this equals NetworkX's
``community.modularity`` definition exactly (tested).

Move gain: for v moving A → B (paper Eq. 1; note the paper's ``deg_w(V)`` is a
typo for ``deg_w(v)``):

    ΔQ_{v→B} = 2·[ (cut_w(v,B⁻) − cut_w(v,A⁻))/vol(V)
                   − deg_w(v)·(vol_w(B⁻) − vol_w(A⁻))/vol(V)² ]

We maximize the equivalent integer-friendly score

    score(B) = vol(V)·(cut_w(v,B⁻) − cut_w(v,A⁻)) − deg_w(v)·(vol_w(B⁻) − vol_w(A⁻))

with ΔQ = 2·score/vol(V)².  ``score(A) = 0`` by construction, so "move iff
score > 0" is exactly "move iff ΔQ > 0".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.structure import Graph


def community_volumes(g: Graph, com: jax.Array) -> jax.Array:
    """vol_w(c) = Σ_{v∈c} deg_w(v), indexed by community id (capacity n_max)."""
    deg = g.weighted_degrees()
    return jax.ops.segment_sum(deg, com, num_segments=g.n_max)


def community_sizes(g: Graph, com: jax.Array) -> jax.Array:
    ones = jnp.where(g.vertex_mask(), 1, 0)
    return jax.ops.segment_sum(ones, com, num_segments=g.n_max)


def intra_weight(g: Graph, com: jax.Array) -> jax.Array:
    """Σ_c w_in(c): directed weight of edges with both endpoints co-clustered."""
    same = com[g.src] == com[g.dst]
    return jnp.sum(jnp.where(g.edge_mask & same, g.w, 0.0))


def modularity(g: Graph, com: jax.Array, *, promote: bool = False) -> jax.Array:
    """Newman–Girvan modularity of the partition ``com`` (f32 scalar).

    Guard rails (DESIGN.md §Robustness):
    * an edgeless graph (vol = 0) returns Q = 0 instead of 0/0 = NaN; for
      vol > 0 the guarded expression is bitwise identical to the unguarded
      one (same divisions, selected verbatim);
    * ``promote=True`` (the drivers set it via ``accum_needs_promotion``
      when m·max-weight approaches float32 precision loss) accumulates the
      volume/intra sums in float64 when x64 is enabled — otherwise it stays
      f32 and ``accum_dtype`` records the risk for the RunReport.
    """
    from repro.kernels.common import accum_dtype

    acc = accum_dtype(promote)
    if acc == jnp.float32:
        vol_v = g.total_volume()
        w_in = intra_weight(g, com)
        vol_c = community_volumes(g, com)
    else:
        wm = jnp.where(g.edge_mask, g.w, 0.0).astype(acc)
        vol_v = jnp.sum(wm)
        same = com[g.src] == com[g.dst]
        w_in = jnp.sum(jnp.where(same, wm, jnp.zeros((), acc)))
        deg = jax.ops.segment_sum(wm, g.src, num_segments=g.n_max)
        vol_c = jax.ops.segment_sum(deg, com, num_segments=g.n_max)
    safe = jnp.where(vol_v > 0, vol_v, jnp.ones((), vol_v.dtype))
    q = w_in / safe - jnp.sum((vol_c / safe) ** 2)
    return jnp.where(vol_v > 0, q, jnp.zeros((), q.dtype)).astype(jnp.float32)


def delta_q_from_score(score: jax.Array, vol_v: jax.Array) -> jax.Array:
    return 2.0 * score / (vol_v * vol_v)


def move_score(
    cut_vB: jax.Array,
    cut_vA: jax.Array,
    deg_v: jax.Array,
    vol_B_minus: jax.Array,
    vol_A_minus: jax.Array,
    vol_v: jax.Array,
) -> jax.Array:
    """score = vol(V)·(cut(v,B⁻) − cut(v,A⁻)) − deg_w(v)·(vol(B⁻) − vol(A⁻))."""
    return vol_v * (cut_vB - cut_vA) - deg_v * (vol_B_minus - vol_A_minus)


def modularity_dense_reference(adj, com) -> float:
    """O(n²) dense oracle for tests: adj is a symmetric numpy matrix with
    doubled diagonal (matching the Graph convention)."""
    import numpy as np

    adj = np.asarray(adj, dtype=np.float64)
    com = np.asarray(com)
    vol_v = adj.sum()
    deg = adj.sum(axis=1)
    q = 0.0
    for c in np.unique(com):
        idx = com == c
        w_in = adj[np.ix_(idx, idx)].sum()
        vol_c = deg[idx].sum()
        q += w_in / vol_v - (vol_c / vol_v) ** 2
    return float(q)
