"""Parallel Louvain (paper Alg. 2 + Alg. 3) — TPU-native.

Faithful structure:
  * singleton init with comID = vertexID, volVertex/volCom arrays (Alg. 2 l.3-8)
  * local-moving: per-vertex parallel Δ𝑄 evaluation over neighboring
    communities (Eq. 1), greedy argmax move when Δ𝑄 > 0 (l.9-24)
  * needCheck set: re-evaluate a vertex only if it or a neighbor changed (l.11,
    l.21, l.25)
  * level loop: local-moving then aggregation until |C| == |V| (Alg. 3)

Adaptations (DESIGN.md §2 / §8): atomic volCom updates (l.18-19) become a
segment-sum recompute at each synchronous sweep; the Lu–Halappanavar singleton
tie-break suppresses the classic PLM two-singleton swap oscillation.

The sweep machinery lives in the shared ``core.engine`` (DESIGN.md §Engine).
With ``pipeline_fused=True`` (default) the ENTIRE level loop — fused
local-moving phase → remap → coarsen → modularity accounting, plus the
optional Leiden refinement phase — runs as one jitted ``lax.while_loop`` over
levels with the Alg. 3 ``|C| == |V|`` convergence predicate evaluated on
device: a whole Louvain/Leiden run is ONE dispatch with ONE host readback at
the end (DESIGN.md §Pipeline).  Per-level modularity / sweep-count /
community-count histories are written into fixed-size on-device buffers
(``-1`` / NaN sentinels) and reconstructed from that single transfer.

``pipeline_fused=False`` keeps the per-level Python driver (one fused
local-moving dispatch per level, aggregation and convergence check on host)
with a bit-for-bit parity contract against the fused pipeline, enforced by
``tests/test_pipeline.py``.

``capacity_schedule`` adds the coarse-level CASCADE (DESIGN.md §Pipeline):
once the carried coarse graph fits a smaller static capacity from a bounded
schedule, the fused loop exits, the graph is compacted on device
(``aggregation.shrink_graph``) and the level loop resumes under a program
compiled at the smaller capacity — so deep-hierarchy aggregation sorts and
sweeps stop paying level-0 cost.  Inside a cascade the ``ell``/``pallas``
backends also apply to COARSE levels, through the traced per-stage ELL
re-bucketing (``graph/ell.traced_ell_tile``); ``capacity_schedule="none"``
pins today's single-capacity program — the bit-for-bit parity oracle, with
the segment evaluator on coarse levels, matched by the per-level driver.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core import aggregation
from repro.core.engine import EngineSpec, SweepEngine, device_phase
from repro.core.modularity import modularity
from repro.core.progcache import program_cache
from repro.graph.structure import Graph
from repro.kernels.common import accum_needs_promotion, pick_ell_width
from repro.utils import faultinject, resilience, telemetry
from repro.utils.errors import (CapacityError, CommunityDetectionError,
                                KernelError, NumericError, RunReport)
from repro.utils.timing import Timer

# Fault-injection points that act inside the sweep trace and therefore ride
# the EngineSpec (the jit cache key); the others act at the aggregation /
# driver / ingest layers and are threaded separately (DESIGN.md §Robustness).
ENGINE_FAULTS = ("oscillation", "vmem_starve")

# Kernel-failure degradation ladder: on a non-taxonomy failure the driver
# retries on the next-simpler backend — each step is bit-identical on clean
# input by the kernel≡ell≡segment parity contracts, so descending can only
# trade speed, never results.
BACKEND_DESCENT = {"pallas": "ell", "ell": "segment"}

# Sweep-counter stride per level and the refinement phase's offset within a
# level: level L's local-moving phase hashes tie noise / Luby gates from
# it0 = L·LEVEL_IT_STRIDE, Leiden refinement from it0 + REFINE_IT_OFFSET.
# Shared with core.distributed so every driver (local per-level, local fused,
# distributed replicated, distributed shard-local) draws the SAME per-sweep
# randomness — a precondition of the bit-for-bit parity contracts.
LEVEL_IT_STRIDE = 1000
REFINE_IT_OFFSET = 500


# ------------------------------------------------------------ capacity schedule


def auto_capacity_schedule(
    n_max: int,
    m_max: int,
    *,
    max_stages: int = 4,
    shrink: int = 4,
    n_floor: int = 256,
    m_floor: int = 2048,
    min_n: int = 4096,
) -> Tuple[Tuple[int, int], ...]:
    """Bounded static capacity schedule for the coarse-level cascade.

    Quarter steps from the full capacity down to the floors, at most
    ``max_stages`` entries — so at most that many distinct compiled stage
    programs per run regardless of graph size or hierarchy depth (DESIGN.md
    §Pipeline).  Graphs below ``min_n`` vertices stay single-capacity: at
    that scale every level is dispatch-bound and extra compiles cost more
    than the shrink saves.
    """
    caps = [(int(n_max), int(m_max))]
    if n_max < min_n:
        return tuple(caps)
    while len(caps) < max_stages:
        # floors are clamped to the previous capacity: a graph whose own
        # capacity sits below a floor (e.g. a capacity-padded sparse graph
        # with m_max < m_floor) must never be scheduled to GROW
        nc = min(caps[-1][0], max(n_floor, -(-caps[-1][0] // shrink)))
        mc = min(caps[-1][1], max(m_floor, -(-caps[-1][1] // shrink)))
        if (nc, mc) == caps[-1]:
            break
        caps.append((nc, mc))
    return tuple(caps)


def _validate_schedule(sched) -> None:
    if isinstance(sched, str) and sched in ("auto", "none"):
        return
    ok = isinstance(sched, tuple) and len(sched) > 0
    if ok:
        for c in sched:
            if not (isinstance(c, tuple) and len(c) == 2 and all(
                    isinstance(x, int) and not isinstance(x, bool) and x > 0
                    for x in c)):
                ok = False
                break
    if ok:
        for a, b in zip(sched, sched[1:]):
            if not (b[0] <= a[0] and b[1] <= a[1] and b != a):
                ok = False
                break
    if not ok:
        raise ValueError(
            "capacity_schedule must be 'auto' (bounded schedule derived from "
            "the graph capacities), 'none' (single-capacity pipeline, the "
            "parity oracle), or an explicit tuple of descending "
            "(n_cap, m_cap) positive-int pairs such as "
            f"((8192, 131072), (2048, 32768)); got {sched!r}")


@dataclasses.dataclass(frozen=True)
class LouvainConfig(ConfigBase):
    max_levels: int = 10
    max_sweeps: int = 25        # Alg. 2 maxIteration
    sweep_threshold: int = 0    # stop local-moving when ΔN <= this
    backend: str = "segment"    # segment | ell | pallas
    # Coarsening path (DESIGN.md §Aggregation kernel): "binned" is the
    # sort-free scatter-accumulation default; "sort" selects the one-sort
    # fused remap+coarsen, kept as the bit-for-bit parity oracle.
    aggregation: str = "binned"  # binned | sort
    # ell/pallas table layout: VMEM-resident vs windowed streaming; "auto"
    # resolves from the VMEM byte budget (DESIGN.md §Kernels)
    table_mode: str = "auto"    # auto | resident | streamed
    use_need_check: bool = True
    singleton_rule: bool = True # Lu et al. swap suppression
    move_prob: float = 0.5      # Luby-style move gating (1.0 = pure Jacobi)
    seed: int = 0
    track_modularity: bool = True
    fused: bool = True          # one while_loop per level vs per-sweep dispatch
    # Whole-run fusion (DESIGN.md §Pipeline): the level loop itself becomes a
    # lax.while_loop, so louvain()/leiden() is one dispatch + one readback.
    # Requires fused sweeps; with fused=False the per-level driver runs.
    pipeline_fused: bool = True
    # Coarse-level cascade (DESIGN.md §Pipeline): once the carried coarse
    # graph fits a smaller static capacity from the schedule, the fused loop
    # descends to a program compiled at that capacity.  "auto" derives a
    # bounded (≤4-program) schedule from (n_max, m_max); "none" pins the
    # single-capacity pipeline (the bit-for-bit parity oracle); an explicit
    # tuple of descending (n_cap, m_cap) pairs is used as given.
    capacity_schedule: "str | Tuple[Tuple[int, int], ...]" = "auto"
    # Leiden-style refinement (beyond paper; the paper cites Leiden [30] as
    # the natural next algorithm): refine each community into well-connected
    # sub-communities before aggregation, then seed the next level with the
    # macro partition instead of singletons.
    refine: bool = False
    refine_sweeps: int = 8
    # Per-level driver only: record additional L<level>/<phase> timer entries
    # (the paper-style fig4 phase split used by `benchmarks/run.py
    # level_fusion`).
    per_level_timing: bool = False
    # Opt-in stage-boundary checkpoint/resume (DESIGN.md §Resilience): at
    # every cascade stage boundary the carried device state (graph arrays,
    # assignment chain, history buffers, level counter) is persisted via the
    # atomic write-then-rename checkpointer (train/checkpoint.py) into this
    # directory; a killed/preempted run re-invoked with the SAME config and
    # graph resumes from the last committed boundary, bit-identical to the
    # uninterrupted run.  Granularity is the stage boundary — a kill inside
    # a stage replays that stage.  One run per directory; checkpoints are
    # cleared on successful completion.  None (default) = no checkpointing;
    # degenerate (single-stage) schedules cross no boundary and never save.
    checkpoint_dir: Optional[str] = None

    def __post_init__(self):
        if self.max_levels < 1:
            raise ValueError(
                f"max_levels must be >= 1, got {self.max_levels}")
        if not (0.0 < self.move_prob <= 1.0):
            raise ValueError(
                f"move_prob must be in (0, 1], got {self.move_prob}")
        if self.refine_sweeps < 1:
            raise ValueError(
                f"refine_sweeps must be >= 1, got {self.refine_sweeps}")
        if self.aggregation not in aggregation.AGGREGATION_METHODS:
            raise ValueError(
                f"aggregation must be one of "
                f"{aggregation.AGGREGATION_METHODS}, got {self.aggregation!r}")
        _validate_schedule(self.capacity_schedule)


@dataclasses.dataclass
class LouvainResult:
    labels: np.ndarray            # community id per ORIGINAL vertex (contiguous)
    n_communities: int
    levels: int
    modularity: float
    modularity_history: list      # per level
    sweeps_per_level: list
    timer: Timer
    n_comm_per_level: list = dataclasses.field(default_factory=list)
    delta_n_per_level: list = dataclasses.field(default_factory=list)
    # (n_cap, m_cap) of each cascade stage actually entered, in order; a
    # single entry means the schedule degenerated to one program
    cascade_stages: list = dataclasses.field(default_factory=list)
    # what the hardened driver repaired / retried / degraded / flagged on
    # the way here (DESIGN.md §Robustness); clean on the happy path
    run_report: RunReport = dataclasses.field(default_factory=RunReport)


def engine_spec(cfg: LouvainConfig, backend: Optional[str] = None,
                max_sweeps: Optional[int] = None,
                faults: frozenset = frozenset()) -> EngineSpec:
    return EngineSpec(
        evaluator="louvain",
        backend=backend or cfg.backend,
        max_sweeps=cfg.max_sweeps if max_sweeps is None else max_sweeps,
        threshold=cfg.sweep_threshold,
        move_prob=float(cfg.move_prob),
        use_frontier=cfg.use_need_check,
        singleton_rule=cfg.singleton_rule,
        table_mode=cfg.table_mode,
        faults=tuple(sorted(f for f in faults if f in ENGINE_FAULTS)),
    )


def _coarse_backend(backend: str) -> str:
    """DESIGN.md §Pipeline: the host-built ELL layout covers the finest
    graph only; OUTSIDE a cascade every coarse level runs the segment
    evaluator (in both the single-capacity pipeline and the per-level
    driver, so they stay bit-identical).  Cascade stages instead re-bucket
    on the fly — see ``_cascade_coarse_spec``."""
    return "segment" if backend in ("ell", "pallas") else backend


def _resolve_schedule(cfg: LouvainConfig, g: Graph) -> Tuple[Tuple[int, int], ...]:
    """Concrete capacity schedule for this graph: full capacity first, then
    the validated descending entries that actually fit under it."""
    sched = cfg.capacity_schedule
    full = (g.n_max, g.m_max)
    if sched == "none":
        return (full,)
    if sched == "auto":
        return auto_capacity_schedule(g.n_max, g.m_max)
    caps = [full]
    for c in sched:
        c = (int(c[0]), int(c[1]))
        if (c[0] <= full[0] and c[1] <= full[1]
                and (c[0] < caps[-1][0] or c[1] < caps[-1][1])):
            caps.append(c)
    return tuple(caps)


def _cascade_coarse_spec(cfg: LouvainConfig, cascade: bool, width: int,
                         faults: frozenset = frozenset()) -> EngineSpec:
    """Coarse-level engine spec for one stage.

    Inside a cascade the ``ell``/``pallas`` backends keep their fused
    local_move kernels on coarse levels via the traced re-bucketing at the
    stage's static ``width``; outside (the parity oracle) the historical
    segment fallback applies."""
    if cascade and cfg.backend in ("ell", "pallas"):
        return engine_spec(cfg, faults=faults).replace(ell_width=width)
    return engine_spec(cfg, backend=_coarse_backend(cfg.backend),
                       faults=faults)


def _refine_spec(cfg: LouvainConfig,
                 faults: frozenset = frozenset()) -> EngineSpec:
    return engine_spec(cfg, backend="segment", max_sweeps=cfg.refine_sweeps,
                       faults=faults).replace(threshold=0)


# ------------------------------------------------------------ transfer hooks

_transfer_count = 0   # incremented on every pipeline readback (test hook)
_stage_sync_count = 0  # incremented on every cascade stage-boundary sync


def _readback(tree):
    """The ONE bulk device→host transfer of the fused pipeline.

    Every host materialization of results in the ``pipeline_fused`` path
    flows through this function, so tests can count transfers by
    monkeypatching it (or by reading ``_transfer_count``)."""
    global _transfer_count
    _transfer_count += 1
    return jax.device_get(tree)


def _stage_sync(tree):
    """The tiny per-stage-boundary host sync of the cascade: five scalars —
    (done, level, n_valid, m_valid, max_deg) — deciding whether to finalize
    or where to descend, and the next stage's traced-ELL width.  Counted
    separately from the one bulk ``_readback`` so tests can assert the
    cascade's transfer accounting; a degenerate (single-capacity) schedule
    never syncs."""
    global _stage_sync_count
    _stage_sync_count += 1
    done, level, nv, mv, max_deg = jax.device_get(tree)
    return bool(done), int(level), int(nv), int(mv), int(max_deg)


# ------------------------------------------------------------ fused pipeline


def _graph_arrays(g: Graph):
    return (g.src, g.dst, g.w, g.edge_mask, g.n_valid, g.m_valid)


def _build_stage(spec0: Optional[EngineSpec], spec_coarse: EngineSpec,
                 refine_spec: Optional[EngineSpec], max_levels: int,
                 track_modularity: bool, next_caps: Optional[Tuple[int, int]],
                 agg_method: str = "binned",
                 faults: frozenset = frozenset(), promote: bool = False):
    """Build one (un-jitted) cascade stage function (DESIGN.md §Pipeline).

    ``_stage_fn`` wraps this in ``jax.jit`` for the single-graph cascade
    driver; the batched many-graph engine (``core.batch``) instead lifts the
    same pure stage function through ``jax.vmap`` — one builder, two
    dispatch disciplines, so the batched path can never drift from the
    single-graph parity oracle.

    ``spec0 is not None`` marks stage 0: level 0 is peeled out of the loop
    (it may use the host-built ELL backend and always starts from
    singletons); with ``next_caps=None`` as well, this is exactly the
    single-capacity whole-run pipeline — the parity oracle.  Later stages
    resume the level loop from carried state at their own (smaller) static
    capacity.  Levels run inside a ``lax.while_loop`` with the Alg. 3
    ``n_comm == n_valid`` predicate on device; ``next_caps`` adds the
    cascade descent predicate — the loop hands control back to the host
    scheduler (one 5-scalar ``_stage_sync``) as soon as the carried coarse
    graph fits the next capacity.

    Histories are fixed-size on-device buffers threaded THROUGH stages and
    written at absolute level indices — ``modularity[max_levels]`` (NaN
    sentinel), ``sweeps/n_comm[max_levels]`` and
    ``delta_n[max_levels, max_sweeps]`` (``-1`` sentinel, the PR-1
    convention) — so the one bulk readback at the end reconstructs
    ``LouvainResult`` unchanged regardless of how many stages ran.  The
    fifth history element is the scalar non-finite-weight flag (numeric
    guard rail): each level ORs in a finiteness check of its input graph,
    and the driver refuses the answer (``NumericError``) if it comes back
    set — it rides the same bulk readback, costing no extra transfer.
    """

    def stage(g: Graph, ell, g0: Graph, seed, assign, init_com, macro_in,
              level_in, hists):
        n = g.n_max
        arange_n = jnp.arange(n, dtype=jnp.int32)

        def run_level(cur: Graph, assign, init_com, level_u32, spec, ell):
            """One level: fused local-moving → sort-free (or one-sort)
            remap+coarsen → (refine).

            Mirrors one iteration of the per-level driver exactly; returns
            the next level's graph arrays + bookkeeping and this level's
            history entries."""
            if "nan_weight" in faults:
                # fault injection: poison one edge weight at level 1 (a
                # coarse graph mid-pipeline, the hardest place to observe) —
                # the guard below must flag it through the single readback
                cur = dataclasses.replace(cur, w=cur.w.at[0].set(jnp.where(
                    level_u32 == jnp.uint32(1), jnp.float32(jnp.nan),
                    cur.w[0])))
            # numeric guard rail: non-finite weights anywhere in the level
            # loop poison sums silently (NaN gains → no proposals → a
            # "converged" wrong answer), so every level checks its input
            lvl_bad = jnp.any(cur.edge_mask & ~jnp.isfinite(cur.w))
            vmask = cur.vertex_mask()
            it0 = level_u32 * jnp.uint32(LEVEL_IT_STRIDE)
            com, _, sweeps, dn_h, _act_h = device_phase(
                spec, cur, ell, init_com, vmask, it0, seed)
            if refine_spec is None:
                # sort-free binned coarsening by default (DESIGN.md
                # §Pipeline sort-free invariant); "sort" selects the fused
                # one-sort oracle — both bit-for-bit identical
                new_com, n_comm, nxt = aggregation.remap_and_coarsen_by(
                    agg_method, cur, com, faults)
            else:
                # Leiden aggregates by the REFINED partition below; only the
                # macro remap is needed here
                new_com, n_comm = aggregation.remap_communities(com, vmask)
            macro_assign = new_com[jnp.clip(assign, 0, n - 1)]
            done = n_comm == cur.n_valid           # Alg. 3 l.6 convergence
            q = (modularity(g0, macro_assign, promote=promote)
                 if track_modularity else jnp.float32(0.0))

            def advance(_):
                if refine_spec is not None:
                    # Leiden: aggregate by the REFINED partition; seed the
                    # next level's local-moving with each super-vertex's
                    # macro id (paper-order: refinement only when not done)
                    ref, _, _, _, _ = device_phase(
                        refine_spec, cur, None, arange_n, vmask,
                        it0 + jnp.uint32(REFINE_IT_OFFSET), seed, restrict=com)
                    new_ref, n_ref, nxt_r = aggregation.remap_and_coarsen_by(
                        agg_method, cur, ref, faults)
                    # macro seed as the CONTIGUIZED macro id (all members of
                    # a refined group share it): values < n_comm stay valid
                    # under any later stage capacity, and the relabeling is
                    # monotone in the raw id, so every order-based tie-break
                    # downstream is unchanged
                    macro_of_ref = jax.ops.segment_max(
                        jnp.where(vmask, new_com, -1),
                        jnp.clip(new_ref, 0, n - 1), num_segments=n)
                    return (_graph_arrays(nxt_r),
                            new_ref[jnp.clip(assign, 0, n - 1)],
                            jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32))
                return _graph_arrays(nxt), macro_assign, arange_n

            def stay(_):
                return _graph_arrays(cur), assign, init_com

            nxt_arrays, assign2, init2 = jax.lax.cond(done, stay, advance,
                                                      None)
            return (nxt_arrays, assign2, init2, macro_assign,
                    sweeps.astype(jnp.int32), dn_h, n_comm, q, done, lvl_bad)

        mod_hist, sweeps_hist, ncomm_hist, dn_hist, bad_w = hists

        if spec0 is not None:
            # peeled level 0: the only level that may use the host-built ELL
            (arrays, assign, init_com, macro, sweeps, dn_h, n_comm, q,
             done, lvl_bad) = run_level(g, assign, init_com, jnp.uint32(0),
                                        spec0, ell)
            mod_hist = mod_hist.at[0].set(q)
            sweeps_hist = sweeps_hist.at[0].set(sweeps)
            ncomm_hist = ncomm_hist.at[0].set(n_comm)
            dn_hist = dn_hist.at[0].set(dn_h)
            bad_w = bad_w | lvl_bad
            level = jnp.int32(1)
        else:
            arrays = _graph_arrays(g)
            macro = macro_in
            done = jnp.bool_(False)
            level = level_in

        def cond(c):
            level, done, arrays = c[0], c[1], c[2]
            keep = (level < max_levels) & (~done)
            if next_caps is not None:
                # cascade descent: exit once the carried graph fits the
                # next (smaller) static capacity
                fits = ((arrays[4] <= next_caps[0])
                        & (arrays[5] <= next_caps[1]))
                keep = keep & (~fits)
            return keep

        def body(c):
            (level, _done, arrays, assign, init_com, _macro,
             mh, sh, nh, dh, bw) = c
            src, dst, w, em, nv, mv = arrays
            # coarsening output is src-sorted and front-compacted — the
            # invariant the traced ELL re-bucketing relies on
            cur = Graph(src=src, dst=dst, w=w, edge_mask=em, n_valid=nv,
                        m_valid=mv, n_max=n, m_max=g.m_max,
                        sorted_by="src")
            (arrays2, assign2, init2, macro2, sweeps, dn_h, n_comm, q,
             done2, lvl_bad) = run_level(cur, assign, init_com,
                                         level.astype(jnp.uint32),
                                         spec_coarse, None)
            mh = mh.at[level].set(q)
            sh = sh.at[level].set(sweeps)
            nh = nh.at[level].set(n_comm)
            dh = dh.at[level].set(dn_h)
            return (level + 1, done2, arrays2, assign2, init2, macro2,
                    mh, sh, nh, dh, bw | lvl_bad)

        carry = (level, done, arrays, assign, init_com, macro,
                 mod_hist, sweeps_hist, ncomm_hist, dn_hist, bad_w)
        carry = jax.lax.while_loop(cond, body, carry)
        (level, done, arrays, assign, init_com, macro,
         mod_hist, sweeps_hist, ncomm_hist, dn_hist, bad_w) = carry

        # stage-boundary stats for the host scheduler: live counts plus the
        # carried graph's max unweighted degree (next stage's width pick) —
        # only a stage that CAN descend pays for the degree reduction
        src, _dst, _w, em, nv, mv = arrays
        if next_caps is None:
            max_deg = jnp.int32(0)
        else:
            deg_cnt = jax.ops.segment_sum(
                jnp.where(em, 1, 0), jnp.clip(src, 0, n - 1), num_segments=n)
            max_deg = jnp.max(jnp.where(arange_n < nv, deg_cnt, 0))

        def finalize(_):
            final_assign, n_final = aggregation.remap_communities(
                macro, g0.vertex_mask())
            return (final_assign, n_final,
                    modularity(g0, final_assign, promote=promote))

        if next_caps is None:
            final_assign, n_final, q_final = finalize(None)
        else:
            # intermediate stages skip the full-capacity final remap +
            # modularity pass: the host only reads these outputs when the
            # run terminates in THIS stage (done or level budget exhausted)
            final_assign, n_final, q_final = jax.lax.cond(
                done | (level >= max_levels), finalize,
                lambda _: (jnp.zeros((g0.n_max,), jnp.int32), jnp.int32(0),
                           jnp.float32(0.0)),
                None)
        return (arrays, assign, init_com, macro,
                (mod_hist, sweeps_hist, ncomm_hist, dn_hist, bad_w),
                level, done, nv, mv, max_deg,
                final_assign, n_final, q_final)

    return stage


@program_cache("louvain.stage", maxsize=64)
def _stage_fn(spec0: Optional[EngineSpec], spec_coarse: EngineSpec,
              refine_spec: Optional[EngineSpec], max_levels: int,
              track_modularity: bool, next_caps: Optional[Tuple[int, int]],
              agg_method: str = "binned",
              faults: frozenset = frozenset(), promote: bool = False):
    """Jitted ``_build_stage``, memoized on the full static key.

    ``faults`` / ``promote`` are part of the cache key ON PURPOSE: a trace
    compiled clean must never be reused under injection (and vice versa).
    Clean runs always pass the defaults, so their cache behavior is
    unchanged.  The cache is bounded (DESIGN.md §Serving): the key ranges
    over the static menus (≤4 cascade capacities, 3 ELL widths, spec
    variants), so 64 entries hold every program a sane workload compiles
    and a long-lived serving process cannot leak programs across config
    churn.
    """
    return jax.jit(_build_stage(spec0, spec_coarse, refine_spec, max_levels,
                                track_modularity, next_caps, agg_method,
                                faults, promote))


@program_cache("louvain.shrink", maxsize=64)
def _shrink_fn(n_in: int, m_in: int, n_out: int, m_out: int):
    """Jitted stage-boundary compaction: slice the front-compacted carried
    graph (and the Leiden macro seed) into the next static capacity —
    ``aggregation.shrink_graph``, entirely on device."""

    def f(arrays, init_com):
        src, dst, w, em, nv, mv = arrays
        gin = Graph(src=src, dst=dst, w=w, edge_mask=em, n_valid=nv,
                    m_valid=mv, n_max=n_in, m_max=m_in, sorted_by="src")
        return aggregation.shrink_graph(gin, n_out, m_out), init_com[:n_out]

    return jax.jit(f)


# ------------------------------------------------- stage checkpoint/resume


def _ckpt_fingerprint(cfg: LouvainConfig, g: Graph) -> dict:
    """Identity of a checkpointable run: the full config (minus the
    checkpoint location itself) + cheap graph identity (capacities, live
    counts, masked weight sum).  A restore whose fingerprint mismatches is
    IGNORED (fresh start + ``louvain.ckpt_mismatch_ignored`` counter) —
    resuming someone else's state would be a silent wrong answer.  The
    json round-trip normalizes tuples to lists so the comparison against
    the manifest-loaded value is exact."""
    d = cfg.to_dict()
    d.pop("checkpoint_dir", None)
    return json.loads(json.dumps({
        "cfg": d,
        "graph": {"n_max": int(g.n_max), "m_max": int(g.m_max),
                  "n_valid": int(g.n_valid), "m_valid": int(g.m_valid),
                  "w_sum": float(jnp.sum(
                      jnp.where(g.edge_mask, g.w, 0.0)))}}))


def _ckpt_save_stage(ckpt_dir: str, fp: dict, k: int, width: int,
                     stage_idxs, g_k: Graph, assign, init_com, macro,
                     level, hists) -> None:
    """Persist the carried device state at a cascade stage boundary —
    the post-shrink graph entering stage ``k`` plus the 5 history buffers,
    the assignment chain and the level counter — via the atomic
    write-then-rename checkpointer, so a crash mid-save never corrupts
    the last committed boundary.  The stage-varying scheduler metadata
    (k, traced-ELL width, stages entered so far) rides the manifest."""
    from repro.train import checkpoint

    tree = {"graph": list(_graph_arrays(g_k)), "assign": assign,
            "init_com": init_com, "macro": macro, "level": level,
            "hists": list(hists)}
    meta = {"fingerprint": fp,
            "stage": {"k": int(k), "width": int(width),
                      "stage_idxs": [int(j) for j in stage_idxs]}}
    checkpoint.save(ckpt_dir, len(stage_idxs), tree,
                    config_json=json.dumps(meta), keep=2)
    telemetry.bump("louvain.ckpt_save")


def _ckpt_try_resume(cfg: LouvainConfig, caps, n0: int, fp: dict):
    """Restore the latest committed stage boundary, or None (no/stale/
    mismatched checkpoint → start fresh)."""
    from repro.train import checkpoint

    ckpt_dir = cfg.checkpoint_dir
    step = checkpoint.latest_step(ckpt_dir)
    if step is None:
        return None
    with open(os.path.join(ckpt_dir, f"step_{step:08d}",
                           "manifest.json")) as f:
        meta = json.load(f)["config"]
    if meta.get("fingerprint") != fp:
        telemetry.bump("louvain.ckpt_mismatch_ignored")
        return None
    stage = meta["stage"]
    k, width = int(stage["k"]), int(stage["width"])
    stage_idxs = [int(j) for j in stage["stage_idxs"]]
    if not 0 < k < len(caps):
        telemetry.bump("louvain.ckpt_mismatch_ignored")
        return None
    n_k, m_k = caps[k]
    sds = jax.ShapeDtypeStruct
    like = {"graph": [sds((m_k,), jnp.int32), sds((m_k,), jnp.int32),
                      sds((m_k,), jnp.float32), sds((m_k,), jnp.bool_),
                      sds((), jnp.int32), sds((), jnp.int32)],
            "assign": sds((n0,), jnp.int32),
            "init_com": sds((n_k,), jnp.int32),
            "macro": sds((n0,), jnp.int32),
            "level": sds((), jnp.int32),
            "hists": [sds((cfg.max_levels,), jnp.float32),
                      sds((cfg.max_levels,), jnp.int32),
                      sds((cfg.max_levels,), jnp.int32),
                      sds((cfg.max_levels, cfg.max_sweeps), jnp.int32),
                      sds((), jnp.bool_)]}
    tree = checkpoint.restore(ckpt_dir, step, like)
    src, dst, w, em, nv, mv = tree["graph"]
    g_k = Graph(src=src, dst=dst, w=w, edge_mask=em, n_valid=nv,
                m_valid=mv, n_max=n_k, m_max=m_k, sorted_by="src")
    return (k, width, stage_idxs, g_k, tree["assign"], tree["init_com"],
            tree["macro"], tree["level"], tuple(tree["hists"]))


def _ckpt_clear(ckpt_dir: str) -> None:
    """Drop committed stage checkpoints after a successful run: the next
    run in this directory starts fresh instead of resuming a finished
    cascade's tail."""
    import shutil

    from repro.train import checkpoint

    for s in checkpoint.all_steps(ckpt_dir):
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _louvain_pipeline(g: Graph, cfg: LouvainConfig,
                      g_original: Optional[Graph],
                      faults: frozenset = frozenset(),
                      promote: bool = False) -> LouvainResult:
    """Whole-run fused driver: a cascade of at most ``len(schedule)`` stage
    dispatches with ONE bulk readback (``_readback``) at the end and one
    5-scalar ``_stage_sync`` per stage boundary.  A degenerate schedule
    (``"none"``, or ``"auto"`` on a small graph) is exactly the historical
    single-dispatch single-readback pipeline."""
    timer = Timer()
    g0 = g_original if g_original is not None else g
    caps = _resolve_schedule(cfg, g)
    cascade = len(caps) > 1
    spec0 = engine_spec(cfg, faults=faults)
    refine_spec = _refine_spec(cfg, faults) if cfg.refine else None

    n0 = g.n_max
    arange0 = jnp.arange(n0, dtype=jnp.int32)
    hists = (jnp.full((cfg.max_levels,), jnp.nan, jnp.float32),
             jnp.full((cfg.max_levels,), -1, jnp.int32),
             jnp.full((cfg.max_levels,), -1, jnp.int32),
             jnp.full((cfg.max_levels, cfg.max_sweeps), -1, jnp.int32),
             jnp.bool_(False))
    seed_a = jnp.uint32(cfg.seed)

    k = 0
    width = pick_ell_width(None, *caps[0])
    g_k = g
    assign, init_com, macro = arange0, arange0, arange0
    level = jnp.int32(0)
    stage_idxs: list = []

    # Stage-boundary checkpointing only has boundaries to commit when the
    # schedule cascades; a degenerate schedule is a single dispatch.
    ckpt_fp = None
    if cfg.checkpoint_dir and cascade:
        ckpt_fp = _ckpt_fingerprint(cfg, g)
        resumed = _ckpt_try_resume(cfg, caps, n0, ckpt_fp)
        if resumed is not None:
            (k, width, stage_idxs, g_k, assign, init_com, macro, level,
             hists) = resumed
            telemetry.bump("louvain.ckpt_resume")

    ell_k = None
    if k == 0 and cfg.backend in ("ell", "pallas"):
        # resumed stages (k > 0) re-bucket via the traced per-stage ELL
        # path, same as post-shrink stages — no host build needed
        from repro.graph import ell as ell_mod

        with timer.phase("ell_build"):
            ell_k = ell_mod.build_device_ell(g)

    with timer.phase("pipeline"):
        while True:
            fn = _stage_fn(spec0 if k == 0 else None,
                           _cascade_coarse_spec(cfg, cascade, width, faults),
                           refine_spec, cfg.max_levels, cfg.track_modularity,
                           caps[k + 1] if k + 1 < len(caps) else None,
                           cfg.aggregation, faults, promote)
            (arrays, assign, init_com, macro, hists, level, done, nv, mv,
             max_deg, final_assign, n_final, q_final) = fn(
                g_k, ell_k, g0, seed_a, assign, init_com, macro, level,
                hists)
            stage_idxs.append(k)
            if k + 1 >= len(caps):
                break
            done_h, level_h, nv_h, mv_h, max_deg_h = _stage_sync(
                (done, level, nv, mv, max_deg))
            if done_h or level_h >= cfg.max_levels:
                break
            # descend to the SMALLEST capacity the carried graph fits, so a
            # fast-collapsing hierarchy skips intermediate programs
            k2 = k
            for j in range(k + 1, len(caps)):
                if nv_h <= caps[j][0] and mv_h <= caps[j][1]:
                    k2 = j
            if k2 == k:
                # unreachable by the loop-exit predicate (it only exits on
                # done / budget / fits-next); a silent break here would
                # return the intermediate stage's skipped final outputs.
                # Typed so the degradation ladder can retry the run on the
                # single-capacity (schedule="none") program.
                raise CapacityError(
                    "cascade invariant violated: stage exited without "
                    f"done/budget and ({nv_h}, {mv_h}) fits no capacity in "
                    f"{caps[k + 1:]}")
            g_k, init_com = _shrink_fn(*caps[k], *caps[k2])(arrays, init_com)
            ell_k = None
            k = k2
            width = pick_ell_width(max_deg_h, *caps[k])
            if ckpt_fp is not None:
                _ckpt_save_stage(cfg.checkpoint_dir, ckpt_fp, k, width,
                                 stage_idxs, g_k, assign, init_com, macro,
                                 level, hists)
            if faultinject.consume("preempt_stage"):
                # AFTER the checkpoint committed: models a kill between
                # stages, the worst-case window the resume path must cover
                raise resilience.Preempted(
                    "injected preemption at cascade stage boundary "
                    f"(entering stage k={k})")

        out = _readback((final_assign, n_final, level, q_final) + hists)
    (final_assign, n_final, levels, q, mod_hist, sweeps_hist, ncomm_hist,
     dn_hist, bad_w) = out

    if bool(bad_w):
        # the guard-rail flag from the level loop (rode the one readback):
        # refuse the answer rather than return a silently-poisoned partition
        raise NumericError(
            "non-finite edge weight detected inside the fused level loop")
    if ckpt_fp is not None:
        _ckpt_clear(cfg.checkpoint_dir)
    levels = int(levels)
    sweeps_per_level = [int(s) for s in sweeps_hist[:levels]]
    return LouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=float(q),
        modularity_history=(
            [float(x) for x in mod_hist[:levels]]
            if cfg.track_modularity else []),
        sweeps_per_level=sweeps_per_level,
        timer=timer,
        n_comm_per_level=[int(x) for x in ncomm_hist[:levels]],
        delta_n_per_level=[
            [int(x) for x in row[:s]]
            for row, s in zip(dn_hist[:levels], sweeps_per_level)],
        cascade_stages=[caps[j] for j in stage_idxs],
    )


# ------------------------------------------------------------ refinement


def _refine_partition(cur: Graph, com_macro: jax.Array, cfg: LouvainConfig,
                      level: int,
                      faults: frozenset = frozenset()) -> jax.Array:
    """Leiden refinement: greedy modularity merges restricted to the macro
    communities, starting from singletons.  Guarantees every aggregated
    super-vertex is contained in (and connected within) a macro community."""
    engine = SweepEngine(cur, _refine_spec(cfg, faults))
    res = engine.run_phase(
        *engine.singleton_state(),
        it0=level * LEVEL_IT_STRIDE + REFINE_IT_OFFSET, seed=cfg.seed,
        restrict=com_macro, fused=cfg.fused,
    )
    return res.labels


# ------------------------------------------------------------ driver (Alg. 3)


def leiden(g: Graph, cfg: LouvainConfig = LouvainConfig(),
           g_original: Optional[Graph] = None) -> LouvainResult:
    """Leiden = Louvain + refinement phase + macro-seeded levels."""
    return louvain(g, cfg.replace(refine=True), g_original)


def _trivial_result(report: RunReport) -> LouvainResult:
    """Degenerate zero-capacity graph: nothing to cluster, nothing to run."""
    return LouvainResult(
        labels=np.zeros((0,), np.int32), n_communities=0, levels=0,
        modularity=0.0, modularity_history=[], sweeps_per_level=[],
        timer=Timer(), run_report=report)


def _finalize_report(res: LouvainResult, cfg: LouvainConfig,
                     report: RunReport) -> LouvainResult:
    """Watchdog accounting + the final numeric gate, after any ladder."""
    for i, s in enumerate(res.sweeps_per_level):
        if s >= cfg.max_sweeps:
            report.warnings.append(f"watchdog:max_sweeps:level{i}")
    if res.levels >= cfg.max_levels:
        report.warnings.append("watchdog:max_levels")
    res.run_report = report
    if not math.isfinite(res.modularity):
        raise NumericError(
            f"non-finite final modularity {res.modularity!r}", report=report)
    return res


def louvain(g: Graph, cfg: LouvainConfig = LouvainConfig(),
            g_original: Optional[Graph] = None) -> LouvainResult:
    """Hardened driver (DESIGN.md §Robustness): runs the fused pipeline or
    the per-level driver under a bounded retry/degradation ladder —

      * capacity bust (``CapacityError``) → ONE retry on the
        single-capacity ``capacity_schedule="none"`` program;
      * non-taxonomy backend failure → descend ``pallas → ell → segment``
        (each step bit-identical on clean input by the parity contracts);
      * typed taxonomy errors (numeric, validation, …) propagate — they
        mean the ANSWER is unsafe, so no amount of retrying helps;

    everything attempted is recorded in ``result.run_report``.  The clean
    path runs exactly one attempt with default fault/promotion state, so
    its traces, transfer counts and results are unchanged."""
    report = RunReport(faults=sorted(faultinject.active()))
    if g.n_max == 0:
        return _trivial_result(report)
    faults = frozenset(faultinject.active())
    promote = accum_needs_promotion(g.m_max)
    if promote:
        report.warnings.append("precision:f32_accum_risk"
                               if not jax.config.jax_enable_x64
                               else "precision:promoted_f64")
    cfg_try = cfg
    while True:
        try:
            if cfg_try.pipeline_fused and cfg_try.fused:
                res = _louvain_pipeline(g, cfg_try, g_original, faults,
                                        promote)
            else:
                res = _louvain_per_level(g, cfg_try, g_original, faults,
                                         promote)
            break
        except CapacityError as err:
            if cfg_try.capacity_schedule == "none":
                err.report = report
                raise
            telemetry.bump("ladder.capacity_retry")
            report.retries.append({
                "kind": "capacity",
                "from": repr(cfg_try.capacity_schedule), "to": "none",
                "error": str(err)})
            cfg_try = cfg_try.replace(capacity_schedule="none")
        except CommunityDetectionError as err:
            err.report = report
            raise
        except Exception as err:  # noqa: BLE001 — the backend-descent rung
            nxt = BACKEND_DESCENT.get(cfg_try.backend)
            if nxt is None:
                raise KernelError(
                    f"backend {cfg_try.backend!r} failed with no descent "
                    f"left: {type(err).__name__}: {err}",
                    report=report) from err
            telemetry.bump("ladder.backend_descent")
            report.degradations.append({
                "kind": "backend_descent",
                "from": cfg_try.backend, "to": nxt,
                "error": f"{type(err).__name__}: {err}"})
            cfg_try = cfg_try.replace(backend=nxt)
    return _finalize_report(res, cfg_try, report)


def _tphase(timer: Timer, name: str, level: int, per_level: bool):
    """timer.phase(name), optionally doubled with a level-tagged entry."""
    if not per_level:
        return timer.phase(name)
    stack = contextlib.ExitStack()
    stack.enter_context(timer.phase(name))
    stack.enter_context(timer.phase(f"L{level:02d}/{name}"))
    return stack


def _louvain_per_level(g: Graph, cfg: LouvainConfig,
                       g_original: Optional[Graph],
                       faults: frozenset = frozenset(),
                       promote: bool = False) -> LouvainResult:
    """Per-level Python driver (``pipeline_fused=False``): one fused
    local-moving dispatch per level, aggregation + Alg. 3 convergence on
    host.  Bit-for-bit parity with the fused pipeline is contractual
    (tests/test_pipeline.py) — any change here must be mirrored in
    ``_stage_fn`` and vice versa."""
    timer = Timer()
    g0 = g_original if g_original is not None else g
    n = g.n_max

    assign = jnp.arange(n, dtype=jnp.int32)  # original vertex -> community
    cur = g
    mod_hist: list = []
    sweeps_per_level: list = []
    n_comm_per_level: list = []
    delta_n_per_level: list = []
    levels = 0

    init_com = None   # Leiden: macro partition seeds the next level
    for level in range(cfg.max_levels):
        spec = engine_spec(
            cfg, backend=cfg.backend if level == 0
            else _coarse_backend(cfg.backend), faults=faults)
        if "nan_weight" in faults and level == 1:
            # fault injection: same poison as the fused pipeline's
            cur = dataclasses.replace(
                cur, w=cur.w.at[0].set(jnp.float32(jnp.nan)))
        # numeric guard rail, mirroring the fused pipeline's per-level
        # check (host-side here: this driver already syncs every level)
        if bool(jnp.any(cur.edge_mask & ~jnp.isfinite(cur.w))):
            raise NumericError(
                f"non-finite edge weight detected at level {level}")
        with timer.phase("ell_build") if spec.backend in ("ell", "pallas") \
                else contextlib.nullcontext():
            engine = SweepEngine(cur, spec)
        com = (jnp.arange(n, dtype=jnp.int32)  # singleton init (Alg. 2 l.4)
               if init_com is None else init_com)
        init_com = None
        need = cur.vertex_mask()               # needCheck = true (l.7)

        # ONE fused while_loop call per level (DESIGN.md §Engine): the whole
        # local-moving phase converges on device before anything syncs back
        with _tphase(timer, "local_moving", level, cfg.per_level_timing):
            res = engine.run_phase(
                com, need, it0=level * LEVEL_IT_STRIDE, seed=cfg.seed, fused=cfg.fused)
        com = res.labels
        sweeps_per_level.append(res.sweeps)
        delta_n_per_level.append(res.delta_n_history)

        with _tphase(timer, "aggregation", level, cfg.per_level_timing):
            # sort-free binned coarsening by default; "sort" keeps the fused
            # one-sort oracle — bit-identical either way, and also to the
            # two-step remap_communities_sorted + coarsen_graph reference
            if cfg.refine:
                new_com, n_comm = aggregation.remap_communities(
                    com, cur.vertex_mask())
            else:
                new_com, n_comm, coarse = aggregation.remap_and_coarsen_by(
                    cfg.aggregation, cur, com, faults)
            # macro labels on ORIGINAL vertices (the result partition); under
            # refinement `assign` tracks the finer refined chain instead
            macro_assign = new_com[jnp.clip(assign, 0, n - 1)]
            n_comm_i = int(n_comm)
            n_valid_i = int(cur.n_valid)
            n_comm_per_level.append(n_comm_i)
            done = n_comm_i == n_valid_i          # Alg. 3 l.6 convergence
            if not done and cfg.refine:
                # Leiden: aggregate by the REFINED partition; seed the next
                # level's local-moving with each super-vertex's macro id
                with _tphase(timer, "refinement", level, cfg.per_level_timing):
                    ref = _refine_partition(cur, com, cfg, level, faults)
                new_ref, n_ref, coarse = aggregation.remap_and_coarsen_by(
                    cfg.aggregation, cur, ref, faults)
                # contiguized macro label of each refined group (refined ⊆
                # macro; monotone relabeling — see _stage_fn.run_level)
                macro_of_ref = jax.ops.segment_max(
                    jnp.where(cur.vertex_mask(), new_com, -1),
                    jnp.clip(new_ref, 0, n - 1), num_segments=n)
                assign = new_ref[jnp.clip(assign, 0, n - 1)]
                cur = coarse
                init_com = jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32)
            elif not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = coarse
        levels = level + 1
        if cfg.track_modularity:
            mod_hist.append(float(modularity(g0, macro_assign,
                                             promote=promote)))
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(
        macro_assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign, promote=promote))
    return LouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        modularity_history=mod_hist,
        sweeps_per_level=sweeps_per_level,
        timer=timer,
        n_comm_per_level=n_comm_per_level,
        delta_n_per_level=delta_n_per_level,
    )
