"""Parallel Louvain (paper Alg. 2 + Alg. 3) — TPU-native.

Faithful structure:
  * singleton init with comID = vertexID, volVertex/volCom arrays (Alg. 2 l.3-8)
  * local-moving: per-vertex parallel Δ𝑄 evaluation over neighboring
    communities (Eq. 1), greedy argmax move when Δ𝑄 > 0 (l.9-24)
  * needCheck set: re-evaluate a vertex only if it or a neighbor changed (l.11,
    l.21, l.25)
  * level loop: local-moving then aggregation until |C| == |V| (Alg. 3)

Adaptations (DESIGN.md §2 / §8): atomic volCom updates (l.18-19) become a
segment-sum recompute at each synchronous sweep; the Lu–Halappanavar singleton
tie-break suppresses the classic PLM two-singleton swap oscillation.  Move
backends: ``segment`` (sort+segment GroupBy) and ``ell``/``pallas``
(degree-bucketed dense tiles through ``kernels/delta_q``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core import aggregation
from repro.core.common import neighbor_or_self_changed
from repro.core.modularity import modularity
from repro.graph import segment as seg
from repro.graph.structure import Graph
from repro.utils.timing import Timer


@dataclasses.dataclass(frozen=True)
class LouvainConfig(ConfigBase):
    max_levels: int = 10
    max_sweeps: int = 25        # Alg. 2 maxIteration
    sweep_threshold: int = 0    # stop local-moving when ΔN <= this
    backend: str = "segment"    # segment | ell | pallas
    use_need_check: bool = True
    singleton_rule: bool = True # Lu et al. swap suppression
    move_prob: float = 0.5      # Luby-style move gating (1.0 = pure Jacobi)
    seed: int = 0
    track_modularity: bool = True
    # Leiden-style refinement (beyond paper; the paper cites Leiden [30] as
    # the natural next algorithm): refine each community into well-connected
    # sub-communities before aggregation, then seed the next level with the
    # macro partition instead of singletons.
    refine: bool = False
    refine_sweeps: int = 8


@dataclasses.dataclass
class LouvainResult:
    labels: np.ndarray            # community id per ORIGINAL vertex (contiguous)
    n_communities: int
    levels: int
    modularity: float
    modularity_history: list      # per level
    sweeps_per_level: list
    timer: Timer


# ------------------------------------------------------------ local moving


@partial(jax.jit, static_argnames=("singleton_rule", "move_prob"))
def _louvain_sweep_segment(
    g: Graph,
    com: jax.Array,
    need: jax.Array,
    it: jax.Array = jnp.uint32(0),
    seed: jax.Array = jnp.uint32(0),
    singleton_rule: bool = True,
    move_prob: float = 1.0,
    restrict: Optional[jax.Array] = None,
):
    """One synchronous local-moving sweep (Alg. 2 l.10-24).

    ``restrict``: optional macro-partition labels — when given, only edges
    whose endpoints share a macro community are considered (the Leiden
    refinement phase: moves never leave the enclosing community)."""
    n = g.n_max
    sentinel = jnp.int32(n)
    vmask = g.vertex_mask()

    deg = g.weighted_degrees()                       # volVertex (Alg. 2 l.5)
    vol_v = g.total_volume()
    vol_com = jax.ops.segment_sum(deg, jnp.clip(com, 0, n - 1), num_segments=n)
    size_com = jax.ops.segment_sum(
        jnp.where(vmask, 1, 0), jnp.clip(com, 0, n - 1), num_segments=n
    )

    # per-vertex best move via the shared GroupBy evaluator (Eq. 1, rescaled
    # by 1/vol(V) for f32 conditioning; ΔQ = 2·gain/vol(V))
    from repro.core import moves

    valid = g.edge_mask & need[jnp.clip(g.dst, 0, n - 1)]
    if restrict is not None:
        same_macro = (restrict[jnp.clip(g.src, 0, n - 1)]
                      == restrict[jnp.clip(g.dst, 0, n - 1)])
        valid = valid & same_macro
    best_gain, best_cand = moves.louvain_best_moves(
        g.src, g.dst, g.w, valid, com, deg, vol_com, size_com, vol_v, n,
        singleton_rule=singleton_rule,
    )

    move = vmask & need & (best_cand >= 0) & (best_gain > 0.0)   # ΔQ > 0 (l.17)
    if move_prob < 1.0:
        # Luby-style symmetry breaking for the synchronous sweep (DESIGN.md §2):
        # moving a random subset of intenders per sweep emulates the async
        # move order of the Chapel version and damps Jacobi oscillation.
        from repro.core.common import hash_u32

        coin = hash_u32(
            jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(0x9E3779B1)
            ^ hash_u32(it + seed * jnp.uint32(101))
        )
        move = move & (coin < jnp.uint32(int(move_prob * 4294967295.0)))
    new_com = jnp.where(move, best_cand, com)
    changed = move & (new_com != com)
    delta_n = jnp.sum(changed.astype(jnp.int32))
    need_next = neighbor_or_self_changed(g, changed)
    return new_com, need_next, delta_n


def _louvain_sweep_ell(g, ell_graph, com, need, singleton_rule, use_pallas,
                       it=0, seed=0, move_prob=1.0):
    """Local-moving over degree-bucketed tiles via the delta_q kernel."""
    from repro.kernels.delta_q import ops as dq_ops

    n = g.n_max
    vmask = g.vertex_mask()
    deg = g.weighted_degrees()
    vol_v = g.total_volume()
    vol_com = jax.ops.segment_sum(deg, jnp.clip(com, 0, n - 1), num_segments=n)
    size_com = jax.ops.segment_sum(
        jnp.where(vmask, 1, 0), jnp.clip(com, 0, n - 1), num_segments=n
    )

    com_ext = jnp.concatenate([com, jnp.int32([n])])
    vol_ext = jnp.concatenate([vol_com, jnp.zeros((1,), vol_com.dtype)])
    size_ext = jnp.concatenate([size_com, jnp.zeros((1,), size_com.dtype)])
    deg_ext = jnp.concatenate([deg, jnp.zeros((1,), deg.dtype)])

    new_com = com
    changed = jnp.zeros((n,), bool)
    for b in ell_graph.buckets:
        rows = jnp.asarray(b.rows)
        nbr = jnp.asarray(b.nbr)
        w = jnp.asarray(b.w)
        rows_c = jnp.clip(rows, 0, n)
        nbr_c = jnp.clip(nbr, 0, n)
        cand = jnp.where(nbr < n, com_ext[nbr_c], n)
        best_cand, best_gain = dq_ops.delta_q_argmax(
            cand_com=cand,
            nbr_w=w,
            cur_com=com_ext[rows_c],
            deg_v=deg_ext[rows_c],
            vol_cand=vol_ext[jnp.clip(cand, 0, n)],
            vol_cur=vol_ext[jnp.clip(com_ext[rows_c], 0, n)],
            size_cand=size_ext[jnp.clip(cand, 0, n)],
            size_cur=size_ext[jnp.clip(com_ext[rows_c], 0, n)],
            vol_total=vol_v,
            sentinel=n,
            singleton_rule=singleton_rule,
            use_pallas=use_pallas,
        )
        row_ok = (rows < n) & need[jnp.clip(rows, 0, n - 1)]
        move = row_ok & (best_cand >= 0) & (best_gain > 0.0)
        if move_prob < 1.0:
            from repro.core.common import hash_u32

            coin = hash_u32(
                jnp.clip(rows, 0, n - 1).astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
                ^ hash_u32(jnp.uint32(it) + jnp.uint32(seed) * jnp.uint32(101))
            )
            move = move & (coin < jnp.uint32(int(move_prob * 4294967295.0)))
        upd = jnp.clip(jnp.where(move, rows, n), 0, n - 1)
        new_vals = jnp.where(move, best_cand, new_com[upd])
        new_com = new_com.at[upd].set(new_vals)
        changed = changed.at[upd].max(move & (best_cand != com_ext[rows_c]))

    if ell_graph.has_tail:
        # high-degree tail: reuse the segment sweep restricted to tail vertices
        is_tail = jnp.zeros((n,), bool).at[jnp.asarray(ell_graph.tail_vertices)].set(True)
        t_com, _, _ = _louvain_sweep_segment(
            g, com, need & is_tail,
            it=jnp.uint32(it), seed=jnp.uint32(seed),
            singleton_rule=singleton_rule, move_prob=move_prob,
        )
        t_changed = t_com != com
        new_com = jnp.where(t_changed, t_com, new_com)
        changed = changed | t_changed

    delta_n = jnp.sum(changed.astype(jnp.int32))
    need_next = neighbor_or_self_changed(g, changed)
    return new_com, need_next, delta_n


# ------------------------------------------------------------ refinement


def _refine_partition(cur: Graph, com_macro: jax.Array, cfg: LouvainConfig,
                      level: int) -> jax.Array:
    """Leiden refinement: greedy modularity merges restricted to the macro
    communities, starting from singletons.  Guarantees every aggregated
    super-vertex is contained in (and connected within) a macro community."""
    n = cur.n_max
    ref = jnp.arange(n, dtype=jnp.int32)
    need = cur.vertex_mask()
    for s in range(cfg.refine_sweeps):
        ref, need, dn = _louvain_sweep_segment(
            g=cur, com=ref, need=need,
            it=jnp.uint32(level * 1000 + 500 + s),
            seed=jnp.uint32(cfg.seed),
            singleton_rule=cfg.singleton_rule,
            move_prob=float(cfg.move_prob),
            restrict=com_macro,
        )
        if int(dn) == 0:
            break
    return ref


# ------------------------------------------------------------ driver (Alg. 3)


def leiden(g: Graph, cfg: LouvainConfig = LouvainConfig(),
           g_original: Optional[Graph] = None) -> LouvainResult:
    """Leiden = Louvain + refinement phase + macro-seeded levels."""
    return louvain(g, cfg.replace(refine=True), g_original)


def louvain(g: Graph, cfg: LouvainConfig = LouvainConfig(), g_original: Optional[Graph] = None) -> LouvainResult:
    timer = Timer()
    g0 = g_original if g_original is not None else g
    n = g.n_max

    assign = jnp.arange(n, dtype=jnp.int32)  # original vertex -> community
    cur = g
    mod_hist: list = []
    sweeps_per_level: list = []
    levels = 0
    ell_graph = None

    init_com = None   # Leiden: macro partition seeds the next level
    for level in range(cfg.max_levels):
        com = (jnp.arange(n, dtype=jnp.int32)  # singleton init (Alg. 2 l.4)
               if init_com is None else init_com)
        init_com = None
        need = cur.vertex_mask()               # needCheck = true (l.7)
        if cfg.backend in ("ell", "pallas"):
            from repro.graph.ell import build_ell

            with timer.phase("ell_build"):
                ell_graph = build_ell(cur)

        sweeps = 0
        for s in range(cfg.max_sweeps):
            with timer.phase("local_moving"):
                if cfg.backend == "segment":
                    com, need, dn = _louvain_sweep_segment(
                        g=cur,
                        com=com,
                        need=need,
                        it=jnp.uint32(level * 1000 + s),
                        seed=jnp.uint32(cfg.seed),
                        singleton_rule=cfg.singleton_rule,
                        move_prob=float(cfg.move_prob),
                    )
                else:
                    com, need, dn = _louvain_sweep_ell(
                        cur, ell_graph, com, need, cfg.singleton_rule,
                        use_pallas=(cfg.backend == "pallas"),
                        it=level * 1000 + s, seed=cfg.seed,
                        move_prob=float(cfg.move_prob),
                    )
                if not cfg.use_need_check:
                    need = cur.vertex_mask()
                dn = int(dn)
            sweeps = s + 1
            if dn <= cfg.sweep_threshold:
                break
        sweeps_per_level.append(sweeps)

        with timer.phase("aggregation"):
            new_com, n_comm = aggregation.remap_communities(com, cur.vertex_mask())
            # macro labels on ORIGINAL vertices (the result partition); under
            # refinement `assign` tracks the finer refined chain instead
            macro_assign = new_com[jnp.clip(assign, 0, n - 1)]
            n_comm_i = int(n_comm)
            n_valid_i = int(cur.n_valid)
            done = n_comm_i == n_valid_i          # Alg. 3 l.6 convergence
            if not done and cfg.refine:
                # Leiden: aggregate by the REFINED partition; seed the next
                # level's local-moving with each super-vertex's macro id
                with timer.phase("refinement"):
                    ref = _refine_partition(cur, com, cfg, level)
                new_ref, n_ref = aggregation.remap_communities(
                    ref, cur.vertex_mask())
                # macro label of each refined group (refined ⊆ macro)
                macro_of_ref = jax.ops.segment_max(
                    jnp.where(cur.vertex_mask(), com, -1),
                    jnp.clip(new_ref, 0, n - 1), num_segments=n)
                assign = new_ref[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_ref, n_ref)
                init_com = jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32)
            elif not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_com, n_comm)
        levels = level + 1
        if cfg.track_modularity:
            mod_hist.append(float(modularity(g0, macro_assign)))
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(
        macro_assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign))
    return LouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        modularity_history=mod_hist,
        sweeps_per_level=sweeps_per_level,
        timer=timer,
    )
