"""Parallel Louvain (paper Alg. 2 + Alg. 3) — TPU-native.

Faithful structure:
  * singleton init with comID = vertexID, volVertex/volCom arrays (Alg. 2 l.3-8)
  * local-moving: per-vertex parallel Δ𝑄 evaluation over neighboring
    communities (Eq. 1), greedy argmax move when Δ𝑄 > 0 (l.9-24)
  * needCheck set: re-evaluate a vertex only if it or a neighbor changed (l.11,
    l.21, l.25)
  * level loop: local-moving then aggregation until |C| == |V| (Alg. 3)

Adaptations (DESIGN.md §2 / §8): atomic volCom updates (l.18-19) become a
segment-sum recompute at each synchronous sweep; the Lu–Halappanavar singleton
tie-break suppresses the classic PLM two-singleton swap oscillation.

The sweep machinery lives in the shared ``core.engine`` (DESIGN.md §Engine):
this module configures the ``louvain`` evaluator, runs one fused local-moving
phase per level (a single jitted ``lax.while_loop`` call with on-device
ΔN ≤ threshold convergence — at most one host transfer per level), and owns
the level loop: aggregation, optional Leiden-style refinement, bookkeeping.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core import aggregation
from repro.core.engine import EngineSpec, SweepEngine
from repro.core.modularity import modularity
from repro.graph.structure import Graph
from repro.utils.timing import Timer


@dataclasses.dataclass(frozen=True)
class LouvainConfig(ConfigBase):
    max_levels: int = 10
    max_sweeps: int = 25        # Alg. 2 maxIteration
    sweep_threshold: int = 0    # stop local-moving when ΔN <= this
    backend: str = "segment"    # segment | ell | pallas
    use_need_check: bool = True
    singleton_rule: bool = True # Lu et al. swap suppression
    move_prob: float = 0.5      # Luby-style move gating (1.0 = pure Jacobi)
    seed: int = 0
    track_modularity: bool = True
    fused: bool = True          # one while_loop per level vs per-sweep dispatch
    # Leiden-style refinement (beyond paper; the paper cites Leiden [30] as
    # the natural next algorithm): refine each community into well-connected
    # sub-communities before aggregation, then seed the next level with the
    # macro partition instead of singletons.
    refine: bool = False
    refine_sweeps: int = 8


@dataclasses.dataclass
class LouvainResult:
    labels: np.ndarray            # community id per ORIGINAL vertex (contiguous)
    n_communities: int
    levels: int
    modularity: float
    modularity_history: list      # per level
    sweeps_per_level: list
    timer: Timer


def engine_spec(cfg: LouvainConfig, backend: Optional[str] = None,
                max_sweeps: Optional[int] = None) -> EngineSpec:
    return EngineSpec(
        evaluator="louvain",
        backend=backend or cfg.backend,
        max_sweeps=cfg.max_sweeps if max_sweeps is None else max_sweeps,
        threshold=cfg.sweep_threshold,
        move_prob=float(cfg.move_prob),
        use_frontier=cfg.use_need_check,
        singleton_rule=cfg.singleton_rule,
    )


# ------------------------------------------------------------ refinement


def _refine_partition(cur: Graph, com_macro: jax.Array, cfg: LouvainConfig,
                      level: int) -> jax.Array:
    """Leiden refinement: greedy modularity merges restricted to the macro
    communities, starting from singletons.  Guarantees every aggregated
    super-vertex is contained in (and connected within) a macro community."""
    spec = engine_spec(cfg, backend="segment",
                       max_sweeps=cfg.refine_sweeps).replace(threshold=0)
    engine = SweepEngine(cur, spec)
    res = engine.run_phase(
        *engine.singleton_state(),
        it0=level * 1000 + 500, seed=cfg.seed,
        restrict=com_macro, fused=cfg.fused,
    )
    return res.labels


# ------------------------------------------------------------ driver (Alg. 3)


def leiden(g: Graph, cfg: LouvainConfig = LouvainConfig(),
           g_original: Optional[Graph] = None) -> LouvainResult:
    """Leiden = Louvain + refinement phase + macro-seeded levels."""
    return louvain(g, cfg.replace(refine=True), g_original)


def louvain(g: Graph, cfg: LouvainConfig = LouvainConfig(), g_original: Optional[Graph] = None) -> LouvainResult:
    timer = Timer()
    g0 = g_original if g_original is not None else g
    n = g.n_max
    spec = engine_spec(cfg)

    assign = jnp.arange(n, dtype=jnp.int32)  # original vertex -> community
    cur = g
    mod_hist: list = []
    sweeps_per_level: list = []
    levels = 0

    init_com = None   # Leiden: macro partition seeds the next level
    for level in range(cfg.max_levels):
        with timer.phase("ell_build") if cfg.backend in ("ell", "pallas") \
                else contextlib.nullcontext():
            engine = SweepEngine(cur, spec)
        com = (jnp.arange(n, dtype=jnp.int32)  # singleton init (Alg. 2 l.4)
               if init_com is None else init_com)
        init_com = None
        need = cur.vertex_mask()               # needCheck = true (l.7)

        # ONE fused while_loop call per level (DESIGN.md §Engine): the whole
        # local-moving phase converges on device before anything syncs back
        with timer.phase("local_moving"):
            res = engine.run_phase(
                com, need, it0=level * 1000, seed=cfg.seed, fused=cfg.fused)
        com = res.labels
        sweeps_per_level.append(res.sweeps)

        with timer.phase("aggregation"):
            new_com, n_comm = aggregation.remap_communities(com, cur.vertex_mask())
            # macro labels on ORIGINAL vertices (the result partition); under
            # refinement `assign` tracks the finer refined chain instead
            macro_assign = new_com[jnp.clip(assign, 0, n - 1)]
            n_comm_i = int(n_comm)
            n_valid_i = int(cur.n_valid)
            done = n_comm_i == n_valid_i          # Alg. 3 l.6 convergence
            if not done and cfg.refine:
                # Leiden: aggregate by the REFINED partition; seed the next
                # level's local-moving with each super-vertex's macro id
                with timer.phase("refinement"):
                    ref = _refine_partition(cur, com, cfg, level)
                new_ref, n_ref = aggregation.remap_communities(
                    ref, cur.vertex_mask())
                # macro label of each refined group (refined ⊆ macro)
                macro_of_ref = jax.ops.segment_max(
                    jnp.where(cur.vertex_mask(), com, -1),
                    jnp.clip(new_ref, 0, n - 1), num_segments=n)
                assign = new_ref[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_ref, n_ref)
                init_com = jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32)
            elif not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_com, n_comm)
        levels = level + 1
        if cfg.track_modularity:
            mod_hist.append(float(modularity(g0, macro_assign)))
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(
        macro_assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign))
    return LouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        modularity_history=mod_hist,
        sweeps_per_level=sweeps_per_level,
        timer=timer,
    )
