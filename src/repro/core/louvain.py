"""Parallel Louvain (paper Alg. 2 + Alg. 3) — TPU-native.

Faithful structure:
  * singleton init with comID = vertexID, volVertex/volCom arrays (Alg. 2 l.3-8)
  * local-moving: per-vertex parallel Δ𝑄 evaluation over neighboring
    communities (Eq. 1), greedy argmax move when Δ𝑄 > 0 (l.9-24)
  * needCheck set: re-evaluate a vertex only if it or a neighbor changed (l.11,
    l.21, l.25)
  * level loop: local-moving then aggregation until |C| == |V| (Alg. 3)

Adaptations (DESIGN.md §2 / §8): atomic volCom updates (l.18-19) become a
segment-sum recompute at each synchronous sweep; the Lu–Halappanavar singleton
tie-break suppresses the classic PLM two-singleton swap oscillation.

The sweep machinery lives in the shared ``core.engine`` (DESIGN.md §Engine).
With ``pipeline_fused=True`` (default) the ENTIRE level loop — fused
local-moving phase → remap → coarsen → modularity accounting, plus the
optional Leiden refinement phase — runs as one jitted ``lax.while_loop`` over
levels with the Alg. 3 ``|C| == |V|`` convergence predicate evaluated on
device: a whole Louvain/Leiden run is ONE dispatch with ONE host readback at
the end (DESIGN.md §Pipeline).  Per-level modularity / sweep-count /
community-count histories are written into fixed-size on-device buffers
(``-1`` / NaN sentinels) and reconstructed from that single transfer.

``pipeline_fused=False`` keeps the per-level Python driver (one fused
local-moving dispatch per level, aggregation and convergence check on host)
with a bit-for-bit parity contract against the fused pipeline, enforced by
``tests/test_pipeline.py``.  The ``ell``/``pallas`` backends apply to the
finest (level-0) graph only; coarse levels use the ``segment`` evaluator in
BOTH drivers — see DESIGN.md §Pipeline for the rule.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ConfigBase
from repro.core import aggregation
from repro.core.engine import EngineSpec, SweepEngine, device_phase
from repro.core.modularity import modularity
from repro.graph.structure import Graph
from repro.utils.timing import Timer


@dataclasses.dataclass(frozen=True)
class LouvainConfig(ConfigBase):
    max_levels: int = 10
    max_sweeps: int = 25        # Alg. 2 maxIteration
    sweep_threshold: int = 0    # stop local-moving when ΔN <= this
    backend: str = "segment"    # segment | ell | pallas
    # ell/pallas table layout: VMEM-resident vs windowed streaming; "auto"
    # resolves from the VMEM byte budget (DESIGN.md §Kernels)
    table_mode: str = "auto"    # auto | resident | streamed
    use_need_check: bool = True
    singleton_rule: bool = True # Lu et al. swap suppression
    move_prob: float = 0.5      # Luby-style move gating (1.0 = pure Jacobi)
    seed: int = 0
    track_modularity: bool = True
    fused: bool = True          # one while_loop per level vs per-sweep dispatch
    # Whole-run fusion (DESIGN.md §Pipeline): the level loop itself becomes a
    # lax.while_loop, so louvain()/leiden() is one dispatch + one readback.
    # Requires fused sweeps; with fused=False the per-level driver runs.
    pipeline_fused: bool = True
    # Leiden-style refinement (beyond paper; the paper cites Leiden [30] as
    # the natural next algorithm): refine each community into well-connected
    # sub-communities before aggregation, then seed the next level with the
    # macro partition instead of singletons.
    refine: bool = False
    refine_sweeps: int = 8
    # Per-level driver only: record additional L<level>/<phase> timer entries
    # (the paper-style fig4 phase split used by `benchmarks/run.py
    # level_fusion`).
    per_level_timing: bool = False

    def __post_init__(self):
        if self.max_levels < 1:
            raise ValueError(
                f"max_levels must be >= 1, got {self.max_levels}")
        if not (0.0 < self.move_prob <= 1.0):
            raise ValueError(
                f"move_prob must be in (0, 1], got {self.move_prob}")
        if self.refine_sweeps < 1:
            raise ValueError(
                f"refine_sweeps must be >= 1, got {self.refine_sweeps}")


@dataclasses.dataclass
class LouvainResult:
    labels: np.ndarray            # community id per ORIGINAL vertex (contiguous)
    n_communities: int
    levels: int
    modularity: float
    modularity_history: list      # per level
    sweeps_per_level: list
    timer: Timer
    n_comm_per_level: list = dataclasses.field(default_factory=list)
    delta_n_per_level: list = dataclasses.field(default_factory=list)


def engine_spec(cfg: LouvainConfig, backend: Optional[str] = None,
                max_sweeps: Optional[int] = None) -> EngineSpec:
    return EngineSpec(
        evaluator="louvain",
        backend=backend or cfg.backend,
        max_sweeps=cfg.max_sweeps if max_sweeps is None else max_sweeps,
        threshold=cfg.sweep_threshold,
        move_prob=float(cfg.move_prob),
        use_frontier=cfg.use_need_check,
        singleton_rule=cfg.singleton_rule,
        table_mode=cfg.table_mode,
    )


def _coarse_backend(backend: str) -> str:
    """DESIGN.md §Pipeline: the ELL layout is built host-side for the finest
    graph only; every coarse level runs the segment evaluator (in both the
    fused pipeline and the per-level driver, so they stay bit-identical)."""
    return "segment" if backend in ("ell", "pallas") else backend


def _refine_spec(cfg: LouvainConfig) -> EngineSpec:
    return engine_spec(cfg, backend="segment",
                       max_sweeps=cfg.refine_sweeps).replace(threshold=0)


# ------------------------------------------------------------ transfer hook

_transfer_count = 0   # incremented on every pipeline readback (test hook)


def _readback(tree):
    """The ONE device→host transfer of the fused pipeline.

    Every host materialization in the ``pipeline_fused`` path flows through
    this function, so tests can count transfers by monkeypatching it (or by
    reading ``_transfer_count``)."""
    global _transfer_count
    _transfer_count += 1
    return jax.device_get(tree)


# ------------------------------------------------------------ fused pipeline


def _graph_arrays(g: Graph):
    return (g.src, g.dst, g.w, g.edge_mask, g.n_valid, g.m_valid)


@lru_cache(maxsize=None)
def _pipeline_fn(spec0: EngineSpec, spec_coarse: EngineSpec,
                 refine_spec: Optional[EngineSpec], max_levels: int,
                 track_modularity: bool):
    """Build the jitted whole-run pipeline (DESIGN.md §Pipeline).

    Level 0 is peeled out of the loop (it may use the ELL backend and always
    starts from singletons); levels >= 1 run inside a ``lax.while_loop`` with
    the Alg. 3 ``n_comm == n_valid`` predicate on device.  Histories are
    fixed-size on-device buffers: ``modularity[max_levels]`` (NaN sentinel),
    ``sweeps/n_comm[max_levels]`` and ``delta_n[max_levels, max_sweeps]``
    (``-1`` sentinel, the PR-1 convention).
    """

    def pipeline(g: Graph, ell, g0: Graph, seed):
        n = g.n_max
        arange_n = jnp.arange(n, dtype=jnp.int32)

        def run_level(cur: Graph, assign, init_com, level_u32, spec, ell):
            """One level: fused local-moving → remap → (refine) → coarsen.

            Mirrors one iteration of the per-level driver exactly; returns
            the next level's graph arrays + bookkeeping and this level's
            history entries."""
            vmask = cur.vertex_mask()
            it0 = level_u32 * jnp.uint32(1000)
            com, _, sweeps, dn_h, _act_h = device_phase(
                spec, cur, ell, init_com, vmask, it0, seed)
            new_com, n_comm = aggregation.remap_communities(com, vmask)
            macro_assign = new_com[jnp.clip(assign, 0, n - 1)]
            done = n_comm == cur.n_valid           # Alg. 3 l.6 convergence
            q = (modularity(g0, macro_assign) if track_modularity
                 else jnp.float32(0.0))

            def advance(_):
                if refine_spec is not None:
                    # Leiden: aggregate by the REFINED partition; seed the
                    # next level's local-moving with each super-vertex's
                    # macro id (paper-order: refinement only when not done)
                    ref, _, _, _, _ = device_phase(
                        refine_spec, cur, None, arange_n, vmask,
                        it0 + jnp.uint32(500), seed, restrict=com)
                    new_ref, n_ref = aggregation.remap_communities(ref, vmask)
                    macro_of_ref = jax.ops.segment_max(
                        jnp.where(vmask, com, -1),
                        jnp.clip(new_ref, 0, n - 1), num_segments=n)
                    nxt = aggregation.coarsen_graph(cur, new_ref, n_ref)
                    return (_graph_arrays(nxt),
                            new_ref[jnp.clip(assign, 0, n - 1)],
                            jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32))
                nxt = aggregation.coarsen_graph(cur, new_com, n_comm)
                return _graph_arrays(nxt), macro_assign, arange_n

            def stay(_):
                return _graph_arrays(cur), assign, init_com

            nxt_arrays, assign2, init2 = jax.lax.cond(done, stay, advance,
                                                      None)
            return (nxt_arrays, assign2, init2, macro_assign,
                    sweeps.astype(jnp.int32), dn_h, n_comm, q, done)

        # fixed-size per-level history buffers, one readback at the end
        mod_hist = jnp.full((max_levels,), jnp.nan, jnp.float32)
        sweeps_hist = jnp.full((max_levels,), -1, jnp.int32)
        ncomm_hist = jnp.full((max_levels,), -1, jnp.int32)
        dn_hist = jnp.full((max_levels, spec_coarse.max_sweeps), -1, jnp.int32)

        # peeled level 0: the only level that may use the ELL/Pallas backend
        (arrays, assign, init_com, macro, sweeps, dn_h, n_comm, q,
         done) = run_level(g, arange_n, arange_n, jnp.uint32(0), spec0, ell)
        mod_hist = mod_hist.at[0].set(q)
        sweeps_hist = sweeps_hist.at[0].set(sweeps)
        ncomm_hist = ncomm_hist.at[0].set(n_comm)
        dn_hist = dn_hist.at[0].set(dn_h)

        def cond(c):
            level, done = c[0], c[1]
            return (level < max_levels) & (~done)

        def body(c):
            (level, _done, arrays, assign, init_com, _macro,
             mh, sh, nh, dh) = c
            src, dst, w, em, nv, mv = arrays
            cur = Graph(src=src, dst=dst, w=w, edge_mask=em, n_valid=nv,
                        m_valid=mv, n_max=g.n_max, m_max=g.m_max,
                        sorted_by=None)
            (arrays2, assign2, init2, macro2, sweeps, dn_h, n_comm, q,
             done2) = run_level(cur, assign, init_com,
                                level.astype(jnp.uint32), spec_coarse, None)
            mh = mh.at[level].set(q)
            sh = sh.at[level].set(sweeps)
            nh = nh.at[level].set(n_comm)
            dh = dh.at[level].set(dn_h)
            return (level + 1, done2, arrays2, assign2, init2, macro2,
                    mh, sh, nh, dh)

        carry = (jnp.int32(1), done, arrays, assign, init_com, macro,
                 mod_hist, sweeps_hist, ncomm_hist, dn_hist)
        carry = jax.lax.while_loop(cond, body, carry)
        (levels, _, _, _, _, macro, mod_hist, sweeps_hist, ncomm_hist,
         dn_hist) = carry

        final_assign, n_final = aggregation.remap_communities(
            macro, g0.vertex_mask())
        q_final = modularity(g0, final_assign)
        return (final_assign, n_final, levels, q_final,
                mod_hist, sweeps_hist, ncomm_hist, dn_hist)

    return jax.jit(pipeline)


def _louvain_pipeline(g: Graph, cfg: LouvainConfig,
                      g_original: Optional[Graph]) -> LouvainResult:
    """Whole-run fused driver: ONE dispatch, ONE readback (``_readback``)."""
    timer = Timer()
    g0 = g_original if g_original is not None else g
    spec0 = engine_spec(cfg)
    spec_coarse = engine_spec(cfg, backend=_coarse_backend(cfg.backend))
    refine_spec = _refine_spec(cfg) if cfg.refine else None

    ell = None
    if cfg.backend in ("ell", "pallas"):
        from repro.graph import ell as ell_mod

        with timer.phase("ell_build"):
            ell = ell_mod.build_device_ell(g)

    fn = _pipeline_fn(spec0, spec_coarse, refine_spec, cfg.max_levels,
                      cfg.track_modularity)
    with timer.phase("pipeline"):
        out = fn(g, ell, g0, jnp.uint32(cfg.seed))
        (final_assign, n_final, levels, q, mod_hist, sweeps_hist,
         ncomm_hist, dn_hist) = _readback(out)

    levels = int(levels)
    sweeps_per_level = [int(s) for s in sweeps_hist[:levels]]
    return LouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=float(q),
        modularity_history=(
            [float(x) for x in mod_hist[:levels]]
            if cfg.track_modularity else []),
        sweeps_per_level=sweeps_per_level,
        timer=timer,
        n_comm_per_level=[int(x) for x in ncomm_hist[:levels]],
        delta_n_per_level=[
            [int(x) for x in row[:s]]
            for row, s in zip(dn_hist[:levels], sweeps_per_level)],
    )


# ------------------------------------------------------------ refinement


def _refine_partition(cur: Graph, com_macro: jax.Array, cfg: LouvainConfig,
                      level: int) -> jax.Array:
    """Leiden refinement: greedy modularity merges restricted to the macro
    communities, starting from singletons.  Guarantees every aggregated
    super-vertex is contained in (and connected within) a macro community."""
    engine = SweepEngine(cur, _refine_spec(cfg))
    res = engine.run_phase(
        *engine.singleton_state(),
        it0=level * 1000 + 500, seed=cfg.seed,
        restrict=com_macro, fused=cfg.fused,
    )
    return res.labels


# ------------------------------------------------------------ driver (Alg. 3)


def leiden(g: Graph, cfg: LouvainConfig = LouvainConfig(),
           g_original: Optional[Graph] = None) -> LouvainResult:
    """Leiden = Louvain + refinement phase + macro-seeded levels."""
    return louvain(g, cfg.replace(refine=True), g_original)


def louvain(g: Graph, cfg: LouvainConfig = LouvainConfig(),
            g_original: Optional[Graph] = None) -> LouvainResult:
    if cfg.pipeline_fused and cfg.fused:
        return _louvain_pipeline(g, cfg, g_original)
    return _louvain_per_level(g, cfg, g_original)


def _tphase(timer: Timer, name: str, level: int, per_level: bool):
    """timer.phase(name), optionally doubled with a level-tagged entry."""
    if not per_level:
        return timer.phase(name)
    stack = contextlib.ExitStack()
    stack.enter_context(timer.phase(name))
    stack.enter_context(timer.phase(f"L{level:02d}/{name}"))
    return stack


def _louvain_per_level(g: Graph, cfg: LouvainConfig,
                       g_original: Optional[Graph]) -> LouvainResult:
    """Per-level Python driver (``pipeline_fused=False``): one fused
    local-moving dispatch per level, aggregation + Alg. 3 convergence on
    host.  Bit-for-bit parity with the fused pipeline is contractual
    (tests/test_pipeline.py) — any change here must be mirrored in
    ``_pipeline_fn`` and vice versa."""
    timer = Timer()
    g0 = g_original if g_original is not None else g
    n = g.n_max

    assign = jnp.arange(n, dtype=jnp.int32)  # original vertex -> community
    cur = g
    mod_hist: list = []
    sweeps_per_level: list = []
    n_comm_per_level: list = []
    delta_n_per_level: list = []
    levels = 0

    init_com = None   # Leiden: macro partition seeds the next level
    for level in range(cfg.max_levels):
        spec = engine_spec(
            cfg, backend=cfg.backend if level == 0
            else _coarse_backend(cfg.backend))
        with timer.phase("ell_build") if spec.backend in ("ell", "pallas") \
                else contextlib.nullcontext():
            engine = SweepEngine(cur, spec)
        com = (jnp.arange(n, dtype=jnp.int32)  # singleton init (Alg. 2 l.4)
               if init_com is None else init_com)
        init_com = None
        need = cur.vertex_mask()               # needCheck = true (l.7)

        # ONE fused while_loop call per level (DESIGN.md §Engine): the whole
        # local-moving phase converges on device before anything syncs back
        with _tphase(timer, "local_moving", level, cfg.per_level_timing):
            res = engine.run_phase(
                com, need, it0=level * 1000, seed=cfg.seed, fused=cfg.fused)
        com = res.labels
        sweeps_per_level.append(res.sweeps)
        delta_n_per_level.append(res.delta_n_history)

        with _tphase(timer, "aggregation", level, cfg.per_level_timing):
            new_com, n_comm = aggregation.remap_communities(com, cur.vertex_mask())
            # macro labels on ORIGINAL vertices (the result partition); under
            # refinement `assign` tracks the finer refined chain instead
            macro_assign = new_com[jnp.clip(assign, 0, n - 1)]
            n_comm_i = int(n_comm)
            n_valid_i = int(cur.n_valid)
            n_comm_per_level.append(n_comm_i)
            done = n_comm_i == n_valid_i          # Alg. 3 l.6 convergence
            if not done and cfg.refine:
                # Leiden: aggregate by the REFINED partition; seed the next
                # level's local-moving with each super-vertex's macro id
                with _tphase(timer, "refinement", level, cfg.per_level_timing):
                    ref = _refine_partition(cur, com, cfg, level)
                new_ref, n_ref = aggregation.remap_communities(
                    ref, cur.vertex_mask())
                # macro label of each refined group (refined ⊆ macro)
                macro_of_ref = jax.ops.segment_max(
                    jnp.where(cur.vertex_mask(), com, -1),
                    jnp.clip(new_ref, 0, n - 1), num_segments=n)
                assign = new_ref[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_ref, n_ref)
                init_com = jnp.clip(macro_of_ref, 0, n - 1).astype(jnp.int32)
            elif not done:
                assign = new_com[jnp.clip(assign, 0, n - 1)]
                cur = aggregation.coarsen_graph(cur, new_com, n_comm)
        levels = level + 1
        if cfg.track_modularity:
            mod_hist.append(float(modularity(g0, macro_assign)))
        if done:
            break

    final_assign, n_final = aggregation.remap_communities(
        macro_assign, g0.vertex_mask())
    q = float(modularity(g0, final_assign))
    return LouvainResult(
        labels=np.asarray(final_assign),
        n_communities=int(n_final),
        levels=levels,
        modularity=q,
        modularity_history=mod_hist,
        sweeps_per_level=sweeps_per_level,
        timer=timer,
        n_comm_per_level=n_comm_per_level,
        delta_n_per_level=delta_n_per_level,
    )
