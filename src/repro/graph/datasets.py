"""Dataset registry: paper's SNAP graphs (Table I) as seeded synthetic stand-ins.

The container is offline, so the six SNAP graphs are represented by generators
matched to each graph's V, E/V ratio and community character, at a reduced
scale (default 1/32 of V; override with ``REPRO_DATASET_SCALE``).  Paper
statistics are kept as metadata so benchmark tables can print both.

  * community-rich graphs (com-amazon, com-dblp) -> SBM with strong planted
    structure (their published Louvain modularity is ~0.92/0.82);
  * heavy-tailed web/social graphs (com-youtube, as-skitter, com-livejournal,
    com-orkut) -> R-MAT with Graph500 skew.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional, Tuple

import numpy as np

from repro.graph import generators
from repro.graph.builders import from_numpy_edges
from repro.graph.structure import Graph
from repro.utils.registry import Registry


@dataclasses.dataclass(frozen=True)
class DatasetMeta:
    name: str
    paper_vertices: Optional[int]
    paper_edges: Optional[int]
    paper_diameter: Optional[int]
    kind: str  # "snap-standin" | "synthetic" | "classic"
    description: str = ""


@dataclasses.dataclass
class LoadedGraph:
    graph: Graph
    truth: Optional[np.ndarray]
    meta: DatasetMeta
    n: int
    m_undirected: int


DATASETS: Registry[Callable[..., LoadedGraph]] = Registry("dataset")

# Paper Table I
_TABLE_I = {
    "com-amazon": (334_863, 925_872, 44),
    "com-dblp": (317_080, 1_049_866, 21),
    "com-youtube": (1_134_890, 2_987_624, 20),
    "com-livejournal": (3_997_962, 34_681_189, 17),
    "as-skitter": (1_696_415, 11_095_298, 25),
    "com-orkut": (3_072_441, 117_185_083, 9),
}


def _scale() -> float:
    return float(os.environ.get("REPRO_DATASET_SCALE", "0.03125"))  # 1/32


def _mk_loaded(u, v, w, truth, meta: DatasetMeta, n: int) -> LoadedGraph:
    g = from_numpy_edges(u, v, w, n=n, sort_by="src")
    return LoadedGraph(graph=g, truth=truth, meta=meta, n=n, m_undirected=len(u))


def _register_snap_standins() -> None:
    def make_sbm_standin(name: str, communities_frac: float, p_in: float, deg_out: float):
        V, E, diam = _TABLE_I[name]

        def load(seed: int = 0, scale: Optional[float] = None) -> LoadedGraph:
            s = scale if scale is not None else _scale()
            n = max(512, int(V * s))
            k = max(4, int(n * communities_frac))
            csize = n / k
            # mean intra-degree = p_in*(csize-1); choose p_out to hit E/V target
            target_deg = 2.0 * E / V
            intra = p_in * (csize - 1)
            p_out = max(0.0, (target_deg - intra)) / max(1.0, (n - csize))
            u, v, w, truth = generators.sbm(n, k, p_in=p_in, p_out=p_out, seed=seed)
            meta = DatasetMeta(name, V, E, diam, "snap-standin", "SBM-matched")
            return _mk_loaded(u, v, w, truth, meta, n)

        DATASETS.register(name, load)

    def make_rmat_standin(name: str):
        V, E, diam = _TABLE_I[name]

        def load(seed: int = 0, scale: Optional[float] = None) -> LoadedGraph:
            s = scale if scale is not None else _scale()
            n_target = max(1024, int(V * s))
            sc = max(10, int(np.ceil(np.log2(n_target))))
            ef = max(2, int(round(E / V)))
            u, v, w = generators.rmat(sc, ef, seed=seed)
            n = 1 << sc
            meta = DatasetMeta(name, V, E, diam, "snap-standin", "R-MAT-matched")
            return _mk_loaded(u, v, w, None, meta, n)

        DATASETS.register(name, load)

    # community-rich graphs: ~30 vertices per community, dense blocks
    make_sbm_standin("com-amazon", communities_frac=1 / 30, p_in=0.35, deg_out=0.5)
    make_sbm_standin("com-dblp", communities_frac=1 / 40, p_in=0.30, deg_out=0.5)
    make_rmat_standin("com-youtube")
    make_rmat_standin("com-livejournal")
    make_rmat_standin("as-skitter")
    make_rmat_standin("com-orkut")


def _register_synthetic() -> None:
    def load_ring(seed: int = 0, n_cliques: int = 16, clique_size: int = 8) -> LoadedGraph:
        u, v, w, truth = generators.ring_of_cliques(n_cliques, clique_size)
        meta = DatasetMeta("ring-of-cliques", None, None, None, "classic")
        return _mk_loaded(u, v, w, truth, meta, n_cliques * clique_size)

    def load_sbm_small(seed: int = 0) -> LoadedGraph:
        n, k = 2000, 40
        u, v, w, truth = generators.sbm(n, k, p_in=0.3, p_out=0.002, seed=seed)
        meta = DatasetMeta("sbm-small", None, None, None, "synthetic")
        return _mk_loaded(u, v, w, truth, meta, n)

    def load_sbm_medium(seed: int = 0) -> LoadedGraph:
        n, k = 20_000, 200
        u, v, w, truth = generators.sbm(n, k, p_in=0.25, p_out=0.0004, seed=seed)
        meta = DatasetMeta("sbm-medium", None, None, None, "synthetic")
        return _mk_loaded(u, v, w, truth, meta, n)

    def load_karate(seed: int = 0) -> LoadedGraph:
        import networkx as nx

        G = nx.karate_club_graph()
        edges = np.asarray(list(G.edges()), dtype=np.int64)
        meta = DatasetMeta("karate", 34, 78, 5, "classic", "Zachary karate club")
        truth = np.asarray(
            [0 if G.nodes[i]["club"] == "Mr. Hi" else 1 for i in G.nodes()]
        )
        return _mk_loaded(
            edges[:, 0], edges[:, 1], np.ones(len(edges)), truth, meta, 34
        )

    DATASETS.register("ring-of-cliques", load_ring)
    DATASETS.register("sbm-small", load_sbm_small)
    DATASETS.register("sbm-medium", load_sbm_medium)
    DATASETS.register("karate", load_karate)


_register_snap_standins()
_register_synthetic()


def load(name: str, **kw) -> LoadedGraph:
    return DATASETS.get(name)(**kw)


def paper_table_i() -> dict:
    return dict(_TABLE_I)
