"""Graph construction: symmetrize + dedup undirected edge lists.

This is the ingest path equivalent to Arachne's "tabular data -> graph"
conversion (§II-D).  The host-side path (numpy) is used for dataset loading;
the jit path (`repro.graph.segment`) is used when graphs are built inside a
compiled program (Louvain aggregation).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.graph.structure import Graph, graph_from_arrays


def from_numpy_edges(
    u: np.ndarray,
    v: np.ndarray,
    w: Optional[np.ndarray] = None,
    *,
    n: Optional[int] = None,
    m_max: Optional[int] = None,
    dedup: bool = True,
    sort_by: str = "src",
) -> Graph:
    """Build a Graph from an undirected host edge list.

    * symmetrizes: {u,v} -> (u,v) and (v,u)
    * input self-loops (u==v) are stored once with DOUBLED weight (paper §II-A:
      "loops are counted twice")
    * optional dedup merges parallel edges by weight summation
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    if w is None:
        w = np.ones(u.shape[0], dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    if u.shape != v.shape or u.shape != w.shape:
        raise ValueError("u, v, w must have identical shapes")
    n = int(n if n is not None else (max(u.max(initial=-1), v.max(initial=-1)) + 1))
    if u.size and (u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n):
        raise ValueError("vertex ids out of range")

    loops = u == v
    nl_u, nl_v, nl_w = u[~loops], v[~loops], w[~loops]
    lp_u, lp_w = u[loops], w[loops]

    src = np.concatenate([nl_u, nl_v, lp_u])
    dst = np.concatenate([nl_v, nl_u, lp_u])
    ww = np.concatenate([nl_w, nl_w, 2.0 * lp_w])

    if dedup and src.size:
        key = src * n + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, ww = key[order], src[order], dst[order], ww[order]
        starts = np.concatenate([[True], key[1:] != key[:-1]])
        rid = np.cumsum(starts) - 1
        sums = np.zeros(rid[-1] + 1, dtype=np.float64)
        np.add.at(sums, rid, ww)
        src, dst, ww = src[starts], dst[starts], sums

    if sort_by == "dst":
        order = np.lexsort((src, dst))
    else:
        order = np.lexsort((dst, src))
    src, dst, ww = src[order], dst[order], ww[order]

    return graph_from_arrays(
        jnp.asarray(src, dtype=jnp.int32),
        jnp.asarray(dst, dtype=jnp.int32),
        jnp.asarray(ww, dtype=jnp.float32),
        n_max=n,
        m_max=m_max,
        n_valid=n,
        sorted_by=sort_by,
    )


def from_undirected_edges(edges, n: Optional[int] = None, **kw) -> Graph:
    """Convenience: iterable of (u, v) or (u, v, w) tuples."""
    arr = np.asarray(list(edges), dtype=np.float64)
    if arr.size == 0:
        arr = np.zeros((0, 2))
    u, v = arr[:, 0].astype(np.int64), arr[:, 1].astype(np.int64)
    w = arr[:, 2] if arr.shape[1] > 2 else None
    return from_numpy_edges(u, v, w, n=n, **kw)


def validate_graph(g: Graph) -> None:
    """Host-side invariant checks (tests / debugging):

    * symmetry: (u,v,w) valid  <=>  (v,u,w) valid (loops once)
    * masks consistent with n_valid/m_valid
    * sort invariant holds
    """
    src, dst, w = g.to_numpy_edges()
    if int(np.sum(np.asarray(g.edge_mask))) != int(g.m_valid):
        raise AssertionError("edge_mask count != m_valid")
    if src.size:
        if src.max() >= int(g.n_valid) or dst.max() >= int(g.n_valid):
            raise AssertionError("valid edge endpoints out of vertex range")
    if g.sorted_by == "src":
        key = src.astype(np.int64) * g.n_max + dst
        if np.any(np.diff(key) < 0):
            raise AssertionError("not sorted by (src, dst)")
    elif g.sorted_by == "dst":
        key = dst.astype(np.int64) * g.n_max + src
        if np.any(np.diff(key) < 0):
            raise AssertionError("not sorted by (dst, src)")
    nonloop = src != dst
    fwd = set(zip(src[nonloop].tolist(), dst[nonloop].tolist()))
    for (a, b) in fwd:
        if (b, a) not in fwd:
            raise AssertionError(f"missing reverse edge for ({a},{b})")
    # reverse weights must match
    wmap = {}
    for a, b, x in zip(src.tolist(), dst.tolist(), w.tolist()):
        wmap[(a, b)] = wmap.get((a, b), 0.0) + x
    for (a, b), x in wmap.items():
        if a != b and abs(wmap[(b, a)] - x) > 1e-5 * max(1.0, abs(x)):
            raise AssertionError(f"asymmetric weight on ({a},{b})")
