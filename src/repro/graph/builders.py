"""Graph construction: symmetrize + dedup undirected edge lists.

This is the ingest path equivalent to Arachne's "tabular data -> graph"
conversion (§II-D).  The host-side path (numpy) is used for dataset loading;
the jit path (`repro.graph.segment`) is used when graphs are built inside a
compiled program (Louvain aggregation).

Robust ingest (DESIGN.md §Robustness): real-world edge lists arrive with
duplicate and reverse-duplicate rows, self-loops, NaN/negative weights and
out-of-range ids.  ``canonicalize_edges`` repairs (or rejects, per policy)
all of those BEFORE symmetrization and returns a structured ``RepairReport``;
``from_numpy_edges_robust`` chains canonicalize → build → ``validate_graph``.
Clean input passes through bit-identically — the repair path returns the
caller's arrays untouched when there is nothing to repair.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.structure import Graph, graph_from_arrays
from repro.utils import telemetry
from repro.utils.errors import InputValidationError

# Default for the ``validate=`` flags below when the caller passes None.
# Production keeps it off (datasets are loaded once and validation is O(m)
# host work); the test suite flips it on via an autouse conftest fixture so
# every graph any test builds is checked.
DEFAULT_VALIDATE = False


def _resolve_validate(validate: Optional[bool]) -> bool:
    return DEFAULT_VALIDATE if validate is None else bool(validate)


@dataclasses.dataclass(frozen=True)
class RepairReport:
    """What ``canonicalize_edges`` changed (all counts are input rows).

    ``clean`` is True iff the input needed no repair — in that case the
    canonicalizer returned the caller's arrays untouched (bit-identity of
    the clean path is structural, not asserted after the fact).
    """

    duplicates_coalesced: int = 0
    self_loops_dropped: int = 0
    nonfinite_weights: int = 0
    negative_weights: int = 0
    out_of_range_ids: int = 0
    actions: Tuple[str, ...] = ()

    @property
    def clean(self) -> bool:
        return not self.actions


def canonicalize_edges(
    u: np.ndarray,
    v: np.ndarray,
    w: Optional[np.ndarray] = None,
    *,
    n: Optional[int] = None,
    self_loops: str = "keep",
    bad_weights: str = "raise",
    bad_ids: str = "raise",
    coalesce: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int, RepairReport]:
    """Repair a raw undirected edge list into canonical form.

    Policies:
      * ``self_loops``: "keep" or "drop"
      * ``bad_weights`` (NaN/Inf, or negative): "raise", "drop" (remove the
        row), or "zero" (clamp the weight to 0.0, keeping the row)
      * ``bad_ids`` (negative or >= n): "raise" or "drop"
      * ``coalesce``: merge duplicate AND reverse-duplicate rows ({u,v} as an
        unordered pair) by weight summation, keeping first-occurrence order
        of the surviving representative rows.

    Returns ``(u, v, w, n, report)``.  When nothing needs repair the input
    arrays are returned as-is (same objects), so the clean path feeds
    ``from_numpy_edges`` bit-identically to calling it directly.
    """
    if self_loops not in ("keep", "drop"):
        raise ValueError(f"self_loops={self_loops!r}, want 'keep' or 'drop'")
    if bad_weights not in ("raise", "drop", "zero"):
        raise ValueError(
            f"bad_weights={bad_weights!r}, want 'raise', 'drop' or 'zero'")
    if bad_ids not in ("raise", "drop"):
        raise ValueError(f"bad_ids={bad_ids!r}, want 'raise' or 'drop'")

    u0, v0, w_in = u, v, w
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    if w is None:
        w = np.ones(u.shape[0], dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    if u.shape != v.shape or u.shape != w.shape:
        raise InputValidationError("u, v, w must have identical shapes")
    n = int(n if n is not None else
            (max(u.max(initial=-1), v.max(initial=-1)) + 1))

    actions: list = []

    id_bad = (u < 0) | (v < 0) | (u >= n) | (v >= n)
    n_id_bad = int(id_bad.sum())
    if n_id_bad:
        telemetry.bump("ingest.out_of_range_ids", n_id_bad)
        if bad_ids == "raise":
            raise InputValidationError(
                f"{n_id_bad} edge(s) with endpoint ids outside [0, {n})")
        actions.append(f"dropped {n_id_bad} out-of-range-id edge(s)")
        u, v, w = u[~id_bad], v[~id_bad], w[~id_bad]

    nonfinite = ~np.isfinite(w)
    negative = np.isfinite(w) & (w < 0)
    n_nonfinite, n_negative = int(nonfinite.sum()), int(negative.sum())
    if n_nonfinite or n_negative:
        telemetry.bump("ingest.bad_weights", n_nonfinite + n_negative)
        if bad_weights == "raise":
            raise InputValidationError(
                f"{n_nonfinite} non-finite and {n_negative} negative edge "
                "weight(s)")
        bad = nonfinite | negative
        if bad_weights == "drop":
            actions.append(f"dropped {int(bad.sum())} bad-weight edge(s)")
            u, v, w = u[~bad], v[~bad], w[~bad]
        else:
            actions.append(f"zeroed {int(bad.sum())} bad weight(s)")
            w = np.where(bad, 0.0, w)

    n_loops_dropped = 0
    if self_loops == "drop":
        loops = u == v
        n_loops_dropped = int(loops.sum())
        if n_loops_dropped:
            telemetry.bump("ingest.self_loops_dropped", n_loops_dropped)
            actions.append(f"dropped {n_loops_dropped} self-loop(s)")
            u, v, w = u[~loops], v[~loops], w[~loops]

    n_coalesced = 0
    if coalesce and u.size:
        # unordered-pair key: duplicates AND reverse-duplicates share it
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        key = lo * n + hi
        uniq, first, inv = np.unique(
            key, return_index=True, return_inverse=True)
        if uniq.size != key.size:
            n_coalesced = int(key.size - uniq.size)
            telemetry.bump("ingest.duplicates_coalesced", n_coalesced)
            actions.append(
                f"coalesced {n_coalesced} duplicate/reverse-duplicate row(s)")
            sums = np.zeros(uniq.size, dtype=np.float64)
            np.add.at(sums, inv, w)
            keep = np.sort(first)          # first-occurrence order
            u, v = u[keep], v[keep]
            w = sums[inv[keep]]   # each survivor's unique-key aggregate

    report = RepairReport(
        duplicates_coalesced=n_coalesced,
        self_loops_dropped=n_loops_dropped,
        nonfinite_weights=n_nonfinite,
        negative_weights=n_negative,
        out_of_range_ids=n_id_bad,
        actions=tuple(actions),
    )
    if report.clean:
        # nothing repaired: hand back the caller's arrays untouched so the
        # downstream build is bit-identical to the non-robust entry point
        return u0, v0, w_in, n, report
    return u, v, w, n, report


def from_numpy_edges(
    u: np.ndarray,
    v: np.ndarray,
    w: Optional[np.ndarray] = None,
    *,
    n: Optional[int] = None,
    m_max: Optional[int] = None,
    dedup: bool = True,
    sort_by: str = "src",
    validate: Optional[bool] = None,
) -> Graph:
    """Build a Graph from an undirected host edge list.

    * symmetrizes: {u,v} -> (u,v) and (v,u)
    * input self-loops (u==v) are stored once with DOUBLED weight (paper §II-A:
      "loops are counted twice")
    * optional dedup merges parallel edges by weight summation
    * ``validate`` runs ``validate_graph`` on the result (None defers to the
      module-level ``DEFAULT_VALIDATE``, flipped on by the test conftest)
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    if w is None:
        w = np.ones(u.shape[0], dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    if u.shape != v.shape or u.shape != w.shape:
        raise ValueError("u, v, w must have identical shapes")
    n = int(n if n is not None else (max(u.max(initial=-1), v.max(initial=-1)) + 1))
    if u.size and (u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n):
        raise InputValidationError("vertex ids out of range")

    loops = u == v
    nl_u, nl_v, nl_w = u[~loops], v[~loops], w[~loops]
    lp_u, lp_w = u[loops], w[loops]

    src = np.concatenate([nl_u, nl_v, lp_u])
    dst = np.concatenate([nl_v, nl_u, lp_u])
    ww = np.concatenate([nl_w, nl_w, 2.0 * lp_w])

    if dedup and src.size:
        key = src * n + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, ww = key[order], src[order], dst[order], ww[order]
        starts = np.concatenate([[True], key[1:] != key[:-1]])
        rid = np.cumsum(starts) - 1
        sums = np.zeros(rid[-1] + 1, dtype=np.float64)
        np.add.at(sums, rid, ww)
        src, dst, ww = src[starts], dst[starts], sums

    if sort_by == "dst":
        order = np.lexsort((src, dst))
    else:
        order = np.lexsort((dst, src))
    src, dst, ww = src[order], dst[order], ww[order]

    g = graph_from_arrays(
        jnp.asarray(src, dtype=jnp.int32),
        jnp.asarray(dst, dtype=jnp.int32),
        jnp.asarray(ww, dtype=jnp.float32),
        n_max=n,
        m_max=m_max,
        n_valid=n,
        sorted_by=sort_by,
        validate=False,      # full validation below covers the structural one
    )
    if _resolve_validate(validate):
        validate_graph(g)
    return g


def from_numpy_edges_robust(
    u: np.ndarray,
    v: np.ndarray,
    w: Optional[np.ndarray] = None,
    *,
    n: Optional[int] = None,
    m_max: Optional[int] = None,
    sort_by: str = "src",
    self_loops: str = "keep",
    bad_weights: str = "raise",
    bad_ids: str = "raise",
) -> Tuple[Graph, RepairReport]:
    """Canonicalize → build → validate.  Clean input produces a Graph
    bit-identical to ``from_numpy_edges(u, v, w, ...)``; repaired input is
    described by the returned ``RepairReport``."""
    u, v, w, n, report = canonicalize_edges(
        u, v, w, n=n, self_loops=self_loops, bad_weights=bad_weights,
        bad_ids=bad_ids)
    g = from_numpy_edges(
        u, v, w, n=n, m_max=m_max, sort_by=sort_by, validate=False)
    validate_graph(g)
    return g, report


def from_undirected_edges(edges, n: Optional[int] = None, **kw) -> Graph:
    """Convenience: iterable of (u, v) or (u, v, w) tuples."""
    arr = np.asarray(list(edges), dtype=np.float64)
    if arr.size == 0:
        arr = np.zeros((0, 2))
    u, v = arr[:, 0].astype(np.int64), arr[:, 1].astype(np.int64)
    w = arr[:, 2] if arr.shape[1] > 2 else None
    return from_numpy_edges(u, v, w, n=n, **kw)


def validate_graph(g: Graph, *, symmetry: bool = True) -> None:
    """Host-side invariant checks (raises ``InputValidationError``):

    * masks consistent with n_valid/m_valid
    * endpoint ids inside [0, n_valid) (negative ids included)
    * weights finite and non-negative
    * sort invariant holds
    * symmetry (vectorized): (u,v) valid <=> (v,u) valid with equal
      aggregate weight, loops exempt.  ``symmetry=False`` runs only the
      structural checks — builder intermediates (e.g. pre-symmetrized
      fixtures through ``graph_from_arrays``) are deliberately one-sided.
    """
    src, dst, w = g.to_numpy_edges()
    if int(np.sum(np.asarray(g.edge_mask))) != int(g.m_valid):
        raise InputValidationError("edge_mask count != m_valid")
    if src.size:
        if src.min() < 0 or dst.min() < 0:
            raise InputValidationError("negative edge endpoint ids")
        if src.max() >= int(g.n_valid) or dst.max() >= int(g.n_valid):
            raise InputValidationError(
                "valid edge endpoints out of vertex range")
    if not np.all(np.isfinite(w)):
        raise InputValidationError("non-finite edge weights")
    if w.size and w.min() < 0:
        raise InputValidationError("negative edge weights")
    if g.sorted_by == "src":
        key = src.astype(np.int64) * g.n_max + dst
        if np.any(np.diff(key) < 0):
            raise InputValidationError("not sorted by (src, dst)")
    elif g.sorted_by == "dst":
        key = dst.astype(np.int64) * g.n_max + src
        if np.any(np.diff(key) < 0):
            raise InputValidationError("not sorted by (dst, src)")
    if not symmetry:
        return
    nonloop = src != dst
    a = src[nonloop].astype(np.int64)
    b = dst[nonloop].astype(np.int64)
    ws = w[nonloop].astype(np.float64)
    n64 = np.int64(g.n_max)
    fwd = a * n64 + b
    # aggregate parallel-edge weights per directed key, then require the
    # transposed key set to exist with matching sums
    uniq, inv = np.unique(fwd, return_inverse=True)
    sums = np.zeros(uniq.size, dtype=np.float64)
    np.add.at(sums, inv, ws)
    ua, ub = uniq // n64, uniq % n64
    rev = ub * n64 + ua
    pos = np.searchsorted(uniq, rev)
    present = (pos < uniq.size) & (uniq[np.clip(pos, 0, uniq.size - 1)] == rev)
    if not np.all(present):
        k = int(np.argmin(present))
        raise InputValidationError(
            f"missing reverse edge for ({int(ua[k])},{int(ub[k])})")
    rsums = sums[pos]
    tol = 1e-5 * np.maximum(1.0, np.abs(sums))
    if np.any(np.abs(rsums - sums) > tol):
        k = int(np.argmax(np.abs(rsums - sums) > tol))
        raise InputValidationError(
            f"asymmetric weight on ({int(ua[k])},{int(ub[k])})")
