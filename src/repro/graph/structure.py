"""Core graph structure.

Representation (DESIGN.md §2): an undirected weighted graph is stored as a
**directed-symmetric** edge list —

  * every undirected edge {u, v}, u != v, appears as BOTH (u, v, w) and (v, u, w);
  * a self-loop on v appears ONCE as (v, v, w_loop) where ``w_loop`` is the
    *doubled* loop weight ("loops are counted twice", paper §II-A).  Louvain
    aggregation produces exactly this form: the self-edge of a super-vertex
    carries the full directed intra-community weight.

With that convention everything is a plain segment reduction over ``src``:

  deg_w(v)  = segment_sum(w, src)[v]                      (loops counted twice)
  vol_w(V)  = sum(w)                                      ("2W")
  cut_w(v,S)= sum of w over out-edges into S, loops excluded

All arrays have **static capacity** (``n_max`` vertices / ``m_max`` directed
edges) with validity masks, so multi-level coarsening reuses the same buffers
under jit — the TPU answer to Arkouda's dynamically-sized GroupBy outputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "w", "edge_mask", "n_valid", "m_valid"],
    meta_fields=["n_max", "m_max", "sorted_by"],
)
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed-symmetric weighted graph with static capacity.

    Attributes:
      src, dst:  int32[m_max] endpoints (invalid entries hold ``n_max`` sentinels)
      w:         float32[m_max] edge weights (0 for invalid entries)
      edge_mask: bool[m_max] validity
      n_valid:   int32 scalar — number of live vertices (vertices are [0, n_valid))
      m_valid:   int32 scalar — number of live directed edges
      n_max, m_max: static capacities
      sorted_by: "src" | "dst" | None — current sort invariant (static metadata)
    """

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    edge_mask: jax.Array
    n_valid: jax.Array
    m_valid: jax.Array
    n_max: int
    m_max: int
    sorted_by: Optional[str]

    # ---- derived quantities (all jit-safe) ----

    def vertex_mask(self) -> jax.Array:
        return jnp.arange(self.n_max, dtype=jnp.int32) < self.n_valid

    def weighted_degrees(self) -> jax.Array:
        """deg_w(v): sum of out-edge weights (self-loops stored doubled)."""
        return jax.ops.segment_sum(
            jnp.where(self.edge_mask, self.w, 0.0), self.src, num_segments=self.n_max
        )

    def unweighted_degrees(self) -> jax.Array:
        ones = jnp.where(self.edge_mask, 1, 0)
        return jax.ops.segment_sum(ones, self.src, num_segments=self.n_max)

    def total_volume(self) -> jax.Array:
        """vol_w(V) = 2W (sum of all directed weights incl. doubled loops)."""
        return jnp.sum(jnp.where(self.edge_mask, self.w, 0.0))

    def is_loop(self) -> jax.Array:
        return self.edge_mask & (self.src == self.dst)

    def loop_weights(self) -> jax.Array:
        """Per-vertex (doubled) self-loop weight."""
        lw = jnp.where(self.is_loop(), self.w, 0.0)
        return jax.ops.segment_sum(lw, self.src, num_segments=self.n_max)

    def row_ptr(self) -> jax.Array:
        """CSR row pointers — requires ``sorted_by == 'src'``."""
        if self.sorted_by != "src":
            raise ValueError("row_ptr requires the graph sorted by src")
        return jnp.searchsorted(
            self.src, jnp.arange(self.n_max + 1, dtype=self.src.dtype), side="left"
        ).astype(jnp.int32)

    # ---- host-side views ----

    def to_numpy_edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, w) of valid directed edges, as host numpy."""
        mask = np.asarray(self.edge_mask)
        return (
            np.asarray(self.src)[mask],
            np.asarray(self.dst)[mask],
            np.asarray(self.w)[mask],
        )

    def n(self) -> int:
        return int(self.n_valid)

    def m_directed(self) -> int:
        return int(self.m_valid)

    def __repr__(self) -> str:  # concise; avoids materializing arrays in logs
        return (
            f"Graph(n_max={self.n_max}, m_max={self.m_max}, sorted_by={self.sorted_by!r})"
        )


def graph_from_arrays(
    src: jax.Array,
    dst: jax.Array,
    w: jax.Array,
    *,
    n_max: int,
    m_max: Optional[int] = None,
    n_valid: Optional[int] = None,
    sorted_by: Optional[str] = None,
    validate: Optional[bool] = None,
) -> Graph:
    """Wrap already-symmetrized directed edge arrays, padding to capacity.

    ``validate`` runs the STRUCTURAL half of ``builders.validate_graph``
    (mask counts, id ranges, weight finiteness, sort invariant) on the
    result; symmetry is deliberately not enforced here because callers hand
    this function deliberately one-sided intermediates.  None defers to
    ``builders.DEFAULT_VALIDATE`` (flipped on by the test conftest).
    """
    m = src.shape[0]
    # floor the edge capacity at 1: an edgeless graph keeps one fully-masked
    # padding slot so every kernel's static-shape assumption (m_max >= 1,
    # e.g. the GroupBy's (m-1,) run-start buffer) holds on degenerate input
    m_max = m_max or max(m, 1)
    if m_max < m:
        raise ValueError(f"m_max={m_max} < m={m}")
    pad = m_max - m
    sentinel = jnp.int32(n_max)
    src = jnp.concatenate([src.astype(jnp.int32), jnp.full((pad,), sentinel)])
    dst = jnp.concatenate([dst.astype(jnp.int32), jnp.full((pad,), sentinel)])
    w = jnp.concatenate([w.astype(jnp.float32), jnp.zeros((pad,), jnp.float32)])
    edge_mask = jnp.arange(m_max) < m
    g = Graph(
        src=src,
        dst=dst,
        w=w,
        edge_mask=edge_mask,
        n_valid=jnp.int32(n_max if n_valid is None else n_valid),
        m_valid=jnp.int32(m),
        n_max=int(n_max),
        m_max=int(m_max),
        sorted_by=sorted_by,
    )
    from repro.graph import builders  # late: builders imports this module
    if builders.DEFAULT_VALIDATE if validate is None else validate:
        builders.validate_graph(g, symmetry=False)
    return g
