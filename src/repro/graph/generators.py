"""Synthetic graph generators (seeded, numpy-vectorized, offline-safe).

The SNAP datasets the paper benchmarks (Table I) are not available offline, so
EXPERIMENTS.md uses (a) SBM planted-partition graphs — ground truth available,
quality measured via NMI + modularity — and (b) R-MAT graphs matched to each
SNAP graph's V/E and degree skew (scaled) for runtime curves.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def sbm(
    n: int,
    k: int,
    *,
    p_in: float,
    p_out: float,
    seed: int = 0,
    weighted: bool = False,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Planted-partition stochastic block model.

    Returns (u, v, w, truth) — undirected unique edges + planted community id.
    Sampling is O(expected_edges) via binomial counts per block pair.
    """
    rng = np.random.default_rng(seed)
    sizes = np.full(k, n // k, dtype=np.int64)
    sizes[: n % k] += 1
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    truth = np.repeat(np.arange(k), sizes)

    us, vs = [], []
    for i in range(k):
        ni = sizes[i]
        # intra-block: sample pairs uniformly; expected count = p_in * ni*(ni-1)/2
        n_pairs = ni * (ni - 1) // 2
        cnt = rng.binomial(n_pairs, p_in) if n_pairs > 0 else 0
        if cnt:
            a = rng.integers(0, ni, size=int(cnt * 1.2) + 8)
            b = rng.integers(0, ni, size=int(cnt * 1.2) + 8)
            ok = a < b
            a, b = a[ok][:cnt], b[ok][:cnt]
            us.append(a + offsets[i])
            vs.append(b + offsets[i])
        for j in range(i + 1, k):
            nj = sizes[j]
            cnt = rng.binomial(ni * nj, p_out)
            if cnt:
                a = rng.integers(0, ni, size=cnt) + offsets[i]
                b = rng.integers(0, nj, size=cnt) + offsets[j]
                us.append(a)
                vs.append(b)
    if us:
        u = np.concatenate(us)
        v = np.concatenate(vs)
    else:
        u = np.zeros(0, dtype=np.int64)
        v = np.zeros(0, dtype=np.int64)
    # dedup
    key = u * n + v
    _, idx = np.unique(key, return_index=True)
    u, v = u[idx], v[idx]
    w = (
        rng.uniform(0.5, 1.5, size=u.shape[0])
        if weighted
        else np.ones(u.shape[0], dtype=np.float64)
    )
    return u, v, w, truth


def rmat(
    scale: int,
    edge_factor: int = 16,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    weighted: bool = False,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """R-MAT power-law generator (Graph500 parameters by default).

    Returns (u, v, w) undirected edges (dedup'd, loops removed), n = 2**scale.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    u = np.zeros(m, dtype=np.int64)
    v = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        right = r >= ab  # bottom half for u
        r2 = rng.random(m)
        # quadrant probabilities conditioned on u-half
        v_right_top = r2 >= (a / ab)
        v_right_bottom = r2 >= (c / (1.0 - ab))
        u |= right.astype(np.int64) << bit
        v |= np.where(right, v_right_bottom, v_right_top).astype(np.int64) << bit
    # undirected canonical form, drop loops, dedup
    lo = np.minimum(u, v)
    hi = np.maximum(u, v)
    ok = lo != hi
    lo, hi = lo[ok], hi[ok]
    key = lo * n + hi
    _, idx = np.unique(key, return_index=True)
    lo, hi = lo[idx], hi[idx]
    w = (
        rng.uniform(0.5, 1.5, size=lo.shape[0])
        if weighted
        else np.ones(lo.shape[0], dtype=np.float64)
    )
    return lo, hi, w


def ring_of_cliques(
    n_cliques: int, clique_size: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Classic community-detection sanity graph: k cliques joined in a ring.

    Returns (u, v, w, truth).  Louvain/LPA must recover the cliques.
    """
    us, vs = [], []
    for ci in range(n_cliques):
        base = ci * clique_size
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                us.append(base + i)
                vs.append(base + j)
        nxt = ((ci + 1) % n_cliques) * clique_size
        us.append(base)  # single bridge edge to the next clique
        vs.append(nxt)
    u = np.asarray(us, dtype=np.int64)
    v = np.asarray(vs, dtype=np.int64)
    w = np.ones(u.shape[0], dtype=np.float64)
    truth = np.repeat(np.arange(n_cliques), clique_size)
    return u, v, w, truth


def random_graph(
    n: int, m: int, *, seed: int = 0, weighted: bool = False
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Erdos-Renyi-ish G(n, m) (dedup'd, no loops)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=int(m * 1.3) + 16)
    v = rng.integers(0, n, size=int(m * 1.3) + 16)
    ok = u < v
    u, v = u[ok], v[ok]
    key = u * n + v
    _, idx = np.unique(key, return_index=True)
    u, v = u[idx][:m], v[idx][:m]
    w = (
        rng.uniform(0.5, 1.5, size=u.shape[0])
        if weighted
        else np.ones(u.shape[0], dtype=np.float64)
    )
    return u, v, w


def nmi(labels_a: np.ndarray, labels_b: np.ndarray) -> float:
    """Normalized mutual information between two partitions (for SBM truth)."""
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    assert a.shape == b.shape
    n = a.shape[0]
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    ka, kb = ai.max() + 1, bi.max() + 1
    cont = np.zeros((ka, kb), dtype=np.float64)
    np.add.at(cont, (ai, bi), 1.0)
    pij = cont / n
    pi = pij.sum(axis=1, keepdims=True)
    pj = pij.sum(axis=0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        mi = np.nansum(pij * np.log(pij / (pi * pj)))
        ha = -np.nansum(pi * np.log(pi))
        hb = -np.nansum(pj * np.log(pj))
    if ha <= 0 or hb <= 0:
        return 1.0 if ka == kb == 1 else 0.0
    return float(mi / np.sqrt(ha * hb))
