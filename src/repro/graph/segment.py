"""Segment / GroupBy primitives — the XLA re-expression of Arkouda's GroupBy.

The paper's aggregation phase leans on Arkouda ``GroupBy`` + ``Broadcast``
(§III-B2).  On TPU the same computation is a multi-operand ``lax.sort``
followed by run detection (`run_starts`), run-id `cumsum`, and
``segment_sum`` — every helper here is jit-safe with static shapes.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def sort_by_keys(
    keys: Sequence[jax.Array], values: Sequence[jax.Array] = ()
) -> Tuple[Tuple[jax.Array, ...], Tuple[jax.Array, ...]]:
    """Stable lexicographic sort of ``values`` by ``keys`` (all same length)."""
    operands = tuple(keys) + tuple(values)
    out = jax.lax.sort(operands, num_keys=len(keys), is_stable=True)
    return out[: len(keys)], out[len(keys):]


def run_starts(*sorted_keys: jax.Array) -> jax.Array:
    """bool[m]: True at the first element of each equal-key run."""
    m = sorted_keys[0].shape[0]
    neq = jnp.zeros((m - 1,), dtype=bool)
    for k in sorted_keys:
        neq = neq | (k[1:] != k[:-1])
    return jnp.concatenate([jnp.ones((1,), dtype=bool), neq])


def run_ids(starts: jax.Array) -> jax.Array:
    """int32[m]: dense run index (0-based) for each element."""
    return jnp.cumsum(starts.astype(jnp.int32)) - 1


def groupby_sum(
    keys: Sequence[jax.Array],
    values: jax.Array,
    valid: jax.Array | None = None,
    compact_via: str = "scatter",
) -> Tuple[Tuple[jax.Array, ...], jax.Array, jax.Array, jax.Array]:
    """GroupBy(keys).sum(values) with static output capacity.

    Invalid entries must already sort to the end (give them sentinel keys).

    Compaction of run representatives to the front is a ``cumsum(starts)``
    scatter/gather off the already-sorted runs (``compact_via="scatter"``,
    default) — ONE ``lax.sort`` per call.  ``compact_via="argsort"`` keeps the
    legacy second full sort for the aggregation benchmark comparison
    (``benchmarks/run.py level_fusion``); the two agree bit-for-bit on the
    first ``n_groups`` slots (slots beyond ``n_groups`` are unspecified and
    must be masked with ``group_valid``).

    Returns (group_keys, group_sums, group_valid, n_groups):
      group_keys: one representative key tuple per run, COMPACTED to the front
      group_sums: float sums per run, compacted to the front
      group_valid: bool[m] — first n_groups entries True
      n_groups: int32 scalar (number of valid groups)
    """
    m = values.shape[0]
    if valid is None:
        valid = jnp.ones((m,), dtype=bool)
    flag = jnp.where(valid, 0, 1).astype(jnp.int32)
    (sk, sv) = sort_by_keys((flag,) + tuple(keys), (values,))
    sflag, *skeys = sk
    svalid = sflag == 0
    starts_all = run_starts(sflag, *skeys)
    starts = starts_all & svalid
    rid = run_ids(starts_all)
    sums = jax.ops.segment_sum(jnp.where(svalid, sv[0], 0.0), rid, num_segments=m)
    n_groups = jnp.sum(starts.astype(jnp.int32))
    group_valid = jnp.arange(m, dtype=jnp.int32) < n_groups
    if compact_via == "scatter":
        # Valid runs sort first, so the j-th valid run start has rid == j:
        # scatter each start's position into output slot rid, then gather.
        # Slots >= n_groups keep index 0 (arbitrary; masked by group_valid),
        # and sums is already rid-indexed so it needs no gather at all.
        pos = jnp.where(starts, rid, m)
        idx = (jnp.zeros((m + 1,), jnp.int32)
               .at[pos].set(jnp.arange(m, dtype=jnp.int32), mode="drop")[:m])
        group_keys = tuple(k[idx] for k in skeys)
        group_sums = sums
    elif compact_via == "argsort":
        order = jnp.argsort(jnp.where(starts, 0, 1), stable=True)
        group_keys = tuple(k[order] for k in skeys)
        group_sums = sums[rid[order]]
    else:
        raise ValueError(f"unknown compact_via {compact_via!r}")
    return group_keys, group_sums, group_valid, n_groups


def compact(
    mask: jax.Array,
    arrays: Sequence[jax.Array],
    via: str = "scatter",
) -> Tuple[Tuple[jax.Array, ...], jax.Array]:
    """Stable-move entries where mask is True to the front. Returns (arrays, count).

    ``via="scatter"`` (default) builds the stable permutation with a
    ``cumsum`` + scatter — the same sort-free compaction ``groupby_sum``
    uses — instead of the legacy full ``argsort`` (``via="argsort"``, kept
    for the ``coarse_cascade`` benchmark A/B).  The two permutations are
    identical: True entries land at their True-rank, False entries at
    count + False-rank, both in original order.
    """
    m = mask.shape[0]
    count = jnp.sum(mask.astype(jnp.int32))
    if via == "scatter":
        csum = jnp.cumsum(mask.astype(jnp.int32))
        pos = jnp.where(mask, csum - 1,
                        count + jnp.arange(m, dtype=jnp.int32) - csum)
        perm = (jnp.zeros((m,), jnp.int32)
                .at[pos].set(jnp.arange(m, dtype=jnp.int32)))
    elif via == "argsort":
        perm = jnp.argsort(jnp.where(mask, 0, 1), stable=True)
    else:
        raise ValueError(f"unknown via {via!r}, want 'scatter' or 'argsort'")
    return tuple(a[perm] for a in arrays), count


def contiguize_ids(
    keys: jax.Array, valid: jax.Array, size: int
) -> Tuple[jax.Array, jax.Array]:
    """Sort-free dense-id assignment for integer keys in ``[0, size)``.

    Presence bitmap + ``cumsum`` instead of the historical sort + run-detect
    (the sort-free invariant of DESIGN.md §Pipeline): scatter 1s at the
    present keys, then the exclusive prefix sum over the bitmap IS the dense
    id, ascending in raw-key order — the same deterministic ordering the
    sorted path produced.

    Returns ``(table, count)``: ``table[k]`` is the dense id of raw key
    ``k`` for present keys and the ``size`` sentinel for absent ones
    (``table`` has ``size`` entries); ``count`` is the number of distinct
    present keys.
    """
    idx = jnp.clip(jnp.where(valid, keys, size), 0, size)
    p = jnp.zeros((size + 1,), jnp.int32).at[idx].set(1)[:size]
    table = jnp.where(p == 1, jnp.cumsum(p) - 1, jnp.int32(size))
    return table, jnp.sum(p)


def segment_argmax(
    scores: jax.Array,
    candidates: jax.Array,
    segments: jax.Array,
    num_segments: int,
    valid: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Per-segment (max score, candidate achieving it; smallest-candidate tie-break).

    scores: f32[m]; candidates: i32[m]; segments: i32[m] in [0, num_segments).
    Returns (best_score[num_segments], best_candidate[num_segments]);
    empty segments get (-inf, -1).
    """
    neg_inf = jnp.float32(-jnp.inf)
    if valid is not None:
        scores = jnp.where(valid, scores, neg_inf)
    best = jax.ops.segment_max(scores, segments, num_segments=num_segments)
    is_best = scores == best[segments]
    big = jnp.int32(2**31 - 1)
    cand_masked = jnp.where(is_best & (scores > neg_inf), candidates, big)
    best_cand = jax.ops.segment_min(cand_masked, segments, num_segments=num_segments)
    best_cand = jnp.where(best_cand == big, -1, best_cand)
    return best, best_cand
