"""Device partitioning of graphs for the shard_map runtime.

Strategy (DESIGN.md §6): sort directed edges by destination; split the vertex
range into D contiguous chunks with ~balanced edge counts ("owner computes" —
device d owns vertices [bounds[d], bounds[d+1]) and all edges INTO them).
Per-device edge slices are padded to a common static length.  This is the
TPU analogue of Chapel's block-distributed arrays over locales.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass(frozen=True)
class EdgePartition:
    """Host-side partition plan + padded device-major arrays."""

    n_devices: int
    vertex_bounds: np.ndarray  # int64[D+1]
    src: np.ndarray  # int32[D, m_pad]   (sentinel n_max where invalid)
    dst: np.ndarray  # int32[D, m_pad]
    w: np.ndarray  # float32[D, m_pad] (0 where invalid)
    edge_mask: np.ndarray  # bool[D, m_pad]
    m_pad: int
    n_max: int


def partition_edges_by_dst(g: Graph, n_devices: int) -> EdgePartition:
    src, dst, w = g.to_numpy_edges()
    order = np.lexsort((src, dst))
    src, dst, w = src[order], dst[order], w[order]
    m = src.shape[0]
    n = int(g.n_valid)

    # balanced split points: i-th device gets edges [i*m/D, (i+1)*m/D), snapped
    # outward to vertex boundaries so each vertex's in-edges live on one device
    targets = (np.arange(1, n_devices) * m) // n_devices
    bounds = [0]
    cut_v = [0]
    for t in targets:
        vcut = dst[min(t, m - 1)] + 1 if m else 0
        vcut = max(vcut, cut_v[-1])
        e = int(np.searchsorted(dst, vcut, side="left"))
        bounds.append(e)
        cut_v.append(int(vcut))
    bounds.append(m)
    cut_v.append(n)
    vertex_bounds = np.asarray(cut_v, dtype=np.int64)

    counts = np.diff(np.asarray(bounds))
    m_pad = int(max(1, counts.max()))
    # round up for alignment-friendly shapes
    m_pad = int(np.ceil(m_pad / 8) * 8)

    S = np.full((n_devices, m_pad), g.n_max, dtype=np.int32)
    D_ = np.full((n_devices, m_pad), g.n_max, dtype=np.int32)
    W = np.zeros((n_devices, m_pad), dtype=np.float32)
    M = np.zeros((n_devices, m_pad), dtype=bool)
    for d in range(n_devices):
        lo, hi = bounds[d], bounds[d + 1]
        c = hi - lo
        S[d, :c] = src[lo:hi]
        D_[d, :c] = dst[lo:hi]
        W[d, :c] = w[lo:hi]
        M[d, :c] = True
    return EdgePartition(
        n_devices=n_devices,
        vertex_bounds=vertex_bounds,
        src=S,
        dst=D_,
        w=W,
        edge_mask=M,
        m_pad=m_pad,
        n_max=g.n_max,
    )


def partition_quality(p: EdgePartition) -> Tuple[float, float]:
    """(load imbalance = max/mean edge count, fraction of cut edges).

    A cut edge is one whose src is owned by a different device than its dst —
    these are the label-exchange edges in the distributed sweep.
    """
    counts = p.edge_mask.sum(axis=1).astype(np.float64)
    imbalance = float(counts.max() / max(1.0, counts.mean()))
    owner_of = np.searchsorted(p.vertex_bounds, np.arange(p.n_max), side="right") - 1
    cut = 0
    total = 0
    for d in range(p.n_devices):
        mask = p.edge_mask[d]
        s = p.src[d][mask]
        cut += int(np.sum(owner_of[s] != d))
        total += int(mask.sum())
    return imbalance, (cut / total if total else 0.0)
