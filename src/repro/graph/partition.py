"""Device partitioning of graphs for the shard_map runtime.

Strategy (DESIGN.md §6): sort directed edges by destination; split the vertex
range into D contiguous chunks with ~balanced edge counts ("owner computes" —
device d owns vertices [bounds[d], bounds[d+1]) and all edges INTO them).
Per-device edge slices are padded to a common static length.  This is the
TPU analogue of Chapel's block-distributed arrays over locales.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass(frozen=True)
class EdgePartition:
    """Host-side partition plan + padded device-major arrays."""

    n_devices: int
    vertex_bounds: np.ndarray  # int64[D+1]
    src: np.ndarray  # int32[D, m_pad]   (sentinel n_max where invalid)
    dst: np.ndarray  # int32[D, m_pad]
    w: np.ndarray  # float32[D, m_pad] (0 where invalid)
    edge_mask: np.ndarray  # bool[D, m_pad]
    m_pad: int
    n_max: int


def partition_edges_by_dst(g: Graph, n_devices: int) -> EdgePartition:
    src, dst, w = g.to_numpy_edges()
    order = np.lexsort((src, dst))
    src, dst, w = src[order], dst[order], w[order]
    m = src.shape[0]
    n = int(g.n_valid)

    # balanced split points: i-th device gets edges [i*m/D, (i+1)*m/D), snapped
    # outward to vertex boundaries so each vertex's in-edges live on one device
    targets = (np.arange(1, n_devices) * m) // n_devices
    bounds = [0]
    cut_v = [0]
    for t in targets:
        vcut = dst[min(t, m - 1)] + 1 if m else 0
        vcut = max(vcut, cut_v[-1])
        e = int(np.searchsorted(dst, vcut, side="left"))
        bounds.append(e)
        cut_v.append(int(vcut))
    bounds.append(m)
    cut_v.append(n)
    vertex_bounds = np.asarray(cut_v, dtype=np.int64)

    counts = np.diff(np.asarray(bounds))
    m_pad = int(max(1, counts.max()))
    # round up for alignment-friendly shapes
    m_pad = int(np.ceil(m_pad / 8) * 8)

    S = np.full((n_devices, m_pad), g.n_max, dtype=np.int32)
    D_ = np.full((n_devices, m_pad), g.n_max, dtype=np.int32)
    W = np.zeros((n_devices, m_pad), dtype=np.float32)
    M = np.zeros((n_devices, m_pad), dtype=bool)
    for d in range(n_devices):
        lo, hi = bounds[d], bounds[d + 1]
        c = hi - lo
        S[d, :c] = src[lo:hi]
        D_[d, :c] = dst[lo:hi]
        W[d, :c] = w[lo:hi]
        M[d, :c] = True
    return EdgePartition(
        n_devices=n_devices,
        vertex_bounds=vertex_bounds,
        src=S,
        dst=D_,
        w=W,
        edge_mask=M,
        m_pad=m_pad,
        n_max=g.n_max,
    )


def owner_of_vertices(p: EdgePartition) -> np.ndarray:
    """int32[n_max]: owning device of each vertex id under the contiguous
    dst-range ownership (``vertex_bounds``); ids past the last bound clamp
    onto the last device."""
    own = np.searchsorted(p.vertex_bounds, np.arange(p.n_max), side="right") - 1
    return np.clip(own, 0, p.n_devices - 1).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class HaloTable:
    """Per-device ghost-vertex (halo) tables for one edge partition.

    Device d owns the vertices in ``[vertex_bounds[d], vertex_bounds[d+1])``
    and all edges INTO them; the srcs of those edges that live OUTSIDE the
    owned range are d's GHOSTS — the boundary vertices whose labels (and,
    for Louvain, whose community volumes) d must receive each sweep.  The
    halo therefore bounds the information-theoretically necessary per-level
    label exchange: ``sum(ghost_counts)`` label words per refresh, versus
    the full O(m) edge payload a gather-then-replicate level loop moves.

    ``ghost_ids`` is padded to a common static width (``n_max`` sentinel,
    ``ghost_mask`` valid) so the table can be shipped to devices as one
    rectangular array when a mesh wants explicit halo gathers.
    """

    n_devices: int
    owner_of: np.ndarray     # int32[n_max]
    ghost_counts: np.ndarray  # int64[D] — distinct non-owned srcs per device
    ghost_ids: np.ndarray    # int32[D, g_pad] (sentinel n_max where invalid)
    ghost_mask: np.ndarray   # bool[D, g_pad]
    g_pad: int

    @property
    def total_ghosts(self) -> int:
        return int(self.ghost_counts.sum())


def build_halo(p: EdgePartition) -> HaloTable:
    """Build the ghost/halo tables for an edge partition.

    Degenerate meshes fall out naturally: a single-device partition has no
    ghosts (every src is owned), and an empty shard (a device whose edge
    slice is all padding) has an empty ghost row.
    """
    owner = owner_of_vertices(p)
    ghosts = []
    for d in range(p.n_devices):
        s = p.src[d][p.edge_mask[d]]
        g = np.unique(s[owner[s] != d]) if s.size else np.zeros(0, np.int64)
        ghosts.append(g.astype(np.int32))
    counts = np.array([g.size for g in ghosts], dtype=np.int64)
    g_pad = max(1, int(counts.max()) if p.n_devices else 1)
    ids = np.full((p.n_devices, g_pad), p.n_max, dtype=np.int32)
    mask = np.zeros((p.n_devices, g_pad), dtype=bool)
    for d, g in enumerate(ghosts):
        ids[d, : g.size] = g
        mask[d, : g.size] = True
    return HaloTable(
        n_devices=p.n_devices,
        owner_of=owner,
        ghost_counts=counts,
        ghost_ids=ids,
        ghost_mask=mask,
        g_pad=g_pad,
    )


class PartitionQuality(NamedTuple):
    """Partition health metrics (DESIGN.md §6), all host-side numpy.

    ``imbalance``     max/mean per-device edge count (1.0 = perfect);
    ``cut_fraction``  fraction of edges whose src is owned elsewhere — the
                      label-exchange edges of the distributed sweep;
    ``halo_factor``   replication factor ``sum_d(owned_d + ghosts_d) / n``:
                      1.0 means no vertex state is ghosted anywhere, D means
                      every device ghosts every foreign vertex;
    ``max_halo_fraction``  worst single device's ghosts / its owned count
                      (the per-device halo memory overhead bound);
    ``total_ghosts``  sum of per-device distinct ghost vertices — the
                      per-level halo-label payload in words.
    """

    imbalance: float
    cut_fraction: float
    halo_factor: float
    max_halo_fraction: float
    total_ghosts: int


def partition_quality(p: EdgePartition,
                      halo: HaloTable | None = None) -> PartitionQuality:
    """Edge balance, cut fraction and halo/replication factor of a partition.

    A cut edge is one whose src is owned by a different device than its dst —
    these are the label-exchange edges in the distributed sweep.  The halo
    terms quantify the ghost-vertex state the shard-local pipeline keeps per
    device (``build_halo``) — surfaced in ``DistLouvainResult`` telemetry
    and the ``dist_scale`` benchmark.
    """
    if halo is None:
        halo = build_halo(p)
    counts = p.edge_mask.sum(axis=1).astype(np.float64)
    imbalance = float(counts.max() / max(1.0, counts.mean()))
    cut = 0
    total = 0
    for d in range(p.n_devices):
        mask = p.edge_mask[d]
        s = p.src[d][mask]
        cut += int(np.sum(halo.owner_of[s] != d))
        total += int(mask.sum())
    owned = np.maximum(np.diff(p.vertex_bounds).astype(np.float64), 0.0)
    n_live = max(1.0, float(p.vertex_bounds[-1]))
    halo_factor = float((owned.sum() + halo.ghost_counts.sum()) / n_live)
    max_halo_fraction = float(
        (halo.ghost_counts / np.maximum(owned, 1.0)).max()) if p.n_devices else 0.0
    return PartitionQuality(
        imbalance=imbalance,
        cut_fraction=(cut / total if total else 0.0),
        halo_factor=halo_factor,
        max_halo_fraction=max_halo_fraction,
        total_ghosts=halo.total_ghosts,
    )
