"""Capacity padding + leading-batch-axis packing (DESIGN.md §Serving).

The batched many-graph engine (``core.batch``) relies on two facts about the
``Graph`` representation:

  * arrays are capacity-padded with validity masks, so re-padding a graph to
    a LARGER static capacity changes only the padding (the sentinel value
    tracks the new ``n_max``) — by the same capacity-portability contract
    the cascade's ``shrink_graph`` descends on, results for valid vertices
    are bit-identical at any capacity that holds the graph;
  * ``Graph`` is a registered pytree whose data fields (src/dst/w/edge_mask/
    n_valid/m_valid) are leaves and whose capacities are STATIC meta, so
    same-capacity graphs stack along a new leading batch axis for free and
    the stacked object is a valid ``jax.vmap`` operand (each vmap lane sees
    an ordinary single ``Graph``).

``pad_graph`` is the exact inverse direction of ``aggregation.shrink_graph``
(grow instead of shrink); ``stack_graphs`` produces the batched container.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.graph.structure import Graph


def pad_graph(g: Graph, n_cap: int, m_cap: int) -> Graph:
    """Re-pad ``g`` into LARGER static capacities (pure pad + sentinel
    rewrite, on device).

    Vertex ids are untouched (valid ids live in [0, n_valid) at any
    capacity); invalid src/dst entries are rewritten from the old ``n_max``
    sentinel to ``n_cap`` and new edge slots are appended fully masked, so
    every invariant (``sorted_by``, front-compaction, mask counts) survives.
    """
    n_cap, m_cap = int(n_cap), int(m_cap)
    if n_cap < g.n_max or m_cap < g.m_max:
        raise ValueError(
            f"pad_graph only grows capacities: have ({g.n_max}, {g.m_max}), "
            f"asked ({n_cap}, {m_cap})")
    if n_cap == g.n_max and m_cap == g.m_max:
        return g
    sent = jnp.int32(n_cap)
    pad = m_cap - g.m_max
    zeros_i = jnp.full((pad,), sent)
    return Graph(
        src=jnp.concatenate([jnp.where(g.edge_mask, g.src, sent), zeros_i]),
        dst=jnp.concatenate([jnp.where(g.edge_mask, g.dst, sent), zeros_i]),
        w=jnp.concatenate([jnp.where(g.edge_mask, g.w, 0.0),
                           jnp.zeros((pad,), jnp.float32)]),
        edge_mask=jnp.concatenate([g.edge_mask,
                                   jnp.zeros((pad,), bool)]),
        n_valid=g.n_valid,
        m_valid=g.m_valid,
        n_max=n_cap,
        m_max=m_cap,
        sorted_by=g.sorted_by,
    )


def empty_slot(n_cap: int, m_cap: int) -> Graph:
    """A fully-masked (0 vertices, 0 edges) graph at the given capacities —
    the batch-axis padding filler (DESIGN.md §Serving).  Runs through every
    evaluator as a no-op lane: no valid vertex is ever active, every level
    converges immediately (0 communities == 0 valid vertices), and the
    modularity guard returns 0 for the zero-volume graph."""
    sent = jnp.int32(n_cap)
    return Graph(
        src=jnp.full((m_cap,), sent),
        dst=jnp.full((m_cap,), sent),
        w=jnp.zeros((m_cap,), jnp.float32),
        edge_mask=jnp.zeros((m_cap,), bool),
        n_valid=jnp.int32(0),
        m_valid=jnp.int32(0),
        n_max=int(n_cap),
        m_max=int(m_cap),
        sorted_by="src",
    )


def stack_graphs(graphs: Sequence[Graph]) -> Graph:
    """Stack same-capacity Graphs along a new leading batch axis.

    Returns a ``Graph`` whose DATA leaves carry a leading batch dimension
    (src/dst/w/edge_mask become ``(B, m_max)``, the valid counts ``(B,)``)
    while the static meta stays scalar — NOT a semantically valid single
    graph, but exactly the pytree ``jax.vmap(..., in_axes=0)`` maps over.
    ``sorted_by`` must agree across the batch (it is static meta and the
    traced-ELL path keys on it); all capacities must already match — pad
    with ``pad_graph`` first.
    """
    if not graphs:
        raise ValueError("stack_graphs needs at least one graph")
    g0 = graphs[0]
    for g in graphs:
        if (g.n_max, g.m_max) != (g0.n_max, g0.m_max):
            raise ValueError(
                f"capacity mismatch in batch: ({g.n_max}, {g.m_max}) vs "
                f"({g0.n_max}, {g0.m_max}) — pad_graph to a common bucket "
                "capacity first")
        if g.sorted_by != g0.sorted_by:
            raise ValueError(
                f"sorted_by mismatch in batch: {g.sorted_by!r} vs "
                f"{g0.sorted_by!r}")
    return Graph(
        src=jnp.stack([g.src for g in graphs]),
        dst=jnp.stack([g.dst for g in graphs]),
        w=jnp.stack([g.w for g in graphs]),
        edge_mask=jnp.stack([g.edge_mask for g in graphs]),
        n_valid=jnp.stack([g.n_valid for g in graphs]),
        m_valid=jnp.stack([g.m_valid for g in graphs]),
        n_max=g0.n_max,
        m_max=g0.m_max,
        sorted_by=g0.sorted_by,
    )
