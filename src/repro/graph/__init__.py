"""Graph substrate: structures, segment (GroupBy) primitives, generators, datasets."""
from repro.graph.structure import Graph, graph_from_arrays
from repro.graph.segment import (
    sort_by_keys,
    run_starts,
    run_ids,
    groupby_sum,
    compact,
    segment_argmax,
)
from repro.graph.builders import (
    RepairReport,
    canonicalize_edges,
    from_undirected_edges,
    from_numpy_edges,
    from_numpy_edges_robust,
    validate_graph,
)
from repro.graph import generators, datasets, partition, ell

__all__ = [
    "Graph",
    "graph_from_arrays",
    "RepairReport",
    "canonicalize_edges",
    "from_undirected_edges",
    "from_numpy_edges",
    "from_numpy_edges_robust",
    "validate_graph",
    "sort_by_keys",
    "run_starts",
    "run_ids",
    "groupby_sum",
    "compact",
    "segment_argmax",
    "generators",
    "datasets",
    "partition",
    "ell",
]
