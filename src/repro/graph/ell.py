"""Degree-bucketed ELL (padded neighbor-list) layout for the Pallas kernel path.

TPU adaptation of the per-vertex neighborhood loops (DESIGN.md §2): vertices
are grouped by degree into buckets of fixed width W ∈ BUCKET_WIDTHS; within a
bucket, neighbor ids/weights are dense (rows, W) tiles — ideal for VMEM
BlockSpecs.  Vertices with deg > max(W) fall back to the sort+segment path
(the "tail"), mirroring how high-degree hubs get special-cased in parallel
community detection codes.

The full multi-width bucketing is a HOST-side build: row capacities are
data-dependent (a jit-native rebuild would need n_max-row buckets per
width), so it applies to the finest (level-0) graph only.  Coarse levels
inside the capacity-scheduled cascade use ``traced_ell_tile`` instead — a
jit-traceable single-bucket rebuild at a STATIC per-stage width over the
src-sorted coarse edge list, with above-width vertices flagged for the
edge-list tail fallback (DESIGN.md §Pipeline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph

BUCKET_WIDTHS = (16, 64, 256, 1024)
ROW_PAD = 8  # sublane alignment for (rows, W) tiles
CHUNK_ELEMS = 1 << 15  # target neighbor slots per stacked chunk (DESIGN.md §Kernels)


@dataclasses.dataclass(frozen=True)
class EllBucket:
    width: int
    rows: np.ndarray      # int32[R] vertex id per row (sentinel n_max for padding rows)
    nbr: np.ndarray       # int32[R, W] neighbor vertex ids (sentinel n_max pad)
    w: np.ndarray         # float32[R, W] edge weights (0 pad)
    n_rows_valid: int


@dataclasses.dataclass(frozen=True)
class EllGraph:
    n_max: int
    buckets: Tuple[EllBucket, ...]
    tail_vertices: np.ndarray     # int32[T] vertices handled by the sort path
    tail_edge_idx: np.ndarray     # int64[K] indices into the dst-sorted edge list
    loop_w: np.ndarray            # float32[n_max] doubled self-loop weight per vertex
    deg_w: np.ndarray             # float32[n_max]

    @property
    def has_tail(self) -> bool:
        return self.tail_vertices.size > 0


def build_ell(
    g: Graph,
    widths: Tuple[int, ...] = BUCKET_WIDTHS,
    include_loops: bool = False,
) -> EllGraph:
    """Host-side ELL build.  Rows are IN-neighborhoods (edges grouped by dst);
    by symmetry these equal out-neighborhoods.  Self-loops are excluded from
    neighbor tiles by default (they are never move candidates) and reported
    separately via ``loop_w``.
    """
    src, dst, w = g.to_numpy_edges()
    n = g.n_max

    loop_w = np.zeros(n, dtype=np.float32)
    np.add.at(loop_w, src[src == dst], w[src == dst])
    deg_w = np.zeros(n, dtype=np.float32)
    np.add.at(deg_w, src, w)

    # Sort the FULL list by (dst, src) first: tail_edge_idx must index the
    # same dst-sorted view that to_device reconstructs when it materializes
    # the tail edge arrays.
    order = np.lexsort((src, dst))
    src, dst, w = src[order], dst[order], w[order]
    deg_full = np.zeros(n, dtype=np.int64)
    np.add.at(deg_full, dst, 1)
    row_ptr_full = np.concatenate([[0], np.cumsum(deg_full)])

    if not include_loops:
        keep = src != dst
        src_b, dst_b, w_b = src[keep], dst[keep], w[keep]
    else:
        src_b, dst_b, w_b = src, dst, w
    deg = np.zeros(n, dtype=np.int64)
    np.add.at(deg, dst_b, 1)
    row_ptr = np.concatenate([[0], np.cumsum(deg)])
    src, dst, w = src_b, dst_b, w_b

    max_w = widths[-1]
    buckets: List[EllBucket] = []
    prev = 0
    # prefix sums of the (dst, src)-sorted neighbor ids: per-row neighbor
    # min is the first entry of the run, the mean comes from the cumsum
    src_cum = np.concatenate([[0.0], np.cumsum(src, dtype=np.float64)])
    for W in widths:
        vids = np.where((deg > prev) & (deg <= W))[0]
        prev = W
        if len(vids):
            # neighbor-ID locality order (Sahu, arXiv:2301.12390): rows whose
            # neighborhoods touch nearby vertex ids become adjacent, so each
            # row-block of the streamed kernel reads a narrow table window
            lo_n = src[row_ptr[vids]]          # in-row neighbors are sorted
            mean_n = ((src_cum[row_ptr[vids + 1]] - src_cum[row_ptr[vids]])
                      / deg[vids])
            vids = vids[np.lexsort((vids, mean_n, lo_n))]
        R = int(np.ceil(max(1, len(vids)) / ROW_PAD) * ROW_PAD)
        rows = np.full(R, n, dtype=np.int32)
        nbr = np.full((R, W), n, dtype=np.int32)
        ww = np.zeros((R, W), dtype=np.float32)
        for r, v in enumerate(vids):
            lo, hi = row_ptr[v], row_ptr[v + 1]
            rows[r] = v
            nbr[r, : hi - lo] = src[lo:hi]
            ww[r, : hi - lo] = w[lo:hi]
        buckets.append(EllBucket(W, rows, nbr, ww, len(vids)))

    tail_vertices = np.where(deg > max_w)[0].astype(np.int32)
    tail_edges = []
    for v in tail_vertices:  # index into the FULL dst-sorted list (loops incl.)
        tail_edges.append(np.arange(row_ptr_full[v], row_ptr_full[v + 1], dtype=np.int64))
    tail_edge_idx = (
        np.concatenate(tail_edges) if tail_edges else np.zeros(0, dtype=np.int64)
    )
    return EllGraph(
        n_max=n,
        buckets=tuple(buckets),
        tail_vertices=tail_vertices,
        tail_edge_idx=tail_edge_idx,
        loop_w=loop_w,
        deg_w=deg_w.astype(np.float32),
    )


# ------------------------------------------------------------ traced rebucketing


def traced_ell_tile(
    g: Graph, width: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Jit-traceable single-bucket ELL view of a src-sorted coarse graph.

    The ``build_ell`` equivalent for graphs built INSIDE a compiled program
    (the cascade's coarse levels, DESIGN.md §Pipeline): one (n_max, width)
    neighbor tile with row v holding vertex v's non-loop out-edges — by the
    directed-symmetric convention these equal the in-neighborhoods
    ``build_ell`` buckets — rebuilt per level from CSR row pointers in O(n·W)
    gathers, no data-dependent shapes.  Vertices whose degree exceeds the
    static ``width`` are flagged ``is_tail`` and their row is masked to pure
    padding; the engine evaluates them through the tables tail evaluator
    over the full edge list (gated out when no tail exists at runtime).

    Returns ``(rows[n], nbr[n, W], w[n, W], is_tail[n])`` with the same
    sentinel conventions as ``EllBucket`` (row id / neighbor id ``n_max``
    and weight 0 mark padding).
    """
    n, m = g.n_max, g.m_max
    if g.sorted_by != "src":
        raise ValueError("traced_ell_tile requires a src-sorted graph")
    rp = g.row_ptr()
    deg = rp[1:] - rp[:-1]
    vmask = g.vertex_mask()
    is_tail = vmask & (deg > width)
    arange_n = jnp.arange(n, dtype=jnp.int32)
    rows = jnp.where(vmask & ~is_tail, arange_n, n)
    j = jnp.arange(width, dtype=jnp.int32)
    idx = jnp.clip(rp[:-1, None] + j[None, :], 0, max(m - 1, 0))
    take = (j[None, :] < deg[:, None]) & (rows < n)[:, None]
    nbr = jnp.where(take, g.dst[idx], n)
    wt = jnp.where(take, g.w[idx], 0.0)
    # self-loops are never move candidates (Graph convention): mask to sink
    loop = nbr == arange_n[:, None]
    return rows, jnp.where(loop, n, nbr), jnp.where(loop, 0.0, wt), is_tail


# ------------------------------------------------------------ device layout
#
# The sweep engine (core/engine.py) runs the whole local-moving phase inside
# one jitted lax.while_loop, so bucket tiles must be device-resident pytree
# leaves (host numpy would force a transfer per sweep).  Each bucket is
# stacked into one (n_chunks, rows_per_chunk, W) array; the fused local_move
# kernel (DESIGN.md §Kernels) consumes it through ``grid_view`` as a single
# (n_chunks·rows_per_chunk, W) tile, so chunks become independent grid steps
# of one dispatch — the chunk dim is kept for layout/debug tooling, not for
# a scan chain.


def _rows_per_chunk(width: int, target_elems: int = CHUNK_ELEMS) -> int:
    return max(ROW_PAD, (target_elems // max(1, width)) // ROW_PAD * ROW_PAD)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["win_blk"],
    meta_fields=["slot", "block_rows", "n_slots"],
)
@dataclasses.dataclass(frozen=True)
class TableWindows:
    """Per-row-block table-window metadata for the streamed local_move path.

    Block b of ``block_rows`` consecutive (locality-ordered) rows touches
    vertex ids within [win_blk[b]·slot, win_blk[b]·slot + 2·slot): the
    streamed kernel DMAs exactly that slice of each per-vertex table per
    grid step (DESIGN.md §Kernels).  ``slot`` (the window offset stride,
    a multiple of the 128-entry lane) and ``n_slots`` (rows of the
    overlapped (n_slots, 2·slot) table view) are STATIC; ``win_blk`` is the
    int32[n_blocks] slot index per block, consumed as a scalar-prefetch
    operand.  Padding/sentinel ids are masked in the kernel and need no
    window coverage.
    """

    win_blk: jax.Array
    slot: int
    block_rows: int
    n_slots: int


def compute_windows(rows: np.ndarray, nbr: np.ndarray, n_max: int,
                    block_rows: int) -> TableWindows:
    """Host-side window build over a bucket's flattened (R,)/(R, W) tiles.

    Per block of ``block_rows`` rows: [lo, hi) spans every REAL id the block
    touches (row ids and neighbor ids; sentinel padding excluded).  The slot
    stride is the max block span rounded up to the lane width, so every
    block's span fits one 2-slot overlapped window regardless of alignment.
    """
    from repro.kernels.common import TABLE_LANE, cdiv

    R = rows.shape[0]
    nb = max(1, cdiv(R, block_rows))
    pad = nb * block_rows - R
    rows_p = np.concatenate([rows, np.full(pad, n_max, rows.dtype)])
    nbr_p = np.concatenate(
        [nbr, np.full((pad, nbr.shape[1]), n_max, nbr.dtype)])
    rows2 = rows_p.reshape(nb, block_rows)
    nbr2 = nbr_p.reshape(nb, block_rows, -1)

    lo = np.minimum(
        np.where(rows2 < n_max, rows2, n_max).min(axis=1),
        np.where(nbr2 < n_max, nbr2, n_max).min(axis=(1, 2)),
    ).astype(np.int64)
    hi = np.maximum(
        np.where(rows2 < n_max, rows2, -1).max(axis=1),
        np.where(nbr2 < n_max, nbr2, -1).max(axis=(1, 2)),
    ).astype(np.int64) + 1
    empty = hi <= lo          # all-padding block: any window works
    lo[empty], hi[empty] = 0, 1

    span = int((hi - lo).max())
    slot = int(np.ceil(max(span, 1) / TABLE_LANE) * TABLE_LANE)
    return TableWindows(
        win_blk=jnp.asarray((lo // slot).astype(np.int32)),
        slot=slot,
        block_rows=int(block_rows),
        n_slots=max(1, cdiv(n_max + 1, slot)),
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "nbr", "w", "windows"],
    meta_fields=["width", "n_rows_valid"],
)
@dataclasses.dataclass(frozen=True)
class DeviceBucket:
    """One degree bucket, chunk-stacked for the local_move Pallas grid.

    rows: int32[C, Rc]      vertex id per row (sentinel n_max for padding)
    nbr:  int32[C, Rc, W]   neighbor ids (sentinel n_max padding)
    w:    float32[C, Rc, W] edge weights (0 padding)

    ``n_rows_valid`` is STATIC (a pytree meta field): the host-side bucketing
    knows how many rows are real, so the sweep engine can skip all-padding
    buckets at trace time instead of evaluating pure-sentinel tiles.
    ``windows`` is the per-row-block table-window metadata enabling the
    streamed (beyond-VMEM) kernel path; None for hand-built buckets, which
    then support the resident path only.
    """

    rows: jax.Array
    nbr: jax.Array
    w: jax.Array
    width: int
    n_rows_valid: int = -1  # -1 = unknown (treated as non-empty)
    windows: Optional[TableWindows] = None


def grid_view(b: DeviceBucket) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Collapse the chunk dim: ``(rows[C·Rc], nbr[C·Rc, W], w[C·Rc, W])``.

    This is the layout the fused local_move kernel grids over — one 1-D grid
    of row-blocks spanning ALL chunks of the bucket (grid length =
    n_chunks × row_blocks_per_chunk), replacing the old per-bucket lax.scan
    chain.  The stack is chunk-major contiguous, so the reshape is free.
    """
    W = b.width
    return b.rows.reshape(-1), b.nbr.reshape(-1, W), b.w.reshape(-1, W)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["buckets", "tail_src", "tail_dst", "tail_w", "is_tail"],
    meta_fields=["n_max", "has_tail"],
)
@dataclasses.dataclass(frozen=True)
class DeviceEll:
    """Device-resident ELL layout consumed inside the fused sweep loop.

    Tail edges are pre-extracted (src, dst, w) arrays so the per-sweep
    ``lexsort`` of the legacy path is hoisted out of the loop entirely.
    """

    buckets: Tuple[DeviceBucket, ...]
    tail_src: jax.Array   # int32[K]
    tail_dst: jax.Array   # int32[K]
    tail_w: jax.Array     # float32[K]
    is_tail: jax.Array    # bool[n_max]
    n_max: int
    has_tail: bool


def to_device(g: Graph, e: EllGraph, rows_per_chunk: Optional[int] = None,
              block_rows: Optional[int] = None) -> DeviceEll:
    """Stack an EllGraph into the device-resident scan layout (one-time cost).

    ``block_rows`` overrides the streamed-path row-block granularity (and
    thereby the window size).  The default is ``pick_row_block_fused(W)``
    with no table charge — the UPPER BOUND of the resident row block, which
    the resident path shrinks further by its table-scratch bytes — so the
    streamed grid is at least as coarse as the resident one.
    """
    from repro.kernels.common import pick_row_block_fused

    n = e.n_max
    buckets: List[DeviceBucket] = []
    for b in e.buckets:
        W = b.width
        rc = rows_per_chunk or _rows_per_chunk(W)
        r = b.rows.shape[0]
        r_pad = int(np.ceil(max(1, r) / rc) * rc)
        rows = np.full(r_pad, n, dtype=np.int32)
        nbr = np.full((r_pad, W), n, dtype=np.int32)
        ww = np.zeros((r_pad, W), dtype=np.float32)
        rows[:r], nbr[:r], ww[:r] = b.rows, b.nbr, b.w
        c = r_pad // rc
        br = min(block_rows or pick_row_block_fused(W), r_pad)
        buckets.append(
            DeviceBucket(
                rows=jnp.asarray(rows.reshape(c, rc)),
                nbr=jnp.asarray(nbr.reshape(c, rc, W)),
                w=jnp.asarray(ww.reshape(c, rc, W)),
                width=W,
                n_rows_valid=b.n_rows_valid,
                windows=compute_windows(rows, nbr, n, br),
            )
        )

    # materialize tail edges from the same dst-sorted view build_ell indexed
    src, dst, w = g.to_numpy_edges()
    order = np.lexsort((src, dst))
    src, dst, w = src[order], dst[order], w[order]
    idx = e.tail_edge_idx
    is_tail = np.zeros(n, dtype=bool)
    is_tail[e.tail_vertices] = True
    return DeviceEll(
        buckets=tuple(buckets),
        tail_src=jnp.asarray(src[idx].astype(np.int32)),
        tail_dst=jnp.asarray(dst[idx].astype(np.int32)),
        tail_w=jnp.asarray(w[idx].astype(np.float32)),
        is_tail=jnp.asarray(is_tail),
        n_max=n,
        has_tail=bool(e.tail_vertices.size),
    )


def build_device_ell(
    g: Graph,
    widths: Tuple[int, ...] = BUCKET_WIDTHS,
    rows_per_chunk: Optional[int] = None,
) -> DeviceEll:
    """build_ell + to_device in one call (the engine's default path)."""
    return to_device(g, build_ell(g, widths), rows_per_chunk)


def ell_stats(e: EllGraph) -> dict:
    out = {"n": e.n_max, "tail_vertices": int(e.tail_vertices.size)}
    total_slots = 0
    used_slots = 0
    for b in e.buckets:
        total_slots += b.nbr.size
        used_slots += int((b.nbr < e.n_max).sum())
        out[f"bucket_w{b.width}_rows"] = b.n_rows_valid
    out["slot_utilization"] = used_slots / max(1, total_slots)
    return out
