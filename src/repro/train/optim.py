"""Optimizers in pure JAX (no optax on the image): AdamW + Adafactor.

Both keep their states sharded exactly like the parameters (the param
PartitionSpecs propagate through jit), which combined with the 'embed'->FSDP
rule gives ZeRO-3-style fully-sharded optimizer memory.

Adafactor is the memory policy for the >=340B archs: factored second moment
(row/col statistics instead of a full f32 tensor) drops optimizer state from
8 bytes/param to ~2 bytes/param + O(rows+cols).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class OptimConfig(ConfigBase):
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 128


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdafactorState(NamedTuple):
    step: jax.Array
    # per-leaf: dict with either {'v': full} or {'vr': row, 'vc': col}
    stats: Any


def lr_schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay (standard LM schedule)."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), gn


# ----------------------------------------------------------------- AdamW


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.int32(0), jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def adamw_update(cfg: OptimConfig, grads, state: AdamWState, params):
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}


# ----------------------------------------------------------------- Adafactor


def _factored(shape, min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params, cfg: Optional[OptimConfig] = None) -> AdafactorState:
    min_dim = cfg.factored_min_dim if cfg else 128

    def init(p):
        if _factored(p.shape, min_dim):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return AdafactorState(jnp.int32(0), jax.tree.map(init, params))


def adafactor_update(cfg: OptimConfig, grads, state: AdafactorState, params):
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-cfg.decay_rate)

    def upd(p, g, st):
        g2 = jnp.square(g) + 1e-30
        if "vr" in st:
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            pre = (vr[..., None] / jnp.maximum(denom[..., None], 1e-30)) * vc[..., None, :]
            update = g * jax.lax.rsqrt(jnp.maximum(pre, 1e-30))
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            update = g * jax.lax.rsqrt(jnp.maximum(v, 1e-30))
            new_st = {"v": v}
        # update clipping (RMS <= 1) — the Adafactor stabilizer
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32) - lr * update
                 - lr * cfg.weight_decay * p.astype(jnp.float32)).astype(p.dtype)
        return new_p, new_st

    is_st = lambda t: isinstance(t, dict) and ("v" in t or "vr" in t)
    # map with the stats tree first: its dict leaves carry the factored flag
    out = jax.tree.map(lambda st, p, g: upd(p, g, st),
                       state.stats, params, grads, is_leaf=is_st)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_st = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return new_p, AdafactorState(step, new_st), {"grad_norm": gn, "lr": lr}


# ----------------------------------------------------------------- dispatch


def init_opt(name: str, params, cfg: Optional[OptimConfig] = None):
    if name == "adamw":
        return adamw_init(params)
    if name == "adafactor":
        return adafactor_init(params, cfg)
    raise ValueError(name)


def apply_opt(name: str, cfg: OptimConfig, grads, state, params):
    if name == "adamw":
        return adamw_update(cfg, grads, state, params)
    if name == "adafactor":
        return adafactor_update(cfg, grads, state, params)
    raise ValueError(name)
