"""Training substrate: optimizers, data pipeline, checkpointing, train step."""
