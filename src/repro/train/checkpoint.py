"""Checkpointing with fault-tolerance semantics.

Production behaviours implemented (single-host file backend; the same layout
maps onto a parallel filesystem / object store at scale):
  * ATOMIC saves: write to ``step_N.tmp/`` then ``rename`` — a crash mid-save
    never corrupts the latest checkpoint;
  * MANIFEST (json): step, config, mesh shape, leaf treedef — restore
    validates it against the running config and REJECTS mismatches loudly;
  * retention: keep the newest ``keep`` checkpoints, delete older ones only
    AFTER the new save committed;
  * ELASTIC restore: arrays are saved unsharded (gathered); restore reshards
    onto whatever mesh the new run has — a restart may use a different
    device count (node failure -> shrink; recovery -> grow);
  * partial-failure recovery: ``latest_step`` skips .tmp directories, so a
    killed run resumes from the last committed step.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key.replace("'", ""), leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, config_json: str = "{}",
         mesh_shape: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically save ``tree`` (params/opt/step bundle) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "config": json.loads(config_json),
                "mesh_shape": mesh_shape or {}, "leaves": []}
    for key, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        # save a flat uint8 view: np.save corrupts ml_dtypes (bf16 -> '|V2');
        # true dtype/shape travel in the manifest
        flat = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
        np.save(os.path.join(tmp, fname), flat)
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # commit point
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any = None, expect_config: Optional[str] = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings for
    elastic resharding (optional — host arrays otherwise)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if expect_config is not None:
        saved = json.dumps(manifest["config"], sort_keys=True)
        want = json.dumps(json.loads(expect_config), sort_keys=True)
        if saved != want:
            raise ValueError(
                "checkpoint config mismatch — refusing to restore "
                f"(saved != running):\n{saved}\nvs\n{want}")
    by_key = {m["key"]: m for m in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten(like)
    keys = [k for k, _ in _leaf_paths(like)]
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat))
    out = []
    for key, leaf, sh in zip(keys, flat, sh_flat):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        raw = np.load(os.path.join(path, meta["file"]))
        arr = np.frombuffer(raw.tobytes(), dtype=_np_dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf '{key}': shape {arr.shape} != {want_shape}")
        # device_put: reshard onto the target sharding (elastic) or default
        arr = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
