"""Jit'd wrapper: sorted segment sum = block kernel + O(num_blocks) spine fix-up."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.segment_sum.kernel import DEFAULT_BLOCK, block_segment_sums_pallas
from repro.kernels.segment_sum.ref import sorted_segment_sum_ref


@partial(jax.jit, static_argnames=("block", "use_pallas", "interpret"))
def sorted_segment_sum(
    keys: jax.Array,
    vals: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """(sums, starts): run totals at run-start positions of SORTED ``keys``.

    Keys may contain any int32 values (including sentinels) as long as they
    are non-decreasing; padding added here uses INT32_MAX.
    """
    keys = keys.astype(jnp.int32)
    vals = vals.astype(jnp.float32)
    if not use_pallas:
        return sorted_segment_sum_ref(keys, vals)

    interp = default_interpret() if interpret is None else interpret
    m = keys.shape[0]
    pad = (-m) % block
    big = jnp.int32(2**31 - 1)
    kp = jnp.pad(keys, (0, pad), constant_values=2**31 - 1)
    vp = jnp.pad(vals, (0, pad))
    mp = m + pad
    nb = mp // block

    within = block_segment_sums_pallas(kp, vp, block=block, interpret=interp)

    starts = jnp.concatenate([jnp.ones((1,), bool), kp[1:] != kp[:-1]])
    rid = jnp.cumsum(starts.astype(jnp.int32)) - 1

    # spine fix-up: attribute each block's first-key partial to the run that
    # started in an earlier block (skip blocks whose first element IS a start)
    p0 = jnp.arange(nb, dtype=jnp.int32) * block
    fs = within[p0]
    carry_needed = ~starts[p0]
    contrib = jnp.where(carry_needed, fs, 0.0)
    extra = jax.ops.segment_sum(contrib, rid[p0], num_segments=mp)

    sums = jnp.where(starts, within + extra[rid], 0.0)
    return sums[:m], starts[:m]
