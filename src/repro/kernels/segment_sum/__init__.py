from repro.kernels.segment_sum import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
