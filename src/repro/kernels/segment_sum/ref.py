"""Pure-jnp oracle for the sorted segment-sum (aggregation GroupBy reduce).

Semantics: given SORTED int32 ``keys`` (runs of equal keys = segments) and
float32 ``vals``, return ``(sums, starts)`` where ``starts[p]`` marks the
first element of each run and ``sums[p]`` is the TOTAL of p's run if
``starts[p]`` else 0.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sorted_segment_sum_ref(keys: jax.Array, vals: jax.Array) -> Tuple[jax.Array, jax.Array]:
    m = keys.shape[0]
    starts = jnp.concatenate(
        [jnp.ones((1,), bool), keys[1:] != keys[:-1]]
    )
    rid = jnp.cumsum(starts.astype(jnp.int32)) - 1
    totals = jax.ops.segment_sum(vals, rid, num_segments=m)
    return jnp.where(starts, totals[rid], 0.0), starts
