"""Pallas TPU kernel: block-segmented sum over sorted keys + spine fix-up.

The paper's aggregation merges parallel edges with Arkouda GroupBy —
effectively a scatter-add after a sort.  TPU scatter-add serializes badly;
instead, for SORTED keys, each (1, B) block computes within-block run totals
with a dense (B, B) equality reduction in VMEM (MXU/VPU-friendly), and a tiny
O(num_blocks) jnp "spine" pass in ops.py stitches runs that cross block
boundaries.  This is the classic two-level segmented-reduction design (GPU
block reduce + spine), re-tiled for TPU VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret

DEFAULT_BLOCK = 512


def _block_segsum_kernel(keys_ref, vals_ref, out_ref):
    """out[p] = Σ_q vals[q] · [keys[q] == keys[p]] within the block."""
    k = keys_ref[...]  # (1, B)
    v = vals_ref[...]  # (1, B)
    eq = k[0, :, None] == k[0, None, :]          # (B, B)
    out = jnp.sum(jnp.where(eq, v[0, :, None], 0.0), axis=0)
    out_ref[...] = out[None, :]


def block_segment_sums_pallas(
    keys: jax.Array,
    vals: jax.Array,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-position within-block run totals; input length must divide ``block``."""
    if interpret is None:
        interpret = default_interpret()
    m = keys.shape[0]
    assert m % block == 0, "caller pads to a block multiple"
    nb = m // block
    k2 = keys.reshape(nb, block)
    v2 = vals.reshape(nb, block)
    out = pl.pallas_call(
        _block_segsum_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(k2, v2)
    return out.reshape(m)
