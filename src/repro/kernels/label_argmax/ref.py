"""Pure-jnp oracle for the PLP weighted-label-mode kernel.

Per row r (one vertex, ELL-padded neighbor tile of width W):

  score(c)  = Σ_k w[r,k] · [lab[r,k] == c] + noise(row_id, c)
  best      = argmax over candidate labels present in the row
  cur_score = score(cur_lab[r]) if cur_lab present among neighbors else 0

Matches the segment-path semantics in ``core.moves.plp_best_labels`` (same
noise formula keyed on (vertex, label)), so segment/ELL/Pallas paths agree.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import tie_noise_jnp


def label_argmax_ref(
    nbr_lab: jax.Array,   # (R, W) int32, ``sentinel`` where padded
    nbr_w: jax.Array,     # (R, W) float32, 0 where padded
    cur_lab: jax.Array,   # (R,) int32
    rows: jax.Array,      # (R,) int32 vertex ids (noise key)
    seed: jax.Array,      # uint32 scalar
    tie_eps: float,
    sentinel: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    valid = nbr_lab != sentinel
    # pairwise label equality: eq[r, k, j] = lab[r,k] == lab[r,j]
    eq = nbr_lab[:, :, None] == nbr_lab[:, None, :]
    score = jnp.sum(jnp.where(eq, nbr_w[:, :, None], 0.0), axis=1)  # (R, W)
    noise = tie_noise_jnp(rows[:, None], nbr_lab, seed, tie_eps)
    eff = jnp.where(valid, score + noise, -jnp.inf)

    best_score = jnp.max(eff, axis=1)
    is_best = (eff == best_score[:, None]) & valid
    best_lab = jnp.min(jnp.where(is_best, nbr_lab, sentinel), axis=1)
    best_lab = jnp.where(best_score > -jnp.inf, best_lab, -1)

    eqc = valid & (nbr_lab == cur_lab[:, None])
    cur_sum = jnp.sum(jnp.where(eqc, nbr_w, 0.0), axis=1)
    cur_present = jnp.any(eqc, axis=1)
    cur_noise = tie_noise_jnp(rows, cur_lab, seed, tie_eps)
    cur_score = jnp.where(cur_present, cur_sum + cur_noise, 0.0)
    return best_lab, best_score, cur_score
