from repro.kernels.label_argmax import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
