"""Public wrapper for the label_argmax kernel (pallas/oracle dispatch).

A plain jit-safe function, deliberately NOT wrapped in ``jax.jit``: it is
called inside the already-jitted sweep loop, where a nested jit adds
trace/dispatch overhead and blocks fusion with the surrounding gather and
scatter code.  Eager callers (tests, notebooks) pay one trace per call —
wrap in ``jax.jit`` at the call site if that matters.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.label_argmax.kernel import label_argmax_pallas
from repro.kernels.label_argmax.ref import label_argmax_ref


def label_argmax(
    nbr_lab: jax.Array,
    nbr_w: jax.Array,
    cur_lab: jax.Array,
    rows: jax.Array,
    seed: jax.Array,
    *,
    tie_eps: float,
    sentinel: int,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(best_label, best_score, cur_score) per row; see ref.py for semantics."""
    nbr_lab = nbr_lab.astype(jnp.int32)
    nbr_w = nbr_w.astype(jnp.float32)
    cur_lab = cur_lab.astype(jnp.int32)
    rows = rows.astype(jnp.int32)
    if use_pallas:
        interp = default_interpret() if interpret is None else interpret
        return label_argmax_pallas(
            nbr_lab, nbr_w, cur_lab, rows, seed, tie_eps, sentinel, interpret=interp
        )
    return label_argmax_ref(nbr_lab, nbr_w, cur_lab, rows, seed, tie_eps, sentinel)
