"""Pallas TPU kernel: per-vertex weighted label mode (PLP move, Alg. 1 l.18).

TPU adaptation of the paper's per-thread neighborhood hash map: for a degree
bucket of width W, a (R_blk, W, W) pairwise label-equality tensor turns the
mode computation into dense VPU reductions held entirely in VMEM — no hash
map, no sort, no HBM round trips.  Noise-based tie-breaking reproduces the
paper's thread-race randomization deterministically.

Tiling: grid over row blocks; ``pick_row_block`` sizes R_blk so the pairwise
tensor stays within a ~8 MB f32 VMEM budget (e.g. W=16 → R_blk=512,
W=1024 → R_blk=1).  Lane dim = W (multiples of 128 for the wide buckets).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, pick_row_block, tie_noise_jnp


def _label_argmax_kernel(
    lab_ref,      # (R_blk, W) int32
    w_ref,        # (R_blk, W) float32
    cur_ref,      # (R_blk, 1) int32
    rows_ref,     # (R_blk, 1) int32
    seed_ref,     # (1, 1) int32
    out_lab_ref,  # (R_blk, 1) int32
    out_best_ref, # (R_blk, 1) float32
    out_cur_ref,  # (R_blk, 1) float32
    *,
    sentinel: int,
    tie_eps: float,
):
    lab = lab_ref[...]
    w = w_ref[...]
    cur = cur_ref[...][:, 0]
    rows = rows_ref[...][:, 0]
    seed = seed_ref[0, 0].astype(jnp.uint32)

    valid = lab != sentinel
    # score[r, j] = Σ_k w[r, k] · [lab[r, k] == lab[r, j]]
    eq = lab[:, :, None] == lab[:, None, :]
    score = jnp.sum(jnp.where(eq, w[:, :, None], 0.0), axis=1)
    noise = tie_noise_jnp(rows[:, None], lab, seed, tie_eps)
    eff = jnp.where(valid, score + noise, -jnp.inf)

    best_score = jnp.max(eff, axis=1)
    is_best = (eff == best_score[:, None]) & valid
    best_lab = jnp.min(jnp.where(is_best, lab, sentinel), axis=1)
    best_lab = jnp.where(best_score > -jnp.inf, best_lab, -1)

    eqc = valid & (lab == cur[:, None])
    cur_sum = jnp.sum(jnp.where(eqc, w, 0.0), axis=1)
    cur_present = jnp.any(eqc, axis=1)
    cur_noise = tie_noise_jnp(rows, cur, seed, tie_eps)
    cur_score = jnp.where(cur_present, cur_sum + cur_noise, 0.0)

    out_lab_ref[...] = best_lab[:, None]
    out_best_ref[...] = best_score[:, None]
    out_cur_ref[...] = cur_score[:, None]


def label_argmax_pallas(
    nbr_lab: jax.Array,   # (R, W) int32
    nbr_w: jax.Array,     # (R, W) float32
    cur_lab: jax.Array,   # (R,) int32
    rows: jax.Array,      # (R,) int32
    seed: jax.Array,      # scalar int/uint32
    tie_eps: float,
    sentinel: int,
    interpret: bool = True,
    row_block: int | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    R, W = nbr_lab.shape
    r_blk = row_block or min(pick_row_block(W), R)
    pad = (-R) % r_blk
    if pad:
        nbr_lab = jnp.pad(nbr_lab, ((0, pad), (0, 0)), constant_values=sentinel)
        nbr_w = jnp.pad(nbr_w, ((0, pad), (0, 0)))
        cur_lab = jnp.pad(cur_lab, (0, pad), constant_values=sentinel)
        rows = jnp.pad(rows, (0, pad), constant_values=sentinel)
    Rp = R + pad

    grid = (Rp // r_blk,)
    kern = functools.partial(_label_argmax_kernel, sentinel=sentinel, tie_eps=tie_eps)
    out_lab, out_best, out_cur = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, W), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, W), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((r_blk, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        nbr_lab,
        nbr_w,
        cur_lab[:, None],
        rows[:, None],
        jnp.asarray(seed, jnp.int32).reshape(1, 1),
    )
    return out_lab[:R, 0], out_best[:R, 0], out_cur[:R, 0]
