"""Pallas TPU kernel: per-edge bin-rank gather for the sort-free aggregation.

Same resident-table layout as the fused local_move kernels (DESIGN.md
§Kernels): the flat (rows·width,) bin-key table rides along in the ANY
memory space, is DMA'd into VMEM scratch on the first grid step, and every
later row-block of edges gathers its (R_blk, width) key rows in-kernel —
the only HBM traffic per block is the two (R_blk, 1) edge tiles and one
(R_blk, 1) output.  The rank math is ref.py's ``bin_rank_ref`` verbatim, so
kernel ≡ ref bit-compatibility holds by construction.

INVARIANT: the grid keeps the default sequential ("arbitrary") semantics —
a parallel dimension would hand later steps never-DMA'd scratch (the same
invariant as local_move's resident kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.aggregation.ref import bin_rank_ref
from repro.kernels.common import (TABLE_LANE, default_interpret,
                                  pick_row_block_fused)


def _pad_lane(tab: jax.Array, fill) -> jax.Array:
    """Pad a flat table to a lane multiple for the ANY→VMEM copy."""
    pad = (-tab.shape[0]) % TABLE_LANE
    return jnp.pad(tab, (0, pad), constant_values=fill) if pad else tab


def _bin_rank_kernel(
    keys_tab_ref,  # (tab_pad,) int32 in ANY — whole flat bin-key table
    cs_ref,        # (R_blk, 1) int32 — per-edge row (source community)
    cd_ref,        # (R_blk, 1) int32 — per-edge key (destination community)
    out_ref,       # (R_blk, 1) int32 — per-edge within-row rank
    keys_vmem,     # (tab_pad,) int32 VMEM scratch
    sem,
    *,
    width: int,
    empty: int,
):
    @pl.when(pl.program_id(0) == 0)
    def _():
        cp = pltpu.make_async_copy(keys_tab_ref, keys_vmem, sem)
        cp.start()
        cp.wait()

    rank = bin_rank_ref(
        keys_vmem[...],
        cs_ref[...][:, 0],
        cd_ref[...][:, 0],
        width=width,
        empty=empty,
    )
    out_ref[...] = rank[:, None]


def bin_rank_pallas(
    keys_flat: jax.Array,  # (rows·width,) int32 — bin-key table
    cs: jax.Array,         # (R,) int32
    cd: jax.Array,         # (R,) int32
    *,
    width: int,
    empty: int,
    interpret: bool | None = None,
    row_block: int | None = None,
    vmem_budget: int | None = None,
) -> jax.Array:
    """Per-edge bin rank (ref.py contract) with the table VMEM-resident.

    Caller guarantees the table fits the resident budget
    (``kernels.common.resolve_bin_impl``); edges padded to the row block
    must carry the sink row index so their gathers stay in range.
    """
    if interpret is None:
        interpret = default_interpret()
    R = cs.shape[0]
    tab = _pad_lane(keys_flat, empty)
    tab_pad = tab.shape[0]
    r_blk = row_block or min(
        pick_row_block_fused(width, vmem_budget, table_bytes=4 * tab_pad), R)
    pad = (-R) % r_blk
    if pad:
        sink_row = keys_flat.shape[0] // width - 1
        cs = jnp.pad(cs, (0, pad), constant_values=sink_row)
        cd = jnp.pad(cd, (0, pad), constant_values=empty)
    Rp = R + pad

    kern = functools.partial(_bin_rank_kernel, width=width, empty=empty)
    col = lambda: pl.BlockSpec((r_blk, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        kern,
        grid=(Rp // r_blk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            col(), col(),
        ],
        out_specs=col(),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((tab_pad,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(tab, cs[:, None], cd[:, None])
    return out[:R, 0]
