"""Sort-free binned coarsening — orchestration around the rank kernel.

Replaces the coarsening GroupBy's ``lax.sort`` with direct binned
accumulation over the DENSE contiguous community ids (DESIGN.md
§Aggregation kernel).  Stages, all jit-native with static shapes:

  1. *Gate*: one ``segment_sum`` bounds each source community's edge count;
     any row over the static bin width falls back to the one-sort path via
     ``lax.cond`` BEFORE paying for probing (hash rows cannot hold more
     distinct destinations than the width, and hub rows would otherwise
     probe for many rounds just to discover the overflow).
  2. *Insert*: a ``lax.while_loop`` of scatter-min claim rounds assigns each
     distinct (src-community, dst-community) pair one slot of the
     (n+1, width) bin-key table.  Edges of the SAME pair share the probe
     sequence, so they claim, win and resolve together in one round —
     which keeps every group's weight accumulation in original edge order,
     the bitwise contract below.  Losers (distinct keys contending for one
     slot; the smallest key wins a round) continue linear probing; any
     survivor after the round budget raises the overflow fallback.
  3. *Rank*: per edge, the rank of its destination key within its bin row
     (kernel.py on TPU / ref.py elsewhere — ``resolve_bin_impl``) plus a
     per-row occupancy count and an exclusive ``cumsum`` over rows give the
     canonical front-compacted src-sorted output position with no sort.
  4. *Output*: three m-sized edge scatters — src/dst ids (duplicates write
     identical values) and a ``segment_sum`` of the weights keyed by output
     position.  Because positions ascend with (src, dst) and the adds apply
     in original edge order, the result is bit-for-bit the one-sort
     ``remap_and_coarsen`` coarse graph, including the padding-slot
     conventions (src = dst = sentinel, w = 0, mask False).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.graph import segment as seg
from repro.graph.structure import Graph
from repro.kernels.aggregation.kernel import bin_rank_pallas
from repro.kernels.aggregation.ref import bin_rank_ref
from repro.kernels.common import (bin_table_bytes, hash_u32_jnp,
                                  pick_bin_width, resolve_bin_impl)
from repro.utils import telemetry


def community_edge_keys(
    g: Graph, new_com: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Per-edge (src-community, dst-community) keys; masked edges get the
    ``n_max`` sentinel on both sides (they sort last / route to the sink
    row, in every path)."""
    n = g.n_max
    sentinel = jnp.int32(n)
    cs = jnp.where(g.edge_mask, new_com[jnp.clip(g.src, 0, n - 1)], sentinel)
    cd = jnp.where(g.edge_mask, new_com[jnp.clip(g.dst, 0, n - 1)], sentinel)
    return cs, cd


def insert_bins(
    g: Graph,
    cs: jax.Array,
    cd: jax.Array,
    *,
    width: int,
    max_rounds: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Gate + probing insert.  Returns ``(keys_flat, resolved, overflow,
    rounds)``: the ((n+1)·width + 1,) bin-key table (last element is the
    claim sink), per-edge resolution, the fallback predicate, and the
    number of probe rounds actually run."""
    n, m = g.n_max, g.m_max
    W = width
    empty = jnp.int32(n)
    active = g.edge_mask
    rounds_max = jnp.int32(max_rounds if max_rounds is not None else W)
    sink = (n + 1) * W
    row_base = jnp.where(active, cs, n) * W
    h0 = (hash_u32_jnp(cd) % jnp.uint32(W)).astype(jnp.int32)
    keys0 = jnp.full((sink + 1,), empty, jnp.int32)

    # gate: a row with more than W edges MAY hold more than W distinct
    # destinations; skip probing entirely and let the sort path run
    row_edges = jax.ops.segment_sum(
        jnp.where(active, 1, 0), jnp.clip(cs, 0, n), num_segments=n + 1)
    fits = jnp.max(row_edges[:n]) <= jnp.int32(W)

    def probe(keys):
        def cond(c):
            return (c[2] < rounds_max) & jnp.any(~c[1])

        def body(c):
            keys, resolved, r = c
            slot = (h0 + r) % W
            idx = row_base + slot
            k_cur = keys[idx]
            hit = ~resolved & (k_cur == cd)
            claim = ~resolved & (k_cur == empty)
            keys = keys.at[jnp.where(claim, idx, sink)].min(cd)
            won = claim & (keys[idx] == cd)
            return keys, resolved | hit | won, r + 1

        return jax.lax.while_loop(cond, body, (keys, ~active, jnp.int32(0)))

    def skip(keys):
        return keys, ~active, jnp.int32(0)

    keys, resolved, rounds = jax.lax.cond(fits, probe, skip, keys0)
    overflow = jnp.any(active & ~resolved)
    return keys, resolved, overflow, rounds


def binned_coarsen(
    g: Graph,
    new_com: jax.Array,
    n_comm: jax.Array,
    *,
    width: Optional[int] = None,
    impl: str = "auto",
    max_rounds: Optional[int] = None,
    row_block: Optional[int] = None,
    vmem_budget: Optional[int] = None,
    force_overflow: bool = False,
) -> Graph:
    """Sort-free coarse graph for CONTIGUOUS community ids ``new_com``.

    Bit-for-bit identical to ``core.aggregation.coarsen_graph`` /
    ``remap_and_coarsen``'s coarse output (tests/test_aggregation.py); the
    one-sort path remains reachable as the in-graph ``lax.cond`` fallback
    AND as the documented oracle (``LouvainConfig.aggregation="sort"``).

    ``force_overflow`` (static; the ``binned_overflow`` fault-injection
    point) pins the overflow predicate true so every aggregation takes the
    sort fallback — the bit-identity of that descent is what
    tests/test_faults.py asserts.
    """
    n, m = g.n_max, g.m_max
    W = width if width is not None else pick_bin_width(n, m)
    sentinel = jnp.int32(n)
    empty = int(n)
    active = g.edge_mask
    impl_r = resolve_bin_impl(impl, bin_table_bytes(n, W), vmem_budget)

    cs, cd = community_edge_keys(g, new_com)
    keys, _resolved, overflow, _rounds = insert_bins(
        g, cs, cd, width=W, max_rounds=max_rounds)
    if force_overflow:
        telemetry.bump("fault.binned_overflow.forced")
        overflow = jnp.bool_(True)

    def binned_path(_):
        keys_flat = keys[:-1]
        occ2d = keys_flat.reshape(n + 1, W) != jnp.int32(empty)
        cnt = jnp.sum(occ2d[:n].astype(jnp.int32), axis=1)
        row_start = jnp.cumsum(cnt) - cnt
        n_groups = jnp.sum(cnt)
        cs_c = jnp.clip(cs, 0, n)
        if impl_r == "kernel":
            rank_e = bin_rank_pallas(
                keys_flat, cs_c, cd, width=W, empty=empty,
                row_block=row_block, vmem_budget=vmem_budget)
        else:
            rank_e = bin_rank_ref(keys_flat, cs_c, cd, width=W, empty=empty)
        epos = jnp.where(
            active, row_start[jnp.clip(cs, 0, n - 1)] + rank_e, m)
        # duplicate positions write identical values (all edges of a group
        # share (cs, cd)), so the scatter order is immaterial for the ids;
        # the weight adds apply in original edge order — the same order the
        # stable one-sort path accumulates in.  When the (cs, cd) pair packs
        # into one int32 (static trace-time check; true for every stand-in
        # capacity) both ids ride ONE m-scatter instead of two — scatters
        # dominate this path on CPU/TPU alike, and integer pack/unpack is
        # exact so the bitwise contract is untouched.
        if (n + 1) * (n + 1) - 1 <= 2**31 - 1:
            base = jnp.int32(n + 1)
            packed = (jnp.full((m + 1,), sentinel * base + sentinel,
                               jnp.int32).at[epos].set(cs * base + cd)[:m])
            gsrc, gdst = packed // base, packed % base
        else:
            # overflow guard: (n_cap+1)² would not fit int32, so the packed
            # single-scatter id encoding is statically disabled for this
            # capacity; the counter makes the (slower) two-scatter descent
            # observable rather than silent
            telemetry.bump("agg.id_pack_disabled")
            gsrc = (jnp.full((m + 1,), sentinel, jnp.int32)
                    .at[epos].set(cs)[:m])
            gdst = (jnp.full((m + 1,), sentinel, jnp.int32)
                    .at[epos].set(cd)[:m])
        sums = jax.ops.segment_sum(
            jnp.where(active, g.w, 0.0), epos, num_segments=m + 1)[:m]
        gmask = jnp.arange(m, dtype=jnp.int32) < n_groups
        return gsrc, gdst, jnp.where(gmask, sums, 0.0), gmask, n_groups

    def sort_path(_):
        # the one-sort GroupBy (graph/segment.py), exactly coarsen_graph's
        # massaging — the cond-gated overflow fallback
        (gk, gs, gvalid, _ng) = seg.groupby_sum(
            (cs, cd), jnp.where(active, g.w, 0.0), valid=active)
        grp_ok = gvalid & (gk[0] < sentinel)
        return (jnp.where(grp_ok, gk[0], sentinel),
                jnp.where(grp_ok, gk[1], sentinel),
                jnp.where(grp_ok, gs, 0.0),
                grp_ok,
                jnp.sum(grp_ok.astype(jnp.int32)))

    gsrc, gdst, gw, gmask, n_groups = jax.lax.cond(
        overflow, sort_path, binned_path, None)
    return Graph(
        src=gsrc,
        dst=gdst,
        w=gw,
        edge_mask=gmask,
        n_valid=n_comm.astype(jnp.int32),
        m_valid=n_groups,
        n_max=n,
        m_max=m,
        sorted_by="src",
    )
