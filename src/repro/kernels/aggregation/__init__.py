"""Sort-free binned aggregation kernels (DESIGN.md §Aggregation kernel)."""
from repro.kernels.aggregation.ops import binned_coarsen  # noqa: F401
