"""Pure-jnp oracle for the binned aggregation rank pass.

After the hash-probe insert (ops.py) every valid edge's destination
community sits in exactly one slot of its source community's (width,)
bin row, and each row holds the DISTINCT destination communities of that
source community.  The coarse graph's canonical slot order (src-sorted,
dst-ascending within src, front-compacted — `core/aggregation.py`'s
contract) then only needs, per edge, the RANK of its destination key
within its row: a gather of the row plus a masked compare-and-count,
with no sort anywhere.

The Pallas kernel (kernel.py) runs this SAME function on the
VMEM-resident key table, so kernel ≡ ref bit-compatibility holds by
construction — the local_move pattern (DESIGN.md §Kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bin_rank_ref(
    keys_flat: jax.Array,   # ((n_rows)·width + pad,) int32 — bin-key table
    cs: jax.Array,          # (R,) int32 — per-edge row (source community)
    cd: jax.Array,          # (R,) int32 — per-edge key (destination community)
    *,
    width: int,
    empty: int,
) -> jax.Array:
    """Per-edge within-row rank: # occupied slots in row ``cs`` with a key
    strictly below ``cd``.  Rows indexed beyond the live communities must
    exist in the table (the +1 sink row) and stay ``empty`` so padded or
    masked edges rank harmlessly to 0."""
    iota_w = jnp.arange(width, dtype=jnp.int32)
    row_keys = keys_flat[cs[:, None] * width + iota_w[None, :]]  # (R, width)
    less = (row_keys != empty) & (row_keys < cd[:, None])
    return jnp.sum(less.astype(jnp.int32), axis=1)
