"""Pure-jnp oracle for the fused gather-in-kernel local-move kernels.

The contract shared with kernel.py: per row r (one vertex, ELL tile of width
W), gather the per-vertex tables at the neighbor ids, then score the move —
the PLP weighted label mode or the Louvain Eq. 1 ΔQ argmax — and emit the
per-row ``(proposal, propose)`` pair directly.

Tables are the (n+1)-entry "extended" arrays the sweep engine builds once per
sweep: slot ``sentinel`` (= n) is the padding sink, so ``labels_ext[n] = n``,
``vol_ext[n] = size_ext[n] = deg_ext[n] = 0``.  Row/neighbor ids are in
[0, n] with n marking padding.

The scoring math is delegated to the label_argmax / delta_q oracles so this
ref stays bit-compatible with the legacy gather-outside two-step by
construction (same gather expressions, same reductions, same tie-breaks).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.delta_q.ref import delta_q_ref
from repro.kernels.label_argmax.ref import label_argmax_ref


def local_move_plp_ref(
    rows: jax.Array,        # (R,) int32 vertex id per row (sentinel = pad)
    nbr: jax.Array,         # (R, W) int32 neighbor ids (sentinel = pad)
    w: jax.Array,           # (R, W) float32 edge weights (0 = pad)
    labels_ext: jax.Array,  # (n+1,) int32, labels_ext[n] = n
    seed: jax.Array,        # uint32 scalar tie-noise seed
    *,
    tie_eps: float,
    sentinel: int,
) -> Tuple[jax.Array, jax.Array]:
    """(best_label[R], propose[R]) for the PLP move, gathers included."""
    n = sentinel
    nbr_lab = jnp.where(nbr < n, labels_ext[jnp.clip(nbr, 0, n)], n)
    cur_lab = labels_ext[jnp.clip(rows, 0, n)]
    rows_n = jnp.where(rows < n, rows, n)
    best_lab, best_score, cur_score = label_argmax_ref(
        nbr_lab, w, cur_lab, rows_n, seed, tie_eps, sentinel
    )
    return best_lab, (best_lab >= 0) & (best_score > cur_score)


def local_move_louvain_ref(
    rows: jax.Array,      # (R,) int32 vertex id per row (sentinel = pad)
    nbr: jax.Array,       # (R, W) int32 neighbor ids (sentinel = pad)
    w: jax.Array,         # (R, W) float32 edge weights (0 = pad)
    com_ext: jax.Array,   # (n+1,) int32 community per vertex, com_ext[n] = n
    vol_ext: jax.Array,   # (n+1,) float32 community volume, vol_ext[n] = 0
    size_ext: jax.Array,  # (n+1,) int32 community size, size_ext[n] = 0
    deg_ext: jax.Array,   # (n+1,) float32 weighted degree, deg_ext[n] = 0
    inv_vol: jax.Array,   # f32 scalar 1 / vol(V)
    *,
    sentinel: int,
    singleton_rule: bool,
) -> Tuple[jax.Array, jax.Array]:
    """(best_community[R], propose[R]) for the Louvain move (Eq. 1)."""
    n = sentinel
    rows_c = jnp.clip(rows, 0, n)
    cand = jnp.where(nbr < n, com_ext[jnp.clip(nbr, 0, n)], n)
    cur = com_ext[rows_c]
    best_cand, best_gain = delta_q_ref(
        cand, w, cur,
        deg_ext[rows_c],
        vol_ext[jnp.clip(cand, 0, n)],
        vol_ext[jnp.clip(cur, 0, n)],
        size_ext[jnp.clip(cand, 0, n)],
        size_ext[jnp.clip(cur, 0, n)],
        inv_vol,
        sentinel=sentinel,
        singleton_rule=singleton_rule,
    )
    return best_cand, (best_cand >= 0) & (best_gain > 0.0)
