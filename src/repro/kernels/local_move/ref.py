"""Pure-jnp oracle for the fused gather-in-kernel local-move kernels.

The contract shared with kernel.py: per row r (one vertex, ELL tile of width
W), gather the per-vertex tables at the row/neighbor ids, then score the
move — the PLP weighted label mode or the Louvain Eq. 1 ΔQ argmax — and emit
the per-row ``(proposal, propose)`` pair directly.

Tables are the (n+1)-entry "extended" arrays the sweep engine builds once per
sweep: slot ``sentinel`` (= n) is the padding sink, so ``labels_ext[n] = n``,
``vol_ext[n] = size_ext[n] = deg_ext[n] = 0``.  Row/neighbor ids are in
[0, n] with n marking padding.  Every table access goes through ``_gather``,
which masks sentinel ids to the sink VALUE explicitly instead of reading the
sink slot — so the same scoring code runs against the full resident table
(``win_lo=None``) or against a streamed window slice rebased by ``win_lo``
(DESIGN.md §Kernels): real ids are guaranteed inside the window by the host
window metadata, sentinel ids never touch the table at all.  Resident and
windowed evaluation are therefore bit-identical by construction.

Louvain's Eq. 1 terms are community-indexed (volCom/sizeCom of the CANDIDATE
community), which a window over vertex ids cannot bound.
``compose_louvain_tables`` folds that second-level gather into per-VERTEX
tables once per sweep (``volcom_v[v] = vol_ext[com_ext[v]]`` …), so the
per-neighbor kernel gathers are all vertex-indexed and window-friendly;
the composed values are the exact floats the two-level gather produced.

The scoring math is delegated to the label_argmax / delta_q oracles so this
ref stays bit-compatible with the legacy gather-outside two-step by
construction (same gather expressions, same reductions, same tie-breaks).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.delta_q.ref import delta_q_ref
from repro.kernels.label_argmax.ref import label_argmax_ref


def _gather(tab: jax.Array, ids: jax.Array, sentinel: int, fill,
            win_lo: Optional[jax.Array]) -> jax.Array:
    """Masked (optionally window-rebased) table gather.

    ``ids`` are vertex ids in [0, n]; real ids (< n = sentinel) must lie in
    [win_lo, win_lo + len(tab)) — guaranteed for windows by the host
    metadata, trivially for the full table.  Sentinel/padding ids take the
    table's documented sink-slot VALUE (``fill``) without reading the table,
    so the clip below never leaks an out-of-window read into the result.
    """
    idx = ids if win_lo is None else ids - win_lo
    idx = jnp.clip(idx, 0, tab.shape[0] - 1)
    return jnp.where(ids < sentinel, tab[idx], fill)


def local_move_plp_ref(
    rows: jax.Array,        # (R,) int32 vertex id per row (sentinel = pad)
    nbr: jax.Array,         # (R, W) int32 neighbor ids (sentinel = pad)
    w: jax.Array,           # (R, W) float32 edge weights (0 = pad)
    labels_ext: jax.Array,  # (n+1,) int32 full table, labels_ext[n] = n —
                            # or a window slice of it when win_lo is given
    seed: jax.Array,        # uint32 scalar tie-noise seed
    *,
    tie_eps: float,
    sentinel: int,
    win_lo: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best_label[R], propose[R]) for the PLP move, gathers included."""
    n = sentinel
    nbr_lab = _gather(labels_ext, nbr, n, n, win_lo)
    cur_lab = _gather(labels_ext, rows, n, n, win_lo)
    rows_n = jnp.where(rows < n, rows, n)
    best_lab, best_score, cur_score = label_argmax_ref(
        nbr_lab, w, cur_lab, rows_n, seed, tie_eps, sentinel
    )
    return best_lab, (best_lab >= 0) & (best_score > cur_score)


def compose_louvain_tables(
    com_ext: jax.Array,   # (n+1,) int32 community per vertex, com_ext[n] = n
    vol_ext: jax.Array,   # (n+1,) float32 community volume, vol_ext[n] = 0
    size_ext: jax.Array,  # (n+1,) int32 community size, size_ext[n] = 0
    deg_ext: jax.Array,   # (n+1,) float32 weighted degree, deg_ext[n] = 0
    sentinel: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-VERTEX composed tables (com_v, volcom_v, sizecom_v, deg_v).

    ``volcom_v[v] = vol_ext[com_ext[v]]`` etc: one (n+1,) gather per sweep
    that turns every community-indexed Eq. 1 term into a vertex-indexed one,
    so the kernels gather by row/neighbor id only.  The sink contract is
    preserved: com_ext[n] = n ⇒ volcom_v[n] = vol_ext[n] = 0 (same for
    size), so composed tables carry the same sink values the two-level
    gather produced.
    """
    idx = jnp.clip(com_ext, 0, sentinel)
    return com_ext, vol_ext[idx], size_ext[idx], deg_ext


def local_move_louvain_tables_ref(
    rows: jax.Array,       # (R,) int32 vertex id per row (sentinel = pad)
    nbr: jax.Array,        # (R, W) int32 neighbor ids (sentinel = pad)
    w: jax.Array,          # (R, W) float32 edge weights (0 = pad)
    com_v: jax.Array,      # (n+1,) int32 community per vertex, com_v[n] = n
    volcom_v: jax.Array,   # (n+1,) f32 vol of v's community, volcom_v[n] = 0
    sizecom_v: jax.Array,  # (n+1,) i32 size of v's community, sizecom_v[n]=0
    deg_v: jax.Array,      # (n+1,) f32 weighted degree, deg_v[n] = 0
    inv_vol: jax.Array,    # f32 scalar 1 / vol(V)
    *,
    sentinel: int,
    singleton_rule: bool,
    win_lo: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best_community[R], propose[R]) on vertex-composed tables (Eq. 1).

    The single scoring path shared by the resident kernel (full tables,
    ``win_lo=None``), the streamed kernel (window slices + rebase), and the
    pure-jnp windowed ref — kernel ≡ ref holds structurally.
    """
    n = sentinel
    cand = _gather(com_v, nbr, n, n, win_lo)
    cur = _gather(com_v, rows, n, n, win_lo)
    best_cand, best_gain = delta_q_ref(
        cand, w, cur,
        _gather(deg_v, rows, n, 0.0, win_lo),
        _gather(volcom_v, nbr, n, 0.0, win_lo),
        _gather(volcom_v, rows, n, 0.0, win_lo),
        _gather(sizecom_v, nbr, n, 0, win_lo),
        _gather(sizecom_v, rows, n, 0, win_lo),
        inv_vol,
        sentinel=sentinel,
        singleton_rule=singleton_rule,
    )
    return best_cand, (best_cand >= 0) & (best_gain > 0.0)


def local_move_louvain_ref(
    rows: jax.Array,      # (R,) int32 vertex id per row (sentinel = pad)
    nbr: jax.Array,       # (R, W) int32 neighbor ids (sentinel = pad)
    w: jax.Array,         # (R, W) float32 edge weights (0 = pad)
    com_ext: jax.Array,   # (n+1,) int32 community per vertex, com_ext[n] = n
    vol_ext: jax.Array,   # (n+1,) float32 community volume, vol_ext[n] = 0
    size_ext: jax.Array,  # (n+1,) int32 community size, size_ext[n] = 0
    deg_ext: jax.Array,   # (n+1,) float32 weighted degree, deg_ext[n] = 0
    inv_vol: jax.Array,   # f32 scalar 1 / vol(V)
    *,
    sentinel: int,
    singleton_rule: bool,
) -> Tuple[jax.Array, jax.Array]:
    """(best_community[R], propose[R]) on community-indexed tables.

    Convenience wrapper: compose the per-vertex tables, then score.  Values
    are identical to the historical two-level gather
    (``vol_ext[com_ext[nbr]]`` = ``volcom_v[nbr]`` elementwise).
    """
    tabs = compose_louvain_tables(com_ext, vol_ext, size_ext, deg_ext, sentinel)
    return local_move_louvain_tables_ref(
        rows, nbr, w, *tabs, inv_vol,
        sentinel=sentinel, singleton_rule=singleton_rule,
    )
