"""Dispatch wrappers for the fused local_move kernels (pallas/oracle).

Plain jit-safe functions, deliberately NOT wrapped in ``jax.jit``: they are
only ever called inside the already-jitted sweep loop, where a nested jit
would add trace/dispatch overhead and block fusion with the surrounding
scatter (same rationale as the label_argmax / delta_q wrappers).

Inputs accept any leading shape — ``rows`` may be the chunk-stacked
(n_chunks, rows) layout of ``graph/ell.DeviceEll`` or already flat; the
wrapper collapses leading dims so the Pallas grid spans all chunks of the
bucket, and reshapes the outputs back.

Table layout selection (DESIGN.md §Kernels): ``table_mode`` picks between
the VMEM-RESIDENT fast path and the WINDOWED STREAMED path; ``auto``
resolves from the VMEM byte budget (``kernels.common.resolve_table_mode``)
at trace time.  Streaming needs the per-row-block window metadata
(``graph.ell.TableWindows``, passed duck-typed so the kernel layer stays
free of graph-layer imports); without it the resident path is used.  Both
pallas layouts and both pure-jnp oracles are bit-identical — the windowed
oracle slices the same windows with ``lax.dynamic_slice`` and runs the SAME
per-block ref the streamed kernel body runs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    TABLE_LANE,
    default_interpret,
    resolve_table_mode,
)
from repro.kernels.local_move.kernel import (
    _check_windows,
    _pad_tiles,
    local_move_louvain_pallas,
    local_move_louvain_pallas_streamed,
    local_move_plp_pallas,
    local_move_plp_pallas_streamed,
    window_flat,
)
from repro.kernels.local_move.ref import (
    compose_louvain_tables,
    local_move_louvain_tables_ref,
    local_move_plp_ref,
)


def _flatten(rows, nbr, w):
    W = nbr.shape[-1]
    return (
        rows.reshape(-1).astype(jnp.int32),
        nbr.reshape(-1, W).astype(jnp.int32),
        w.reshape(-1, W).astype(jnp.float32),
    )


def _resolve_mode(table_mode: str, windows, n_tables: int, sentinel: int,
                  vmem_budget: Optional[int]) -> str:
    """Static resident-vs-streamed decision for one dispatch.

    ``auto`` additionally requires the STREAMED footprint to earn its keep:

    * the window must be narrower than the table — with poor id-locality
      one outlier row inflates the per-bucket slot stride to the whole id
      range (``TableWindows`` docstring), and a 2-slot window ≥ the table
      would re-read the full table per grid step, strictly worse than the
      resident one-shot DMA;
    * the double-buffered windows (2 live buffers of 2·slot entries per
      table) must fit the same half-budget bound the resident tables were
      tested against — mediocre locality past the resident budget would
      otherwise stream windows that bust VMEM just the same.

    Failing either check falls back to resident (on a real TPU a
    past-budget resident layout may still fail to compile — the fix is
    better locality or a finer ``block_rows``, see DESIGN.md §Kernels).
    Explicit ``table_mode='streamed'`` is honored unchecked (the
    degenerate-window parity tests rely on it).
    """
    if windows is None:
        if table_mode == "streamed":
            raise ValueError(
                "table_mode='streamed' requires window metadata "
                "(graph.ell.TableWindows); build buckets via to_device()")
        return "resident"
    n_pad = -(-(sentinel + 1) // TABLE_LANE) * TABLE_LANE
    mode = resolve_table_mode(table_mode, 4 * n_tables * n_pad, vmem_budget)
    if mode == "streamed" and table_mode == "auto":
        win_bytes = 4 * n_tables * (2 * windows.slot) * 2  # 2 = live buffers
        if (2 * windows.slot >= n_pad
                or resolve_table_mode("auto", win_bytes, vmem_budget)
                != "resident"):
            return "resident"
    return mode


def _blocked(windows, rows, nbr, w, sentinel: int):
    """Reshape flat tiles into the (n_blocks, block_rows, ·) window layout
    (same metadata validation as the Pallas streamed path)."""
    R, W = nbr.shape
    nb = _check_windows(windows, R)
    r_blk = windows.block_rows
    rows, nbr, w, _ = _pad_tiles(rows, nbr, w, r_blk, sentinel)
    return (rows.reshape(nb, r_blk), nbr.reshape(nb, r_blk, W),
            w.reshape(nb, r_blk, W), R)


def _window_flat(tab, windows, fill):
    """Flat table padded so every 2-slot window slice is in range — the
    SAME padding step (kernel.window_flat) the overlapped BlockSpec view is
    built from."""
    return window_flat(tab, windows.slot, windows.n_slots, fill)


def local_move_plp(
    rows: jax.Array,        # (..., ) int32 vertex id per row
    nbr: jax.Array,         # (..., W) int32 neighbor ids
    w: jax.Array,           # (..., W) float32 edge weights
    labels_ext: jax.Array,  # (n+1,) labels table, labels_ext[n] = n
    seed: jax.Array,        # scalar tie-noise seed
    *,
    tie_eps: float,
    sentinel: int,
    use_pallas: bool = False,
    interpret: bool | None = None,
    windows=None,                       # graph.ell.TableWindows | None
    table_mode: str = "auto",           # auto | resident | streamed
    vmem_budget: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best_label, propose) per row, gathers fused into the evaluator."""
    lead = rows.shape
    rows_f, nbr_f, w_f = _flatten(rows, nbr, w)
    labels_ext = labels_ext.astype(jnp.int32)
    mode = _resolve_mode(table_mode, windows, 1, sentinel, vmem_budget)
    if use_pallas:
        interp = default_interpret() if interpret is None else interpret
        if mode == "streamed":
            best, prop = local_move_plp_pallas_streamed(
                rows_f, nbr_f, w_f, labels_ext, seed,
                tie_eps=tie_eps, sentinel=sentinel, interpret=interp,
                windows=windows,
            )
        else:
            best, prop = local_move_plp_pallas(
                rows_f, nbr_f, w_f, labels_ext, seed,
                tie_eps=tie_eps, sentinel=sentinel, interpret=interp,
                vmem_budget=vmem_budget,
            )
        prop = prop != 0
    elif mode == "streamed":
        # pure-jnp windowed oracle: per block, slice the SAME 2-slot window
        # the streamed kernel's BlockSpec lands and run the SAME ref body
        R = rows_f.shape[0]
        rows_b, nbr_b, w_b, _ = _blocked(windows, rows_f, nbr_f, w_f, sentinel)
        flat = _window_flat(labels_ext, windows, sentinel)
        S = windows.slot

        def one(r_, nb_, w_, k):
            winv = jax.lax.dynamic_slice(flat, (k * S,), (2 * S,))
            return local_move_plp_ref(
                r_, nb_, w_, winv, seed,
                tie_eps=tie_eps, sentinel=sentinel, win_lo=k * S)

        best, prop = jax.vmap(one)(rows_b, nbr_b, w_b, windows.win_blk)
        best, prop = best.reshape(-1)[:R], prop.reshape(-1)[:R]
    else:
        best, prop = local_move_plp_ref(
            rows_f, nbr_f, w_f, labels_ext, seed,
            tie_eps=tie_eps, sentinel=sentinel,
        )
    return best.reshape(lead), prop.reshape(lead)


def local_move_louvain(
    rows: jax.Array,      # (..., ) int32 vertex id per row
    nbr: jax.Array,       # (..., W) int32 neighbor ids
    w: jax.Array,         # (..., W) float32 edge weights
    com_ext: jax.Array,   # (n+1,) community table, com_ext[n] = n
    vol_ext: jax.Array,   # (n+1,) community volumes, vol_ext[n] = 0
    size_ext: jax.Array,  # (n+1,) community sizes, size_ext[n] = 0
    deg_ext: jax.Array,   # (n+1,) weighted degrees, deg_ext[n] = 0
    vol_total: jax.Array,  # scalar vol(V)
    *,
    sentinel: int,
    singleton_rule: bool = True,
    use_pallas: bool = False,
    interpret: bool | None = None,
    windows=None,                       # graph.ell.TableWindows | None
    table_mode: str = "auto",           # auto | resident | streamed
    vmem_budget: int | None = None,
    composed=None,                      # per-vertex composed table 4-tuple
) -> Tuple[jax.Array, jax.Array]:
    """(best_community, propose) per row; gain test is Eq. 1 > 0.

    ``composed`` lets a caller evaluating MANY buckets per sweep (the ELL
    engine) pass the per-vertex composed tables of
    ``ref.compose_louvain_tables`` built ONCE per sweep, instead of this
    wrapper re-composing them per bucket dispatch.
    """
    lead = rows.shape
    rows_f, nbr_f, w_f = _flatten(rows, nbr, w)
    inv_vol = (1.0 / vol_total).astype(jnp.float32)
    if composed is None:
        composed = compose_louvain_tables(
            com_ext.astype(jnp.int32), vol_ext.astype(jnp.float32),
            size_ext.astype(jnp.int32), deg_ext.astype(jnp.float32),
            sentinel)
    com_v, volcom_v, sizecom_v, deg_v = composed
    mode = _resolve_mode(table_mode, windows, 4, sentinel, vmem_budget)
    if use_pallas:
        interp = default_interpret() if interpret is None else interpret
        if mode == "streamed":
            best, prop = local_move_louvain_pallas_streamed(
                rows_f, nbr_f, w_f, com_v, volcom_v, sizecom_v, deg_v,
                inv_vol, sentinel=sentinel, singleton_rule=singleton_rule,
                interpret=interp, windows=windows,
            )
        else:
            best, prop = local_move_louvain_pallas(
                rows_f, nbr_f, w_f, com_v, volcom_v, sizecom_v, deg_v,
                inv_vol, sentinel=sentinel, singleton_rule=singleton_rule,
                interpret=interp, vmem_budget=vmem_budget,
            )
        prop = prop != 0
    elif mode == "streamed":
        R = rows_f.shape[0]
        rows_b, nbr_b, w_b, _ = _blocked(windows, rows_f, nbr_f, w_f, sentinel)
        flats = (
            _window_flat(com_v, windows, sentinel),
            _window_flat(volcom_v, windows, 0),
            _window_flat(sizecom_v, windows, 0),
            _window_flat(deg_v, windows, 0),
        )
        S = windows.slot

        def one(r_, nb_, w_, k):
            wins = tuple(
                jax.lax.dynamic_slice(f, (k * S,), (2 * S,)) for f in flats)
            return local_move_louvain_tables_ref(
                r_, nb_, w_, *wins, inv_vol,
                sentinel=sentinel, singleton_rule=singleton_rule,
                win_lo=k * S)

        best, prop = jax.vmap(one)(rows_b, nbr_b, w_b, windows.win_blk)
        best, prop = best.reshape(-1)[:R], prop.reshape(-1)[:R]
    else:
        best, prop = local_move_louvain_tables_ref(
            rows_f, nbr_f, w_f, com_v, volcom_v, sizecom_v, deg_v, inv_vol,
            sentinel=sentinel, singleton_rule=singleton_rule,
        )
    return best.reshape(lead), prop.reshape(lead)
