"""Dispatch wrappers for the fused local_move kernels (pallas/oracle).

Plain jit-safe functions, deliberately NOT wrapped in ``jax.jit``: they are
only ever called inside the already-jitted sweep loop, where a nested jit
would add trace/dispatch overhead and block fusion with the surrounding
scatter (same rationale as the label_argmax / delta_q wrappers).

Inputs accept any leading shape — ``rows`` may be the chunk-stacked
(n_chunks, rows) layout of ``graph/ell.DeviceEll`` or already flat; the
wrapper collapses leading dims so the Pallas grid spans all chunks of the
bucket, and reshapes the outputs back.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.local_move.kernel import (
    local_move_louvain_pallas,
    local_move_plp_pallas,
)
from repro.kernels.local_move.ref import (
    local_move_louvain_ref,
    local_move_plp_ref,
)


def _flatten(rows, nbr, w):
    W = nbr.shape[-1]
    return (
        rows.reshape(-1).astype(jnp.int32),
        nbr.reshape(-1, W).astype(jnp.int32),
        w.reshape(-1, W).astype(jnp.float32),
    )


def local_move_plp(
    rows: jax.Array,        # (..., ) int32 vertex id per row
    nbr: jax.Array,         # (..., W) int32 neighbor ids
    w: jax.Array,           # (..., W) float32 edge weights
    labels_ext: jax.Array,  # (n+1,) labels table, labels_ext[n] = n
    seed: jax.Array,        # scalar tie-noise seed
    *,
    tie_eps: float,
    sentinel: int,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best_label, propose) per row, gathers fused into the evaluator."""
    lead = rows.shape
    rows_f, nbr_f, w_f = _flatten(rows, nbr, w)
    labels_ext = labels_ext.astype(jnp.int32)
    if use_pallas:
        interp = default_interpret() if interpret is None else interpret
        best, prop = local_move_plp_pallas(
            rows_f, nbr_f, w_f, labels_ext, seed,
            tie_eps=tie_eps, sentinel=sentinel, interpret=interp,
        )
        prop = prop != 0
    else:
        best, prop = local_move_plp_ref(
            rows_f, nbr_f, w_f, labels_ext, seed,
            tie_eps=tie_eps, sentinel=sentinel,
        )
    return best.reshape(lead), prop.reshape(lead)


def local_move_louvain(
    rows: jax.Array,      # (..., ) int32 vertex id per row
    nbr: jax.Array,       # (..., W) int32 neighbor ids
    w: jax.Array,         # (..., W) float32 edge weights
    com_ext: jax.Array,   # (n+1,) community table, com_ext[n] = n
    vol_ext: jax.Array,   # (n+1,) community volumes, vol_ext[n] = 0
    size_ext: jax.Array,  # (n+1,) community sizes, size_ext[n] = 0
    deg_ext: jax.Array,   # (n+1,) weighted degrees, deg_ext[n] = 0
    vol_total: jax.Array,  # scalar vol(V)
    *,
    sentinel: int,
    singleton_rule: bool = True,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best_community, propose) per row; gain test is Eq. 1 > 0."""
    lead = rows.shape
    rows_f, nbr_f, w_f = _flatten(rows, nbr, w)
    com_ext = com_ext.astype(jnp.int32)
    vol_ext = vol_ext.astype(jnp.float32)
    size_ext = size_ext.astype(jnp.int32)
    deg_ext = deg_ext.astype(jnp.float32)
    inv_vol = (1.0 / vol_total).astype(jnp.float32)
    if use_pallas:
        interp = default_interpret() if interpret is None else interpret
        best, prop = local_move_louvain_pallas(
            rows_f, nbr_f, w_f, com_ext, vol_ext, size_ext, deg_ext, inv_vol,
            sentinel=sentinel, singleton_rule=singleton_rule, interpret=interp,
        )
        prop = prop != 0
    else:
        best, prop = local_move_louvain_ref(
            rows_f, nbr_f, w_f, com_ext, vol_ext, size_ext, deg_ext, inv_vol,
            sentinel=sentinel, singleton_rule=singleton_rule,
        )
    return best.reshape(lead), prop.reshape(lead)
