"""Fused gather-in-kernel local-move kernels (DESIGN.md §Kernels).

One kernel family replaces the gather→``label_argmax``/``delta_q_argmax``
two-step of the ELL evaluator: the kernel receives the ELL neighbor tiles
blocked into VMEM plus the per-vertex tables (labels / community / volume /
size / degree) — either WHOLE in the ANY memory space (VMEM-resident fast
path) or as per-row-block WINDOWS streamed by the Pallas pipeline under a
parallel grid (beyond-VMEM path; selection via the VMEM byte budget in
``kernels.common``) — performs the per-neighbor gathers inside the kernel,
and emits ``(proposal, propose)`` directly — no gathered (rows, W)
intermediates ever hit HBM.

Layout mirrors the sibling kernels: kernel.py (pl.pallas_call + BlockSpec),
ops.py (plain jit-safe dispatch wrapper), ref.py (pure-jnp oracle reusing the
label_argmax / delta_q oracles for bit-compatibility).
"""
from repro.kernels.local_move import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
