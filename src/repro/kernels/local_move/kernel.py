"""Pallas TPU kernel: fused gather + local-move scoring (DESIGN.md §Kernels).

The legacy ELL path materialized four gathered (rows, W) tiles in HBM before
every ``label_argmax`` / ``delta_q_argmax`` launch and serialized chunks
through a per-bucket ``lax.scan``.  Here the whole per-vertex tables ride
along in the ANY memory space, are DMA'd once into VMEM scratch on the first
grid step, and every gather happens inside the kernel — the only HBM traffic
per row-block is the neighbor tile itself plus two (R_blk, 1) outputs.

Grid scheme: one pallas_call per degree bucket with a 1-D grid over
row-blocks spanning ALL chunks of the bucket (the (n_chunks, rows, W) stack
of ``graph/ell.to_device`` collapses to (n_chunks·rows, W) for free), so
chunks become independent grid steps of one dispatch instead of a
lax.scan-carried chain.  INVARIANT: the grid must keep the default
sequential ("arbitrary") dimension semantics — the table scratch is
populated only on the first grid step, so declaring the dimension parallel
(megacore) would hand later steps never-DMA'd scratch.
``pick_row_block_fused`` sizes R_blk so the (R_blk, W, W) pairwise tensor
stays within the VMEM budget; the table scratch adds ~(n+1) entries per
table (4 B each), which bounds this layout to graphs whose tables fit VMEM
— beyond that the tables would be streamed per block (future work).

The scoring math lives in ref.py (which itself delegates to the
label_argmax / delta_q oracles): each kernel body is just table-DMA +
in-kernel gather+score via the SAME traced code as the oracle path, so
kernel ≡ ref bit-compatibility holds by construction.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pick_row_block_fused
from repro.kernels.local_move.ref import (
    local_move_louvain_ref,
    local_move_plp_ref,
)

TABLE_LANE = 128  # table padding unit (lane width) for the VMEM scratch


def _pad_table(tab: jax.Array, fill) -> jax.Array:
    """Pad a (n+1,) table to a lane multiple for the ANY→VMEM copy."""
    m = tab.shape[0]
    pad = (-m) % TABLE_LANE
    return jnp.pad(tab, (0, pad), constant_values=fill) if pad else tab


def _copy_tables_once(table_refs, scratch_refs, sem):
    """DMA every table into VMEM scratch on the first grid step only;
    scratch persists across grid steps, so later blocks reuse the copies.
    Relies on the sequential ("arbitrary") grid execution order — see the
    module-docstring INVARIANT."""

    @pl.when(pl.program_id(0) == 0)
    def _():
        for src, dst in zip(table_refs, scratch_refs):
            cp = pltpu.make_async_copy(src, dst, sem)
            cp.start()
            cp.wait()


def _local_move_plp_kernel(
    lab_tab_ref,   # (n_pad,) int32 in ANY — whole labels_ext table
    rows_ref,      # (R_blk, 1) int32
    nbr_ref,       # (R_blk, W) int32
    w_ref,         # (R_blk, W) float32
    seed_ref,      # (1, 1) int32
    out_lab_ref,   # (R_blk, 1) int32
    out_prop_ref,  # (R_blk, 1) int32 (0/1)
    lab_vmem,      # (n_pad,) int32 VMEM scratch
    sem,
    *,
    sentinel: int,
    tie_eps: float,
):
    _copy_tables_once((lab_tab_ref,), (lab_vmem,), sem)
    # gathers + scoring run in-kernel on the VMEM-resident table, through the
    # SAME code as the oracle path (ref.py); indices are clipped to [0, n],
    # so the lane padding of the (n_pad,) scratch is never read
    best_lab, prop = local_move_plp_ref(
        rows_ref[...][:, 0],
        nbr_ref[...],
        w_ref[...],
        lab_vmem[...],
        seed_ref[0, 0].astype(jnp.uint32),
        tie_eps=tie_eps,
        sentinel=sentinel,
    )
    out_lab_ref[...] = best_lab[:, None]
    out_prop_ref[...] = prop.astype(jnp.int32)[:, None]


def _local_move_louvain_kernel(
    com_tab_ref,   # (n_pad,) int32 in ANY
    vol_tab_ref,   # (n_pad,) float32 in ANY
    size_tab_ref,  # (n_pad,) int32 in ANY
    deg_tab_ref,   # (n_pad,) float32 in ANY
    rows_ref,      # (R_blk, 1) int32
    nbr_ref,       # (R_blk, W) int32
    w_ref,         # (R_blk, W) float32
    invvol_ref,    # (1, 1) float32
    out_cand_ref,  # (R_blk, 1) int32
    out_prop_ref,  # (R_blk, 1) int32 (0/1)
    com_vmem,
    vol_vmem,
    size_vmem,
    deg_vmem,
    sem,
    *,
    sentinel: int,
    singleton_rule: bool,
):
    _copy_tables_once(
        (com_tab_ref, vol_tab_ref, size_tab_ref, deg_tab_ref),
        (com_vmem, vol_vmem, size_vmem, deg_vmem),
        sem,
    )
    # gathers (candidate community, then the Eq. 1 volume/size/degree terms —
    # five tiles that never touch HBM) + scoring run in-kernel on the
    # VMEM-resident tables, through the SAME code as the oracle path (ref.py)
    best_cand, prop = local_move_louvain_ref(
        rows_ref[...][:, 0],
        nbr_ref[...],
        w_ref[...],
        com_vmem[...],
        vol_vmem[...],
        size_vmem[...],
        deg_vmem[...],
        invvol_ref[0, 0],
        sentinel=sentinel,
        singleton_rule=singleton_rule,
    )
    out_cand_ref[...] = best_cand[:, None]
    out_prop_ref[...] = prop.astype(jnp.int32)[:, None]


def _pad_tiles(rows, nbr, w, r_blk: int, sentinel: int):
    R = rows.shape[0]
    pad = (-R) % r_blk
    if pad:
        rows = jnp.pad(rows, (0, pad), constant_values=sentinel)
        nbr = jnp.pad(nbr, ((0, pad), (0, 0)), constant_values=sentinel)
        w = jnp.pad(w, ((0, pad), (0, 0)))
    return rows, nbr, w, R + pad


def local_move_plp_pallas(
    rows: jax.Array,        # (R,) int32
    nbr: jax.Array,         # (R, W) int32
    w: jax.Array,           # (R, W) float32
    labels_ext: jax.Array,  # (n+1,) int32
    seed: jax.Array,        # scalar int/uint32
    *,
    tie_eps: float,
    sentinel: int,
    interpret: bool,
    row_block: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    R, W = nbr.shape
    r_blk = row_block or min(pick_row_block_fused(W), R)
    rows, nbr, w, Rp = _pad_tiles(rows, nbr, w, r_blk, sentinel)
    tab = _pad_table(labels_ext, sentinel)
    n_pad = tab.shape[0]

    kern = functools.partial(
        _local_move_plp_kernel, sentinel=sentinel, tie_eps=tie_eps
    )
    wide = lambda: pl.BlockSpec((r_blk, W), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((r_blk, 1), lambda i: (i, 0))
    out_lab, out_prop = pl.pallas_call(
        kern,
        grid=(Rp // r_blk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            col(), wide(), wide(),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[col(), col()],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_pad,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(
        tab,
        rows[:, None],
        nbr,
        w,
        jnp.asarray(seed, jnp.int32).reshape(1, 1),
    )
    return out_lab[:R, 0], out_prop[:R, 0]


def local_move_louvain_pallas(
    rows: jax.Array,      # (R,) int32
    nbr: jax.Array,       # (R, W) int32
    w: jax.Array,         # (R, W) float32
    com_ext: jax.Array,   # (n+1,) int32
    vol_ext: jax.Array,   # (n+1,) float32
    size_ext: jax.Array,  # (n+1,) int32
    deg_ext: jax.Array,   # (n+1,) float32
    inv_vol: jax.Array,   # f32 scalar
    *,
    sentinel: int,
    singleton_rule: bool,
    interpret: bool,
    row_block: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    R, W = nbr.shape
    r_blk = row_block or min(pick_row_block_fused(W), R)
    rows, nbr, w, Rp = _pad_tiles(rows, nbr, w, r_blk, sentinel)
    com_t = _pad_table(com_ext, sentinel)
    vol_t = _pad_table(vol_ext, 0)
    size_t = _pad_table(size_ext, 0)
    deg_t = _pad_table(deg_ext, 0)
    n_pad = com_t.shape[0]

    kern = functools.partial(
        _local_move_louvain_kernel,
        sentinel=sentinel,
        singleton_rule=singleton_rule,
    )
    any_spec = lambda: pl.BlockSpec(memory_space=pltpu.ANY)
    wide = lambda: pl.BlockSpec((r_blk, W), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((r_blk, 1), lambda i: (i, 0))
    out_cand, out_prop = pl.pallas_call(
        kern,
        grid=(Rp // r_blk,),
        in_specs=[
            any_spec(), any_spec(), any_spec(), any_spec(),
            col(), wide(), wide(),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[col(), col()],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_pad,), jnp.int32),
            pltpu.VMEM((n_pad,), jnp.float32),
            pltpu.VMEM((n_pad,), jnp.int32),
            pltpu.VMEM((n_pad,), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(
        com_t, vol_t, size_t, deg_t,
        rows[:, None],
        nbr,
        w,
        jnp.asarray(inv_vol, jnp.float32).reshape(1, 1),
    )
    return out_cand[:R, 0], out_prop[:R, 0]
