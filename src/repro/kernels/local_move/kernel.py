"""Pallas TPU kernels: fused gather + local-move scoring (DESIGN.md §Kernels).

The legacy ELL path materialized four gathered (rows, W) tiles in HBM before
every ``label_argmax`` / ``delta_q_argmax`` launch and serialized chunks
through a per-bucket ``lax.scan``.  Here the per-vertex tables never leave
the kernel family: every per-neighbor gather happens in-kernel, and the only
HBM traffic per row-block is the neighbor tile plus two (R_blk, 1) outputs.
Two table layouts exist, selected by the VMEM-byte budget in
``kernels/common.py`` (``resolve_table_mode``):

* **resident** (fast path, tables fit VMEM): whole (n+1,) tables ride along
  in the ANY memory space and are DMA'd once into VMEM scratch on the first
  grid step; scratch persists, later row-blocks reuse the copies.
  INVARIANT: the grid keeps the default sequential ("arbitrary") semantics —
  a parallel dimension would hand later steps never-DMA'd scratch.

* **streamed** (beyond-VMEM): each grid step reads only its row-block's
  TABLE WINDOW.  Host-side locality ordering (graph/ell.py) makes each
  block's ids span a narrow range [lo, hi); ``TableWindows`` publishes the
  per-block slot index ``win_blk[b] = lo // slot`` as a scalar-prefetch
  operand and the table is presented as an OVERLAPPED (n_slots, 2·slot)
  view (row k covers flat[k·slot : k·slot + 2·slot)), so the BlockSpec
  index map ``(win_blk[b], 0)`` lands the window at slot granularity.  The
  window is a regular blocked input: Pallas's pipeline double-buffers it,
  prefetching block b+1's windows while block b scores, and — because no
  scratch state crosses grid steps — the grid dimension is declared
  PARALLEL (megacore-able).  In-kernel gathers are rebased to window-local
  indices via ``win_lo = win_blk[b]·slot``.

Grid scheme: one pallas_call per degree bucket with a 1-D grid over
row-blocks spanning ALL chunks of the bucket (the (n_chunks, rows, W) stack
of ``graph/ell.to_device`` collapses to (n_chunks·rows, W) for free).
``pick_row_block_fused`` sizes the resident R_blk, charging the table
scratch against the VMEM budget; the streamed block size is pinned by the
window metadata (``TableWindows.block_rows``).

The scoring math lives in ref.py (which itself delegates to the
label_argmax / delta_q oracles): each kernel body is table-DMA/window-load +
the SAME traced gather+score code as the oracle path (sentinel ids are
masked to sink values, never read), so kernel ≡ ref bit-compatibility holds
by construction for both layouts.  Louvain runs on the per-VERTEX composed
tables of ``ref.compose_louvain_tables`` so every in-kernel gather is
vertex-indexed and therefore window-friendly.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import TABLE_LANE, cdiv, pick_row_block_fused
from repro.kernels.local_move.ref import (
    local_move_louvain_tables_ref,
    local_move_plp_ref,
)


def _pad_table(tab: jax.Array, fill) -> jax.Array:
    """Pad a (n+1,) table to a lane multiple for the ANY→VMEM copy."""
    m = tab.shape[0]
    pad = (-m) % TABLE_LANE
    return jnp.pad(tab, (0, pad), constant_values=fill) if pad else tab


def _copy_tables_once(table_refs, scratch_refs, sem):
    """DMA every table into VMEM scratch on the first grid step only;
    scratch persists across grid steps, so later blocks reuse the copies.
    Relies on the sequential ("arbitrary") grid execution order — see the
    module-docstring INVARIANT (the STREAMED kernels have no such state and
    run under a parallel grid)."""

    @pl.when(pl.program_id(0) == 0)
    def _():
        for src, dst in zip(table_refs, scratch_refs):
            cp = pltpu.make_async_copy(src, dst, sem)
            cp.start()
            cp.wait()


def window_flat(tab: jax.Array, slot: int, n_slots: int, fill) -> jax.Array:
    """Flat table padded to (n_slots+1)·slot so every 2-slot window slice
    [k·slot, k·slot + 2·slot) is in range for k < n_slots.  Shared by the
    overlapped BlockSpec view below and the pure-jnp windowed oracle's
    ``dynamic_slice`` (ops.py) — ONE copy of the padding invariant.
    Padding beyond id n is never read (sentinel ids are masked in
    ref._gather), ``fill`` just keeps it typed."""
    pad = (n_slots + 1) * slot - tab.shape[0]
    return jnp.pad(tab, (0, pad), constant_values=fill) if pad else tab


def _window_view(tab: jax.Array, slot: int, n_slots: int, fill) -> jax.Array:
    """Overlapped (n_slots, 2·slot) window view of a flat (n+1,) table.

    Row k covers flat[k·slot : k·slot + 2·slot): window offsets get slot
    granularity from a plain BlockSpec index map even though block indices
    are multiplied by the block shape.  Built per sweep from live tables by
    pad + reshape + concat — XLA fuses it; the 2× copy lives in HBM, which
    is the point of streaming.
    """
    t2 = window_flat(tab, slot, n_slots, fill).reshape(n_slots + 1, slot)
    return jnp.concatenate([t2[:-1], t2[1:]], axis=1)


def _pad_tiles(rows, nbr, w, r_blk: int, sentinel: int):
    R = rows.shape[0]
    pad = (-R) % r_blk
    if pad:
        rows = jnp.pad(rows, (0, pad), constant_values=sentinel)
        nbr = jnp.pad(nbr, ((0, pad), (0, 0)), constant_values=sentinel)
        w = jnp.pad(w, ((0, pad), (0, 0)))
    return rows, nbr, w, R + pad


def _check_windows(windows, R: int):
    nb = windows.win_blk.shape[0]
    if cdiv(R, windows.block_rows) != nb:
        raise ValueError(
            f"window metadata mismatch: {nb} blocks of "
            f"{windows.block_rows} rows vs {R} tile rows — windows must be "
            f"computed over the same (padded) bucket layout they score")
    return nb


# ----------------------------------------------------------------- PLP


def _local_move_plp_kernel(
    lab_tab_ref,   # (n_pad,) int32 in ANY — whole labels_ext table
    rows_ref,      # (R_blk, 1) int32
    nbr_ref,       # (R_blk, W) int32
    w_ref,         # (R_blk, W) float32
    seed_ref,      # (1, 1) int32
    out_lab_ref,   # (R_blk, 1) int32
    out_prop_ref,  # (R_blk, 1) int32 (0/1)
    lab_vmem,      # (n_pad,) int32 VMEM scratch
    sem,
    *,
    sentinel: int,
    tie_eps: float,
):
    _copy_tables_once((lab_tab_ref,), (lab_vmem,), sem)
    # gathers + scoring run in-kernel on the VMEM-resident table, through the
    # SAME code as the oracle path (ref.py); sentinel ids are masked to the
    # sink value, real ids index inside [0, n], so the lane padding of the
    # (n_pad,) scratch is never read
    best_lab, prop = local_move_plp_ref(
        rows_ref[...][:, 0],
        nbr_ref[...],
        w_ref[...],
        lab_vmem[...],
        seed_ref[0, 0].astype(jnp.uint32),
        tie_eps=tie_eps,
        sentinel=sentinel,
    )
    out_lab_ref[...] = best_lab[:, None]
    out_prop_ref[...] = prop.astype(jnp.int32)[:, None]


def _local_move_plp_streamed_kernel(
    win_ref,       # (n_blocks,) int32 scalar-prefetch — slot index per block
    rows_ref,      # (R_blk, 1) int32
    nbr_ref,       # (R_blk, W) int32
    w_ref,         # (R_blk, W) float32
    seed_ref,      # (1, 1) int32
    lab_win_ref,   # (1, 2·slot) int32 — this block's window of labels_ext
    out_lab_ref,   # (R_blk, 1) int32
    out_prop_ref,  # (R_blk, 1) int32 (0/1)
    *,
    sentinel: int,
    tie_eps: float,
    slot: int,
):
    base = win_ref[pl.program_id(0)] * slot
    best_lab, prop = local_move_plp_ref(
        rows_ref[...][:, 0],
        nbr_ref[...],
        w_ref[...],
        lab_win_ref[...].reshape(-1),
        seed_ref[0, 0].astype(jnp.uint32),
        tie_eps=tie_eps,
        sentinel=sentinel,
        win_lo=base,
    )
    out_lab_ref[...] = best_lab[:, None]
    out_prop_ref[...] = prop.astype(jnp.int32)[:, None]


def local_move_plp_pallas(
    rows: jax.Array,        # (R,) int32
    nbr: jax.Array,         # (R, W) int32
    w: jax.Array,           # (R, W) float32
    labels_ext: jax.Array,  # (n+1,) int32
    seed: jax.Array,        # scalar int/uint32
    *,
    tie_eps: float,
    sentinel: int,
    interpret: bool,
    row_block: int | None = None,
    vmem_budget: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    R, W = nbr.shape
    tab = _pad_table(labels_ext, sentinel)
    n_pad = tab.shape[0]
    r_blk = row_block or min(
        pick_row_block_fused(W, vmem_budget, table_bytes=4 * n_pad), R)
    rows, nbr, w, Rp = _pad_tiles(rows, nbr, w, r_blk, sentinel)

    kern = functools.partial(
        _local_move_plp_kernel, sentinel=sentinel, tie_eps=tie_eps
    )
    wide = lambda: pl.BlockSpec((r_blk, W), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((r_blk, 1), lambda i: (i, 0))
    out_lab, out_prop = pl.pallas_call(
        kern,
        grid=(Rp // r_blk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            col(), wide(), wide(),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[col(), col()],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_pad,), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(
        tab,
        rows[:, None],
        nbr,
        w,
        jnp.asarray(seed, jnp.int32).reshape(1, 1),
    )
    return out_lab[:R, 0], out_prop[:R, 0]


def local_move_plp_pallas_streamed(
    rows: jax.Array,        # (R,) int32
    nbr: jax.Array,         # (R, W) int32
    w: jax.Array,           # (R, W) float32
    labels_ext: jax.Array,  # (n+1,) int32
    seed: jax.Array,        # scalar int/uint32
    *,
    tie_eps: float,
    sentinel: int,
    interpret: bool,
    windows,                # graph.ell.TableWindows
) -> Tuple[jax.Array, jax.Array]:
    R, W = nbr.shape
    nb = _check_windows(windows, R)
    r_blk, S = windows.block_rows, windows.slot
    rows, nbr, w, Rp = _pad_tiles(rows, nbr, w, r_blk, sentinel)
    ov = _window_view(labels_ext, S, windows.n_slots, sentinel)

    kern = functools.partial(
        _local_move_plp_streamed_kernel,
        sentinel=sentinel, tie_eps=tie_eps, slot=S,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((r_blk, 1), lambda i, wb: (i, 0)),
            pl.BlockSpec((r_blk, W), lambda i, wb: (i, 0)),
            pl.BlockSpec((r_blk, W), lambda i, wb: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, wb: (0, 0)),
            pl.BlockSpec((1, 2 * S), lambda i, wb: (wb[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, 1), lambda i, wb: (i, 0)),
            pl.BlockSpec((r_blk, 1), lambda i, wb: (i, 0)),
        ],
    )
    out_lab, out_prop = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(
        windows.win_blk,
        rows[:, None],
        nbr,
        w,
        jnp.asarray(seed, jnp.int32).reshape(1, 1),
        ov,
    )
    return out_lab[:R, 0], out_prop[:R, 0]


# ----------------------------------------------------------------- Louvain


def _local_move_louvain_kernel(
    com_tab_ref,   # (n_pad,) int32 in ANY — com_v (per-vertex community)
    vol_tab_ref,   # (n_pad,) float32 in ANY — volcom_v
    size_tab_ref,  # (n_pad,) int32 in ANY — sizecom_v
    deg_tab_ref,   # (n_pad,) float32 in ANY — deg_v
    rows_ref,      # (R_blk, 1) int32
    nbr_ref,       # (R_blk, W) int32
    w_ref,         # (R_blk, W) float32
    invvol_ref,    # (1, 1) float32
    out_cand_ref,  # (R_blk, 1) int32
    out_prop_ref,  # (R_blk, 1) int32 (0/1)
    com_vmem,
    vol_vmem,
    size_vmem,
    deg_vmem,
    sem,
    *,
    sentinel: int,
    singleton_rule: bool,
):
    _copy_tables_once(
        (com_tab_ref, vol_tab_ref, size_tab_ref, deg_tab_ref),
        (com_vmem, vol_vmem, size_vmem, deg_vmem),
        sem,
    )
    # gathers (candidate community + the Eq. 1 volume/size/degree terms, all
    # vertex-indexed thanks to compose_louvain_tables — five tiles that never
    # touch HBM) + scoring run in-kernel on the VMEM-resident tables, through
    # the SAME code as the oracle path (ref.py)
    best_cand, prop = local_move_louvain_tables_ref(
        rows_ref[...][:, 0],
        nbr_ref[...],
        w_ref[...],
        com_vmem[...],
        vol_vmem[...],
        size_vmem[...],
        deg_vmem[...],
        invvol_ref[0, 0],
        sentinel=sentinel,
        singleton_rule=singleton_rule,
    )
    out_cand_ref[...] = best_cand[:, None]
    out_prop_ref[...] = prop.astype(jnp.int32)[:, None]


def _local_move_louvain_streamed_kernel(
    win_ref,        # (n_blocks,) int32 scalar-prefetch — slot index per block
    rows_ref,       # (R_blk, 1) int32
    nbr_ref,        # (R_blk, W) int32
    w_ref,          # (R_blk, W) float32
    invvol_ref,     # (1, 1) float32
    com_win_ref,    # (1, 2·slot) int32 — window of com_v
    vol_win_ref,    # (1, 2·slot) float32 — window of volcom_v
    size_win_ref,   # (1, 2·slot) int32 — window of sizecom_v
    deg_win_ref,    # (1, 2·slot) float32 — window of deg_v
    out_cand_ref,   # (R_blk, 1) int32
    out_prop_ref,   # (R_blk, 1) int32 (0/1)
    *,
    sentinel: int,
    singleton_rule: bool,
    slot: int,
):
    base = win_ref[pl.program_id(0)] * slot
    best_cand, prop = local_move_louvain_tables_ref(
        rows_ref[...][:, 0],
        nbr_ref[...],
        w_ref[...],
        com_win_ref[...].reshape(-1),
        vol_win_ref[...].reshape(-1),
        size_win_ref[...].reshape(-1),
        deg_win_ref[...].reshape(-1),
        invvol_ref[0, 0],
        sentinel=sentinel,
        singleton_rule=singleton_rule,
        win_lo=base,
    )
    out_cand_ref[...] = best_cand[:, None]
    out_prop_ref[...] = prop.astype(jnp.int32)[:, None]


def local_move_louvain_pallas(
    rows: jax.Array,       # (R,) int32
    nbr: jax.Array,        # (R, W) int32
    w: jax.Array,          # (R, W) float32
    com_v: jax.Array,      # (n+1,) int32 — COMPOSED per-vertex tables
    volcom_v: jax.Array,   # (n+1,) float32  (ref.compose_louvain_tables,
    sizecom_v: jax.Array,  # (n+1,) int32     built once per sweep by the
    deg_v: jax.Array,      # (n+1,) float32   caller and shared by buckets)
    inv_vol: jax.Array,    # f32 scalar
    *,
    sentinel: int,
    singleton_rule: bool,
    interpret: bool,
    row_block: int | None = None,
    vmem_budget: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    R, W = nbr.shape
    com_t = _pad_table(com_v, sentinel)
    vol_t = _pad_table(volcom_v, 0)
    size_t = _pad_table(sizecom_v, 0)
    deg_t = _pad_table(deg_v, 0)
    n_pad = com_t.shape[0]
    r_blk = row_block or min(
        pick_row_block_fused(W, vmem_budget, table_bytes=4 * 4 * n_pad), R)
    rows, nbr, w, Rp = _pad_tiles(rows, nbr, w, r_blk, sentinel)

    kern = functools.partial(
        _local_move_louvain_kernel,
        sentinel=sentinel,
        singleton_rule=singleton_rule,
    )
    any_spec = lambda: pl.BlockSpec(memory_space=pltpu.ANY)
    wide = lambda: pl.BlockSpec((r_blk, W), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((r_blk, 1), lambda i: (i, 0))
    out_cand, out_prop = pl.pallas_call(
        kern,
        grid=(Rp // r_blk,),
        in_specs=[
            any_spec(), any_spec(), any_spec(), any_spec(),
            col(), wide(), wide(),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[col(), col()],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_pad,), jnp.int32),
            pltpu.VMEM((n_pad,), jnp.float32),
            pltpu.VMEM((n_pad,), jnp.int32),
            pltpu.VMEM((n_pad,), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(
        com_t, vol_t, size_t, deg_t,
        rows[:, None],
        nbr,
        w,
        jnp.asarray(inv_vol, jnp.float32).reshape(1, 1),
    )
    return out_cand[:R, 0], out_prop[:R, 0]


def local_move_louvain_pallas_streamed(
    rows: jax.Array,       # (R,) int32
    nbr: jax.Array,        # (R, W) int32
    w: jax.Array,          # (R, W) float32
    com_v: jax.Array,      # (n+1,) int32 — COMPOSED per-vertex tables
    volcom_v: jax.Array,   # (n+1,) float32  (see local_move_louvain_pallas)
    sizecom_v: jax.Array,  # (n+1,) int32
    deg_v: jax.Array,      # (n+1,) float32
    inv_vol: jax.Array,    # f32 scalar
    *,
    sentinel: int,
    singleton_rule: bool,
    interpret: bool,
    windows,              # graph.ell.TableWindows
) -> Tuple[jax.Array, jax.Array]:
    R, W = nbr.shape
    nb = _check_windows(windows, R)
    r_blk, S = windows.block_rows, windows.slot
    rows, nbr, w, Rp = _pad_tiles(rows, nbr, w, r_blk, sentinel)
    ov_com = _window_view(com_v, S, windows.n_slots, sentinel)
    ov_vol = _window_view(volcom_v, S, windows.n_slots, 0)
    ov_size = _window_view(sizecom_v, S, windows.n_slots, 0)
    ov_deg = _window_view(deg_v, S, windows.n_slots, 0)

    kern = functools.partial(
        _local_move_louvain_streamed_kernel,
        sentinel=sentinel, singleton_rule=singleton_rule, slot=S,
    )
    win = lambda: pl.BlockSpec((1, 2 * S), lambda i, wb: (wb[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((r_blk, 1), lambda i, wb: (i, 0)),
            pl.BlockSpec((r_blk, W), lambda i, wb: (i, 0)),
            pl.BlockSpec((r_blk, W), lambda i, wb: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, wb: (0, 0)),
            win(), win(), win(), win(),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, 1), lambda i, wb: (i, 0)),
            pl.BlockSpec((r_blk, 1), lambda i, wb: (i, 0)),
        ],
    )
    out_cand, out_prop = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(
        windows.win_blk,
        rows[:, None],
        nbr,
        w,
        jnp.asarray(inv_vol, jnp.float32).reshape(1, 1),
        ov_com, ov_vol, ov_size, ov_deg,
    )
    return out_cand[:R, 0], out_prop[:R, 0]
