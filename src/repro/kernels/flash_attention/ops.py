"""Jit wrapper for the flash-attention kernel.

``use_pallas=True`` routes through the Pallas kernel (interpret mode on CPU,
compiled on TPU); ``False`` through the jnp oracle.  Shapes must satisfy the
kernel's tiling constraints (Sq % block_q == 0, Sk % block_k == 0); the
wrapper falls back to the oracle otherwise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, use_pallas: bool = False,
                    interpret: bool | None = None):
    if not use_pallas:
        return _ref.attention_ref(q, k, v, causal=causal)
    sq, sk = q.shape[2], k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        return _ref.attention_ref(q, k, v, causal=causal)
    itp = default_interpret() if interpret is None else interpret
    return _k.flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                                  block_k=bk, interpret=itp)
