"""Pallas TPU kernel: causal GQA flash attention (fwd).

The §Perf "flash-fuse" iteration: the jnp flash path materializes the
(Sq, Sk-chunk) probability tensors and running (m, l, acc) statistics to HBM
every chunk — measured at 8-25% of the memory term on the train/prefill
cells (flash_attn_interior rows of the dry-run profile).  This kernel keeps
the entire online-softmax interior in VMEM:

  grid = (B * Hq, Sq / BLOCK_Q)    one program per query block per head
  for each k block (BLOCK_K wide, ascending):
      s   = q_blk @ k_blk^T        (MXU, f32 accum)
      causal masking via iota comparison (no materialized mask)
      online-softmax update of (m, l, acc) — all VMEM residents
  out = acc / l

VMEM budget per program (defaults BLOCK_Q=512, BLOCK_K=512, D=128, f32):
  q 256KB + k/v 2x256KB + s 1MB + acc 256KB  ≈ 2MB  « 16MB/core.
Block shapes are (multiple-of-8, 128)-aligned for the MXU/VPU.

GQA: the kernel receives k/v already grouped per q-head (index_map selects
the kv head h // group); no repeat materialization in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sk: int,
                      causal: bool, q_offset_blocks: int):
    """q_ref: (1, BLOCK_Q, D); k_ref/v_ref: (1, Sk, D); o_ref: (1, BLOCK_Q, D)."""
    _, block_q, d = q_ref.shape
    qi = pl.program_id(1)                       # query block index
    q = q_ref[0].astype(jnp.float32) / jnp.sqrt(jnp.float32(d))

    q_start = qi * block_q
    n_kblocks = sk // block_k

    def body(ki, carry):
        m, l, acc = carry
        # Index the leading singleton axis with a length-1 slice, not a bare
        # int: interpret-mode discharge (_load_discharge_rule) chokes on
        # scalar indices mixed into a dynamic-slice index tuple.
        k_blk = pl.load(
            k_ref, (pl.ds(0, 1), pl.ds(ki * block_k, block_k), slice(None))
        )[0]
        v_blk = pl.load(
            v_ref, (pl.ds(0, 1), pl.ds(ki * block_k, block_k), slice(None))
        )[0]
        s = jax.lax.dot_general(
            q, k_blk.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (BQ, BK)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_blk.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    # causal: skip k blocks entirely above the diagonal
    if causal:
        last = (q_start + block_q + block_k - 1) // block_k
        n_iter = jnp.minimum(n_kblocks, last)
    else:
        n_iter = n_kblocks

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hk, sk = k.shape[1], k.shape[2]
    assert hq % hk == 0 and sq % block_q == 0 and sk % block_k == 0
    group = hq // hk

    grid = (b * hq, sq // block_q)

    def q_map(bh, qi):
        return (bh, qi, 0)

    def kv_map(bh, qi):
        return (bh // group if group > 1 else bh, 0, 0)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hk, sk, d)
    vr = v.reshape(b * hk, sk, d)
    # kv index_map works on the flattened (B*Hkv) axis: program bh maps to
    # (bh // hq) * hk + (bh % hq) // group
    def kv_map2(bh, qi):
        bidx = bh // hq
        hidx = (bh % hq) // group
        return (bidx * hk + hidx, 0, 0)

    kernel = functools.partial(
        _flash_fwd_kernel, block_k=block_k, sk=sk, causal=causal,
        q_offset_blocks=0)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, sk, d), kv_map2),
            pl.BlockSpec((1, sk, d), kv_map2),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
