"""Pure-jnp oracle for the flash-attention kernel (causal GQA attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    f32 softmax, bf16-friendly output — the semantic spec for the kernel."""
    b, hq, sq, d = q.shape
    hk, sk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)
