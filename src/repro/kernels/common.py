"""Shared kernel utilities."""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import faultinject, telemetry

# Default per-core VMEM capacity assumed by the budget policy (~16 MB/core on
# contemporary TPUs).  Override per TPU generation with the
# REPRO_VMEM_BUDGET_BYTES environment variable or the ``budget_bytes`` kwargs.
DEFAULT_VMEM_BUDGET_BYTES = 16 << 20
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET_BYTES"

# Largest integer float32 accumulates exactly (24-bit mantissa).  Volume /
# modularity sums approach this once m_valid · max-weight nears it: every
# add past 2^24 can round away an entire unit-weight edge, silently biasing
# Q at com-orkut scale (117M directed edges, Table I).
F32_ACCUM_SAFE = 1 << 24

TABLE_MODES = ("auto", "resident", "streamed")

# Table padding / window-offset granularity (the TPU lane width): resident
# table scratch is padded to a multiple of this, and streamed window offsets
# are multiples of the per-bucket slot stride, itself a multiple of this.
TABLE_LANE = 128


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def default_interpret() -> bool:
    """Pallas interpret mode: True unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def vmem_budget_bytes(budget_bytes: int | None = None) -> int:
    """Resolve the per-core VMEM byte budget.

    Precedence: explicit kwarg > REPRO_VMEM_BUDGET_BYTES env var > the
    built-in ~16 MB default.  Read at trace time, so the resident/streamed
    decision and row-block sizing are static per compiled program.
    """
    if budget_bytes is not None:
        b = int(budget_bytes)
    else:
        env = os.environ.get(VMEM_BUDGET_ENV)
        b = int(env) if env else DEFAULT_VMEM_BUDGET_BYTES
    if faultinject.is_active("vmem_starve"):
        # fault injection: collapse the budget so every capacity-adaptive
        # policy (resident/streamed tables, kernel/ref bin rank) lands in
        # its fallback regime — those regimes are bit-identical by the
        # parity contracts, which is exactly what tests/test_faults.py
        # asserts.  Callers arming this fault key their traces on it
        # (EngineSpec.faults), so a clean-cached trace is never reused.
        telemetry.bump("fault.vmem_starve.budget_clamped")
        b = min(b, 1024)
    return b


def accum_needs_promotion(m_cap: int, w_max: float = 1.0) -> bool:
    """Trace-time predicate for the volume/modularity precision guard:
    True when ``m_cap`` edge weights of magnitude ``w_max`` could sum past
    float32's exact-integer range.  Uses the static edge CAPACITY (an upper
    bound on m_valid), so the decision needs no device sync and is part of
    the compiled program's cache key."""
    return float(m_cap) * max(float(w_max), 1.0) >= float(F32_ACCUM_SAFE)


def accum_dtype(promote: bool):
    """Accumulator dtype for volume/modularity sums.

    float64 only when promotion is requested AND x64 is enabled; otherwise
    float32 with a telemetry bump (``numeric.f32_accum_risk``) so the risk
    is observable — the drivers surface it as a ``RunReport`` warning."""
    if not promote:
        return jnp.float32
    if jax.config.jax_enable_x64:
        return jnp.float64
    telemetry.bump("numeric.f32_accum_risk")
    return jnp.float32


def pick_row_block(width: int, budget_elems: int = 1 << 21,
                   max_rows: int = 512) -> int:
    """Rows per block so the (R_blk, W, W) pairwise tensor stays within a
    ~8 MB f32 VMEM budget; sublane-aligned."""
    r = max(1, budget_elems // max(1, width * width))
    r = min(r, max_rows)
    if r >= 8:
        r = (r // 8) * 8
    return r


def pick_row_block_fused(width: int, budget_bytes: int | None = None,
                         table_bytes: int = 0) -> int:
    """Row block for the gather-in-kernel local_move grid.

    Unlike the scored-tile kernels, the fused kernel receives no gathered
    (R_blk, W) input tiles — its per-step VMEM footprint is the (R_blk, W, W)
    pairwise tensor plus whatever table state is resident — so narrow buckets
    can afford much taller blocks.  Fewer grid steps amortize the table
    residency (and, in interpret mode, the per-step dispatch).

    ``table_bytes`` (the resident table scratch, or the streamed double-
    buffered windows) is charged against half the VMEM budget before sizing
    the pairwise tensor; the other half is reserved for Pallas's
    double-buffered tile pipeline.  With the default budget and no tables
    this reduces to the historical ~8 MB pairwise budget.  The pairwise
    budget is floored at budget//8: when the tables ALONE bust the half
    budget the layout cannot fit VMEM no matter the row block (that regime
    is streamed-or-bust), so collapsing to 1-row grid steps would add a
    pathological grid without recovering anything.
    """
    budget = vmem_budget_bytes(budget_bytes)
    avail = max(budget // 2 - table_bytes, budget // 8)
    return pick_row_block(width, max(1, avail // 4), max_rows=2048)


# Static width menu for the cascade's traced coarse-level re-bucketing
# (DESIGN.md §Pipeline).  A small menu keeps the number of distinct compiled
# stage programs bounded: each cascade stage picks ONE width from it.
STAGE_WIDTH_MENU = (16, 64, 256)


def pick_ell_width(max_deg: int | None, n_cap: int, m_cap: int) -> int:
    """Static ELL width for one cascade stage's traced re-bucketing.

    ``max_deg`` is the carried coarse graph's max unweighted degree, read at
    the stage boundary sync; the pick is the smallest menu width covering it
    (no tail pass at stage entry).  Hubs appearing at DEEPER levels inside
    the stage — or exceeding the widest menu entry — fall back to the
    engine's cond-gated edge-list tail, so the width only affects
    performance, never results.  ``max_deg=None`` (stage 0's coarse loop,
    before any boundary sync has run) uses a 4×-average-degree heuristic
    derived from the static stage capacities.
    """
    if max_deg is None:
        max_deg = max(STAGE_WIDTH_MENU[0], (4 * m_cap) // max(1, n_cap))
    for width in STAGE_WIDTH_MENU:
        if max_deg <= width:
            return width
    return STAGE_WIDTH_MENU[-1]


# ------------------------------------------------------------ capacity buckets

# Static capacity menu for the batched many-graph engine (DESIGN.md
# §Serving): doubling steps UP from ego-net-scale floors.  Graphs are
# padded up to the smallest menu capacity that holds them, so the set of
# distinct padded shapes — and with it the set of compiled batch programs —
# grows logarithmically in the largest graph served, not linearly in the
# number of distinct graph sizes.
#
# The step is 2 (not the cascade's shrink=4) and the floors sit well below
# the cascade floors ON PURPOSE: padding is pure wasted compute for every
# lane of a batch (a vmapped sweep touches every padded slot), so the menu
# bounds the waste at <2× worst-case / ~1.4× expected, where a quarter-step
# menu anchored at (256, 2048) inflates ego-net-sized graphs (n≈30-100,
# m≈100-600) by up to an order of magnitude.  The cost of the finer menu is
# only more compiled programs — still logarithmic, still LRU-bounded.
BUCKET_N_FLOOR = 64
BUCKET_M_FLOOR = 256
BUCKET_STEP = 2


def bucket_capacity(x: int, floor: int, step: int = BUCKET_STEP) -> int:
    """Smallest menu capacity >= x, menu = floor · step^k (k >= 0)."""
    if x < 0:
        raise ValueError(f"capacity must be >= 0, got {x}")
    cap = int(floor)
    while cap < x:
        cap *= step
    return cap


class CapacitySignature(NamedTuple):
    """Hashable identity of one compiled batch program (DESIGN.md §Serving).

    Two graphs with equal signatures pack into the same bucket and run under
    the SAME cached compiled program: ``n_cap``/``m_cap`` are the padded
    static capacities (the array shapes), ``ell_width`` the traced-ELL menu
    width those capacities pick (the ell/pallas tile shape), and
    ``schedule`` the capacity schedule the padded graph would cascade
    through — all static trace inputs, so equal signatures imply equal
    traces.
    """

    n_cap: int
    m_cap: int
    ell_width: int
    schedule: tuple


def capacity_signature(n_cap: int, m_cap: int,
                       ell_width: int | None = None,
                       schedule: tuple | None = None) -> CapacitySignature:
    """Bucket a graph's (n_max, m_max) onto the static capacity menu.

    Reuses the existing static menus end to end: capacities quantize onto
    the doubling menu above, ``ell_width``
    defaults to the ``pick_ell_width`` menu pick at the bucket capacities
    (``pick_bin_width`` resolves identically, so the aggregation bin width
    is covered by the same field), and ``schedule`` defaults to the bounded
    ``auto_capacity_schedule`` at the bucket capacities.
    """
    nb = bucket_capacity(int(n_cap), BUCKET_N_FLOOR)
    mb = bucket_capacity(int(m_cap), BUCKET_M_FLOOR)
    if ell_width is None:
        ell_width = pick_ell_width(None, nb, mb)
    if schedule is None:
        # late import: core.louvain imports this module at load time
        from repro.core.louvain import auto_capacity_schedule

        schedule = auto_capacity_schedule(nb, mb)
    return CapacitySignature(nb, mb, int(ell_width), tuple(schedule))


# ------------------------------------------------------- distributed capacity

# Per-shard partial-coarsen capacity floor: below this the fixed-cost terms
# (collective latency, program dispatch) dominate any memory win, so shards
# never shrink their partial-edge buffers past it.
HALO_CAP_FLOOR = 256


def pick_halo_cap(m_pad: int, n_devices: int) -> int:
    """Static per-shard capacity for partial coarse edge lists (DESIGN.md §6).

    Each device's partial coarsening of its local shard emits at most
    ``m_pad`` distinct (community, community) edges, but real graphs shrink
    ≥4× per level; half the shard capacity is a comfortable bound with a 2×
    memory/communication win.  The merged coarse capacity is then
    ``n_devices · cap`` — the gathered partial lists — which replaces the
    replicated ``n_devices · m_pad`` edge list.  Overflow past the cap is
    detected on device (a psum'd flag) and handled by the host degradation
    ladder (retry with replicated coarsening), so the cap affects memory and
    communication, never results.  Sublane-aligned like ``m_pad`` itself.
    """
    if m_pad <= 0 or n_devices <= 0:
        raise ValueError(f"need positive m_pad/n_devices, got {m_pad}/{n_devices}")
    cap = max(HALO_CAP_FLOOR, m_pad // 2)
    # never exceed the shard capacity itself: partial lists are static
    # [:cap] slices of m_pad-length buffers
    return min(int(m_pad), int(cdiv(cap, 8) * 8))


# Wire-format byte widths for the comm model: one edge is (src:int32,
# dst:int32, w:float32) plus a 1-byte validity mask; one label word is int32.
EDGE_WIRE_BYTES = 13
LABEL_WIRE_BYTES = 4


def dist_comm_bytes_per_level(n: int, m_pad: int, h_cap: int,
                              n_devices: int) -> dict:
    """Analytic per-level collective payload (bytes) of both coarsening modes.

    ``replicated`` moves the full padded edge list to every device once
    (the gather-then-replicate baseline: D·m_pad edges on the wire);
    ``shard_local`` moves only the two-phase contiguization table (n label
    words + D stripe counts) and the gathered partial coarse lists
    (D·h_cap edges) — O(boundary + communities), not O(m).
    """
    return {
        "replicated": n_devices * m_pad * EDGE_WIRE_BYTES,
        "shard_local": (n * LABEL_WIRE_BYTES
                        + n_devices * LABEL_WIRE_BYTES
                        + n_devices * h_cap * EDGE_WIRE_BYTES),
    }


# ---------------------------------------------------------------- aggregation

BIN_IMPLS = ("auto", "kernel", "ref")


def pick_bin_width(n_cap: int, m_cap: int) -> int:
    """Static per-src-community bin-row width for the sort-free aggregation
    (DESIGN.md §Aggregation kernel).

    Rows hold the DISTINCT destination communities of one source community,
    so the width must cover the coarse graph's out-degree, which is unknown
    at trace time; the pick reuses the cascade's 4×-average-degree heuristic
    over the STAGE capacities (same menu as the traced ELL re-bucketing, so
    the number of distinct compiled programs stays bounded).  Rows that
    exceed the width at runtime fall back to the one-sort path via a
    ``lax.cond`` gate — the width only affects performance, never results.
    """
    return pick_ell_width(None, n_cap, m_cap)


def bin_table_bytes(n_cap: int, width: int) -> int:
    """HBM/VMEM footprint of the (n_cap+1, width) int32 bin-key table (the
    +1 row is the sink for masked edges)."""
    return 4 * (n_cap + 1) * width


def resolve_bin_impl(impl: str, table_bytes: int,
                     budget_bytes: int | None = None) -> str:
    """Kernel-vs-ref policy for the binned aggregation rank pass.

    ``auto`` uses the Pallas kernel when running on a real TPU AND the bin
    table fits HALF the VMEM budget (the resident-table contract of
    DESIGN.md §Kernels — the other half covers the gathered (R_blk, W)
    tiles and the double-buffered pipeline); otherwise the pure-jnp ref
    path runs (interpret-mode emulation would only add per-grid-step
    dispatch overhead off-TPU, and an over-budget table cannot be resident).
    """
    if impl not in BIN_IMPLS:
        raise ValueError(f"unknown bin impl {impl!r}, want one of {BIN_IMPLS}")
    if impl != "auto":
        return impl
    if table_bytes > vmem_budget_bytes(budget_bytes) // 2:
        return "ref"
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def resolve_table_mode(mode: str, table_bytes: int,
                       budget_bytes: int | None = None) -> str:
    """Resident-vs-streamed policy for the local_move per-vertex tables.

    ``auto`` keeps the tables VMEM-resident while they fit HALF the VMEM
    budget (the other half covers the pairwise tensor and the
    double-buffered tile pipeline) and streams per-block windows beyond
    that: resident  iff  table_bytes <= vmem_budget_bytes() // 2.
    """
    if mode not in TABLE_MODES:
        raise ValueError(f"unknown table_mode {mode!r}, want one of {TABLE_MODES}")
    if mode != "auto":
        return mode
    return ("resident" if table_bytes <= vmem_budget_bytes(budget_bytes) // 2
            else "streamed")


def hash_u32_jnp(x: jax.Array) -> jax.Array:
    """splitmix32 avalanche — identical to core.common.hash_u32 (kept local so
    kernels do not import the algorithm layer)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def tie_noise_jnp(a: jax.Array, b: jax.Array, seed: jax.Array, eps: float) -> jax.Array:
    h = hash_u32_jnp(
        a.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
        ^ hash_u32_jnp(b.astype(jnp.uint32) + seed.astype(jnp.uint32))
    )
    return h.astype(jnp.float32) * jnp.float32(eps / 4294967296.0)
