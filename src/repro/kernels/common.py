"""Shared kernel utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def default_interpret() -> bool:
    """Pallas interpret mode: True unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def pick_row_block(width: int, budget_elems: int = 1 << 21,
                   max_rows: int = 512) -> int:
    """Rows per block so the (R_blk, W, W) pairwise tensor stays within a
    ~8 MB f32 VMEM budget; sublane-aligned."""
    r = max(1, budget_elems // max(1, width * width))
    r = min(r, max_rows)
    if r >= 8:
        r = (r // 8) * 8
    return r


def pick_row_block_fused(width: int, budget_elems: int = 1 << 21) -> int:
    """Row block for the gather-in-kernel local_move grid.

    Unlike the scored-tile kernels, the fused kernel receives no gathered
    (R_blk, W) input tiles — its per-step VMEM footprint is the neighbor tile
    plus the shared table scratch — so narrow buckets can afford much taller
    blocks under the same (R_blk, W, W) pairwise budget.  Fewer grid steps
    amortize the table residency (and, in interpret mode, the per-step
    dispatch) across the whole bucket."""
    return pick_row_block(width, budget_elems, max_rows=2048)


def hash_u32_jnp(x: jax.Array) -> jax.Array:
    """splitmix32 avalanche — identical to core.common.hash_u32 (kept local so
    kernels do not import the algorithm layer)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def tie_noise_jnp(a: jax.Array, b: jax.Array, seed: jax.Array, eps: float) -> jax.Array:
    h = hash_u32_jnp(
        a.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
        ^ hash_u32_jnp(b.astype(jnp.uint32) + seed.astype(jnp.uint32))
    )
    return h.astype(jnp.float32) * jnp.float32(eps / 4294967296.0)
