"""Pure-jnp oracle for the Louvain Δ𝑄 local-moving kernel (Eq. 1).

Per row r (one vertex v, ELL tile of width W; candidate j is the community of
neighbor j):

  S(c)        = Σ_k w[r,k] · [cand[r,k] == c]          (= cut_w(v, c))
  S_A         = S(cur_com[r])                          (= cut_w(v, A⁻))
  vol(B⁻)     = vol_cand[r,j] − [cand==A]·deg_v[r]
  vol(A⁻)     = vol_cur[r] − deg_v[r]
  gain(j)     = (S(cand_j) − S_A) − deg_v·(vol(B⁻) − vol(A⁻))/vol_total
  Δ𝑄          = 2·gain/vol_total   (move iff gain > 0)

Lu–Halappanavar rule: candidate suppressed when both communities are
singletons and cand > cur.  Argmax tie-break: smallest candidate id —
identical semantics to ``core.moves.louvain_best_moves``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def delta_q_ref(
    cand_com: jax.Array,   # (R, W) int32 (sentinel where padded)
    nbr_w: jax.Array,      # (R, W) float32
    cur_com: jax.Array,    # (R,) int32
    deg_v: jax.Array,      # (R,) float32
    vol_cand: jax.Array,   # (R, W) float32  volCom[cand]
    vol_cur: jax.Array,    # (R,) float32    volCom[cur]
    size_cand: jax.Array,  # (R, W) int32    |cand community|
    size_cur: jax.Array,   # (R,) int32
    inv_vol_total: jax.Array,  # f32 scalar (1 / vol(V))
    sentinel: int,
    singleton_rule: bool,
) -> Tuple[jax.Array, jax.Array]:
    valid = cand_com != sentinel
    eq = cand_com[:, :, None] == cand_com[:, None, :]
    S = jnp.sum(jnp.where(eq, nbr_w[:, :, None], 0.0), axis=1)        # (R, W)
    eqA = valid & (cand_com == cur_com[:, None])
    S_A = jnp.sum(jnp.where(eqA, nbr_w, 0.0), axis=1)                  # (R,)

    is_A = cand_com == cur_com[:, None]
    vol_B_minus = vol_cand - jnp.where(is_A, deg_v[:, None], 0.0)
    vol_A_minus = (vol_cur - deg_v)[:, None]
    gain = (S - S_A[:, None]) - deg_v[:, None] * (
        (vol_B_minus - vol_A_minus) * inv_vol_total
    )

    if singleton_rule:
        both_single = (size_cur[:, None] == 1) & (size_cand == 1)
        gain = jnp.where(both_single & (cand_com > cur_com[:, None]), -jnp.inf, gain)

    eff = jnp.where(valid & ~is_A, gain, -jnp.inf)
    best_gain = jnp.max(eff, axis=1)
    is_best = (eff == best_gain[:, None]) & valid
    best_cand = jnp.min(jnp.where(is_best, cand_com, sentinel), axis=1)
    best_cand = jnp.where(best_gain > -jnp.inf, best_cand, -1)
    return best_cand, best_gain
