"""Public wrapper for the delta_q kernel (pallas/oracle dispatch).

A plain jit-safe function, deliberately NOT wrapped in ``jax.jit``: it is
called inside the already-jitted sweep loop, where a nested jit adds
trace/dispatch overhead and blocks fusion with the surrounding gather and
scatter code.  Eager callers (tests, notebooks) pay one trace per call —
wrap in ``jax.jit`` at the call site if that matters.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.delta_q.kernel import delta_q_pallas
from repro.kernels.delta_q.ref import delta_q_ref


def delta_q_argmax(
    cand_com: jax.Array,
    nbr_w: jax.Array,
    cur_com: jax.Array,
    deg_v: jax.Array,
    vol_cand: jax.Array,
    vol_cur: jax.Array,
    size_cand: jax.Array,
    size_cur: jax.Array,
    vol_total: jax.Array,
    *,
    sentinel: int,
    singleton_rule: bool = True,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """(best_community, best_gain) per row; gain is Eq. 1 / vol(V)."""
    cand_com = cand_com.astype(jnp.int32)
    nbr_w = nbr_w.astype(jnp.float32)
    cur_com = cur_com.astype(jnp.int32)
    deg_v = deg_v.astype(jnp.float32)
    vol_cand = vol_cand.astype(jnp.float32)
    vol_cur = vol_cur.astype(jnp.float32)
    size_cand = size_cand.astype(jnp.int32)
    size_cur = size_cur.astype(jnp.int32)
    inv_vol = (1.0 / vol_total).astype(jnp.float32)
    if use_pallas:
        interp = default_interpret() if interpret is None else interpret
        return delta_q_pallas(
            cand_com, nbr_w, cur_com, deg_v, vol_cand, vol_cur,
            size_cand, size_cur, inv_vol,
            sentinel=sentinel, singleton_rule=singleton_rule, interpret=interp,
        )
    return delta_q_ref(
        cand_com, nbr_w, cur_com, deg_v, vol_cand, vol_cur,
        size_cand, size_cur, inv_vol,
        sentinel=sentinel, singleton_rule=singleton_rule,
    )
