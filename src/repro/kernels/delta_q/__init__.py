from repro.kernels.delta_q import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
