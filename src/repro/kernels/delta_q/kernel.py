"""Pallas TPU kernel: fused Louvain Δ𝑄 evaluation + argmax (Alg. 2 l.13-16).

The paper evaluates Δ𝑄 per neighboring community with nested parallel loops
over a hash map of community→cut weights.  TPU version: the per-vertex cut
S(c) comes from the same W×W pairwise-equality reduction as label_argmax, and
the full Eq. 1 gain (volume terms gathered into the tile beforehand) plus the
Lu singleton rule and the argmax are fused into one VMEM-resident pass —
one kernel launch per degree bucket instead of per-vertex hash maps.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_row_block


def _delta_q_kernel(
    cand_ref,      # (R_blk, W) int32
    w_ref,         # (R_blk, W) float32
    volc_ref,      # (R_blk, W) float32
    sizec_ref,     # (R_blk, W) int32
    cur_ref,       # (R_blk, 1) int32
    deg_ref,       # (R_blk, 1) float32
    volcur_ref,    # (R_blk, 1) float32
    sizecur_ref,   # (R_blk, 1) int32
    invvol_ref,    # (1, 1) float32
    out_cand_ref,  # (R_blk, 1) int32
    out_gain_ref,  # (R_blk, 1) float32
    *,
    sentinel: int,
    singleton_rule: bool,
):
    cand = cand_ref[...]
    w = w_ref[...]
    vol_cand = volc_ref[...]
    size_cand = sizec_ref[...]
    cur = cur_ref[...][:, 0]
    deg = deg_ref[...][:, 0]
    vol_cur = volcur_ref[...][:, 0]
    size_cur = sizecur_ref[...][:, 0]
    inv_vol = invvol_ref[0, 0]

    valid = cand != sentinel
    eq = cand[:, :, None] == cand[:, None, :]
    S = jnp.sum(jnp.where(eq, w[:, :, None], 0.0), axis=1)
    is_A = cand == cur[:, None]
    S_A = jnp.sum(jnp.where(valid & is_A, w, 0.0), axis=1)

    vol_B_minus = vol_cand - jnp.where(is_A, deg[:, None], 0.0)
    vol_A_minus = (vol_cur - deg)[:, None]
    gain = (S - S_A[:, None]) - deg[:, None] * ((vol_B_minus - vol_A_minus) * inv_vol)

    if singleton_rule:
        both_single = (size_cur[:, None] == 1) & (size_cand == 1)
        gain = jnp.where(both_single & (cand > cur[:, None]), -jnp.inf, gain)

    eff = jnp.where(valid & ~is_A, gain, -jnp.inf)
    best_gain = jnp.max(eff, axis=1)
    is_best = (eff == best_gain[:, None]) & valid
    best_cand = jnp.min(jnp.where(is_best, cand, sentinel), axis=1)
    best_cand = jnp.where(best_gain > -jnp.inf, best_cand, -1)

    out_cand_ref[...] = best_cand[:, None]
    out_gain_ref[...] = best_gain[:, None]


def delta_q_pallas(
    cand_com: jax.Array,
    nbr_w: jax.Array,
    cur_com: jax.Array,
    deg_v: jax.Array,
    vol_cand: jax.Array,
    vol_cur: jax.Array,
    size_cand: jax.Array,
    size_cur: jax.Array,
    inv_vol_total: jax.Array,
    sentinel: int,
    singleton_rule: bool,
    interpret: bool = True,
    row_block: int | None = None,
) -> Tuple[jax.Array, jax.Array]:
    R, W = cand_com.shape
    r_blk = row_block or min(pick_row_block(W), R)
    pad = (-R) % r_blk
    if pad:
        cand_com = jnp.pad(cand_com, ((0, pad), (0, 0)), constant_values=sentinel)
        nbr_w = jnp.pad(nbr_w, ((0, pad), (0, 0)))
        vol_cand = jnp.pad(vol_cand, ((0, pad), (0, 0)))
        size_cand = jnp.pad(size_cand, ((0, pad), (0, 0)))
        cur_com = jnp.pad(cur_com, (0, pad), constant_values=sentinel)
        deg_v = jnp.pad(deg_v, (0, pad))
        vol_cur = jnp.pad(vol_cur, (0, pad))
        size_cur = jnp.pad(size_cur, (0, pad))
    Rp = R + pad

    kern = functools.partial(
        _delta_q_kernel, sentinel=sentinel, singleton_rule=singleton_rule
    )
    wide = lambda: pl.BlockSpec((r_blk, W), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((r_blk, 1), lambda i: (i, 0))
    out_cand, out_gain = pl.pallas_call(
        kern,
        grid=(Rp // r_blk,),
        in_specs=[
            wide(), wide(), wide(), wide(),
            col(), col(), col(), col(),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[col(), col()],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        cand_com,
        nbr_w,
        vol_cand,
        size_cand,
        cur_com[:, None],
        deg_v[:, None],
        vol_cur[:, None],
        size_cur[:, None],
        jnp.asarray(inv_vol_total, jnp.float32).reshape(1, 1),
    )
    return out_cand[:R, 0], out_gain[:R, 0]
