"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §2).

Three kernels, each the TPU-native re-derivation of a phase the paper
parallelizes on CPU threads:

* ``label_argmax`` — PLP move (Alg. 1 l.18): per-vertex weighted label mode
  over degree-bucketed ELL tiles, via a W×W pairwise-equality reduction in
  VMEM (replaces the per-thread hash map).
* ``delta_q`` — Louvain local-moving (Alg. 2 l.13-16): fused Eq. 1 gain +
  argmax over neighboring communities on the same tiles.
* ``segment_sum`` — aggregation GroupBy reduce (Alg. 3): block-segmented sums
  over sorted keys with an O(num_blocks) spine fix-up (replaces scatter-add).

Layout: <name>/kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
pallas/oracle dispatch), ref.py (pure-jnp oracle).
"""
from repro.kernels import label_argmax, delta_q, segment_sum

__all__ = ["label_argmax", "delta_q", "segment_sum"]
