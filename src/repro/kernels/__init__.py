"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §2, §Kernels).

Four kernels, each the TPU-native re-derivation of a phase the paper
parallelizes on CPU threads:

* ``local_move`` — the fused local-moving hot path (Alg. 1 l.18 / Alg. 2
  l.13-16): per-neighbor table gathers + PLP label mode / Louvain Eq. 1
  argmax in ONE kernel, tables resident in the ANY memory space, one grid
  over all chunks of a degree bucket.  This is what the sweep engine runs.
* ``label_argmax`` — PLP move scoring only: per-vertex weighted label mode
  over pre-gathered ELL tiles, via a W×W pairwise-equality reduction in
  VMEM (replaces the per-thread hash map).
* ``delta_q`` — Louvain Δ𝑄 scoring only: fused Eq. 1 gain + argmax over
  pre-gathered candidate tiles.
* ``segment_sum`` — aggregation GroupBy reduce (Alg. 3): block-segmented sums
  over sorted keys with an O(num_blocks) spine fix-up (replaces scatter-add).

``label_argmax``/``delta_q`` are kept as the scored-tile building blocks for
the gather_fusion benchmark baseline and standalone use; the engine routes
through ``local_move``.

Layout: <name>/kernel.py (pl.pallas_call + BlockSpec), ops.py (dispatch
wrapper, pallas/oracle), ref.py (pure-jnp oracle).
"""
from repro.kernels import label_argmax, delta_q, local_move, segment_sum

__all__ = ["label_argmax", "delta_q", "local_move", "segment_sum"]
