"""Decoder-only / encoder-decoder transformer stack (dense, MoE, VLM, audio).

Covers: qwen3-8b, qwen3-1.7b, nemotron-4-340b, phi3-medium-14b (dense),
qwen3-moe-30b-a3b, llama4-maverick-400b-a17b (moe), llama-3.2-vision-11b
(vlm: cross-attn layers over stub patch embeddings), whisper-large-v3
(audio: encoder + causal decoder with cross-attn, stub conv frontend).

Implementation idioms (MaxText-style):
  * homogeneous layers are STACKED (leading L dim) and iterated with
    ``jax.lax.scan`` — keeps the HLO size O(1) in depth, which is what makes
    96-layer dry-run compiles tractable;
  * every layer body is wrapped in ``jax.checkpoint`` (policy per config) so
    train-time activation memory is L × (layer-boundary residual) only;
  * sharding is expressed through *logical axis names* resolved against the
    active mesh by ``repro.launch.sharding`` (no-op when no mesh is active, so
    the same code runs CPU smoke tests and 512-chip dry-runs);
  * KV caches live in (L, B, H_kv_eff, S, hd) stacked form and are scanned in
    lock-step with the layer stack; optional int8 quantization halves cache
    bytes for the 32k/500k decode cells.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.arch_config import ArchConfig
from repro.models.common import (
    ParamDecl, apply_rope, cast_compute, cross_entropy_loss, gelu_mlp,
    layer_norm, rms_norm, squared_relu_mlp, swiglu,
)
from repro.launch.sharding import constrain

P = ParamDecl


# --------------------------------------------------------------- declarations


def _attn_decls(c: ArchConfig, L: int, d_in: int | None = None) -> Dict[str, P]:
    d = d_in or c.d_model
    hd, hq, hkv = c.hd, c.n_heads, c.n_kv_heads
    out: Dict[str, P] = {
        "wq": P((L, d, hq * hd), ("layers", "embed", "heads")),
        "wk": P((L, d, hkv * hd), ("layers", "embed", None)),
        "wv": P((L, d, hkv * hd), ("layers", "embed", None)),
        "wo": P((L, hq * hd, c.d_model), ("layers", "heads", "embed")),
    }
    if c.qk_norm:
        out["q_norm"] = P((L, hd), ("layers", None), init="zeros")
        out["k_norm"] = P((L, hd), ("layers", None), init="zeros")
    return out


def _ffn_decls(c: ArchConfig, L: int, d_ff: int, prefix: str = "") -> Dict[str, P]:
    d = c.d_model
    if c.activation == "swiglu":
        return {
            prefix + "w_gate": P((L, d, d_ff), ("layers", "embed", "mlp")),
            prefix + "w_up": P((L, d, d_ff), ("layers", "embed", "mlp")),
            prefix + "w_down": P((L, d_ff, d), ("layers", "mlp", "embed")),
        }
    if c.activation == "squared_relu":
        return {
            prefix + "w_up": P((L, d, d_ff), ("layers", "embed", "mlp")),
            prefix + "w_down": P((L, d_ff, d), ("layers", "mlp", "embed")),
        }
    # gelu (whisper)
    return {
        prefix + "w_up": P((L, d, d_ff), ("layers", "embed", "mlp")),
        prefix + "b_up": P((L, d_ff), ("layers", "mlp"), init="zeros"),
        prefix + "w_down": P((L, d_ff, d), ("layers", "mlp", "embed")),
        prefix + "b_down": P((L, d), ("layers", "embed"), init="zeros"),
    }


def _moe_decls(c: ArchConfig, L: int) -> Dict[str, P]:
    d, e, f = c.d_model, c.n_experts, c.d_ff_expert
    out = {
        "w_router": P((L, d, e), ("layers", "embed", None), dtype=jnp.float32),
        "we_gate": P((L, e, d, f), ("layers", "experts", "embed", None)),
        "we_up": P((L, e, d, f), ("layers", "experts", "embed", None)),
        "we_down": P((L, e, f, d), ("layers", "experts", None, "embed")),
    }
    if c.shared_expert:
        out.update(_ffn_decls(
            dataclasses.replace(c, activation="swiglu"), L, c.d_ff_shared, "shared_"))
    return out


def _norm_decls(c: ArchConfig, L: int, names: Tuple[str, ...]) -> Dict[str, P]:
    d = c.d_model
    out: Dict[str, P] = {}
    for nm in names:
        out[nm] = P((L, d), ("layers", None), init="zeros")
        if c.norm == "layer":
            out[nm + "_b"] = P((L, d), ("layers", None), init="zeros")
    return out


def _block_decls(c: ArchConfig, L: int, *, moe: bool) -> Dict[str, P]:
    out = dict(_attn_decls(c, L))
    out.update(_norm_decls(c, L, ("ln1", "ln2")))
    if moe:
        out.update(_moe_decls(c, L))
    else:
        out.update(_ffn_decls(c, L, c.d_ff))
    return out


def _cross_decls(c: ArchConfig, L: int) -> Dict[str, P]:
    """Cross-attention block (VLM gated variant / whisper decoder)."""
    out = {("x_" + k): v for k, v in _attn_decls(c, L).items()}
    out.update(_norm_decls(c, L, ("x_ln",)))
    if c.family == "vlm":
        # llama-3.2 style gated cross-attn + its own gated FFN
        out["x_attn_gate"] = P((L,), ("layers",), init="zeros")
        out["x_mlp_gate"] = P((L,), ("layers",), init="zeros")
        out.update({("x_" + k): v for k, v in _ffn_decls(c, L, c.d_ff).items()})
        out.update(_norm_decls(c, L, ("x_ln_mlp",)))
    return out


def build_decls(c: ArchConfig) -> Dict[str, Any]:
    """Full parameter declaration tree for dense/moe/vlm/audio families."""
    d, v = c.d_model, c.vocab_size
    out: Dict[str, Any] = {
        "embed": P((v, d), ("vocab", "embed"), init="embed"),
        "final_norm": P((d,), (None,), init="zeros"),
    }
    if c.norm == "layer":
        out["final_norm_b"] = P((d,), (None,), init="zeros")
    if not c.tie_embeddings:
        out["unembed"] = P((d, v), ("embed", "vocab"))

    if c.family in ("dense",):
        out["layers"] = _block_decls(c, c.n_layers, moe=False)
    elif c.family == "moe":
        if c.moe_every == 1:
            out["layers"] = _block_decls(c, c.n_layers, moe=True)
        else:  # llama4: alternating dense / moe pairs
            n_pairs = c.n_layers // 2
            out["dense_layers"] = _block_decls(c, n_pairs, moe=False)
            out["moe_layers"] = _block_decls(c, n_pairs, moe=True)
    elif c.family == "vlm":
        out["layers"] = _block_decls(c, c.n_layers, moe=False)
        n_cross = c.n_layers // c.cross_attn_every
        out["cross"] = _cross_decls(c, n_cross)
    elif c.family == "audio":
        out["enc_layers"] = _block_decls(c, c.n_enc_layers, moe=False)
        out["dec_layers"] = _block_decls(c, c.n_layers, moe=False)
        out["dec_cross"] = _cross_decls(c, c.n_layers)
        out["enc_final_norm"] = P((d,), (None,), init="zeros")
        out["enc_final_norm_b"] = P((d,), (None,), init="zeros")
    else:
        raise ValueError(f"transformer.build_decls: unsupported family {c.family}")
    return out


# --------------------------------------------------------------- layer bodies


def _norm(c: ArchConfig, p, x, name: str):
    if c.norm == "layer":
        return layer_norm(x, 1.0 + p[name], p[name + "_b"])
    return rms_norm(x, p[name])


def _project_qkv(c: ArchConfig, p, x, positions, prefix: str = "",
                 rope: bool = True, kv_from: Optional[jax.Array] = None):
    """Project to (B,H,S,hd) with qk-norm + RoPE; KV repeated to kv_eff."""
    hd, hq, hkv = c.hd, c.n_heads, c.n_kv_heads
    kv_src = x if kv_from is None else kv_from
    b, sq = x.shape[0], x.shape[1]
    sk = kv_src.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p[prefix + "wq"]).reshape(b, sq, hq, hd)
    k = jnp.einsum("bsd,dh->bsh", kv_src, p[prefix + "wk"]).reshape(b, sk, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", kv_src, p[prefix + "wv"]).reshape(b, sk, hkv, hd)
    if c.qk_norm:
        q = rms_norm(q, p[prefix + "q_norm"])
        k = rms_norm(k, p[prefix + "k_norm"])
    q = q.transpose(0, 2, 1, 3)  # (B,H,S,hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if rope:
        q = apply_rope(q, positions, c.rope_theta)
        kpos = positions if kv_from is None else jnp.arange(sk)
        k = apply_rope(k, kpos, c.rope_theta)
    reps = c.kv_eff // hkv
    k = attn.repeat_kv(k, reps)
    v = attn.repeat_kv(v, reps)
    q = constrain(q, ("batch", "heads_act", None, None))
    k = constrain(k, ("batch", "heads_act", None, None))
    v = constrain(v, ("batch", "heads_act", None, None))
    return q, k, v


def _self_attn(c: ArchConfig, p, x, positions, causal=True):
    q, k, v = _project_qkv(c, p, x, positions)
    o = attn.flash_attention(q, k, v, causal=causal, chunk=min(1024, q.shape[2]))
    b, _, s, _ = q.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, s, c.n_heads * c.hd)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def _ffn(c: ArchConfig, p, x, prefix: str = "", d_ff: int | None = None):
    if c.activation == "swiglu" or prefix == "shared_":
        return swiglu(x, p[prefix + "w_gate"], p[prefix + "w_up"], p[prefix + "w_down"])
    if c.activation == "squared_relu":
        return squared_relu_mlp(x, p[prefix + "w_up"], p[prefix + "w_down"])
    return gelu_mlp(x, p[prefix + "w_up"], p[prefix + "b_up"],
                    p[prefix + "w_down"], p[prefix + "b_down"])


def _moe_ffn(c: ArchConfig, p, x):
    out = moe_lib.moe_layer(
        x, p["w_router"], p["we_gate"], p["we_up"], p["we_down"],
        top_k=c.top_k, capacity_factor=c.capacity_factor,
    )
    y = out.y
    if c.shared_expert:
        y = y + swiglu(x, p["shared_w_gate"], p["shared_w_up"], p["shared_w_down"])
    return y, out.aux_loss


def _block(c: ArchConfig, p, x, positions, *, moe: bool, causal: bool = True):
    """Pre-norm transformer block; returns (x, aux_loss)."""
    h1 = _norm(c, p, x, "ln1")
    if c.shard_residual_embed:
        # Megatron-SP pattern: ALL-GATHER the (smaller) normed input before
        # the projections rather than letting XLA psum the (larger) projected
        # outputs — §Perf iteration "sp-allgather".
        h1 = constrain(h1, ("batch", None, None))
    x = x + _self_attn(c, p, h1, positions, causal=causal)
    x = constrain(x, ("batch", None, "embed_act"))
    h = _norm(c, p, x, "ln2")
    if c.shard_residual_embed:
        h = constrain(h, ("batch", None, None))
    if moe:
        y, aux = _moe_ffn(c, p, h)
    else:
        y, aux = _ffn(c, p, h), jnp.float32(0.0)
    x = x + y
    return constrain(x, ("batch", None, "embed_act")), aux


def _cross_block(c: ArchConfig, p, x, kv_feats):
    """Cross-attention (+ gated FFN for VLM) over precomputed features."""
    h = _norm(c, p, x, "x_ln")
    q, k, v = _project_qkv(c, p, h, jnp.arange(h.shape[1]), prefix="x_",
                           rope=False, kv_from=kv_feats)
    o = attn.full_attention(q, k, v, causal=False)
    b, _, s, _ = q.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, s, c.n_heads * c.hd)
    o = jnp.einsum("bsh,hd->bsd", o, p["x_wo"])
    if c.family == "vlm":
        x = x + jnp.tanh(p["x_attn_gate"]).astype(x.dtype) * o
        m = _ffn(c, p, _norm(c, p, x, "x_ln_mlp"), prefix="x_")
        x = x + jnp.tanh(p["x_mlp_gate"]).astype(x.dtype) * m
    else:
        x = x + o
    return constrain(x, ("batch", None, "embed_act"))


def _ckpt_policy(c: ArchConfig):
    if c.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if c.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.everything_saveable


def _scan_blocks(c: ArchConfig, stacked, x, positions, *, moe: bool, causal=True):
    """lax.scan over a stacked layer tree; accumulates MoE aux loss."""
    def body(carry, layer_p):
        h, aux = carry
        h, a = _block(c, cast_compute(layer_p), h, positions, moe=moe,
                      causal=causal)
        return (h, aux + a), None

    body = jax.checkpoint(body, policy=_ckpt_policy(c), prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


# --------------------------------------------------------------- full forward


def forward(c: ArchConfig, params, tokens, *, img_embeds=None, enc_embeds=None):
    """Training/prefill forward -> logits (B, S, V).

    tokens: (B, S) int32.  img_embeds: (B, n_img, D) for vlm.
    enc_embeds: (B, n_frames, D) stub frame embeddings for audio.
    """
    x = params["embed"][tokens].astype(jnp.bfloat16)
    x = constrain(x, ("batch", None, "embed_act"))
    positions = jnp.arange(tokens.shape[1])
    aux = jnp.float32(0.0)

    if c.family == "dense":
        x, aux = _scan_blocks(c, params["layers"], x, positions, moe=False)
    elif c.family == "moe":
        if c.moe_every == 1:
            x, aux = _scan_blocks(c, params["layers"], x, positions, moe=True)
        else:
            def pair_body(carry, lp):
                h, a = carry
                lp = cast_compute(lp)
                h, a1 = _block(c, lp["dense"], h, positions, moe=False)
                h, a2 = _block(c, lp["moe"], h, positions, moe=True)
                return (h, a + a1 + a2), None
            pair_body = jax.checkpoint(pair_body, policy=_ckpt_policy(c),
                                       prevent_cse=False)
            stacked = {"dense": params["dense_layers"], "moe": params["moe_layers"]}
            (x, aux), _ = jax.lax.scan(pair_body, (x, aux), stacked)
    elif c.family == "vlm":
        every = c.cross_attn_every
        n_groups = c.n_layers // every
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])

        def group_body(carry, gp):
            h, a = carry
            gp = cast_compute(gp)
            h = _cross_block(c, gp["cross"], h, img_embeds)
            for i in range(every):
                lp = jax.tree.map(lambda t: t[i], gp["self"])
                h, a1 = _block(c, lp, h, positions, moe=False)
                a = a + a1
            return (h, a), None

        group_body = jax.checkpoint(group_body, policy=_ckpt_policy(c),
                                    prevent_cse=False)
        stacked = {"self": grouped, "cross": params["cross"]}
        (x, aux), _ = jax.lax.scan(group_body, (x, aux), stacked)
    elif c.family == "audio":
        enc = encode_audio(c, params, enc_embeds)
        x, aux = _dec_scan(c, params, x, positions, enc)
    else:
        raise ValueError(c.family)

    x = rms_norm(x, params["final_norm"]) if c.norm == "rms" else layer_norm(
        x, 1.0 + params["final_norm"], params["final_norm_b"])
    unembed = params["embed"].T if c.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(x.dtype))
    return constrain(logits, ("batch", None, "vocab_act")), aux


def encode_audio(c: ArchConfig, params, enc_embeds):
    """Whisper encoder over stub frame embeddings (+ sinusoidal positions)."""
    s = enc_embeds.shape[1]
    x = enc_embeds.astype(jnp.bfloat16) + _sinusoid(s, c.d_model).astype(jnp.bfloat16)
    x = constrain(x, ("batch", None, "embed_act"))
    x, _ = _scan_blocks(c, params["enc_layers"], x, jnp.arange(s),
                        moe=False, causal=False)
    return layer_norm(x, 1.0 + params["enc_final_norm"], params["enc_final_norm_b"])


def _dec_scan(c: ArchConfig, params, x, positions, enc_out):
    def body(carry, lp):
        h, a = carry
        lp = cast_compute(lp)
        h, a1 = _block(c, lp["self"], h, positions, moe=False)
        h = _cross_block(c, lp["cross"], h, enc_out)
        return (h, a + a1), None
    body = jax.checkpoint(body, policy=_ckpt_policy(c), prevent_cse=False)
    stacked = {"self": params["dec_layers"], "cross": params["dec_cross"]}
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def _sinusoid(length: int, channels: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(channels // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(1, channels // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------- loss


def loss_fn(c: ArchConfig, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(
        c, params, batch["tokens"],
        img_embeds=batch.get("img_embeds"), enc_embeds=batch.get("enc_embeds"))
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------- KV cache


class KVCache(NamedTuple):
    k: jax.Array          # (L, B, H_eff, S, hd) — int8 or bf16
    v: jax.Array
    k_scale: Optional[jax.Array]  # (L, B, H_eff, S, 1) f32 when int8
    v_scale: Optional[jax.Array]
    pos: jax.Array        # (B,) int32 — PER-SLOT filled length (vLLM-style)


def init_cache(c: ArchConfig, n_layers: int, batch: int, max_seq: int) -> KVCache:
    shape = (n_layers, batch, c.kv_eff, max_seq, c.hd)
    pos0 = jnp.zeros((batch,), jnp.int32)
    if c.kv_cache_dtype == "int8":
        z8 = jnp.zeros(shape, jnp.int8)
        sc = jnp.zeros(shape[:-1] + (1,), jnp.float32)
        return KVCache(z8, z8, sc, sc, pos0)
    z = jnp.zeros(shape, jnp.bfloat16)
    return KVCache(z, z, None, None, pos0)


def _quant(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dus_per_slot(cache, new, pos):
    """Per-slot write: cache (B,H,S,..), new (B,H,1,..), pos (B,) int32.

    Expressed as a one-hot ``where`` in the cache dtype rather than a vmapped
    dynamic-update-slice: XLA lowers the latter to an f32 scatter plus
    full-stack dtype round-trips (measured 0.44 s of the 0.58 s decode memory
    term — §Perf iteration "decode-onehot-write"); the where-form stays in
    bf16/int8 and fuses into the cache read."""
    s = cache.shape[2]
    onehot = jnp.arange(s, dtype=jnp.int32)[None, :] == pos[:, None]  # (B,S)
    m = onehot[:, None, :, None]
    return jnp.where(m, new.astype(cache.dtype), cache)


def _cache_write(cache_k, cache_v, sk, sv, k_new, v_new, pos):
    """Write (B,H,1,hd) into per-layer cache slices at per-slot ``pos`` (B,)."""
    if sk is not None:
        qk, sck = _quant(k_new)
        qv, scv = _quant(v_new)
        cache_k = _dus_per_slot(cache_k, qk, pos)
        cache_v = _dus_per_slot(cache_v, qv, pos)
        sk = _dus_per_slot(sk, sck, pos)
        sv = _dus_per_slot(sv, scv, pos)
        return cache_k, cache_v, sk, sv
    cache_k = _dus_per_slot(cache_k, k_new, pos)
    cache_v = _dus_per_slot(cache_v, v_new, pos)
    return cache_k, cache_v, None, None


def _cache_read(ck, cv, sk, sv):
    if sk is not None:
        return (ck.astype(jnp.bfloat16) * sk.astype(jnp.bfloat16),
                cv.astype(jnp.bfloat16) * sv.astype(jnp.bfloat16))
    return ck, cv


# --------------------------------------------------------------- decode


class DecodeState(NamedTuple):
    cache: KVCache
    cross_k: Optional[jax.Array]   # (L_cross, B, H_eff, n_kv, hd)
    cross_v: Optional[jax.Array]


def _decode_self_attn(c: ArchConfig, p, x, cache_layer, pos):
    """Single-token self-attention against one layer's cache slice.
    ``pos`` is the per-slot (B,) position vector."""
    ck, cv, sk, sv = cache_layer
    q, k, v = _project_qkv(c, p, x, pos[:, None, None])
    # pin the cache-write operands to the cache dtype BEFORE fusion: without
    # the barrier XLA fuses the (f32) RoPE tail into the cache update and
    # upcasts the whole loop-carried stack (§Perf "decode-onehot-write")
    k, v = jax.lax.optimization_barrier(
        (k.astype(ck.dtype), v.astype(cv.dtype)))
    ck, cv, sk, sv = _cache_write(ck, cv, sk, sv, k, v, pos)
    kk, vv = _cache_read(ck, cv, sk, sv)
    o = attn.decode_attention(q, kk, vv, pos + 1)
    b = x.shape[0]
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, c.n_heads * c.hd)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), (ck, cv, sk, sv)


def _decode_cross_attn(c: ArchConfig, p, x, xk, xv):
    q = jnp.einsum("bsd,dh->bsh", _norm(c, p, x, "x_ln"), p["x_wq"])
    b = x.shape[0]
    q = q.reshape(b, 1, c.n_heads, c.hd).transpose(0, 2, 1, 3)
    if c.qk_norm:
        q = rms_norm(q.transpose(0, 2, 1, 3), p["x_q_norm"]).transpose(0, 2, 1, 3)
    o = attn.decode_attention(q, xk, xv, xk.shape[2])
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, c.n_heads * c.hd)
    o = jnp.einsum("bsh,hd->bsd", o, p["x_wo"])
    if c.family == "vlm":
        h = x + jnp.tanh(p["x_attn_gate"]).astype(x.dtype) * o
        m = _ffn(c, p, _norm(c, p, h, "x_ln_mlp"), prefix="x_")
        return h + jnp.tanh(p["x_mlp_gate"]).astype(x.dtype) * m
    return x + o


def _decode_block(c: ArchConfig, p, x, cache_layer, pos, *, moe: bool):
    a, cache_layer = _decode_self_attn(c, p, _norm(c, p, x, "ln1"), cache_layer, pos)
    x = x + a
    h = _norm(c, p, x, "ln2")
    if moe:
        y, _ = _moe_ffn(c, p, h)
    else:
        y = _ffn(c, p, h)
    return x + y, cache_layer


def precompute_cross_kv(c: ArchConfig, params, feats, stack_key: str):
    """Project cross-attention K/V once (prefill); returns (L,B,H,S,hd) pair."""
    stacked = params[stack_key]
    def body(_, lp):
        lp = cast_compute(lp)
        kv_src = feats
        b, sk = kv_src.shape[0], kv_src.shape[1]
        k = jnp.einsum("bsd,dh->bsh", kv_src, lp["x_wk"]).reshape(
            b, sk, c.n_kv_heads, c.hd)
        v = jnp.einsum("bsd,dh->bsh", kv_src, lp["x_wv"]).reshape(
            b, sk, c.n_kv_heads, c.hd)
        if c.qk_norm:
            k = rms_norm(k, lp["x_k_norm"])
        k = attn.repeat_kv(k.transpose(0, 2, 1, 3), c.kv_eff // c.n_kv_heads)
        v = attn.repeat_kv(v.transpose(0, 2, 1, 3), c.kv_eff // c.n_kv_heads)
        return None, (k, v)
    _, (xk, xv) = jax.lax.scan(body, None, stacked)
    return xk, xv


def decode_step(c: ArchConfig, params, token, state: DecodeState):
    """One-token decode: token (B,) int32 -> (logits (B,V), new state)."""
    pos = state.cache.pos
    x = params["embed"][token][:, None, :].astype(jnp.bfloat16)  # (B,1,D)
    cache = state.cache

    def scan_cache(stack, body):
        xs = (stack, cache.k, cache.v,
              cache.k_scale if cache.k_scale is not None else cache.k,
              cache.v_scale if cache.v_scale is not None else cache.v)
        def wrapped(h, xs_l):
            lp, ck, cv, sk, sv = xs_l
            lp = cast_compute(lp)
            if cache.k_scale is None:
                sk = sv = None
            h, (ck, cv, sk, sv) = body(h, lp, (ck, cv, sk, sv))
            if sk is None:
                sk, sv = ck, cv  # placeholder to keep scan pytree static
            return h, (ck, cv, sk, sv)
        h, (nk, nv, nsk, nsv) = jax.lax.scan(wrapped, x, xs)
        new_cache = KVCache(
            nk, nv,
            nsk if cache.k_scale is not None else None,
            nsv if cache.v_scale is not None else None,
            pos + 1)
        return h, new_cache

    if c.family in ("dense",) or (c.family == "moe" and c.moe_every == 1):
        is_moe = c.family == "moe"
        def body2(h, lp, cl):
            return _decode_block(c, lp, h, cl, pos, moe=is_moe)
        x, new_cache = scan_cache(params["layers"], body2)
        new_state = DecodeState(new_cache, state.cross_k, state.cross_v)
    elif c.family == "moe":  # llama4 alternating: scan over pairs
        n_pairs = c.n_layers // 2
        def split(t):
            de = jax.tree.map(lambda a: a.reshape((n_pairs, 2) + a.shape[2:]), t)
            return de
        kd = cache.k.reshape((n_pairs, 2) + cache.k.shape[1:])
        # simpler: interleave stacks — dense at even slots, moe at odd
        stacked = {"dense": params["dense_layers"], "moe": params["moe_layers"]}
        ck = cache.k.reshape((n_pairs, 2) + cache.k.shape[1:])
        cv = cache.v.reshape((n_pairs, 2) + cache.v.shape[1:])
        has_sc = cache.k_scale is not None
        csk = (cache.k_scale if has_sc else cache.k).reshape(
            (n_pairs, 2) + (cache.k_scale if has_sc else cache.k).shape[1:])
        csv = (cache.v_scale if has_sc else cache.v).reshape(
            (n_pairs, 2) + (cache.v_scale if has_sc else cache.v).shape[1:])
        def pair_body(h, xs_l):
            lp, ckl, cvl, skl, svl = xs_l
            lp = cast_compute(lp)
            sk0 = skl[0] if has_sc else None
            sv0 = svl[0] if has_sc else None
            h, cl_d = _decode_block(c, lp["dense"], h, (ckl[0], cvl[0], sk0, sv0),
                                    pos, moe=False)
            sk1 = skl[1] if has_sc else None
            sv1 = svl[1] if has_sc else None
            h, cl_m = _decode_block(c, lp["moe"], h, (ckl[1], cvl[1], sk1, sv1),
                                    pos, moe=True)
            nck = jnp.stack([cl_d[0], cl_m[0]])
            ncv = jnp.stack([cl_d[1], cl_m[1]])
            nsk = jnp.stack([cl_d[2], cl_m[2]]) if has_sc else nck
            nsv = jnp.stack([cl_d[3], cl_m[3]]) if has_sc else ncv
            return h, (nck, ncv, nsk, nsv)
        x, (nk, nv, nsk, nsv) = jax.lax.scan(pair_body, x, (stacked, ck, cv, csk, csv))
        L = c.n_layers
        new_cache = KVCache(
            nk.reshape((L,) + nk.shape[2:]), nv.reshape((L,) + nv.shape[2:]),
            nsk.reshape((L,) + nsk.shape[2:]) if has_sc else None,
            nsv.reshape((L,) + nsv.shape[2:]) if has_sc else None,
            pos + 1)
        new_state = DecodeState(new_cache, state.cross_k, state.cross_v)
    elif c.family == "vlm":
        every = c.cross_attn_every
        n_groups = c.n_layers // every
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])
        ck = cache.k.reshape((n_groups, every) + cache.k.shape[1:])
        cv = cache.v.reshape((n_groups, every) + cache.v.shape[1:])
        has_sc = cache.k_scale is not None
        csk = (cache.k_scale if has_sc else cache.k)
        csv = (cache.v_scale if has_sc else cache.v)
        csk = csk.reshape((n_groups, every) + csk.shape[1:])
        csv = csv.reshape((n_groups, every) + csv.shape[1:])
        def group_body(h, xs_l):
            gp, ckg, cvg, skg, svg = xs_l
            gp = dict(gp, cross=cast_compute(gp["cross"]),
                      self=cast_compute(gp["self"]))
            h = _decode_cross_attn(c, gp["cross"], h, gp["xk"], gp["xv"])
            outs = []
            for i in range(every):
                lp = jax.tree.map(lambda t: t[i], gp["self"])
                cl = (ckg[i], cvg[i], skg[i] if has_sc else None,
                      svg[i] if has_sc else None)
                h, cl2 = _decode_block(c, lp, h, cl, pos, moe=False)
                outs.append(cl2)
            nck = jnp.stack([o[0] for o in outs])
            ncv = jnp.stack([o[1] for o in outs])
            nsk = jnp.stack([o[2] for o in outs]) if has_sc else nck
            nsv = jnp.stack([o[3] for o in outs]) if has_sc else ncv
            return h, (nck, ncv, nsk, nsv)
        stacked = {"self": grouped,
                   "cross": params["cross"],
                   "xk": state.cross_k, "xv": state.cross_v}
        x, (nk, nv, nsk, nsv) = jax.lax.scan(group_body, x, (stacked, ck, cv, csk, csv))
        L = c.n_layers
        new_cache = KVCache(
            nk.reshape((L,) + nk.shape[2:]), nv.reshape((L,) + nv.shape[2:]),
            nsk.reshape((L,) + nsk.shape[2:]) if has_sc else None,
            nsv.reshape((L,) + nsv.shape[2:]) if has_sc else None,
            pos + 1)
        new_state = DecodeState(new_cache, state.cross_k, state.cross_v)
    elif c.family == "audio":
        has_sc = cache.k_scale is not None
        def body(h, xs_l):
            lp, ck, cv, sk, sv, xk, xv = xs_l
            lp = cast_compute(lp)
            if not has_sc:
                sk = sv = None
            h, cl = _decode_block(c, lp["self"], h, (ck, cv, sk, sv), pos, moe=False)
            h = _decode_cross_attn(c, lp["cross"], h, xk, xv)
            if cl[2] is None:
                cl = (cl[0], cl[1], cl[0], cl[1])
            return h, cl
        xs = ({"self": params["dec_layers"], "cross": params["dec_cross"]},
              cache.k, cache.v,
              cache.k_scale if has_sc else cache.k,
              cache.v_scale if has_sc else cache.v,
              state.cross_k, state.cross_v)
        x, (nk, nv, nsk, nsv) = jax.lax.scan(body, x, xs)
        new_cache = KVCache(nk, nv, nsk if has_sc else None,
                            nsv if has_sc else None, pos + 1)
        new_state = DecodeState(new_cache, state.cross_k, state.cross_v)
    else:
        raise ValueError(c.family)

    x = rms_norm(x, params["final_norm"]) if c.norm == "rms" else layer_norm(
        x, 1.0 + params["final_norm"], params["final_norm_b"])
    unembed = params["embed"].T if c.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed.astype(x.dtype))[:, 0]
    return constrain(logits, ("batch", "vocab_act")), new_state
