"""LM model substrate for the ten assigned architectures (DESIGN.md §5)."""
