"""Unified model API: one entry point over all families.

``build(cfg)`` returns a ``ModelAPI`` whose members are pure functions —
suitable for ``jax.jit`` / ``.lower()`` with ShapeDtypeStruct inputs (the
dry-run) or real arrays (smoke tests / the training example).

Batch dict conventions:
  train:    {tokens (B,S) i32, labels (B,S) i32 [, img_embeds | enc_embeds]}
  prefill:  {tokens (B,S) i32 [, img_embeds | enc_embeds]}
  decode:   token (B,) i32 + a family-specific decode state pytree
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import rwkv6, ssm, transformer
from repro.models.arch_config import ArchConfig, ShapeCell
from repro.models.common import ParamDecl, to_shape_tree


class ModelAPI(NamedTuple):
    cfg: ArchConfig
    decls: Any                                     # ParamDecl tree
    loss_fn: Callable[[Any, Dict], Any]            # (params, batch) -> (loss, metrics)
    prefill_fn: Callable[[Any, Dict], Any]         # (params, batch) -> logits
    decode_fn: Callable[[Any, jax.Array, Any], Any]  # (params, token, state)
    init_decode_state: Callable[..., Any]          # (batch, max_seq) -> state
    input_specs: Callable[[ShapeCell], Dict[str, jax.ShapeDtypeStruct]]
    decode_state_specs: Callable[[ShapeCell], Any]
    model_flops: Callable[[ShapeCell], float]


def _token_specs(c: ArchConfig, cell: ShapeCell, with_labels: bool) -> Dict:
    b, s = cell.global_batch, cell.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if c.family == "vlm":
        out["img_embeds"] = jax.ShapeDtypeStruct(
            (b, c.n_img_tokens, c.d_model), jnp.bfloat16)
    if c.family == "audio":
        out["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, c.n_frames, c.d_model), jnp.bfloat16)
    return out


def _decl_params(decls) -> int:
    import numpy as np
    from repro.models.common import is_decl
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(decls, is_leaf=is_decl))


def _flops(c: ArchConfig, cell: ShapeCell, decls=None) -> float:
    """MODEL_FLOPS: 6·N_active·tokens for train, 2·N_active·tokens for fwd."""
    if decls is not None and c.n_experts == 0:
        n_act = _decl_params(decls)        # exact for non-MoE
    else:
        n_act = c.active_params()
    toks = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6.0 if cell.kind == "train" else 2.0
    flops = mult * n_act * toks
    # attention score/value FLOPs (full-attention archs)
    if c.family in ("dense", "moe", "vlm", "audio"):
        hq, hd = c.n_heads, c.hd
        if cell.kind == "train":
            flops += 6.0 * 2 * cell.global_batch * hq * hd * cell.seq_len ** 2 / 2 * c.n_layers
        elif cell.kind == "prefill":
            flops += 2.0 * 2 * cell.global_batch * hq * hd * cell.seq_len ** 2 / 2 * c.n_layers
        else:  # decode: q of len 1 against S keys
            flops += 2.0 * 2 * cell.global_batch * hq * hd * cell.seq_len * c.n_layers
    return flops


def build(c: ArchConfig) -> ModelAPI:
    fam = c.family
    if fam in ("dense", "moe", "vlm", "audio"):
        decls = transformer.build_decls(c)

        def loss_fn(params, batch):
            return transformer.loss_fn(c, params, batch)

        def prefill_fn(params, batch):
            logits, _ = transformer.forward(
                c, params, batch["tokens"],
                img_embeds=batch.get("img_embeds"),
                enc_embeds=batch.get("enc_embeds"))
            return logits

        def decode_fn(params, token, state):
            return transformer.decode_step(c, params, token, state)

        def init_decode_state(params, batch_size, max_seq, *,
                              img_embeds=None, enc_embeds=None):
            cache = transformer.init_cache(c, c.n_layers, batch_size, max_seq)
            xk = xv = None
            if fam == "vlm":
                xk, xv = transformer.precompute_cross_kv(c, params, img_embeds, "cross")
            if fam == "audio":
                enc = transformer.encode_audio(c, params, enc_embeds)
                xk, xv = transformer.precompute_cross_kv(c, params, enc, "dec_cross")
            return transformer.DecodeState(cache, xk, xv)

        def decode_state_specs(cell: ShapeCell):
            b, s = cell.global_batch, cell.seq_len
            shape = (c.n_layers, b, c.kv_eff, s, c.hd)
            pos = jax.ShapeDtypeStruct((b,), jnp.int32)   # per-slot positions
            if c.kv_cache_dtype == "int8":
                k = jax.ShapeDtypeStruct(shape, jnp.int8)
                sc = jax.ShapeDtypeStruct(shape[:-1] + (1,), jnp.float32)
                cache = transformer.KVCache(k, k, sc, sc, pos)
            else:
                k = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
                cache = transformer.KVCache(k, k, None, None, pos)
            xk = xv = None
            if fam == "vlm":
                n_cross = c.n_layers // c.cross_attn_every
                xk = jax.ShapeDtypeStruct(
                    (n_cross, b, c.kv_eff, c.n_img_tokens, c.hd), jnp.bfloat16)
                xv = xk
            if fam == "audio":
                xk = jax.ShapeDtypeStruct(
                    (c.n_layers, b, c.kv_eff, c.n_frames, c.hd), jnp.bfloat16)
                xv = xk
            return transformer.DecodeState(cache, xk, xv)

    elif fam == "ssm":
        decls = rwkv6.build_decls(c)

        def loss_fn(params, batch):
            return rwkv6.loss_fn(c, params, batch)

        def prefill_fn(params, batch):
            logits, _ = rwkv6.forward(c, params, batch["tokens"])
            return logits

        def decode_fn(params, token, state):
            return rwkv6.decode_step(c, params, token, state)

        def init_decode_state(params, batch_size, max_seq, **_):
            return rwkv6.init_state(c, batch_size)

        def decode_state_specs(cell: ShapeCell):
            b = cell.global_batch
            d = c.d_model
            H, N = d // c.rwkv_head_dim, c.rwkv_head_dim
            z = jax.ShapeDtypeStruct((c.n_layers, b, d), jnp.bfloat16)
            return rwkv6.RWKVState(
                z, z, jax.ShapeDtypeStruct((c.n_layers, b, H, N, N), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.int32))

    elif fam == "hybrid":
        decls = ssm.build_decls(c)

        def loss_fn(params, batch):
            return ssm.loss_fn(c, params, batch)

        def prefill_fn(params, batch):
            logits, _ = ssm.forward(c, params, batch["tokens"])
            return logits

        def decode_fn(params, token, state):
            return ssm.decode_step(c, params, token, state)

        def init_decode_state(params, batch_size, max_seq, **_):
            return ssm.init_state(c, batch_size, max_seq)

        def decode_state_specs(cell: ShapeCell):
            b, s = cell.global_batch, cell.seq_len
            d_in = c.ssm_expand * c.d_model
            H = d_in // c.ssm_head_dim
            conv_ch = d_in + 2 * c.ssm_state
            conv = jax.ShapeDtypeStruct(
                (c.n_layers, b, c.conv_width - 1, conv_ch), jnp.bfloat16)
            ssm_st = jax.ShapeDtypeStruct(
                (c.n_layers, b, H, c.ssm_state, c.ssm_head_dim), jnp.float32)
            if c.shared_attn_every:
                ninv = ssm.n_shared_invocations(c)
                kz = jax.ShapeDtypeStruct((ninv, b, c.kv_eff, s, c.hd), jnp.bfloat16)
                return ssm.ZambaState(conv, ssm_st, kz, kz,
                                      jax.ShapeDtypeStruct((), jnp.int32))
            return ssm.ZambaState(conv, ssm_st, None, None,
                                  jax.ShapeDtypeStruct((), jnp.int32))
    else:
        raise ValueError(f"unknown family {fam}")

    def input_specs(cell: ShapeCell):
        if cell.kind == "train":
            return _token_specs(c, cell, with_labels=True)
        if cell.kind == "prefill":
            return _token_specs(c, cell, with_labels=False)
        return {"token": jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)}

    return ModelAPI(
        cfg=c,
        decls=decls,
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        init_decode_state=init_decode_state,
        input_specs=input_specs,
        decode_state_specs=decode_state_specs,
        model_flops=lambda cell: _flops(c, cell, decls),
    )
