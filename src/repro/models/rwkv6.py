"""RWKV6 "Finch" — attention-free LM with data-dependent decay (arXiv:2404.05892).

Structure per layer: time-mix (the WKV6 linear-attention form) + channel-mix.
Key Finch features implemented faithfully:
  * data-dependent token-shift (ddlerp): per-projection mix coefficients are a
    base mu plus a low-rank (LoRA) function of the shifted input;
  * data-dependent decay: w_t = exp(-exp(w0 + lora_w(x_w,t))) per channel;
  * bonus ``u`` ("time_faaaa") for the current token;
  * per-head GroupNorm and SiLU(g) output gating;
  * channel-mix with squared-ReLU.

TPU adaptation: training/prefill uses the CHUNKED parallel form — within a
chunk the decay-weighted attention is a dense masked (C x C) einsum (MXU
friendly), across chunks a (H, N, N) state is carried through ``lax.scan``.
Decode is the O(1) recurrence.  This is the standard chunked linear-attention
factorization; exp arguments are differences of cumulative log-decays along
the chunk, which are <= 0, so everything is numerically safe in f32.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.arch_config import ArchConfig
from repro.models.common import ParamDecl, cast_compute, cross_entropy_loss, rms_norm
from repro.launch.sharding import constrain

P = ParamDecl
MIX = ("r", "k", "v", "g", "w")


def build_decls(c: ArchConfig) -> Dict[str, Any]:
    d, L, r = c.d_model, c.n_layers, c.rwkv_lora_rank
    H = d // c.rwkv_head_dim
    N = c.rwkv_head_dim
    lyr: Dict[str, P] = {
        # ddlerp: base mus + shared lora (x) + per-target loras
        "mu_x": P((L, d), ("layers", None), init="zeros"),
        "tm_w1": P((L, d, 5 * r), ("layers", "embed", None), init="small"),
        "tm_w2": P((L, 5, r, d), ("layers", None, None, "embed"), init="small"),
        "decay_w1": P((L, d, r), ("layers", "embed", None), init="small"),
        "decay_w2": P((L, r, d), ("layers", None, "embed"), init="small"),
        "w0": P((L, d), ("layers", None), init="zeros"),
        "u": P((L, H, N), ("layers", "heads", None), init="small"),
        "ln_x_scale": P((L, d), ("layers", None), init="ones"),
        "ln_x_bias": P((L, d), ("layers", None), init="zeros"),
        "ln1": P((L, d), ("layers", None), init="zeros"),
        "ln2": P((L, d), ("layers", None), init="zeros"),
        # channel mix
        "cm_mu_k": P((L, d), ("layers", None), init="zeros"),
        "cm_mu_r": P((L, d), ("layers", None), init="zeros"),
        "cm_wk": P((L, d, c.d_ff), ("layers", "embed", "mlp")),
        "cm_wv": P((L, c.d_ff, d), ("layers", "mlp", "embed")),
        "cm_wr": P((L, d, d), ("layers", "embed", "heads")),
    }
    for t in MIX:
        lyr[f"mu_{t}"] = P((L, d), ("layers", None), init="zeros")
    for t in ("r", "k", "v", "g", "o"):
        lyr[f"w{t}"] = P((L, d, d), ("layers", "embed", "heads"))
    return {
        "embed": P((c.vocab_size, d), ("vocab", "embed"), init="embed"),
        "final_norm": P((d,), (None,), init="zeros"),
        "unembed": P((d, c.vocab_size), ("embed", "vocab")),
        "layers": lyr,
    }


# ------------------------------------------------------------- time mix math


def _ddlerp(p, x, xprev):
    """Data-dependent lerp -> dict of mixed inputs for r,k,v,g,w."""
    dx = xprev - x
    xx = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.einsum("bsd,dr->bsr", xx, p["tm_w1"].astype(x.dtype))
    lora = jnp.tanh(lora.astype(jnp.float32)).astype(x.dtype)
    b, s, _ = x.shape
    r5 = p["tm_w1"].shape[-1] // 5
    lora = lora.reshape(b, s, 5, r5)
    adj = jnp.einsum("bstr,trd->bstd", lora, p["tm_w2"].astype(x.dtype))
    out = {}
    for i, t in enumerate(MIX):
        mu = p[f"mu_{t}"].astype(x.dtype) + adj[:, :, i]
        out[t] = x + dx * mu
    return out


def _decay(p, xw):
    """log-decay per channel: logw = -exp(w0 + lora_w(xw)) (<= 0)."""
    h = jnp.einsum("bsd,dr->bsr", xw, p["decay_w1"].astype(xw.dtype))
    h = jnp.tanh(h.astype(jnp.float32))
    h = jnp.einsum("bsr,rd->bsd", h, p["decay_w2"].astype(jnp.float32))
    return -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + h, -20.0, 8.0))


def _group_norm(x, scale, bias, n_heads, eps=64e-5):
    """Per-head LayerNorm over head_dim (RWKV ln_x)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(b, s, d) * scale.astype(jnp.float32)
            + bias.astype(jnp.float32))


def pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (chunked scans need s % c == 0)."""
    c = min(chunk, s)
    while s % c:
        c -= 1
    return max(1, c)


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked WKV6.

    r,k,v: (B,S,H,N); logw: (B,S,H,N) (<=0, f32); u: (H,N);
    state: (B,H,N,N) f32.  Returns (out (B,S,H,N) f32, new state).
    """
    b, s, h, n = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rc = r.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)  # (nc,B,H,C,N)
    kc = k.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)
    wc = logw.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)

    tri_lower_strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def body(S, xs):
        rb, kb, vb, wb = xs  # (B,H,C,N)
        rb32, kb32, vb32 = (a.astype(jnp.float32) for a in (rb, kb, vb))
        cum = jnp.cumsum(wb, axis=2)                      # lw_t (inclusive)
        cum_prev = cum - wb                               # lw_{t-1} exclusive
        # intra-chunk: A[t,s] = sum_i r_t k_s exp(cum_prev_t - cum_s), s < t
        # exponent <= 0 because cum is decreasing and s <= t-1.
        ert = jnp.exp(cum_prev)                           # may underflow only
        # compute via logs to stay safe: use difference form directly
        # A_ts = sum_i r[t,i] k[s,i] exp(cum_prev[t,i] - cum[s,i])
        q_dec = rb32 * jnp.exp(cum_prev)                  # (B,H,C,N)
        k_dec = kb32 * jnp.exp(-cum)                      # (B,H,C,N)
        A = jnp.einsum("bhtn,bhsn->bhts", q_dec, k_dec)
        A = jnp.where(tri_lower_strict, A, 0.0)
        # diagonal (current-token) bonus term with u
        diag = jnp.einsum("bhtn,bhtn->bht", rb32 * u.astype(jnp.float32)[None, :, None, :], kb32)
        out = jnp.einsum("bhts,bhsn->bhtn", A, vb32)
        out = out + diag[..., None] * vb32
        # inter-chunk: r_t decayed to chunk start @ S
        out = out + jnp.einsum("bhtn,bhnm->bhtm", q_dec, S)
        # state update: S' = diag(exp(cum_last)) S + sum_s exp(cum_last-cum_s) k_s v_s^T
        cum_last = cum[:, :, -1:, :]                      # (B,H,1,N)
        S_new = jnp.exp(cum_last[:, :, 0, :, None]) * S + jnp.einsum(
            "bhsn,bhsm->bhnm", kb32 * jnp.exp(cum_last - cum), vb32)
        return S_new, out

    state, out = jax.lax.scan(body, state.astype(jnp.float32), (rc, kc, vc, wc))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, s, h, n)  # back to (B,S,H,N)
    return out, state


def _wkv_step(r, k, v, logw, u, state):
    """One-token WKV6 recurrence. r..: (B,H,N); state (B,H,N,N) f32."""
    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    kv = jnp.einsum("bhn,bhm->bhnm", k32, v32)
    out = jnp.einsum("bhn,bhnm->bhm", r32, state + u.astype(jnp.float32)[None, :, :, None] * kv)
    state = jnp.exp(logw.astype(jnp.float32))[..., None] * state + kv
    return out, state


# ------------------------------------------------------------- layer fwd


def _time_mix(c: ArchConfig, p, x, xprev_last, state, *, chunk):
    """x: (B,S,D). xprev_last: (B,D) carry (token S-1 of previous segment)."""
    b, s, d = x.shape
    H, N = d // c.rwkv_head_dim, c.rwkv_head_dim
    xprev = jnp.concatenate([xprev_last[:, None], x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, xprev)
    r = jnp.einsum("bsd,de->bse", mixed["r"], p["wr"]).reshape(b, s, H, N)
    k = jnp.einsum("bsd,de->bse", mixed["k"], p["wk"]).reshape(b, s, H, N)
    v = jnp.einsum("bsd,de->bse", mixed["v"], p["wv"]).reshape(b, s, H, N)
    g = jnp.einsum("bsd,de->bse", mixed["g"], p["wg"])
    logw = _decay(p, mixed["w"]).reshape(b, s, H, N)
    out, state = _wkv_chunked(r, k, v, logw, p["u"], state,
                              chunk=pick_chunk(s, chunk))
    out = _group_norm(out.reshape(b, s, d), p["ln_x_scale"], p["ln_x_bias"], H)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", out, p["wo"])
    return y, x[:, -1], state


def _channel_mix(c, p, x, xprev_last):
    xprev = jnp.concatenate([xprev_last[:, None], x[:, :-1]], axis=1)
    dx = xprev - x
    xk = x + dx * p["cm_mu_k"].astype(x.dtype)
    xr = x + dx * p["cm_mu_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["cm_wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    v = jnp.einsum("bsf,fd->bsd", k, p["cm_wv"])
    rg = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cm_wr"]).astype(jnp.float32)).astype(x.dtype)
    return rg * v, x[:, -1]


class RWKVState(NamedTuple):
    tm_prev: jax.Array   # (L, B, D)  last token fed to time-mix
    cm_prev: jax.Array   # (L, B, D)
    wkv: jax.Array       # (L, B, H, N, N) f32
    pos: jax.Array


def init_state(c: ArchConfig, batch: int) -> RWKVState:
    d = c.d_model
    H, N = d // c.rwkv_head_dim, c.rwkv_head_dim
    z = jnp.zeros((c.n_layers, batch, d), jnp.bfloat16)
    return RWKVState(z, z, jnp.zeros((c.n_layers, batch, H, N, N), jnp.float32),
                     jnp.int32(0))


def forward(c: ArchConfig, params, tokens, state: RWKVState | None = None,
            return_state: bool = False):
    """Training / prefill forward.  Returns (logits, aux[, state])."""
    b, s = tokens.shape
    if state is None:
        state = init_state(c, b)
    x = params["embed"][tokens].astype(jnp.bfloat16)
    x = constrain(x, ("batch", None, "embed_act"))

    def body(carry, xs):
        h = carry
        lp, tm_prev, cm_prev, wkv = xs
        lp = cast_compute(lp)
        y, tm_new, wkv = _time_mix(c, lp, rms_norm(h, lp["ln1"]), tm_prev, wkv,
                                   chunk=c.chunk_size)
        h = h + y
        y, cm_new = _channel_mix(c, lp, rms_norm(h, lp["ln2"]), cm_prev)
        h = h + y
        h = constrain(h, ("batch", None, "embed_act"))
        return h, (tm_new, cm_new, wkv)

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable,
                          prevent_cse=False)
    x, (tm, cm, wkv) = jax.lax.scan(
        body, x, (params["layers"], state.tm_prev, state.cm_prev, state.wkv))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    logits = constrain(logits, ("batch", None, "vocab_act"))
    aux = jnp.float32(0.0)
    if return_state:
        return logits, aux, RWKVState(tm, cm, wkv, state.pos + s)
    return logits, aux


def loss_fn(c: ArchConfig, params, batch):
    logits, aux = forward(c, params, batch["tokens"])
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


def decode_step(c: ArchConfig, params, token, state: RWKVState):
    """token: (B,) -> (logits (B,V), state).  O(1) per token."""
    b = token.shape[0]
    d = c.d_model
    H, N = d // c.rwkv_head_dim, c.rwkv_head_dim
    x = params["embed"][token].astype(jnp.bfloat16)[:, None]  # (B,1,D)

    def body(h, xs):
        lp, tm_prev, cm_prev, wkv = xs
        lp = cast_compute(lp)
        xin = rms_norm(h, lp["ln1"])
        mixed = _ddlerp(lp, xin, tm_prev[:, None])
        r = jnp.einsum("bsd,de->bse", mixed["r"], lp["wr"]).reshape(b, H, N)
        k = jnp.einsum("bsd,de->bse", mixed["k"], lp["wk"]).reshape(b, H, N)
        v = jnp.einsum("bsd,de->bse", mixed["v"], lp["wv"]).reshape(b, H, N)
        g = jnp.einsum("bsd,de->bse", mixed["g"], lp["wg"])
        logw = _decay(lp, mixed["w"]).reshape(b, H, N)
        out, wkv = _wkv_step(r, k, v, logw, lp["u"], wkv)
        out = _group_norm(out.reshape(b, 1, d), lp["ln_x_scale"], lp["ln_x_bias"], H)
        out = out.astype(h.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
        h = h + jnp.einsum("bsd,de->bse", out, lp["wo"])
        tm_new = xin[:, 0]
        xin2 = rms_norm(h, lp["ln2"])
        y, cm_new = _channel_mix(c, lp, xin2, cm_prev)
        h = h + y
        return h, (tm_new, cm_new, wkv)

    x, (tm, cm, wkv) = jax.lax.scan(
        body, x, (params["layers"], state.tm_prev, state.cm_prev, state.wkv))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))[:, 0]
    return constrain(logits, ("batch", "vocab_act")), RWKVState(tm, cm, wkv, state.pos + 1)
