"""Mamba2 (SSD) blocks + the Zamba2 hybrid (arXiv:2411.15242).

Zamba2 = a backbone of Mamba2 layers with ONE shared full-attention
transformer block (weights tied across invocations) applied every
``shared_attn_every`` layers on concat(hidden, original_embedding) — the
paper's "shared attn blocks".

Mamba2 SSD is implemented in the chunked parallel form (the TPU-native
factorization, mirrors rwkv6.py): per-head scalar decay a·dt, intra-chunk
masked (C x C) einsum on the MXU, inter-chunk (H, N, P) state carried by
``lax.scan``.  Decode is the O(1) recurrence with a rolling conv buffer.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.arch_config import ArchConfig
from repro.models.common import (
    ParamDecl, apply_rope, cast_compute, cross_entropy_loss, rms_norm)
from repro.launch.sharding import constrain

P = ParamDecl


def _dims(c: ArchConfig):
    d_in = c.ssm_expand * c.d_model
    H = d_in // c.ssm_head_dim
    N = c.ssm_state
    G = 1  # n_groups
    conv_ch = d_in + 2 * G * N
    return d_in, H, N, G, conv_ch


def build_decls(c: ArchConfig) -> Dict[str, Any]:
    d, L = c.d_model, c.n_layers
    d_in, H, N, G, conv_ch = _dims(c)
    proj_out = 2 * d_in + 2 * G * N + H
    lyr = {
        "ln": P((L, d), ("layers", None), init="zeros"),
        "in_proj": P((L, d, proj_out), ("layers", "embed", "mlp")),
        "conv_w": P((L, c.conv_width, conv_ch), ("layers", None, None), init="small"),
        "conv_b": P((L, conv_ch), ("layers", None), init="zeros"),
        "dt_bias": P((L, H), ("layers", "heads"), init="zeros"),
        "a_log": P((L, H), ("layers", "heads"), init="zeros"),
        "d_skip": P((L, H), ("layers", "heads"), init="ones"),
        "norm_y": P((L, d_in), ("layers", "mlp"), init="zeros"),
        "out_proj": P((L, d_in, d), ("layers", "mlp", "embed")),
    }
    out: Dict[str, Any] = {
        "embed": P((c.vocab_size, d), ("vocab", "embed"), init="embed"),
        "final_norm": P((d,), (None,), init="zeros"),
        "unembed": P((d, c.vocab_size), ("embed", "vocab")),
        "mamba_layers": lyr,
    }
    if c.shared_attn_every:
        hq = c.n_heads * c.hd
        out["shared"] = {
            "ln": P((2 * d,), (None,), init="zeros"),
            "wq": P((2 * d, hq), ("embed", "heads")),
            "wk": P((2 * d, c.n_kv_heads * c.hd), ("embed", None)),
            "wv": P((2 * d, c.n_kv_heads * c.hd), ("embed", None)),
            "wo": P((hq, d), ("heads", "embed")),
            "ln_mlp": P((2 * d,), (None,), init="zeros"),
            "w_gate": P((2 * d, c.d_ff), ("embed", "mlp")),
            "w_up": P((2 * d, c.d_ff), ("embed", "mlp")),
            "w_down": P((c.d_ff, d), ("mlp", "embed")),
        }
    return out


# ----------------------------------------------------------------- SSD math


def _ssd_chunked(x, dt, a, B, C, state, chunk: int):
    """Chunked SSD scan.

    x: (Bt,S,H,P); dt: (Bt,S,H) (post-softplus); a: (H,) (negative);
    B, C: (Bt,S,G=1,N); state: (Bt,H,N,P) f32.
    Returns (y (Bt,S,H,P) f32, new state).
    """
    bt, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    xr = x.reshape(bt, nc, chunk, h, p).transpose(1, 0, 3, 2, 4)   # (nc,Bt,H,C,P)
    dtr = dt.reshape(bt, nc, chunk, h).transpose(1, 0, 3, 2)       # (nc,Bt,H,C)
    Br = B.reshape(bt, nc, chunk, n).transpose(1, 0, 2, 3)         # (nc,Bt,C,N)
    Cr = C.reshape(bt, nc, chunk, n).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))                 # incl diag

    def body(S, xs):
        xb, dtb, Bb, Cb = xs
        xb32 = xb.astype(jnp.float32)
        lc = jnp.cumsum(a[None, :, None] * dtb, axis=-1)           # (Bt,H,C) <=0
        # intra: M[t,s] = (C_t.B_s) exp(lc_t - lc_s) dt_s   (s <= t)
        cb = jnp.einsum("btn,bsn->bts", Cb.astype(jnp.float32), Bb.astype(jnp.float32))
        q_dec = jnp.exp(lc)                                        # (Bt,H,C)
        k_dec = jnp.exp(-lc) * dtb                                 # (Bt,H,C)
        M = cb[:, None] * q_dec[..., :, None] * k_dec[..., None, :]
        M = jnp.where(tri, M, 0.0)
        y = jnp.einsum("bhts,bhsp->bhtp", M, xb32)
        # inter: y[t] += C_t . (exp(lc_t) S)
        y = y + jnp.einsum("btn,bhnp,bht->bhtp", Cb.astype(jnp.float32), S, q_dec)
        # state: S' = exp(lc_last) S + sum_s exp(lc_last - lc_s) dt_s B_s x_s
        lc_last = lc[..., -1:]
        w = jnp.exp(lc_last - lc) * dtb                            # (Bt,H,C)
        S = jnp.exp(lc_last)[..., None] * S + jnp.einsum(
            "bsn,bhsp,bhs->bhnp", Bb.astype(jnp.float32), xb32, w)
        return S, y

    state, y = jax.lax.scan(body, state.astype(jnp.float32), (xr, dtr, Br, Cr))
    y = y.transpose(1, 0, 3, 2, 4).reshape(bt, s, h, p)
    return y, state


def _ssd_step(x, dt, a, B, C, state):
    """One-token SSD: x (Bt,H,P), dt (Bt,H), B/C (Bt,N), state (Bt,H,N,P)."""
    x32 = x.astype(jnp.float32)
    decay = jnp.exp(a[None] * dt)                                   # (Bt,H)
    upd = jnp.einsum("bn,bhp,bh->bhnp", B.astype(jnp.float32), x32, dt)
    state = decay[..., None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), state)
    return y, state


def _split_proj(c: ArchConfig, zxbcdt):
    d_in, H, N, G, _ = _dims(c)
    z, xc, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    return z, xc, B, C, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv via K shifted adds. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    y = jnp.zeros_like(x, shape=x.shape).astype(jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(y + b.astype(jnp.float32)).astype(x.dtype)


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    """Mamba2 out-norm: rmsnorm(y * silu(z))."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return rms_norm(y, scale, eps)


def _mamba_block(c: ArchConfig, p, x, conv_state, ssm_state, *, chunk):
    """x: (B,S,D) normed input.  Returns (y, conv_tail, ssm_state)."""
    b, s, d = x.shape
    d_in, H, N, G, conv_ch = _dims(c)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xc, B, C, dt = _split_proj(c, zxbcdt)
    xbc = jnp.concatenate([xc, B, C], axis=-1)                      # (B,S,conv_ch)
    # prepend carried conv tail (K-1 tokens) for cross-segment correctness
    k = c.conv_width
    xbc_ext = jnp.concatenate([conv_state, xbc], axis=1)            # (B,S+K-1,..)
    conv = _causal_conv(xbc_ext, p["conv_w"], p["conv_b"])[:, k - 1:]
    xc2, B2, C2 = jnp.split(conv, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xc2.reshape(b, s, H, c.ssm_head_dim)
    from repro.models.rwkv6 import pick_chunk
    y, ssm_state = _ssd_chunked(xh, dt, a, B2, C2, ssm_state,
                                chunk=pick_chunk(s, chunk))
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_y"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, xbc_ext[:, -(k - 1):], ssm_state


def _shared_attn_block(c: ArchConfig, p, x, x0, positions, cache=None, pos=None):
    """Zamba2 shared block on concat(x, x0); returns (x, new kv slice)."""
    b = x.shape[0]
    h2 = jnp.concatenate([x, x0], axis=-1)
    h2 = rms_norm(h2, p["ln"])
    hd, hq, hkv = c.hd, c.n_heads, c.n_kv_heads
    sq = x.shape[1]
    q = jnp.einsum("bsd,dh->bsh", h2, p["wq"]).reshape(b, sq, hq, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dh->bsh", h2, p["wk"]).reshape(b, sq, hkv, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,dh->bsh", h2, p["wv"]).reshape(b, sq, hkv, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, c.rope_theta)
    k = apply_rope(k, positions, c.rope_theta)
    reps = c.kv_eff // hkv
    k = attn_lib.repeat_kv(k, reps)
    v = attn_lib.repeat_kv(v, reps)
    new_kv = None
    if cache is None:
        o = attn_lib.flash_attention(q, k, v, causal=True, chunk=min(1024, sq))
    else:
        ck, cv = cache
        ck, cv = attn_lib.update_cache(ck, cv, k, v, pos)
        o = attn_lib.decode_attention(q, ck, cv, pos + 1)
        new_kv = (ck, cv)
    o = o.transpose(0, 2, 1, 3).reshape(b, sq, hq * hd)
    x = x + jnp.einsum("bsh,hd->bsd", o, p["wo"])
    h2 = rms_norm(jnp.concatenate([x, x0], axis=-1), p["ln_mlp"])
    g = jnp.einsum("bsd,df->bsf", h2, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", h2, p["w_up"])
    m = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    x = x + jnp.einsum("bsf,fd->bsd", m, p["w_down"])
    return x, new_kv


class ZambaState(NamedTuple):
    conv: jax.Array            # (L, B, K-1, conv_ch)
    ssm: jax.Array             # (L, B, H, N, P) f32
    attn_k: Optional[jax.Array]  # (n_inv, B, H_eff, S_max, hd)
    attn_v: Optional[jax.Array]
    pos: jax.Array


def n_shared_invocations(c: ArchConfig) -> int:
    return c.n_layers // c.shared_attn_every if c.shared_attn_every else 0


def init_state(c: ArchConfig, batch: int, max_seq: int) -> ZambaState:
    d_in, H, N, G, conv_ch = _dims(c)
    conv = jnp.zeros((c.n_layers, batch, c.conv_width - 1, conv_ch), jnp.bfloat16)
    ssm = jnp.zeros((c.n_layers, batch, H, N, c.ssm_head_dim), jnp.float32)
    if c.shared_attn_every:
        ninv = n_shared_invocations(c)
        kz = jnp.zeros((ninv, batch, c.kv_eff, max_seq, c.hd), jnp.bfloat16)
        return ZambaState(conv, ssm, kz, kz, jnp.int32(0))
    return ZambaState(conv, ssm, None, None, jnp.int32(0))


def forward(c: ArchConfig, params, tokens):
    """Training/prefill forward -> (logits, aux)."""
    b, s = tokens.shape
    x0 = params["embed"][tokens].astype(jnp.bfloat16)
    x = constrain(x0, ("batch", None, "embed_act"))
    positions = jnp.arange(s)
    d_in, H, N, G, conv_ch = _dims(c)
    every = c.shared_attn_every or (c.n_layers + 1)
    n_groups = c.n_layers // every
    tail = c.n_layers - n_groups * every

    def mamba_body(h, lp):
        lp = cast_compute(lp)
        zc = jnp.zeros((b, c.conv_width - 1, conv_ch), jnp.bfloat16)
        zs = jnp.zeros((b, H, N, c.ssm_head_dim), jnp.float32)
        y, _, _ = _mamba_block(c, lp, rms_norm(h, lp["ln"]), zc, zs, chunk=c.chunk_size)
        h = h + y
        return constrain(h, ("batch", None, "embed_act")), None

    mamba_body = jax.checkpoint(
        mamba_body, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False)

    if n_groups:
        grouped = jax.tree.map(
            lambda t: t[: n_groups * every].reshape((n_groups, every) + t.shape[1:]),
            params["mamba_layers"])

        shared_c = cast_compute(params["shared"])

        def group_body(h, gp):
            h, _ = _shared_attn_block(c, shared_c, h, x0, positions)
            h, _ = jax.lax.scan(mamba_body, h, gp)
            return h, None

        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)
        x, _ = jax.lax.scan(group_body, x, grouped)
    if tail:
        tail_stack = jax.tree.map(lambda t: t[-tail:], params["mamba_layers"])
        x, _ = jax.lax.scan(mamba_body, x, tail_stack)

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    return constrain(logits, ("batch", None, "vocab_act")), jnp.float32(0.0)


def loss_fn(c: ArchConfig, params, batch):
    logits, aux = forward(c, params, batch["tokens"])
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


def decode_step(c: ArchConfig, params, token, state: ZambaState):
    """One-token decode with conv/ssm/attn-cache state."""
    b = token.shape[0]
    d_in, H, N, G, conv_ch = _dims(c)
    x0 = params["embed"][token].astype(jnp.bfloat16)[:, None]
    x = x0
    pos = state.pos
    every = c.shared_attn_every or (c.n_layers + 1)
    n_groups = c.n_layers // every
    tail = c.n_layers - n_groups * every
    k = c.conv_width

    def mamba_step(h, lp, conv_st, ssm_st):
        lp = cast_compute(lp)
        xin = rms_norm(h, lp["ln"])
        zxbcdt = jnp.einsum("bsd,de->bse", xin, lp["in_proj"])
        z, xc, B, C, dt = _split_proj(c, zxbcdt)
        xbc = jnp.concatenate([xc, B, C], axis=-1)        # (B,1,conv_ch)
        xbc_ext = jnp.concatenate([conv_st, xbc], axis=1)  # (B,K,conv_ch)
        conv = jnp.einsum("bkc,kc->bc", xbc_ext.astype(jnp.float32),
                          lp["conv_w"].astype(jnp.float32))
        conv = jax.nn.silu(conv + lp["conv_b"].astype(jnp.float32)).astype(h.dtype)
        xc2, B2, C2 = jnp.split(conv, [d_in, d_in + G * N], axis=-1)
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                              + lp["dt_bias"].astype(jnp.float32))
        a = -jnp.exp(lp["a_log"].astype(jnp.float32))
        xh = xc2.reshape(b, H, c.ssm_head_dim)
        y, ssm_st = _ssd_step(xh, dtv, a, B2, C2, ssm_st)
        y = y + lp["d_skip"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, 1, d_in).astype(h.dtype)
        y = _gated_rmsnorm(y, z, lp["norm_y"])
        h = h + jnp.einsum("bse,ed->bsd", y, lp["out_proj"])
        return h, xbc_ext[:, 1:], ssm_st

    def mamba_scan(h, stack, conv_sts, ssm_sts):
        def body(hh, xs):
            lp, cst, sst = xs
            hh, cst, sst = mamba_step(hh, lp, cst, sst)
            return hh, (cst, sst)
        h, (ncv, nss) = jax.lax.scan(body, h, (stack, conv_sts, ssm_sts))
        return h, ncv, nss

    new_conv, new_ssm = [], []
    nk, nv = state.attn_k, state.attn_v
    li = 0
    if n_groups:
        for gi in range(n_groups):
            sl = slice(li, li + every)
            if nk is not None:
                x, (ck, cv) = _shared_attn_block(
                    c, cast_compute(params["shared"]), x, x0, pos[None],
                    cache=(nk[gi], nv[gi]), pos=pos)
                nk = nk.at[gi].set(ck)
                nv = nv.at[gi].set(cv)
            stack = jax.tree.map(lambda t: t[sl], params["mamba_layers"])
            x, ncv, nss = mamba_scan(x, stack, state.conv[sl], state.ssm[sl])
            new_conv.append(ncv)
            new_ssm.append(nss)
            li += every
    if tail:
        stack = jax.tree.map(lambda t: t[li:], params["mamba_layers"])
        x, ncv, nss = mamba_scan(x, stack, state.conv[li:], state.ssm[li:])
        new_conv.append(ncv)
        new_ssm.append(nss)

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))[:, 0]
    new_state = ZambaState(
        jnp.concatenate(new_conv), jnp.concatenate(new_ssm), nk, nv, pos + 1)
    return constrain(logits, ("batch", "vocab_act")), new_state
