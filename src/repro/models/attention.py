"""Attention: GQA with qk-norm, RoPE, chunked (flash-style) softmax, KV cache.

Design notes (DESIGN.md §6):
  * ``flash_attention``: jnp online-softmax over KV chunks (lax.scan) — keeps
    the (S, S) score matrix out of memory for 32k prefill; this is the pure-JAX
    expression of the flash pattern, XLA fuses the inner body.
  * GQA with TP > n_kv_heads: KV heads are repeated to ``kv_eff`` (a divisor-
    friendly multiple) at projection time; queries are grouped per effective
    KV head, so each TP shard holds exactly the KV heads its queries need.
  * decode: single-token attention over a cache laid out
    (batch, kv_eff, max_seq, head_dim); a position mask handles partial fill.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k: jax.Array, repeats: int) -> jax.Array:
    """(B, H_kv, S, D) -> (B, H_kv*repeats, S, D), interleaved so that head
    h_eff = h_orig*repeats + r (query group locality under TP sharding)."""
    if repeats == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, repeats, s, d)).reshape(
        b, h * repeats, s, d
    )


def full_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv_eff, Sk, D)
    v: jax.Array,
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    qg = q.reshape(b, hk, g, sq, d)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    sk = k.shape[2]
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    if kv_valid_len is not None:
        kmask = jnp.arange(sk) < kv_valid_len      # (sk,)
        scores = jnp.where(kmask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
    return out.reshape(b, hq, sq, d)


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv_eff, Sk, D)
    v: jax.Array,
    causal: bool = True,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV chunks (memory O(Sq·chunk)).

    Wrapped in named_scope("flash_attn_interior") so the dry-run profiler
    (launch/hlo_cost.profile) can attribute the interior HBM traffic that the
    Pallas kernel (kernels/flash_attention) keeps VMEM-resident on TPU.
    """
    with jax.named_scope("flash_attn_interior"):
        return _flash_attention_jnp(q, k, v, causal, chunk)


def _flash_attention_jnp(q, k, v, causal, chunk):
    b, hq, sq, d = q.shape
    hk, sk = k.shape[1], k.shape[2]
    g = hq // hk
    if sk <= chunk or sk % chunk != 0:
        # short or non-tileable KV (e.g. whisper's 1500 frames): dense path
        return full_attention(q, k, v, causal)
    nchunks = sk // chunk
    qg = q.reshape(b, hk, g, sq, d)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq)[:, None]

    kc = k.reshape(b, hk, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hk, nchunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def body(carry, xs):
        acc, m, l, ci = carry
        kb, vb = xs  # (B, hk, chunk, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb).astype(jnp.float32) * scale
        if causal:
            kpos = ci * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(q.dtype), vb
        ).astype(jnp.float32)
        return (acc_new, m_new, l_new, ci + 1), None

    acc0 = jnp.zeros((b, hk, g, sq, d), jnp.float32)
    m0 = jnp.full((b, hk, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype).reshape(b, hq, sq, d)


def decode_attention(
    q: jax.Array,          # (B, Hq, 1, D)
    k_cache: jax.Array,    # (B, Hkv_eff, S_max, D)
    v_cache: jax.Array,
    valid_len: jax.Array,  # scalar or (B,) — filled cache length incl. this step
) -> jax.Array:
    b, hq, _, d = q.shape
    hk, smax = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        mask = jnp.arange(smax)[None, None, None, :] < vl
    else:
        mask = jnp.arange(smax)[None, :] < vl[:, None]
        mask = mask[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache)
    return out.reshape(b, hq, 1, d)


def update_cache(
    k_cache: jax.Array, v_cache: jax.Array, k_new: jax.Array, v_new: jax.Array,
    position: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Insert (B, H, S_new, D) at ``position`` along the seq axis."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), position, axis=2
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), position, axis=2
    )
    return k_cache, v_cache
