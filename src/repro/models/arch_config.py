"""Unified architecture config for the assigned model zoo.

One frozen dataclass covers all 10 assigned architectures; family-specific
fields are optional and ignored by other families.  Families:

  dense   — decoder-only transformer (qwen3-8b/1.7b, nemotron-4-340b, phi3)
  moe     — decoder-only with routed-expert FFNs (llama4-maverick, qwen3-moe)
  vlm     — dense decoder + cross-attention layers over precomputed patch
            embeddings (llama-3.2-vision); the vision tower is a STUB —
            ``input_specs`` provides the patch embeddings directly.
  ssm     — RWKV6 "Finch" (attention-free, data-dependent decay)
  hybrid  — Zamba2: Mamba2 backbone + one shared attention block
  audio   — Whisper enc-dec; conv frontend is a STUB (precomputed frame
            embeddings), decoder is a standard causal transformer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class ArchConfig(ConfigBase):
    name: str = "unnamed"
    family: str = "dense"          # dense | moe | vlm | ssm | hybrid | audio

    # core transformer dims
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0              # 0 -> d_model // n_heads
    activation: str = "swiglu"     # swiglu | squared_relu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm: str = "rms"              # rms | layer
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0             # 0 -> dense FFN
    top_k: int = 1
    moe_every: int = 1             # 1 = every layer routed; 2 = alternate dense/moe
    d_ff_expert: int = 0
    shared_expert: bool = False
    d_ff_shared: int = 0
    capacity_factor: float = 1.25

    # VLM cross-attention
    cross_attn_every: int = 0      # every k-th layer gets a cross-attn block
    n_img_tokens: int = 0

    # SSM / RWKV / hybrid
    ssm_state: int = 0             # Mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0     # Zamba2: shared attn block cadence
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    chunk_size: int = 128          # chunked linear-attention/SSD chunk length

    # enc-dec (audio)
    n_enc_layers: int = 0
    n_frames: int = 1500           # encoder frames emitted by the (stub) frontend

    # precision / memory policy
    dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8
    optimizer: str = "adamw"       # adamw | adafactor (big archs)
    grad_accum: int = 1            # microbatch accumulation steps
    kv_repeat_to: int = 1          # expand KV heads to >= this (TP divisibility)
    shard_residual_embed: bool = False  # shard residual D over 'model' (SP-like)

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def kv_eff(self) -> int:
        """Effective KV heads after TP-divisibility expansion."""
        k = self.n_kv_heads
        while k < self.kv_repeat_to:
            k *= 2
        return k

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def active_params(self) -> int:
        """Parameters touched per token (MoE counts top_k experts only)."""
        return _count_params(self, active_only=True)

    def total_params(self) -> int:
        return _count_params(self, active_only=False)


def _count_params(c: ArchConfig, active_only: bool) -> int:
    d, hd = c.d_model, c.hd
    embed = c.vocab_size * d * (1 if c.tie_embeddings else 2)
    attn = d * (c.n_heads * hd) + 2 * d * (c.n_kv_heads * hd) + (c.n_heads * hd) * d

    def ffn(d_ff: int) -> int:
        mults = 3 if c.activation == "swiglu" else 2
        return mults * d * d_ff

    if c.family == "ssm":  # RWKV6
        per = 0
        per += 6 * c.rwkv_lora_rank * d * 2          # ddlerp loras (r,k,v,g,w,x)
        per += 4 * d * d + d * d                     # r,k,v,g,o projections
        per += 2 * d * c.d_ff                        # channel mix (relu^2)
        return c.n_layers * per + embed
    if c.family == "hybrid":  # Zamba2
        d_in = c.ssm_expand * d
        nheads = d_in // c.ssm_head_dim
        per = d * (2 * d_in + 2 * c.ssm_state + nheads) + d_in * d  # in/out proj
        per += c.conv_width * (d_in + 2 * c.ssm_state)
        shared = (2 * d) * (c.n_heads * hd) + 2 * (2 * d) * (c.n_kv_heads * hd) \
            + (c.n_heads * hd) * d + 3 * (2 * d) * c.d_ff // 2 + c.d_ff // 2 * d
        return c.n_layers * per + shared + embed
    if c.family == "audio":
        enc = c.n_enc_layers * (attn + ffn(c.d_ff) + (2 * d * c.d_ff - ffn(c.d_ff)))
        enc = c.n_enc_layers * (attn + 2 * d * c.d_ff)
        dec = c.n_layers * (2 * attn + 2 * d * c.d_ff)   # self + cross attn
        return enc + dec + embed
    # dense / moe / vlm
    per_dense = attn + ffn(c.d_ff)
    if c.n_experts == 0:
        total = c.n_layers * per_dense
        if c.cross_attn_every:
            n_cross = c.n_layers // c.cross_attn_every
            total += n_cross * (attn + ffn(c.d_ff))
        return total + embed
    # MoE
    n_moe = c.n_layers // c.moe_every
    n_dense = c.n_layers - n_moe
    router = d * c.n_experts
    experts_all = c.n_experts * ffn(c.d_ff_expert)
    experts_act = c.top_k * ffn(c.d_ff_expert)
    shared = ffn(c.d_ff_shared) if c.shared_expert else 0
    per_moe_total = attn + router + experts_all + shared
    per_moe_act = attn + router + experts_act + shared
    per_moe = per_moe_act if active_only else per_moe_total
    return n_moe * per_moe + n_dense * per_dense + embed


# ---- shape cells (assigned input shapes; identical for every LM arch) ----

@dataclasses.dataclass(frozen=True)
class ShapeCell(ConfigBase):
    name: str = "train_4k"
    kind: str = "train"            # train | prefill | decode
    seq_len: int = 4096
    global_batch: int = 256


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)

SHAPES = {s.name: s for s in SHAPE_CELLS}


def cell_applicable(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the DESIGN.md §Arch-applicability rules."""
    if cell.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k-token decode is quadratic-cost; skipped per spec"
    return True, ""
