"""Model-building primitives: param declarations, norms, RoPE, init.

Parameters are declared as trees of ``ParamDecl`` — (shape, logical dim names,
dtype, init) — so the same declaration serves three consumers:
  * ``to_shape_tree``      -> ShapeDtypeStructs for the dry-run ``.lower()``
  * ``init_params``        -> real arrays for CPU smoke tests
  * ``distributed.sharding.build_specs`` -> divisibility-aware PartitionSpecs
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    names: Tuple[Optional[str], ...]   # logical dim names (None = no sharding)
    # f32 master weights (MaxText convention): compute casts to bf16 at the
    # scan-body slice via ``cast_compute`` — see §Perf "f32-master-params".
    dtype: Any = jnp.float32
    init: str = "normal"               # normal | zeros | ones | embed | small
    scale: float = 1.0                 # fan-in style multiplier for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.names), (self.shape, self.names)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def to_shape_tree(decls) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls, is_leaf=is_decl
    )


def names_tree(decls) -> Any:
    return jax.tree.map(lambda d: d.names, decls, is_leaf=is_decl)


def init_params(decls, seed: int = 0) -> Any:
    """Materialize real parameters (smoke tests / examples; NOT the dry-run)."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=is_decl)
    rng = np.random.default_rng(seed)
    out = []
    for d in leaves:
        if d.init == "zeros":
            a = np.zeros(d.shape, np.float32)
        elif d.init == "ones":
            a = np.ones(d.shape, np.float32)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(1, fan_in))
            if d.init == "embed":
                std = 0.02 * d.scale
            elif d.init == "small":
                std = 1e-3 * d.scale
            a = rng.normal(0.0, std, d.shape).astype(np.float32)
        out.append(jnp.asarray(a, dtype=d.dtype))
    return jax.tree.unflatten(treedef, out)


# ----------------------------------------------------------------- layers


def cast_compute(tree, dtype=jnp.bfloat16):
    """Cast f32 weight leaves to the compute dtype at USE site (inside scan
    bodies).  Params are STORED f32 (master weights); casting per-layer-slice
    keeps the backward scan's gradient stacks f32 end-to-end, which removes
    the full-stack bf16<->f32 convert round-trips XLA otherwise materializes
    per layer iteration (§Perf iteration "f32-master-params")."""
    return jax.tree.map(
        lambda t: t.astype(dtype) if (hasattr(t, "dtype") and t.dtype == jnp.float32
                                      and t.ndim >= 2) else t, tree)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e6) -> jax.Array:
    """x: (..., seq, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w_down)


def squared_relu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """Nemotron-4 style: relu(xW1)² W2."""
    h = jnp.einsum("...d,df->...f", x, w_up)
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array, w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_up) + b_up.astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_down) + b_down.astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None,
    vocab_valid: int | None = None,
) -> jax.Array:
    """Stable CE over (possibly padded, possibly vocab-sharded) logits."""
    lg = logits.astype(jnp.float32)
    if vocab_valid is not None and vocab_valid < lg.shape[-1]:
        pad = jnp.arange(lg.shape[-1]) >= vocab_valid
        lg = jnp.where(pad, -1e30, lg)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
