"""Mixture-of-Experts layer: GROUP-LOCAL sort-based dispatch (GSPMD-style).

The dispatch is the same sort+segment GroupBy pattern as the paper's Louvain
aggregation (DESIGN.md §5 kinship).  V1 used one flat dispatch over all
global tokens — profiling the dry-run showed XLA turning the global
gather/scatter into per-layer all-reduces of full activation buffers
(§Perf iteration "moe-group-dispatch", before: collective term 51.3 s on
qwen3-moe train_4k).  V2 restructures the computation so every gather /
scatter is LOCAL to a data shard:

  x (B,S,D) -> (G, Tg, D)        G = number of data shards (static)
  router/top-k/sort/capacity     per group, vmapped — no cross-group indices
  buf (G, E, Cg, D)              scatter within group (local)
  constrain E -> 'model'         THE one reshard (data-sharded G stays)
  expert FFN                     einsum batched over (G, Cg) — fully local
  scatter-back partial y + sum   partials over 'model' — one reduction

Aux losses: Switch load-balance + router z-loss, averaged over groups.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import active_mesh, constrain


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


def _n_groups(total_tokens: int) -> int:
    """Static dispatch-group count = data-parallel extent of the active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        g *= mesh.shape.get(ax, 1)
    while g > 1 and total_tokens % g:
        g //= 2
    return max(1, g)


def _dispatch_indices(expert_ids: jax.Array, n_experts: int, capacity: int):
    """expert_ids: (T,) int32 — returns (slot, keep): slot in [0, E*C)."""
    t = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    starts = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    pos = jnp.arange(t, dtype=jnp.int32)
    run_start_pos = jnp.where(starts, pos, 0)
    run_start_pos = jax.lax.associative_scan(jnp.maximum, run_start_pos)
    rank_sorted = pos - run_start_pos
    rank = jnp.zeros((t,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < capacity
    slot = jnp.clip(expert_ids, 0, n_experts - 1) * capacity + jnp.clip(
        rank, 0, capacity - 1
    )
    return slot, keep


def moe_layer(
    x: jax.Array,            # (B, S, D)
    w_router: jax.Array,     # (D, E)
    w_gate: jax.Array,       # (E, D, F)
    w_up: jax.Array,         # (E, D, F)
    w_down: jax.Array,       # (E, F, D)
    top_k: int,
    capacity_factor: float = 1.25,
    router_z_coef: float = 1e-3,
    balance_coef: float = 1e-2,
) -> MoEOut:
    b, s, d = x.shape
    e = w_router.shape[-1]
    t = b * s
    G = _n_groups(t)
    tg = t // G
    xg = x.reshape(G, tg, d)
    xg = constrain(xg, ("batch", None, None))           # G over data axes

    # expert weights: constrain to expert-sharding only at USE site — when
    # stored FSDP ('embed' over data) this is an explicit per-layer weight
    # all-gather instead of an (8x bigger) activation psum
    w_gate = constrain(w_gate, ("experts_act", None, None))
    w_up = constrain(w_up, ("experts_act", None, None))
    w_down = constrain(w_down, ("experts_act", None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)          # (G, Tg, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    capacity = max(8, int(capacity_factor * top_k * tg / e))
    flat_e = top_e.reshape(G, tg * top_k).astype(jnp.int32)
    flat_w = top_p.reshape(G, tg * top_k)
    slot, keep = jax.vmap(
        lambda ids: _dispatch_indices(ids, e, capacity))(flat_e)

    token_of = jnp.tile(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), top_k)[None], (G, 1))

    def scatter_group(xt_g, slot_g, keep_g, token_g):
        buf = jnp.zeros((e * capacity, d), x.dtype)
        idx = jnp.where(keep_g, slot_g, e * capacity - 1)
        return buf.at[idx].add(
            jnp.where(keep_g[:, None], xt_g[token_g], 0).astype(x.dtype))

    buf = jax.vmap(scatter_group)(xg, slot, keep, token_of)   # (G, E*C, D)
    buf = buf.reshape(G, e, capacity, d)
    # THE reshard: G stays on data axes, experts go to 'model'
    buf = constrain(buf, ("batch", "experts_act", None, None))

    # expert FFN (SwiGLU), batched over (G, C)
    g_ = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    u_ = jnp.einsum("gecd,edf->gecf", buf, w_up)
    h = jax.nn.silu(g_.astype(jnp.float32)).astype(x.dtype) * u_
    yb = jnp.einsum("gecf,efd->gecd", h, w_down)
    yb = constrain(yb, ("batch", "experts_act", None, None))
    yb = yb.reshape(G, e * capacity, d)

    # combine: gather each assignment's expert output within its group,
    # weight, scatter-add back to token positions (partials summed over the
    # expert shards by the partitioner)
    def combine_group(yb_g, slot_g, keep_g, w_g, token_g):
        contrib = jnp.where(keep_g[:, None],
                            yb_g[jnp.clip(slot_g, 0, e * capacity - 1)], 0)
        contrib = contrib * w_g[:, None].astype(x.dtype)
        return jnp.zeros((tg, d), x.dtype).at[token_g].add(contrib)

    y = jax.vmap(combine_group)(yb, slot, keep, flat_w, token_of)
    y = constrain(y, ("batch", None, None))

    # Switch aux losses (group-averaged)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    one_hot_top1 = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    balance = e * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = balance_coef * balance + router_z_coef * z
    return MoEOut(y.reshape(b, s, d), aux.astype(jnp.float32))
