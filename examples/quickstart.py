"""Quickstart: community detection with the repro framework (30 seconds).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.louvain import louvain
from repro.core.plp import PLPConfig, plp
from repro.core.modularity import modularity
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import nmi, sbm


def main():
    # a planted-partition graph: 1000 vertices, 20 communities
    u, v, w, truth = sbm(1000, 20, p_in=0.3, p_out=0.005, seed=0)
    g = from_numpy_edges(u, v, w)
    print(f"graph: {int(g.n_valid)} vertices, {int(g.m_valid)//2} undirected edges")

    # --- parallel label propagation (paper Alg. 1) ---
    r = plp(g, PLPConfig(max_iterations=50))
    print(f"PLP      : {r.iterations} iterations, "
          f"{len(set(np.asarray(r.labels)[:1000].tolist()))} communities, "
          f"NMI vs truth = {nmi(np.asarray(r.labels)[:1000], truth):.3f}")

    # --- parallel Louvain (paper Alg. 2/3) ---
    res = louvain(g)
    print(f"Louvain  : {res.levels} levels, {int(res.n_communities)} communities, "
          f"Q = {res.modularity:.4f}, "
          f"NMI vs truth = {nmi(np.asarray(res.labels)[:1000], truth):.3f}")


if __name__ == "__main__":
    main()
