"""Resilient serving: deadlines, backpressure, circuit breakers, and
stage-boundary checkpoint/resume (DESIGN.md §Resilience).

    PYTHONPATH=src python examples/resilient_serve.py

Demonstrates the four resilience layers on top of the batched service:

  1. Per-request ``deadline_ms`` — a stalled dispatch is abandoned by
     the watchdog and fails ONLY the over-deadline requests with a
     typed ``DeadlineError``; the service never hangs.
  2. Bounded-queue admission control — depth + estimated-cost sheds
     answer at ``submit()`` time with a typed ``OverloadError``.
  3. Retries + per-signature circuit breakers — a transient batch
     failure is retried with jittered backoff; a persistently failing
     signature bucket trips open and probes its way back.
  4. ``LouvainConfig(checkpoint_dir=...)`` — a long cascade killed
     mid-run resumes from the last completed stage, bit-identical to
     an uninterrupted run.
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from launch.community_serve import CommunityRequest, CommunityServeEngine
from repro.core.louvain import LouvainConfig, louvain
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import sbm
from repro.utils import faultinject, resilience, telemetry


def demo_deadlines_and_backpressure():
    print("== deadlines + bounded queue ==")
    eng = CommunityServeEngine(max_queue_depth=3, max_retries=1,
                               backoff_base_s=0.01)
    responses = []
    for i in range(5):
        u, v, _w, _t = sbm(30, 3, p_in=0.35, p_out=0.03, seed=i)
        rejected = eng.submit(CommunityRequest(
            request_id=f"r{i}", u=u, v=v, n=30, deadline_ms=60000.0))
        if rejected is not None:  # shed at the door, typed, immediate
            responses.append(rejected)
    responses += eng.flush()
    for r in sorted(responses, key=lambda r: r.request_id):
        print(f"  {r.request_id}: ok={r.ok}"
              + ("" if r.ok else f"  {r.error.splitlines()[0]}"))


def demo_retry_absorbs_transient_fault():
    print("== transient batch failure absorbed by retry ==")
    eng = CommunityServeEngine(max_retries=2, backoff_base_s=0.01)
    telemetry.reset()
    with faultinject.inject("transient_batch_fail"):
        faultinject.set_fuel("transient_batch_fail", 1)  # exactly one fire
        u, v, _w, _t = sbm(30, 3, p_in=0.35, p_out=0.03, seed=7)
        eng.submit(CommunityRequest(request_id="t0", u=u, v=v, n=30))
        responses = eng.flush()
    print(f"  ok={all(r.ok for r in responses)} "
          f"retries={telemetry.get('serve.retry')} "
          f"breaker_trips={telemetry.get('serve.breaker_trip')}")


def demo_checkpoint_resume():
    print("== checkpoint/resume: kill mid-cascade, resume bit-identical ==")
    # ring of cliques — coarsens through 2 cascade stages, so there is a
    # stage boundary to checkpoint at
    edges = []
    n, k = 600, 20
    for c in range(n // k):
        base = c * k
        for i in range(k):
            for j in range(i + 1, k):
                edges.append((base + i, base + j))
        edges.append((base, ((c + 1) % (n // k)) * k))
    e = np.array(edges, np.int64)
    g = from_numpy_edges(e[:, 0], e[:, 1], n=n)
    cfg = LouvainConfig(capacity_schedule=((256, 2048),), backend="segment")

    oracle = louvain(g, cfg)  # uninterrupted reference

    with tempfile.TemporaryDirectory() as ckpt_dir:
        cfg_ck = cfg.replace(checkpoint_dir=ckpt_dir)
        telemetry.reset()
        try:
            with faultinject.inject("preempt_stage"):
                louvain(g, cfg_ck)  # killed at the stage boundary
        except resilience.Preempted as exc:
            print(f"  killed: {exc}")
        print(f"  stages checkpointed: {telemetry.get('louvain.ckpt_save')}")

        res = louvain(g, cfg_ck)  # same config + dir -> resumes
        print(f"  resumed from checkpoint: "
              f"{telemetry.get('louvain.ckpt_resume') == 1}")
        print(f"  bit-identical labels:    "
              f"{bool(np.array_equal(res.labels, oracle.labels))}")
        print(f"  identical modularity:    "
              f"{res.modularity == oracle.modularity}")


if __name__ == "__main__":
    demo_deadlines_and_backpressure()
    demo_retry_absorbs_transient_fault()
    demo_checkpoint_resume()
