"""End-to-end reproduction of the paper's experimental pipeline (laptop scale).

Runs both algorithms on the six SNAP stand-ins (Table I), against the
NetworkX baselines the paper compares with, and prints runtime + modularity
tables mirroring Figs. 1-3.

    PYTHONPATH=src python examples/paper_pipeline.py [--scale 0.03125]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=None,
                    help="fraction of the paper's |V| (default 1/32)")
    args = ap.parse_args()
    if args.scale:
        os.environ["REPRO_DATASET_SCALE"] = str(args.scale)

    from benchmarks.run import bench_table1, bench_fig1_lpa, bench_fig2_fig3_louvain
    print("===== Table I (datasets) =====")
    bench_table1()
    print("\n===== Fig. 1 (LPA runtime) =====")
    bench_fig1_lpa()
    print("\n===== Fig. 2/3 (Louvain runtime + modularity) =====")
    bench_fig2_fig3_louvain()


if __name__ == "__main__":
    main()
