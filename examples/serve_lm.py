"""Batched serving example: slot-based continuous batching over a small LM.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

from repro import configs
from repro.launch.serve import Request, ServeEngine
from repro.models import api as model_api
from repro.models.common import init_params


def main():
    c = configs.get("qwen3-1.7b", reduced=True)
    model = model_api.build(c)
    params = init_params(model.decls, seed=0)
    engine = ServeEngine(c, params, batch_slots=4, max_seq=128)

    requests = [Request(prompt=[10 + i, 20 + i, 30 + i], max_new=12)
                for i in range(10)]
    t0 = time.time()
    done = engine.run(requests)
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {total_new} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s, batch_slots=4)")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt={list(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()
