"""Batched many-graph serving: cluster a stream of ego-net-sized graphs
through the capacity-bucketed batch engine (DESIGN.md §Serving).

    PYTHONPATH=src python examples/batch_serve.py

Demonstrates the three layers of the serving stack:

  1. ``louvain_batch``/``plp_batch`` — bucket → pack → one vmapped
     dispatch per bucket, bit-identical to the single-graph drivers.
  2. The bounded compiled-program caches — a second wave of fresh
     same-signature traffic adds ZERO compiles.
  3. ``CommunityServeEngine`` — the request-batching service: robust
     ingest, per-request RunReports, poisoned requests isolated.
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from launch.community_serve import CommunityRequest, CommunityServeEngine
from repro.core import progcache
from repro.core.batch import louvain_batch
from repro.core.louvain import louvain
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import sbm
from repro.kernels.common import capacity_signature


def make_egonets(count, seed=0):
    """Ego-net-scale planted-partition stand-ins (tens of vertices)."""
    rng = np.random.default_rng(seed)
    graphs = []
    for i in range(count):
        n = int(rng.choice((25, 35, 45)))
        u, v, _w, _t = sbm(n, int(rng.integers(3, 6)),
                           p_in=0.35, p_out=0.03, seed=seed + 31 * i)
        graphs.append((n, u, v))
    return graphs


def main():
    egonets = make_egonets(64)

    # --- 1. direct batch API: one dispatch, bitwise parity -----------------
    graphs = [from_numpy_edges(u, v, n=n) for n, u, v in egonets]
    sigs = {capacity_signature(g.n_max, g.m_max) for g in graphs}
    print(f"{len(graphs)} graphs -> {len(sigs)} capacity bucket(s): "
          f"{sorted((s.n_cap, s.m_cap) for s in sigs)}")

    results = louvain_batch(graphs)          # compiles once per bucket
    t0 = time.perf_counter()
    results = louvain_batch(graphs)          # steady state: cache hit
    batched_s = time.perf_counter() - t0
    oracle = louvain(graphs[0])
    assert np.array_equal(results[0].labels, oracle.labels)
    assert results[0].modularity == oracle.modularity
    print(f"batched: {len(graphs)} graphs in {batched_s*1e3:.1f} ms "
          f"({len(graphs)/batched_s:.0f} graphs/s), "
          f"slot 0 bit-identical to unbatched louvain()")

    # --- 2. zero steady-state recompiles ----------------------------------
    before = progcache.cache_stats()["batch.louvain"]["misses"]
    fresh = [from_numpy_edges(u, v, n=n) for n, u, v in make_egonets(8, seed=99)]
    louvain_batch(fresh)                     # new graphs, same signatures
    after = progcache.cache_stats()["batch.louvain"]["misses"]
    print(f"fresh same-signature traffic: {after - before} new compiles")

    # --- 3. the request-batching service ----------------------------------
    eng = CommunityServeEngine()
    for i, (n, u, v) in enumerate(make_egonets(16, seed=7)):
        eng.submit(CommunityRequest(request_id=f"ego{i}", u=u, v=v, n=n,
                                    algo="plp" if i % 2 else "louvain"))
    # a malformed request: rejected at ingest, never joins a batch
    eng.submit(CommunityRequest(request_id="poison",
                                u=np.array([0, 1]), v=np.array([1, 0]),
                                w=np.array([np.nan, np.nan])))
    responses = eng.flush()
    ok = [r for r in responses if r.ok]
    bad = [r for r in responses if not r.ok]
    print(f"service: {len(ok)} served / {len(bad)} rejected "
          f"(mean batch size {np.mean([r.batch_size for r in ok]):.1f})")
    for r in ok[:2]:
        print(f"  {r.request_id}: {len(set(r.labels.tolist()))} communities, "
              f"latency {r.latency_s*1e3:.1f} ms, signature {r.signature}")
    print(f"  {bad[0].request_id}: rejected ({bad[0].error.split(':')[0]})")
    stats = eng.stats()
    print(f"stats: served={stats['served']} dispatches={stats['dispatches']} "
          f"programs={sorted(stats['programs'])}")


if __name__ == "__main__":
    main()
