"""End-to-end training driver: ~100M-parameter qwen3-family LM, a few hundred
steps on CPU with checkpointing — the framework's full train path (data
pipeline -> model -> optimizer -> checkpoints) at laptop scale.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import json
import os

from repro.configs.qwen3_8b import CONFIG
from repro.launch.train import train
from repro.models.arch_config import ShapeCell


def make_100m():
    """qwen3-family ~100M config (exact same block structure as qwen3-8b)."""
    return CONFIG.replace(
        name="qwen3-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=65536, grad_accum=1, kv_repeat_to=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    c = make_100m()
    n_params = c.total_params()
    print(f"arch {c.name}: {n_params/1e6:.1f}M params")
    cell = ShapeCell("example", "train", args.seq_len, args.global_batch)
    params, opt, hist = train(
        c, cell, steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=20)
    out = {
        "params_m": n_params / 1e6,
        "first_loss": hist[0]["loss"],
        "final_loss": hist[-1]["loss"],
        "steps": len(hist),
        "tokens_seen": len(hist) * args.seq_len * args.global_batch,
    }
    art = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "artifacts", "train_lm_example.json")
    os.makedirs(os.path.dirname(art), exist_ok=True)
    with open(art, "w") as f:
        json.dump({"history": hist, **out}, f, indent=1)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
