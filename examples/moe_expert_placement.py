"""Beyond-paper integration: Louvain community detection for MoE expert
placement (see src/repro/core/expert_placement.py and DESIGN.md §9).

Builds a skewed synthetic router trace (experts co-fire in latent clusters,
as observed in practice), then compares cross-device dispatch traffic under
random vs Louvain-derived placement.

    PYTHONPATH=src python examples/moe_expert_placement.py
"""
import numpy as np

from repro.core.expert_placement import (
    coactivation_graph, louvain_placement, placement_traffic, random_placement)


def synth_routing(n_tokens=20000, n_experts=128, top_k=8, n_latent=16, seed=0):
    """Tokens pick a latent topic; experts cluster around topics (realistic
    co-activation skew for a trained router)."""
    rng = np.random.default_rng(seed)
    topic_of_expert = rng.integers(0, n_latent, n_experts)
    experts_by_topic = [np.where(topic_of_expert == t)[0] for t in range(n_latent)]
    out = np.zeros((n_tokens, top_k), np.int32)
    for i in range(n_tokens):
        t = rng.integers(0, n_latent)
        pool = experts_by_topic[t]
        if rng.random() < 0.2 or pool.size < top_k:  # 20% off-topic leakage
            out[i] = rng.choice(n_experts, top_k, replace=False)
        else:
            out[i] = rng.choice(pool, top_k, replace=pool.size < top_k)
    return out


def main():
    n_experts, n_groups, top_k = 128, 16, 8   # qwen3-moe on a 16-way EP axis
    routing = synth_routing(n_experts=n_experts, top_k=top_k)
    g = coactivation_graph(routing, n_experts)
    pl_rand = random_placement(n_experts, n_groups)
    pl_louv = louvain_placement(g, n_experts, n_groups)
    t_rand = placement_traffic(routing, pl_rand, n_groups)
    t_louv = placement_traffic(routing, pl_louv, n_groups)
    print(f"experts={n_experts} groups={n_groups} top_k={top_k}")
    print(f"cross-group dispatch fraction:")
    print(f"  random placement : {t_rand:.3f}")
    print(f"  louvain placement: {t_louv:.3f}")
    print(f"  reduction        : {100*(1 - t_louv/t_rand):.1f}% of correlated "
          f"all-to-all traffic avoided")
    assert t_louv < t_rand, "Louvain placement should beat random"


if __name__ == "__main__":
    main()
