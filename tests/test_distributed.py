"""Distributed tests (8 fake host devices, subprocess-isolated where needed):
  * distributed PLP/Louvain vs single-device quality parity;
  * logical sharding rules: divisibility-aware resolution;
  * sharded train step == unsharded train step (numerics);
  * int8 gradient compression bounded error.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
           XLA_FLAGS="--xla_force_host_platform_device_count=8")


def _run_py(code: str) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    return p.stdout


def test_distributed_louvain_quality_parity():
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import sbm, nmi
        from repro.graph.builders import from_numpy_edges
        from repro.core.louvain import louvain
        from repro.core.distributed import distributed_louvain
        u,v,w,gt = sbm(400, 8, p_in=0.3, p_out=0.01, seed=2)
        g = from_numpy_edges(u,v,w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        rd = distributed_louvain(g, mesh)
        rs = louvain(g)
        print('DIST', float(rd.modularity), 'SINGLE', float(rs.modularity),
              'NMI', nmi(np.asarray(rd.labels)[:len(gt)], gt))
    """)
    toks = out.split()
    q_dist, q_single, nmi_v = float(toks[1]), float(toks[3]), float(toks[5])
    assert q_dist > q_single - 0.05
    assert nmi_v > 0.85


def test_distributed_pipeline_level_loop_in_worker():
    """pipeline_fused=True: the whole level loop runs inside the shard_map
    worker (one dispatch, one readback).  Must agree with the per-level
    distributed driver on quality, produce coherent per-level histories,
    and be deterministic across calls."""
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import sbm, nmi
        from repro.graph.builders import from_numpy_edges
        from repro.core.distributed import distributed_louvain
        u,v,w,gt = sbm(400, 8, p_in=0.3, p_out=0.01, seed=2)
        g = from_numpy_edges(u,v,w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        rp = distributed_louvain(g, mesh, pipeline_fused=True)
        rl = distributed_louvain(g, mesh, pipeline_fused=False)
        rp2 = distributed_louvain(g, mesh, pipeline_fused=True)
        assert rp.levels == len(rp.sweeps_per_level) == len(rp.n_comm_per_level)
        assert all(s >= 1 for s in rp.sweeps_per_level)
        assert rp.n_comm_per_level[-1] == rp.n_communities
        assert np.array_equal(rp.labels, rp2.labels)
        print('PIPE', float(rp.modularity), 'STEP', float(rl.modularity),
              'NMI', nmi(np.asarray(rp.labels)[:len(gt)], gt))
    """)
    toks = out.split()
    q_pipe, q_step, nmi_v = float(toks[1]), float(toks[3]), float(toks[5])
    assert q_pipe > q_step - 0.03
    assert nmi_v > 0.85


def test_shard_local_bitwise_parity_all_mesh_sizes():
    """The PR-10 invariant: shard-local coarsening ≡ replicated oracle ≡
    local fused driver BIT-FOR-BIT (labels, Q, every per-level history) on
    1/2/4/8 emulated devices, for both Louvain and Leiden; the shard-local
    collective payload stays under the replicated all_gather baseline; a
    halo-cap overflow degrades to replicated with identical results."""
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import sbm
        from repro.graph.builders import from_numpy_edges
        from repro.core.louvain import louvain, leiden, LouvainConfig
        from repro.core.distributed import (distributed_leiden,
                                            distributed_louvain)
        u, v, w, _ = sbm(400, 8, p_in=0.3, p_out=0.01, seed=2)
        g = from_numpy_edges(u, v, w)
        rloc = {"louvain": louvain(g, LouvainConfig()),
                "leiden": leiden(g, LouvainConfig())}
        for nd in (1, 2, 4, 8):
            mesh = Mesh(np.array(jax.devices()[:nd]).reshape(nd), ('data',))
            for name, dfn in (("louvain", distributed_louvain),
                              ("leiden", distributed_leiden)):
                rs = dfn(g, mesh, coarsening="shard_local")
                rr = dfn(g, mesh, coarsening="replicated")
                rl = rloc[name]
                tag = (nd, name)
                assert np.array_equal(rs.labels, rr.labels), tag
                assert np.array_equal(rs.labels, rl.labels), tag
                assert rs.modularity == rr.modularity == float(rl.modularity), tag
                assert rs.levels == rr.levels == rl.levels, tag
                assert (rs.sweeps_per_level == rr.sweeps_per_level
                        == rl.sweeps_per_level), tag
                assert (rs.n_comm_per_level == rr.n_comm_per_level
                        == rl.n_comm_per_level), tag
                assert (rs.modularity_history == rr.modularity_history
                        == [float(x) for x in rl.modularity_history]), tag
                assert (rs.delta_n_per_level == rr.delta_n_per_level
                        == rl.delta_n_per_level), tag
                assert rs.coarsening == "shard_local", tag
                assert rs.run_report.degradations == [], tag
                # O(boundary + communities) payload, never O(m): every
                # level's actual collective bytes under the all_gather bar
                cs = rs.comm_stats
                rep = cs["bytes_per_level_model"]["replicated"]
                assert cs["actual_bytes_per_level"], tag
                assert all(b < rep for b in cs["actual_bytes_per_level"]), tag
                assert all(p >= 0 for p in cs["gathered_groups_per_level"]), tag
                assert rs.partition_stats["imbalance"] >= 1.0, tag
            print("MESH_OK", nd)
        # halo-cap overflow: degraded to replicated, results identical
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ('data',))
        rr = distributed_louvain(g, mesh, coarsening="replicated")
        ro = distributed_louvain(g, mesh, coarsening="shard_local", halo_cap=8)
        assert np.array_equal(ro.labels, rr.labels)
        assert ro.modularity == rr.modularity
        assert ro.coarsening == "replicated"
        assert any(d["kind"] == "halo_overflow"
                   for d in ro.run_report.degradations)
        print("OVERFLOW_OK")
        print("DONE")
    """)
    assert "DONE" in out and "OVERFLOW_OK" in out


def test_shard_local_parity_degenerate_mesh():
    """Empty shards (more devices than populated vertex ranges) keep the
    bitwise-parity invariant — the two-phase contiguize and the halo merge
    must survive devices that own nothing."""
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import ring_of_cliques
        from repro.graph.builders import from_numpy_edges
        from repro.core.louvain import louvain, LouvainConfig
        from repro.core.distributed import distributed_louvain
        u, v, w, _ = ring_of_cliques(4, 5)   # 20 vertices on 8 devices
        g = from_numpy_edges(u, v, w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        rs = distributed_louvain(g, mesh, coarsening="shard_local")
        rr = distributed_louvain(g, mesh, coarsening="replicated")
        rl = louvain(g, LouvainConfig())
        assert np.array_equal(rs.labels, rr.labels)
        assert np.array_equal(rs.labels, rl.labels)
        assert rs.modularity == rr.modularity == float(rl.modularity)
        assert rs.delta_n_per_level == rl.delta_n_per_level
        print("DEGENERATE_OK")
    """)
    assert "DEGENERATE_OK" in out


def test_halo_table_ownership_and_degenerate_meshes():
    """Host-side halo/ghost-table unit tests (no devices needed):
    boundary-vertex ownership, empty-shard and single-owner meshes."""
    import numpy as np

    from repro.graph.builders import from_numpy_edges
    from repro.graph.generators import sbm
    from repro.graph.partition import (build_halo, owner_of_vertices,
                                       partition_edges_by_dst,
                                       partition_quality)

    u, v, w, _ = sbm(200, 4, p_in=0.3, p_out=0.05, seed=7)
    g = from_numpy_edges(u, v, w)
    part = partition_edges_by_dst(g, 4)
    owner = owner_of_vertices(part)
    halo = build_halo(part)
    assert halo.owner_of.shape == (g.n_max,)
    for d in range(4):
        srcs = part.src[d][part.edge_mask[d]]
        ghosts = halo.ghost_ids[d][halo.ghost_mask[d]]
        # every ghost is a boundary src owned elsewhere...
        assert np.all(owner[ghosts] != d)
        assert set(ghosts) <= set(srcs)
        # ...and every non-owned src IS a ghost (nothing missed)
        foreign = np.unique(srcs[owner[srcs] != d])
        assert np.array_equal(np.sort(ghosts), foreign)
        assert halo.ghost_counts[d] == foreign.size
        # sentinel discipline on the padded rectangle
        assert np.all(halo.ghost_ids[d][~halo.ghost_mask[d]] == g.n_max)
    pq = partition_quality(part, halo)
    assert pq.imbalance >= 1.0
    assert 0.0 < pq.cut_fraction < 1.0
    assert pq.halo_factor >= 1.0
    assert pq.total_ghosts == int(halo.ghost_counts.sum())

    # single-owner mesh: no ghosts anywhere, zero cut
    p1 = partition_edges_by_dst(g, 1)
    h1 = build_halo(p1)
    assert h1.total_ghosts == 0
    q1 = partition_quality(p1, h1)
    assert q1.cut_fraction == 0.0
    assert q1.halo_factor == 1.0

    # empty shards: a 2-vertex graph split 8 ways leaves most devices
    # without edges — their ghost rows must be empty, not garbage
    u2 = np.array([0, 1], np.int64)
    v2 = np.array([1, 0], np.int64)
    g2 = from_numpy_edges(u2, v2)
    p2 = partition_edges_by_dst(g2, 8)
    h2 = build_halo(p2)
    empty = [d for d in range(8) if not p2.edge_mask[d].any()]
    assert empty, "expected at least one empty shard"
    for d in empty:
        assert h2.ghost_counts[d] == 0
        assert not h2.ghost_mask[d].any()
    q2 = partition_quality(p2, h2)
    assert q2.imbalance >= 1.0


def test_distributed_plp_runs_and_converges():
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import ring_of_cliques, nmi
        from repro.graph.builders import from_numpy_edges
        from repro.core.distributed import distributed_plp
        u,v,w,gt = ring_of_cliques(8, 6)
        g = from_numpy_edges(u,v,w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        labels, history = distributed_plp(g, mesh, max_iterations=40)
        print('NMI', nmi(np.asarray(labels)[:len(gt)], gt), 'ITERS', len(history))
    """)
    assert float(out.split()[1]) > 0.9


def test_sharding_rules_divisibility():
    out = _run_py("""
        import jax
        from jax.sharding import PartitionSpec as PS
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        with shd.use_mesh(mesh):
            # divisible: sharded
            s1 = shd.resolve_spec(('embed', 'mlp'), (64, 128))
            # vocab 51866 not divisible by model=4 -> replicated
            s2 = shd.resolve_spec(('vocab', 'embed'), (51866, 64))
            # 'pod' absent from mesh -> filtered out of 'embed'
            s3 = shd.resolve_spec(('batch', None), (16, 7))
            print(repr(s1)); print(repr(s2)); print(repr(s3))
    """)
    lines = out.strip().splitlines()
    assert "'data'" in lines[0] and "'model'" in lines[0]
    assert lines[1].startswith("PartitionSpec(None") or "None" in lines[1]
    assert "'data'" in lines[2]


def test_sharded_train_matches_unsharded():
    out = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import build_trainer
        from repro.models.arch_config import ShapeCell
        from repro.train.data import make_batch
        c = configs.get('qwen3-1.7b', reduced=True)
        cell = ShapeCell('t', 'train', 64, 4)
        batch_np = make_batch(c, cell, 0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        losses = {}
        for tag, mesh in (('un', None), ('sh', make_host_mesh(2, 4))):
            with shd.use_mesh(mesh):
                model, step, init_fn = build_trainer(c, cell, mesh)
                params, opt = init_fn(0)
                for i in range(3):
                    b = {k: jnp.asarray(v) for k, v in make_batch(c, cell, i).items()}
                    params, opt, m = step(params, opt, b)
                losses[tag] = float(m['loss'])
        print('UN', losses['un'], 'SH', losses['sh'])
    """)
    toks = out.split()
    assert abs(float(toks[1]) - float(toks[3])) < 2e-2, out


def test_int8_grad_compression_bounded_error():
    out = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.train_step import quantize_grads_int8
        rng = np.random.default_rng(0)
        g = {'w': jnp.asarray(rng.standard_normal((64, 64)) * 1e-3, jnp.float32)}
        q = quantize_grads_int8(g)
        rel = float(jnp.linalg.norm(q['w'] - g['w']) / jnp.linalg.norm(g['w']))
        print('REL', rel)
    """)
    assert float(out.split()[1]) < 0.01


def test_multipod_mesh_axes():
    # 512 fake devices need their own subprocess (device count locks on init)
    code = "\n".join([
        "import os",
        'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"',
        "from repro.launch.mesh import make_production_mesh",
        "m1 = make_production_mesh(multi_pod=False)",
        "m2 = make_production_mesh(multi_pod=True)",
        "print(dict(m1.shape), dict(m2.shape))",
    ])
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=ENV, cwd=REPO, timeout=900)
    assert p.returncode == 0, p.stderr
    out = p.stdout
    assert "'pod': 2" in out and "'data': 16" in out and "'model': 16" in out
