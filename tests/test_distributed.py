"""Distributed tests (8 fake host devices, subprocess-isolated where needed):
  * distributed PLP/Louvain vs single-device quality parity;
  * logical sharding rules: divisibility-aware resolution;
  * sharded train step == unsharded train step (numerics);
  * int8 gradient compression bounded error.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
           XLA_FLAGS="--xla_force_host_platform_device_count=8")


def _run_py(code: str) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    return p.stdout


def test_distributed_louvain_quality_parity():
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import sbm, nmi
        from repro.graph.builders import from_numpy_edges
        from repro.core.louvain import louvain
        from repro.core.distributed import distributed_louvain
        u,v,w,gt = sbm(400, 8, p_in=0.3, p_out=0.01, seed=2)
        g = from_numpy_edges(u,v,w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        rd = distributed_louvain(g, mesh)
        rs = louvain(g)
        print('DIST', float(rd.modularity), 'SINGLE', float(rs.modularity),
              'NMI', nmi(np.asarray(rd.labels)[:len(gt)], gt))
    """)
    toks = out.split()
    q_dist, q_single, nmi_v = float(toks[1]), float(toks[3]), float(toks[5])
    assert q_dist > q_single - 0.05
    assert nmi_v > 0.85


def test_distributed_pipeline_level_loop_in_worker():
    """pipeline_fused=True: the whole level loop runs inside the shard_map
    worker (one dispatch, one readback).  Must agree with the per-level
    distributed driver on quality, produce coherent per-level histories,
    and be deterministic across calls."""
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import sbm, nmi
        from repro.graph.builders import from_numpy_edges
        from repro.core.distributed import distributed_louvain
        u,v,w,gt = sbm(400, 8, p_in=0.3, p_out=0.01, seed=2)
        g = from_numpy_edges(u,v,w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        rp = distributed_louvain(g, mesh, pipeline_fused=True)
        rl = distributed_louvain(g, mesh, pipeline_fused=False)
        rp2 = distributed_louvain(g, mesh, pipeline_fused=True)
        assert rp.levels == len(rp.sweeps_per_level) == len(rp.n_comm_per_level)
        assert all(s >= 1 for s in rp.sweeps_per_level)
        assert rp.n_comm_per_level[-1] == rp.n_communities
        assert np.array_equal(rp.labels, rp2.labels)
        print('PIPE', float(rp.modularity), 'STEP', float(rl.modularity),
              'NMI', nmi(np.asarray(rp.labels)[:len(gt)], gt))
    """)
    toks = out.split()
    q_pipe, q_step, nmi_v = float(toks[1]), float(toks[3]), float(toks[5])
    assert q_pipe > q_step - 0.03
    assert nmi_v > 0.85


def test_distributed_plp_runs_and_converges():
    out = _run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph.generators import ring_of_cliques, nmi
        from repro.graph.builders import from_numpy_edges
        from repro.core.distributed import distributed_plp
        u,v,w,gt = ring_of_cliques(8, 6)
        g = from_numpy_edges(u,v,w)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
        labels, history = distributed_plp(g, mesh, max_iterations=40)
        print('NMI', nmi(np.asarray(labels)[:len(gt)], gt), 'ITERS', len(history))
    """)
    assert float(out.split()[1]) > 0.9


def test_sharding_rules_divisibility():
    out = _run_py("""
        import jax
        from jax.sharding import PartitionSpec as PS
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        with shd.use_mesh(mesh):
            # divisible: sharded
            s1 = shd.resolve_spec(('embed', 'mlp'), (64, 128))
            # vocab 51866 not divisible by model=4 -> replicated
            s2 = shd.resolve_spec(('vocab', 'embed'), (51866, 64))
            # 'pod' absent from mesh -> filtered out of 'embed'
            s3 = shd.resolve_spec(('batch', None), (16, 7))
            print(repr(s1)); print(repr(s2)); print(repr(s3))
    """)
    lines = out.strip().splitlines()
    assert "'data'" in lines[0] and "'model'" in lines[0]
    assert lines[1].startswith("PartitionSpec(None") or "None" in lines[1]
    assert "'data'" in lines[2]


def test_sharded_train_matches_unsharded():
    out = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import configs
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import build_trainer
        from repro.models.arch_config import ShapeCell
        from repro.train.data import make_batch
        c = configs.get('qwen3-1.7b', reduced=True)
        cell = ShapeCell('t', 'train', 64, 4)
        batch_np = make_batch(c, cell, 0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        losses = {}
        for tag, mesh in (('un', None), ('sh', make_host_mesh(2, 4))):
            with shd.use_mesh(mesh):
                model, step, init_fn = build_trainer(c, cell, mesh)
                params, opt = init_fn(0)
                for i in range(3):
                    b = {k: jnp.asarray(v) for k, v in make_batch(c, cell, i).items()}
                    params, opt, m = step(params, opt, b)
                losses[tag] = float(m['loss'])
        print('UN', losses['un'], 'SH', losses['sh'])
    """)
    toks = out.split()
    assert abs(float(toks[1]) - float(toks[3])) < 2e-2, out


def test_int8_grad_compression_bounded_error():
    out = _run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.train_step import quantize_grads_int8
        rng = np.random.default_rng(0)
        g = {'w': jnp.asarray(rng.standard_normal((64, 64)) * 1e-3, jnp.float32)}
        q = quantize_grads_int8(g)
        rel = float(jnp.linalg.norm(q['w'] - g['w']) / jnp.linalg.norm(g['w']))
        print('REL', rel)
    """)
    assert float(out.split()[1]) < 0.01


def test_multipod_mesh_axes():
    # 512 fake devices need their own subprocess (device count locks on init)
    code = "\n".join([
        "import os",
        'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"',
        "from repro.launch.mesh import make_production_mesh",
        "m1 = make_production_mesh(multi_pod=False)",
        "m2 = make_production_mesh(multi_pod=True)",
        "print(dict(m1.shape), dict(m2.shape))",
    ])
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=ENV, cwd=REPO, timeout=900)
    assert p.returncode == 0, p.stderr
    out = p.stdout
    assert "'pod': 2" in out and "'data': 16" in out and "'model': 16" in out
