"""Chunked-scan invariances for the sub-quadratic families (RWKV6 / Mamba2)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import rwkv6, ssm
from repro.models.common import init_params


@pytest.mark.parametrize("chunks", [(2, 8), (4, 16)])
def test_rwkv_chunk_size_invariant(chunks, rng):
    """The chunked WKV6 factorization must be exact: logits identical for
    any chunk size (pure math identity, not an approximation)."""
    c1, c2 = chunks
    base = configs.get("rwkv6-1.6b", reduced=True).replace(chunk_size=c1)
    params = init_params(rwkv6.build_decls(base), seed=0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 16)), jnp.int32)
    l1, _ = rwkv6.forward(base, params, toks)
    l2, _ = rwkv6.forward(base.replace(chunk_size=c2), params, toks)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("chunks", [(2, 8), (4, 16)])
def test_mamba_chunk_size_invariant(chunks, rng):
    c1, c2 = chunks
    base = configs.get("zamba2-1.2b", reduced=True).replace(chunk_size=c1)
    params = init_params(ssm.build_decls(base), seed=0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 16)), jnp.int32)
    l1, _ = ssm.forward(base, params, toks)
    l2, _ = ssm.forward(base.replace(chunk_size=c2), params, toks)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-2, rtol=1e-2)


def test_rwkv_decode_is_exact_recurrence(rng):
    """Sequential decode must reproduce the chunked-parallel forward exactly
    (state-passing correctness across the full layer stack)."""
    c = configs.get("rwkv6-1.6b", reduced=True)
    params = init_params(rwkv6.build_decls(c), seed=1)
    toks = jnp.asarray(rng.integers(0, c.vocab_size, (1, 12)), jnp.int32)
    logits, _ = rwkv6.forward(c, params, toks)
    st = rwkv6.init_state(c, 1)
    for t in range(12):
        dl, st = rwkv6.decode_step(c, params, toks[:, t], st)
    np.testing.assert_allclose(np.asarray(dl, np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               atol=5e-2, rtol=5e-2)


def test_rwkv_state_carries_across_segments(rng):
    """forward(s1) then forward(s2, state) == forward(s1+s2)."""
    c = configs.get("rwkv6-1.6b", reduced=True).replace(chunk_size=4)
    params = init_params(rwkv6.build_decls(c), seed=2)
    toks = jnp.asarray(rng.integers(0, c.vocab_size, (2, 16)), jnp.int32)
    full, _ = rwkv6.forward(c, params, toks)
    _, _, st = rwkv6.forward(c, params, toks[:, :8], return_state=True)
    seg2, _, _ = rwkv6.forward(c, params, toks[:, 8:], state=st, return_state=True)
    np.testing.assert_allclose(np.asarray(seg2, np.float32),
                               np.asarray(full[:, 8:], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_zamba_shared_block_is_tied(rng):
    """Zamba2's shared attention block must be ONE set of weights: perturbing
    it changes every invocation point's output."""
    c = configs.get("zamba2-1.2b", reduced=True)
    params = init_params(ssm.build_decls(c), seed=3)
    toks = jnp.asarray(rng.integers(0, c.vocab_size, (1, 8)), jnp.int32)
    l1, _ = ssm.forward(c, params, toks)
    params2 = dict(params)
    params2["shared"] = jax.tree.map(lambda t: t + 0.01, params["shared"])
    l2, _ = ssm.forward(c, params2, toks)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4
