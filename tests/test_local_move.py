"""Parity suite for the fused gather-in-kernel local_move family
(DESIGN.md §Kernels): kernel ≡ ref ≡ legacy two-step ≡ segment evaluator
bit-for-bit, across all bucket widths, tail-heavy layouts, both evaluators,
interpret mode, plus a fused-pipeline end-to-end check.  The windowed
STREAMED table layout must match the resident layout bit-for-bit as well —
across window sizes (degenerate 1-block and window-spans-whole-table
included), with the resident/streamed auto-selection flipping at the
documented VMEM-budget threshold."""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.engine import EngineSpec, SweepEngine
from repro.graph.builders import from_numpy_edges
from repro.graph.ell import (
    BUCKET_WIDTHS,
    build_ell,
    compute_windows,
    grid_view,
    to_device,
)
from repro.graph.generators import sbm
from repro.kernels import common as kcommon
from repro.kernels.delta_q import ops as dq_ops
from repro.kernels.label_argmax import ops as la_ops
from repro.kernels.local_move import ops as lm_ops


def _graph(seed=13, n=300, k=6):
    u, v, w, _ = sbm(n, k, p_in=0.3, p_out=0.03, seed=seed)
    return from_numpy_edges(u, v, w)


def _tiles(rows, width, n, seed):
    """Random ELL tile + consistent per-vertex tables for kernel-level tests."""
    rng = np.random.default_rng(seed)
    r_ids = np.full(rows, n, np.int32)
    real = rng.random(rows) < 0.9
    r_ids[real] = rng.choice(n, size=int(real.sum()), replace=False)
    nbr = rng.integers(0, n, (rows, width)).astype(np.int32)
    pad = rng.random((rows, width)) < 0.25
    pad[~real] = True
    nbr[pad] = n
    w = np.where(pad, 0.0, rng.random((rows, width))).astype(np.float32)
    labels = rng.integers(0, n, n).astype(np.int32)
    labels_ext = np.concatenate([labels, [n]]).astype(np.int32)
    deg = (rng.random(n) + 0.1).astype(np.float32)
    vol = (rng.random(n) * 5).astype(np.float32)
    size = rng.integers(1, 5, n).astype(np.int32)
    tables = dict(
        com_ext=jnp.asarray(labels_ext),
        vol_ext=jnp.asarray(np.concatenate([vol, [0.0]]).astype(np.float32)),
        size_ext=jnp.asarray(np.concatenate([size, [0]]).astype(np.int32)),
        deg_ext=jnp.asarray(np.concatenate([deg, [0.0]]).astype(np.float32)),
    )
    return (jnp.asarray(r_ids), jnp.asarray(nbr), jnp.asarray(w),
            jnp.asarray(labels_ext), tables)


@pytest.mark.parametrize("width", BUCKET_WIDTHS)
def test_plp_kernel_matches_ref(width):
    rows = 8 if width >= 256 else 32
    n = 64
    r_ids, nbr, w, labels_ext, _ = _tiles(rows, width, n, seed=width)
    kw = dict(tie_eps=0.25, sentinel=n)
    seed = jnp.uint32(7)
    best_k, prop_k = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, use_pallas=True, interpret=True, **kw)
    best_r, prop_r = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, use_pallas=False, **kw)
    np.testing.assert_array_equal(np.asarray(best_k), np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(prop_k), np.asarray(prop_r))


@pytest.mark.parametrize("width", BUCKET_WIDTHS)
@pytest.mark.parametrize("singleton_rule", [True, False])
def test_louvain_kernel_matches_ref(width, singleton_rule):
    rows = 8 if width >= 256 else 32
    n = 64
    r_ids, nbr, w, _, tables = _tiles(rows, width, n, seed=width + 1)
    kw = dict(sentinel=n, singleton_rule=singleton_rule)
    vol_total = jnp.float32(37.0)
    best_k, prop_k = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, use_pallas=True, interpret=True,
        **tables, **kw)
    best_r, prop_r = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, use_pallas=False, **tables, **kw)
    np.testing.assert_array_equal(np.asarray(best_k), np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(prop_k), np.asarray(prop_r))


def test_fused_matches_legacy_two_step():
    """The fused kernel must reproduce the legacy gather-outside two-step
    (jnp gathers into (rows, W) tiles + label_argmax / delta_q kernels)
    bit-for-bit — the contract the gather_fusion benchmark relies on."""
    n = 96
    r_ids, nbr, w, labels_ext, tables = _tiles(48, 16, n, seed=5)
    seed = jnp.uint32(3)

    # PLP
    best_f, prop_f = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, tie_eps=0.25, sentinel=n,
        use_pallas=True)
    nbr_lab = jnp.where(nbr < n, labels_ext[jnp.clip(nbr, 0, n)], n)
    cur_lab = labels_ext[jnp.clip(r_ids, 0, n)]
    best_l, bs, cs = la_ops.label_argmax(
        nbr_lab, w, cur_lab, jnp.where(r_ids < n, r_ids, n), seed,
        tie_eps=0.25, sentinel=n, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(best_f), np.asarray(best_l))
    np.testing.assert_array_equal(
        np.asarray(prop_f), np.asarray((best_l >= 0) & (bs > cs)))

    # Louvain
    vol_total = jnp.float32(41.0)
    best_f, prop_f = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, sentinel=n, singleton_rule=True,
        use_pallas=True, **tables)
    com_ext, vol_ext = tables["com_ext"], tables["vol_ext"]
    size_ext, deg_ext = tables["size_ext"], tables["deg_ext"]
    rows_c = jnp.clip(r_ids, 0, n)
    cand = jnp.where(nbr < n, com_ext[jnp.clip(nbr, 0, n)], n)
    best_l, gain = dq_ops.delta_q_argmax(
        cand_com=cand, nbr_w=w, cur_com=com_ext[rows_c],
        deg_v=deg_ext[rows_c],
        vol_cand=vol_ext[jnp.clip(cand, 0, n)],
        vol_cur=vol_ext[jnp.clip(com_ext[rows_c], 0, n)],
        size_cand=size_ext[jnp.clip(cand, 0, n)],
        size_cur=size_ext[jnp.clip(com_ext[rows_c], 0, n)],
        vol_total=vol_total, sentinel=n, singleton_rule=True,
        use_pallas=True)
    np.testing.assert_array_equal(np.asarray(best_f), np.asarray(best_l))
    np.testing.assert_array_equal(
        np.asarray(prop_f), np.asarray((best_l >= 0) & (gain > 0.0)))


def test_chunk_stacked_input_shapes():
    """ops must accept the (n_chunks, rows) stacked DeviceBucket layout and
    agree with the flattened grid_view call."""
    g = _graph(seed=2, n=120, k=4)
    n = g.n_max
    ell = to_device(g, build_ell(g, widths=(8, 16)), rows_per_chunk=8)
    labels_ext = jnp.concatenate(
        [jnp.arange(n, dtype=jnp.int32), jnp.int32([n])])
    b = ell.buckets[0]
    assert b.rows.ndim == 2 and b.rows.shape[0] > 1  # really chunk-stacked
    best_s, prop_s = lm_ops.local_move_plp(
        b.rows, b.nbr, b.w, labels_ext, jnp.uint32(0),
        tie_eps=0.25, sentinel=n, use_pallas=True)
    rows, nbr, w = grid_view(b)
    best_f, prop_f = lm_ops.local_move_plp(
        rows, nbr, w, labels_ext, jnp.uint32(0),
        tie_eps=0.25, sentinel=n, use_pallas=True)
    assert best_s.shape == b.rows.shape
    np.testing.assert_array_equal(
        np.asarray(best_s).ravel(), np.asarray(best_f))
    np.testing.assert_array_equal(
        np.asarray(prop_s).ravel(), np.asarray(prop_f))


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
def test_sweep_backends_bitwise_equal(evaluator):
    """Full fused phase: pallas (fused kernel) ≡ ell (jnp ref) ≡ segment
    evaluator, labels and histories bit-for-bit."""
    g = _graph()
    res = {}
    for backend in ("segment", "ell", "pallas"):
        spec = EngineSpec(evaluator=evaluator, backend=backend,
                          max_sweeps=30, move_prob=0.75)
        eng = SweepEngine(g, spec)
        res[backend] = eng.run_phase(*eng.singleton_state(), seed=3)
    for backend in ("ell", "pallas"):
        np.testing.assert_array_equal(
            np.asarray(res["segment"].labels), np.asarray(res[backend].labels))
        assert res[backend].sweeps == res["segment"].sweeps
        assert (res[backend].delta_n_history
                == res["segment"].delta_n_history)


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
def test_sweep_tail_heavy_bitwise_equal(evaluator):
    """Tiny bucket widths force most vertices onto the tail path; pallas and
    ell must still agree bit-for-bit with each other."""
    g = _graph(seed=11)
    ell = to_device(g, build_ell(g, widths=(4, 8)))
    assert ell.has_tail
    res = {}
    for backend in ("ell", "pallas"):
        spec = EngineSpec(evaluator=evaluator, backend=backend,
                          max_sweeps=30, move_prob=0.75)
        eng = SweepEngine(g, spec, ell=ell)
        res[backend] = eng.run_phase(*eng.singleton_state(), seed=5)
    np.testing.assert_array_equal(
        np.asarray(res["ell"].labels), np.asarray(res["pallas"].labels))
    assert res["ell"].delta_n_history == res["pallas"].delta_n_history


# ------------------------------------------------------- windowed streaming


def _windows_for(r_ids, nbr, n, block_rows):
    return compute_windows(np.asarray(r_ids), np.asarray(nbr), n, block_rows)


@pytest.mark.parametrize("width", BUCKET_WIDTHS)
@pytest.mark.parametrize("block_rows", [8, 64])
def test_plp_streamed_matches_resident(width, block_rows):
    """Streamed kernel ≡ streamed jnp oracle ≡ resident ref, bit-for-bit.

    block_rows=64 exceeds the tile row count → degenerate 1-block grid; the
    random (non-locality-ordered) tiles make every block span nearly the
    whole id range, so the window also degenerates to whole-table coverage
    (slot ≥ n+1) — both documented edge cases."""
    rows = 8 if width >= 256 else 32
    n = 64
    r_ids, nbr, w, labels_ext, _ = _tiles(rows, width, n, seed=width + 2)
    win = _windows_for(r_ids, nbr, n, block_rows)
    if block_rows == 64:
        assert win.win_blk.shape[0] == 1          # degenerate 1-block grid
    assert win.slot >= n + 1                      # window spans whole table
    kw = dict(tie_eps=0.25, sentinel=n)
    seed = jnp.uint32(7)
    best_r, prop_r = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, table_mode="resident", **kw)
    best_k, prop_k = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, use_pallas=True, interpret=True,
        windows=win, table_mode="streamed", **kw)
    best_j, prop_j = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed,
        windows=win, table_mode="streamed", **kw)
    for got in ((best_k, prop_k), (best_j, prop_j)):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(best_r))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(prop_r))


@pytest.mark.parametrize("width", BUCKET_WIDTHS)
@pytest.mark.parametrize("singleton_rule", [True, False])
def test_louvain_streamed_matches_resident(width, singleton_rule):
    rows = 8 if width >= 256 else 32
    n = 64
    r_ids, nbr, w, _, tables = _tiles(rows, width, n, seed=width + 3)
    win = _windows_for(r_ids, nbr, n, block_rows=8)
    kw = dict(sentinel=n, singleton_rule=singleton_rule)
    vol_total = jnp.float32(37.0)
    best_r, prop_r = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, table_mode="resident",
        **tables, **kw)
    best_k, prop_k = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, use_pallas=True, interpret=True,
        windows=win, table_mode="streamed", **tables, **kw)
    best_j, prop_j = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total,
        windows=win, table_mode="streamed", **tables, **kw)
    for got in ((best_k, prop_k), (best_j, prop_j)):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(best_r))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(prop_r))


def _banded_graph(n=1024, band=40, k=6, seed=3):
    """Random graph whose neighbors sit within ``band`` ids of the vertex —
    the id-locality the streamed path exploits (Sahu, arXiv:2301.12390)."""
    rng = np.random.default_rng(seed)
    u = np.repeat(np.arange(n), k)
    v = np.clip(u + rng.integers(1, band, size=n * k), 0, n - 1)
    keep = u != v
    u, v = u[keep], v[keep]
    uu, vv = np.concatenate([u, v]), np.concatenate([v, u])
    return from_numpy_edges(uu, vv, np.ones(uu.size, np.float32))


def test_streamed_narrow_windows_on_real_buckets():
    """Locality-ordered banded buckets must yield windows NARROWER than the
    table (the whole point of streaming) and still score bit-identically."""
    g = _banded_graph()
    n = g.n_max
    ell = to_device(g, build_ell(g, widths=(16, 64)), block_rows=64)
    labels_ext = jnp.concatenate(
        [jnp.arange(n, dtype=jnp.int32) % 97, jnp.int32([n])])
    narrow = 0
    for b in ell.buckets:
        if b.n_rows_valid == 0:
            continue
        assert b.windows is not None
        narrow += int(b.windows.slot < n + 1)
        rows, nbr, w = grid_view(b)
        best_r, prop_r = lm_ops.local_move_plp(
            rows, nbr, w, labels_ext, jnp.uint32(3),
            tie_eps=0.25, sentinel=n, table_mode="resident")
        best_s, prop_s = lm_ops.local_move_plp(
            rows, nbr, w, labels_ext, jnp.uint32(3),
            tie_eps=0.25, sentinel=n, use_pallas=True, interpret=True,
            windows=b.windows, table_mode="streamed")
        np.testing.assert_array_equal(np.asarray(best_s), np.asarray(best_r))
        np.testing.assert_array_equal(np.asarray(prop_s), np.asarray(prop_r))
    assert narrow > 0, "no bucket produced a sub-table window"


def test_streamed_requires_windows():
    n = 32
    r_ids, nbr, w, labels_ext, _ = _tiles(16, 16, n, seed=1)
    with pytest.raises(ValueError, match="window metadata"):
        lm_ops.local_move_plp(
            r_ids, nbr, w, labels_ext, jnp.uint32(0),
            tie_eps=0.25, sentinel=n, table_mode="streamed")


def test_auto_mode_rejects_degenerate_windows():
    """Windows as wide as the table make streaming strictly worse than the
    resident one-shot DMA: auto falls back to resident even past the byte
    threshold; explicit 'streamed' is still honored (parity tests use it)."""
    from repro.kernels.local_move.ops import _resolve_mode

    n = 64  # n_pad = 128; random tiles span the whole range -> 2*slot >= n_pad
    r_ids, nbr, w, _, _ = _tiles(16, 16, n, seed=2)
    win = _windows_for(r_ids, nbr, n, 8)
    assert 2 * win.slot >= 128
    assert _resolve_mode("auto", win, 1, n, 1) == "resident"
    assert _resolve_mode("streamed", win, 1, n, 1) == "streamed"


def test_table_mode_auto_flips_at_budget_threshold():
    """The documented rule: resident iff table_bytes <= budget // 2, with
    the budget taken from kwarg > env var > default, in that order."""
    assert kcommon.resolve_table_mode("auto", 512, budget_bytes=1024) == "resident"
    assert kcommon.resolve_table_mode("auto", 513, budget_bytes=1024) == "streamed"
    assert kcommon.resolve_table_mode("resident", 1 << 40) == "resident"
    assert kcommon.resolve_table_mode("streamed", 1) == "streamed"
    with pytest.raises(ValueError, match="table_mode"):
        kcommon.resolve_table_mode("bogus", 1)
    old = os.environ.get(kcommon.VMEM_BUDGET_ENV)
    os.environ[kcommon.VMEM_BUDGET_ENV] = "2048"
    try:
        assert kcommon.resolve_table_mode("auto", 1024) == "resident"
        assert kcommon.resolve_table_mode("auto", 1025) == "streamed"
        # explicit kwarg outranks the env var
        assert kcommon.resolve_table_mode("auto", 1025, budget_bytes=1 << 20) \
            == "resident"
    finally:
        if old is None:
            del os.environ[kcommon.VMEM_BUDGET_ENV]
        else:
            os.environ[kcommon.VMEM_BUDGET_ENV] = old
    # pick_row_block_fused charges the table bytes: a fatter table must
    # shrink (never grow) the row block
    assert (kcommon.pick_row_block_fused(64, table_bytes=6 << 20)
            < kcommon.pick_row_block_fused(64, table_bytes=0))
    assert kcommon.pick_row_block_fused(16, table_bytes=0) == 2048
    # tables alone past half the budget: the pairwise floor (budget//8)
    # keeps a sane grid instead of collapsing to 1-row steps — that regime
    # cannot fit VMEM via row-block shrinking anyway (streamed-or-bust)
    assert kcommon.pick_row_block_fused(64, table_bytes=1 << 30) == \
        kcommon.pick_row_block_fused(
            64, budget_bytes=kcommon.vmem_budget_bytes() // 4)


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
def test_sweep_streamed_backends_bitwise_equal(evaluator):
    """Full fused phase with table_mode='streamed': pallas (streamed kernel)
    ≡ ell (windowed jnp oracle) ≡ the resident segment evaluator."""
    g = _graph()
    res = {}
    for backend, tm in (("segment", "auto"), ("ell", "streamed"),
                        ("pallas", "streamed")):
        spec = EngineSpec(evaluator=evaluator, backend=backend,
                          max_sweeps=30, move_prob=0.75, table_mode=tm)
        eng = SweepEngine(g, spec)
        res[backend] = eng.run_phase(*eng.singleton_state(), seed=3)
    for backend in ("ell", "pallas"):
        np.testing.assert_array_equal(
            np.asarray(res["segment"].labels), np.asarray(res[backend].labels))
        assert res[backend].sweeps == res["segment"].sweeps
        assert (res[backend].delta_n_history
                == res["segment"].delta_n_history)


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
def test_sweep_tail_heavy_matches_segment(evaluator):
    """Tail-heavy layout vs the segment evaluator: locks the tail path's
    once-per-sweep extended-table gathers (moves.*_tables) AND the streamed
    bucket path to the segment reference bit-for-bit."""
    g = _graph(seed=11)
    ell = to_device(g, build_ell(g, widths=(4, 8)))
    assert ell.has_tail
    seg = SweepEngine(g, EngineSpec(evaluator=evaluator, backend="segment",
                                    max_sweeps=30, move_prob=0.75))
    base = seg.run_phase(*seg.singleton_state(), seed=5)
    for backend in ("ell", "pallas"):
        for tm in ("resident", "streamed"):
            spec = EngineSpec(evaluator=evaluator, backend=backend,
                              max_sweeps=30, move_prob=0.75, table_mode=tm)
            eng = SweepEngine(g, spec, ell=ell)
            r = eng.run_phase(*eng.singleton_state(), seed=5)
            np.testing.assert_array_equal(
                np.asarray(base.labels), np.asarray(r.labels))
            assert base.delta_n_history == r.delta_n_history


def _streaming_budget(windows, n_tables, n_max):
    """A VMEM budget under which auto genuinely streams: the double-buffered
    windows fit half of it, the resident tables do not (ops._resolve_mode)."""
    win_bytes = 4 * n_tables * (2 * windows.slot) * 2
    n_pad = -(-(n_max + 1) // 128) * 128
    table_bytes = 4 * n_tables * n_pad
    assert win_bytes < table_bytes, "windows too coarse to ever win"
    return 2 * ((win_bytes + table_bytes) // 2)


def test_e2e_streamed_past_resident_budget():
    """A graph whose tables exceed the (shrunk) resident VMEM budget must
    run end-to-end through louvain()/plp() on the pallas backend via the
    auto-selected streamed path, bit-identical to the resident run.

    The graph must have genuine id-locality (banded) and enough rows per
    bucket for multi-block grids — otherwise auto correctly refuses
    windows that are no cheaper than the resident tables and stays
    resident."""
    from repro.core.louvain import LouvainConfig, louvain
    from repro.core.plp import PLPConfig, plp
    from repro.graph.ell import build_device_ell
    from repro.kernels.local_move.ops import _resolve_mode

    g = _banded_graph(n=16384, band=48, k=3, seed=5)
    n = g.n_max
    # per-evaluator budgets derived from the ACTUAL default-build windows,
    # with an engagement assertion so slot drift fails loudly here rather
    # than silently degrading to a resident-only test
    ell = build_device_ell(g)
    big = max((b for b in ell.buckets if b.n_rows_valid),
              key=lambda b: b.n_rows_valid)
    budget = {nt: _streaming_budget(big.windows, nt, n) for nt in (1, 4)}
    assert _resolve_mode("auto", big.windows, 1, n, budget[1]) == "streamed"
    assert _resolve_mode("auto", big.windows, 4, n, budget[4]) == "streamed"

    lcfg = LouvainConfig(seed=8, backend="pallas", track_modularity=False)
    pcfg = PLPConfig(seed=8, backend="pallas")
    r_res = louvain(g, lcfg.replace(table_mode="resident"))
    p_res = plp(g, pcfg.replace(table_mode="resident"))
    old = os.environ.get(kcommon.VMEM_BUDGET_ENV)
    try:
        os.environ[kcommon.VMEM_BUDGET_ENV] = str(budget[4])
        r_str = louvain(g, lcfg)
        os.environ[kcommon.VMEM_BUDGET_ENV] = str(budget[1])
        p_str = plp(g, pcfg)
    finally:
        if old is None:
            del os.environ[kcommon.VMEM_BUDGET_ENV]
        else:
            os.environ[kcommon.VMEM_BUDGET_ENV] = old
    np.testing.assert_array_equal(r_res.labels, r_str.labels)
    assert r_res.levels == r_str.levels
    assert r_res.sweeps_per_level == r_str.sweeps_per_level
    np.testing.assert_array_equal(p_res.labels, p_str.labels)
    assert p_res.iterations == p_str.iterations


def test_pipeline_pallas_matches_ell_end_to_end():
    """Fused multi-level pipeline: the pallas backend (level 0 through the
    fused kernel) must reproduce the ell backend's whole-run result."""
    from repro.core.louvain import LouvainConfig, louvain

    g = _graph(seed=4)
    cfg = LouvainConfig(seed=4, track_modularity=False, pipeline_fused=True)
    r_ell = louvain(g, cfg.replace(backend="ell"))
    r_pal = louvain(g, cfg.replace(backend="pallas"))
    np.testing.assert_array_equal(
        np.asarray(r_ell.labels), np.asarray(r_pal.labels))
    assert r_ell.levels == r_pal.levels
    assert r_ell.sweeps_per_level == r_pal.sweeps_per_level
    assert r_ell.modularity == r_pal.modularity
