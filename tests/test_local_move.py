"""Parity suite for the fused gather-in-kernel local_move family
(DESIGN.md §Kernels): kernel ≡ ref ≡ legacy two-step ≡ segment evaluator
bit-for-bit, across all bucket widths, tail-heavy layouts, both evaluators,
interpret mode, plus a fused-pipeline end-to-end check."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.engine import EngineSpec, SweepEngine
from repro.graph.builders import from_numpy_edges
from repro.graph.ell import BUCKET_WIDTHS, build_ell, grid_view, to_device
from repro.graph.generators import sbm
from repro.kernels.delta_q import ops as dq_ops
from repro.kernels.label_argmax import ops as la_ops
from repro.kernels.local_move import ops as lm_ops


def _graph(seed=13, n=300, k=6):
    u, v, w, _ = sbm(n, k, p_in=0.3, p_out=0.03, seed=seed)
    return from_numpy_edges(u, v, w)


def _tiles(rows, width, n, seed):
    """Random ELL tile + consistent per-vertex tables for kernel-level tests."""
    rng = np.random.default_rng(seed)
    r_ids = np.full(rows, n, np.int32)
    real = rng.random(rows) < 0.9
    r_ids[real] = rng.choice(n, size=int(real.sum()), replace=False)
    nbr = rng.integers(0, n, (rows, width)).astype(np.int32)
    pad = rng.random((rows, width)) < 0.25
    pad[~real] = True
    nbr[pad] = n
    w = np.where(pad, 0.0, rng.random((rows, width))).astype(np.float32)
    labels = rng.integers(0, n, n).astype(np.int32)
    labels_ext = np.concatenate([labels, [n]]).astype(np.int32)
    deg = (rng.random(n) + 0.1).astype(np.float32)
    vol = (rng.random(n) * 5).astype(np.float32)
    size = rng.integers(1, 5, n).astype(np.int32)
    tables = dict(
        com_ext=jnp.asarray(labels_ext),
        vol_ext=jnp.asarray(np.concatenate([vol, [0.0]]).astype(np.float32)),
        size_ext=jnp.asarray(np.concatenate([size, [0]]).astype(np.int32)),
        deg_ext=jnp.asarray(np.concatenate([deg, [0.0]]).astype(np.float32)),
    )
    return (jnp.asarray(r_ids), jnp.asarray(nbr), jnp.asarray(w),
            jnp.asarray(labels_ext), tables)


@pytest.mark.parametrize("width", BUCKET_WIDTHS)
def test_plp_kernel_matches_ref(width):
    rows = 8 if width >= 256 else 32
    n = 64
    r_ids, nbr, w, labels_ext, _ = _tiles(rows, width, n, seed=width)
    kw = dict(tie_eps=0.25, sentinel=n)
    seed = jnp.uint32(7)
    best_k, prop_k = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, use_pallas=True, interpret=True, **kw)
    best_r, prop_r = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, use_pallas=False, **kw)
    np.testing.assert_array_equal(np.asarray(best_k), np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(prop_k), np.asarray(prop_r))


@pytest.mark.parametrize("width", BUCKET_WIDTHS)
@pytest.mark.parametrize("singleton_rule", [True, False])
def test_louvain_kernel_matches_ref(width, singleton_rule):
    rows = 8 if width >= 256 else 32
    n = 64
    r_ids, nbr, w, _, tables = _tiles(rows, width, n, seed=width + 1)
    kw = dict(sentinel=n, singleton_rule=singleton_rule)
    vol_total = jnp.float32(37.0)
    best_k, prop_k = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, use_pallas=True, interpret=True,
        **tables, **kw)
    best_r, prop_r = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, use_pallas=False, **tables, **kw)
    np.testing.assert_array_equal(np.asarray(best_k), np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(prop_k), np.asarray(prop_r))


def test_fused_matches_legacy_two_step():
    """The fused kernel must reproduce the legacy gather-outside two-step
    (jnp gathers into (rows, W) tiles + label_argmax / delta_q kernels)
    bit-for-bit — the contract the gather_fusion benchmark relies on."""
    n = 96
    r_ids, nbr, w, labels_ext, tables = _tiles(48, 16, n, seed=5)
    seed = jnp.uint32(3)

    # PLP
    best_f, prop_f = lm_ops.local_move_plp(
        r_ids, nbr, w, labels_ext, seed, tie_eps=0.25, sentinel=n,
        use_pallas=True)
    nbr_lab = jnp.where(nbr < n, labels_ext[jnp.clip(nbr, 0, n)], n)
    cur_lab = labels_ext[jnp.clip(r_ids, 0, n)]
    best_l, bs, cs = la_ops.label_argmax(
        nbr_lab, w, cur_lab, jnp.where(r_ids < n, r_ids, n), seed,
        tie_eps=0.25, sentinel=n, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(best_f), np.asarray(best_l))
    np.testing.assert_array_equal(
        np.asarray(prop_f), np.asarray((best_l >= 0) & (bs > cs)))

    # Louvain
    vol_total = jnp.float32(41.0)
    best_f, prop_f = lm_ops.local_move_louvain(
        r_ids, nbr, w, vol_total=vol_total, sentinel=n, singleton_rule=True,
        use_pallas=True, **tables)
    com_ext, vol_ext = tables["com_ext"], tables["vol_ext"]
    size_ext, deg_ext = tables["size_ext"], tables["deg_ext"]
    rows_c = jnp.clip(r_ids, 0, n)
    cand = jnp.where(nbr < n, com_ext[jnp.clip(nbr, 0, n)], n)
    best_l, gain = dq_ops.delta_q_argmax(
        cand_com=cand, nbr_w=w, cur_com=com_ext[rows_c],
        deg_v=deg_ext[rows_c],
        vol_cand=vol_ext[jnp.clip(cand, 0, n)],
        vol_cur=vol_ext[jnp.clip(com_ext[rows_c], 0, n)],
        size_cand=size_ext[jnp.clip(cand, 0, n)],
        size_cur=size_ext[jnp.clip(com_ext[rows_c], 0, n)],
        vol_total=vol_total, sentinel=n, singleton_rule=True,
        use_pallas=True)
    np.testing.assert_array_equal(np.asarray(best_f), np.asarray(best_l))
    np.testing.assert_array_equal(
        np.asarray(prop_f), np.asarray((best_l >= 0) & (gain > 0.0)))


def test_chunk_stacked_input_shapes():
    """ops must accept the (n_chunks, rows) stacked DeviceBucket layout and
    agree with the flattened grid_view call."""
    g = _graph(seed=2, n=120, k=4)
    n = g.n_max
    ell = to_device(g, build_ell(g, widths=(8, 16)), rows_per_chunk=8)
    labels_ext = jnp.concatenate(
        [jnp.arange(n, dtype=jnp.int32), jnp.int32([n])])
    b = ell.buckets[0]
    assert b.rows.ndim == 2 and b.rows.shape[0] > 1  # really chunk-stacked
    best_s, prop_s = lm_ops.local_move_plp(
        b.rows, b.nbr, b.w, labels_ext, jnp.uint32(0),
        tie_eps=0.25, sentinel=n, use_pallas=True)
    rows, nbr, w = grid_view(b)
    best_f, prop_f = lm_ops.local_move_plp(
        rows, nbr, w, labels_ext, jnp.uint32(0),
        tie_eps=0.25, sentinel=n, use_pallas=True)
    assert best_s.shape == b.rows.shape
    np.testing.assert_array_equal(
        np.asarray(best_s).ravel(), np.asarray(best_f))
    np.testing.assert_array_equal(
        np.asarray(prop_s).ravel(), np.asarray(prop_f))


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
def test_sweep_backends_bitwise_equal(evaluator):
    """Full fused phase: pallas (fused kernel) ≡ ell (jnp ref) ≡ segment
    evaluator, labels and histories bit-for-bit."""
    g = _graph()
    res = {}
    for backend in ("segment", "ell", "pallas"):
        spec = EngineSpec(evaluator=evaluator, backend=backend,
                          max_sweeps=30, move_prob=0.75)
        eng = SweepEngine(g, spec)
        res[backend] = eng.run_phase(*eng.singleton_state(), seed=3)
    for backend in ("ell", "pallas"):
        np.testing.assert_array_equal(
            np.asarray(res["segment"].labels), np.asarray(res[backend].labels))
        assert res[backend].sweeps == res["segment"].sweeps
        assert (res[backend].delta_n_history
                == res["segment"].delta_n_history)


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
def test_sweep_tail_heavy_bitwise_equal(evaluator):
    """Tiny bucket widths force most vertices onto the tail path; pallas and
    ell must still agree bit-for-bit with each other."""
    g = _graph(seed=11)
    ell = to_device(g, build_ell(g, widths=(4, 8)))
    assert ell.has_tail
    res = {}
    for backend in ("ell", "pallas"):
        spec = EngineSpec(evaluator=evaluator, backend=backend,
                          max_sweeps=30, move_prob=0.75)
        eng = SweepEngine(g, spec, ell=ell)
        res[backend] = eng.run_phase(*eng.singleton_state(), seed=5)
    np.testing.assert_array_equal(
        np.asarray(res["ell"].labels), np.asarray(res["pallas"].labels))
    assert res["ell"].delta_n_history == res["pallas"].delta_n_history


def test_pipeline_pallas_matches_ell_end_to_end():
    """Fused multi-level pipeline: the pallas backend (level 0 through the
    fused kernel) must reproduce the ell backend's whole-run result."""
    from repro.core.louvain import LouvainConfig, louvain

    g = _graph(seed=4)
    cfg = LouvainConfig(seed=4, track_modularity=False, pipeline_fused=True)
    r_ell = louvain(g, cfg.replace(backend="ell"))
    r_pal = louvain(g, cfg.replace(backend="pallas"))
    np.testing.assert_array_equal(
        np.asarray(r_ell.labels), np.asarray(r_pal.labels))
    assert r_ell.levels == r_pal.levels
    assert r_ell.sweeps_per_level == r_pal.sweeps_per_level
    assert r_ell.modularity == r_pal.modularity
