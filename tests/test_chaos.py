"""Chaos harness (DESIGN.md §Resilience, ISSUE 9 acceptance).

Drive the serving engine end-to-end under EVERY registered fault point and
enforce the service contract: a typed response for every accepted request —
result or taxonomy error — never a hang (an in-test watchdog bounds the
flush) and never a dropped response.  Faults that poison answers must come
back as typed errors; faults with a lossless fallback must come back
bit-identical ``ok`` results (the per-fault suites in ``test_faults.py``
pin WHICH; here we pin "always answered, never deadlocked").
"""
import numpy as np
import pytest

from launch.community_serve import CommunityRequest, CommunityServeEngine
from repro.graph.generators import sbm
from repro.utils import faultinject, resilience

#: generous wall-clock bound for one flush under faults: recompiles ride the
#: fault-set cache key, so the first faulted flush pays a fresh trace
FLUSH_DEADLINE_S = 300.0


def _traffic(eng, count=4, deadline_ms=None):
    accepted = []
    for i in range(count):
        n = 24 if i % 2 else 48
        u, v, _w, _t = sbm(n, 3, p_in=0.35, p_out=0.03, seed=40 + i)
        req = CommunityRequest(request_id=f"c{i}", u=u, v=v, n=n,
                               algo="plp" if i == 3 else "louvain",
                               deadline_ms=deadline_ms)
        if eng.submit(req) is None:
            accepted.append(req.request_id)
    return accepted


@pytest.mark.parametrize("fault", faultinject.FAULT_POINTS)
def test_service_answers_everything_under_fault(fault):
    eng = CommunityServeEngine(max_retries=1, backoff_base_s=0.01)
    with faultinject.inject(fault):
        accepted = _traffic(eng)
        responses = resilience.call_with_deadline(eng.flush,
                                                  FLUSH_DEADLINE_S)
    assert {r.request_id for r in responses} == set(accepted)
    for r in responses:
        # the contract: a result or a TYPED error, never silence
        if r.ok:
            assert r.labels is not None
        else:
            assert r.error and r.error.split(":")[0].endswith("Error")
    # the engine survives: a clean follow-up flush serves normally
    accepted2 = _traffic(eng, count=2)
    responses2 = resilience.call_with_deadline(eng.flush, FLUSH_DEADLINE_S)
    assert {r.request_id for r in responses2} == set(accepted2)
    assert all(r.ok for r in responses2)


def test_service_answers_everything_under_paired_faults():
    """Correlated chaos: a stalled dispatch AND transient failures at once
    still drain the queue with typed outcomes."""
    eng = CommunityServeEngine(max_retries=1, backoff_base_s=0.01)
    with faultinject.inject("slow_dispatch", "transient_batch_fail"):
        faultinject.set_rate("transient_batch_fail", 0.5)
        try:
            accepted = _traffic(eng)
            responses = resilience.call_with_deadline(eng.flush,
                                                      FLUSH_DEADLINE_S)
        finally:
            faultinject.disarm("transient_batch_fail", "slow_dispatch")
    assert {r.request_id for r in responses} == set(accepted)
    assert all(r.ok or r.error for r in responses)


def test_deadlined_traffic_under_stall_is_split_not_hung(monkeypatch):
    """A hung dispatch with per-request deadlines: the watchdog releases
    the flush on time and every request gets a typed DeadlineError —
    the service never blocks on the stalled device work."""
    monkeypatch.setenv(faultinject.SLOW_DISPATCH_ENV, "30.0")
    eng = CommunityServeEngine(max_retries=0)
    with faultinject.inject("slow_dispatch"):
        accepted = _traffic(eng, count=2, deadline_ms=500.0)
        responses = resilience.call_with_deadline(eng.flush, 60.0)
    assert {r.request_id for r in responses} == set(accepted)
    assert all(not r.ok and "DeadlineError" in r.error for r in responses)


def test_smoke_entrypoint_is_clean():
    from launch.community_serve import _smoke

    assert _smoke(n_requests=4, deadline_ms=60000.0) == 0
