"""Unit tests for the loop-aware HLO cost model (launch/hlo_cost.py) —
validated against analytically-known FLOP counts via subprocess compiles."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
           XLA_FLAGS="--xla_force_host_platform_device_count=8")


def _run_py(code: str) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    return p.stdout


def test_scan_matmul_flops_counted_with_trip_count():
    out = _run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_cost import analyze
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y
        comp = jax.jit(f).lower(jax.ShapeDtypeStruct((8,128), jnp.bfloat16),
                                jax.ShapeDtypeStruct((128,128), jnp.bfloat16)).compile()
        a = analyze(comp.as_text())
        print(a['flops_per_device'])
    """)
    flops = float(out.strip())
    floor = 7 * 2 * 8 * 128 * 128
    assert floor <= flops <= 1.2 * floor


def test_nested_scan_flops_multiply():
    out = _run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_cost import analyze
        def g(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y
        comp = jax.jit(g).lower(jax.ShapeDtypeStruct((8,128), jnp.float32),
                                jax.ShapeDtypeStruct((128,128), jnp.float32)).compile()
        print(analyze(comp.as_text())['flops_per_device'])
    """)
    flops = float(out.strip())
    expect = 15 * 2 * 8 * 128 * 128
    assert abs(flops - expect) / expect < 0.01


def test_spmd_per_device_flops_and_collectives():
    out = _run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_cost import analyze
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(2, 4)
        def h(x, w):
            y = x @ w                     # contracted dim sharded -> psum
            return y
        sx = NamedSharding(mesh, P('data', 'model'))
        sw = NamedSharding(mesh, P('model', None))
        comp = jax.jit(h, in_shardings=(sx, sw),
                       out_shardings=NamedSharding(mesh, P('data', None))).lower(
            jax.ShapeDtypeStruct((16, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 512), jnp.float32)).compile()
        a = analyze(comp.as_text())
        print(a['flops_per_device'], a['collective_bytes_per_device'])
    """)
    flops, coll = map(float, out.split())
    # per-device: (16/2) x (256/4) x 512 x 2
    assert abs(flops - 2 * 8 * 64 * 512) / (2 * 8 * 64 * 512) < 0.01
    assert coll > 0  # the contraction psum must be visible


def test_dynamic_slice_counts_touched_bytes_only():
    out = _run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_cost import analyze
        def f(stack):
            def body(c, i):
                return c + jax.lax.dynamic_index_in_dim(stack, i, keepdims=False), None
            y, _ = jax.lax.scan(body, jnp.zeros((64,64), jnp.float32),
                                jnp.arange(16), length=16)
            return y
        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((16,64,64), jnp.float32)).compile()
        a = analyze(comp.as_text())
        print(a['bytes_per_device'])
    """)
    b = float(out.strip())
    # touched per iter ~ 3-4 slices of 16KB; full-stack counting would be
    # >= 16 iters x 256KB = 4MB
    assert b < 3.0e6, b


def test_parser_handles_tuple_types_with_index_comments():
    from repro.launch.hlo_cost import _parse_instr
    line = ("%while.1 = (s32[], bf16[8,128]{1,0}, /*index=5*/f32[4,4]{1,0}) "
            "while(%tuple.8), condition=%cond, body=%body, "
            'backend_config={"known_trip_count":{"n":"7"}}')
    ins = _parse_instr(line)
    assert ins is not None and ins.opcode == "while"


def test_dryrun_artifacts_are_coherent():
    """Any existing dry-run artifacts must satisfy basic invariants."""
    import glob
    import json
    pat = os.path.join(REPO, "benchmarks", "artifacts", "dryrun", "*", "*.json")
    files = glob.glob(pat)
    if not files:
        pytest.skip("no dry-run artifacts yet")
    for f in files:
        rec = json.load(open(f))
        assert rec["status"] in ("ok", "skipped"), (f, rec.get("error"))
        if rec["status"] == "ok":
            ca = rec["cost_loop_aware"]
            assert ca["flops_per_device"] > 0
            assert ca["bytes_per_device"] > 0
            assert rec["model_flops_global"] > 0
