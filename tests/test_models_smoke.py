"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family — one forward/train step on CPU asserting output shapes + no NaNs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api as model_api
from repro.models.common import init_params


def _batch(c, rng, B=2, S=16):
    toks = jnp.asarray(rng.integers(0, c.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    kw = {}
    if c.family == "vlm":
        e = jnp.asarray(rng.normal(size=(B, c.n_img_tokens, c.d_model)),
                        jnp.bfloat16)
        batch["img_embeds"] = kw["img_embeds"] = e
    if c.family == "audio":
        e = jnp.asarray(rng.normal(size=(B, c.n_frames, c.d_model)), jnp.bfloat16)
        batch["enc_embeds"] = kw["enc_embeds"] = e
    return batch, kw


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_decode(arch, rng):
    c = configs.get(arch, reduced=True)
    m = model_api.build(c)
    params = init_params(m.decls, seed=0)
    B, S = 2, 16
    batch, kw = _batch(c, rng, B, S)
    logits = m.prefill_fn(params, batch)
    assert logits.shape == (B, S, c.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    loss, metrics = m.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    st = m.init_decode_state(params, B, 32, **kw)
    dl, st2 = m.decode_fn(params, batch["tokens"][:, 0], st)
    assert dl.shape == (B, c.vocab_size)
    assert not bool(jnp.any(jnp.isnan(dl.astype(jnp.float32))))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch, rng):
    """One real optimizer step on the reduced config; loss finite, params move."""
    from repro.launch.train import build_trainer
    from repro.models.arch_config import ShapeCell
    c = configs.get(arch, reduced=True)
    cell = ShapeCell("t", "train", 16, 2)
    model, step, init_fn = build_trainer(c, cell)
    params, opt = init_fn(0)
    batch, _ = _batch(c, rng, 2, 16)
    p0 = np.asarray(jax.device_get(jax.tree.leaves(params)[0])).copy()
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    p1 = np.asarray(jax.device_get(jax.tree.leaves(params2)[0]))
    assert not np.allclose(p0.astype(np.float32), p1.astype(np.float32))


@pytest.mark.parametrize("arch", ["qwen3-8b", "qwen3-moe-30b-a3b", "rwkv6-1.6b",
                                  "zamba2-1.2b"])
def test_decode_matches_prefill(arch, rng):
    """Greedy next-token from decode path == argmax of prefill logits."""
    c = configs.get(arch, reduced=True)
    m = model_api.build(c)
    params = init_params(m.decls, seed=1)
    B, S = 2, 8
    batch, kw = _batch(c, rng, B, S)
    logits = m.prefill_fn(params, batch)
    st = m.init_decode_state(params, B, 16, **kw)
    dl = None
    for t in range(S):
        dl, st = m.decode_fn(params, batch["tokens"][:, t], st)
    a = np.asarray(jnp.argmax(logits[:, -1], -1))
    b = np.asarray(jnp.argmax(dl, -1))
    np.testing.assert_array_equal(a, b)


def test_exact_config_dims():
    """The full configs carry the exact assigned dims (spot checks)."""
    c = configs.get("qwen3-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (36, 4096, 32, 8, 12288, 151936)
    assert c.qk_norm
    c = configs.get("nemotron-4-340b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        96, 18432, 96, 73728, 256000)
    assert c.activation == "squared_relu"
    c = configs.get("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.top_k, c.d_ff_expert) == (128, 8, 768)
    c = configs.get("zamba2-1.2b")
    assert (c.n_layers, c.ssm_state) == (38, 64)
    c = configs.get("whisper-large-v3")
    assert (c.n_enc_layers, c.n_layers, c.d_model, c.vocab_size) == (
        32, 32, 1280, 51866)


def test_param_counts_near_published():
    expect = {"qwen3-8b": 8.2e9, "nemotron-4-340b": 340e9,
              "llama4-maverick-400b-a17b": 400e9, "qwen3-moe-30b-a3b": 30.5e9,
              "rwkv6-1.6b": 1.6e9, "zamba2-1.2b": 1.2e9,
              "whisper-large-v3": 1.55e9}
    for a, n_exp in expect.items():
        c = configs.get(a)
        n = c.total_params()
        assert abs(n - n_exp) / n_exp < 0.12, (a, n, n_exp)


def test_moe_active_params():
    c = configs.get("llama4-maverick-400b-a17b")
    assert abs(c.active_params() - 17e9) / 17e9 < 0.15
    c = configs.get("qwen3-moe-30b-a3b")
    assert abs(c.active_params() - 3.3e9) / 3.3e9 < 0.15
