"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the jnp oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.label_argmax import ops as la_ops
from repro.kernels.segment_sum import ops as ss_ops
from repro.kernels.delta_q import ops as dq_ops


@pytest.mark.parametrize("rows,width", [(8, 8), (16, 32), (64, 16), (128, 128),
                                        (33, 8)])
@pytest.mark.parametrize("seed", [0, 1])
def test_label_argmax_matches_ref(rows, width, seed):
    rng = np.random.default_rng(seed)
    n_labels = 7
    sentinel = 1000
    nbr_lab = rng.integers(0, n_labels, (rows, width)).astype(np.int32)
    # inject padding entries (sentinel labels, zero weight)
    pad = rng.random((rows, width)) < 0.2
    nbr_lab = np.where(pad, sentinel, nbr_lab)
    w = np.where(pad, 0.0, rng.random((rows, width))).astype(np.float32)
    cur = rng.integers(0, n_labels, (rows,)).astype(np.int32)
    rows_idx = np.arange(rows, dtype=np.int32)
    args = (jnp.asarray(nbr_lab), jnp.asarray(w), jnp.asarray(cur),
            jnp.asarray(rows_idx), jnp.uint32(seed))
    kw = dict(tie_eps=0.1, sentinel=sentinel)
    out_p = la_ops.label_argmax(*args, use_pallas=True, **kw)
    out_r = la_ops.label_argmax(*args, use_pallas=False, **kw)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), rtol=1e-6)


@pytest.mark.parametrize("m,block", [(64, 16), (512, 128), (1000, 256)])
def test_sorted_segment_sum_matches_ref(m, block):
    rng = np.random.default_rng(m)
    keys = np.sort(rng.integers(0, 50, m)).astype(np.int32)
    vals = rng.standard_normal(m).astype(np.float32)
    out_p = ss_ops.sorted_segment_sum(jnp.asarray(keys), jnp.asarray(vals),
                                      block=block, use_pallas=True)
    out_r = ss_ops.sorted_segment_sum(jnp.asarray(keys), jnp.asarray(vals),
                                      block=block, use_pallas=False)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


def test_sorted_segment_sum_matches_numpy():
    rng = np.random.default_rng(7)
    m = 256
    keys = np.sort(rng.integers(0, 17, m)).astype(np.int32)
    vals = rng.standard_normal(m).astype(np.float32)
    sums, _ = ss_ops.sorted_segment_sum(jnp.asarray(keys), jnp.asarray(vals),
                                        use_pallas=True)
    expect = np.zeros(17)
    np.add.at(expect, keys, vals)
    got = np.zeros(17)
    # kernel returns per-run sums aligned to run starts
    starts = np.concatenate([[True], keys[1:] != keys[:-1]])
    got[keys[starts]] = np.asarray(sums)[starts]
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("rows,width", [(8, 8), (32, 64), (65, 16)])
@pytest.mark.parametrize("singleton_rule", [True, False])
def test_delta_q_matches_ref(rows, width, singleton_rule):
    rng = np.random.default_rng(rows + width)
    n_com = 9
    sentinel = 997
    cand = rng.integers(0, n_com, (rows, width)).astype(np.int32)
    pad = rng.random((rows, width)) < 0.15
    cand = np.where(pad, sentinel, cand)
    nbr_w = np.where(pad, 0.0, rng.random((rows, width))).astype(np.float32)
    cur = rng.integers(0, n_com, (rows,)).astype(np.int32)
    deg = rng.random(rows).astype(np.float32) + 0.1
    volc = rng.random((rows, width)).astype(np.float32) * 5
    volcur = rng.random(rows).astype(np.float32) * 5
    szc = rng.integers(1, 5, (rows, width)).astype(np.int32)
    szcur = rng.integers(1, 5, rows).astype(np.int32)
    volv = jnp.float32(37.0)
    args = (jnp.asarray(cand), jnp.asarray(nbr_w), jnp.asarray(cur),
            jnp.asarray(deg), jnp.asarray(volc), jnp.asarray(volcur),
            jnp.asarray(szc), jnp.asarray(szcur), volv)
    kw = dict(sentinel=sentinel, singleton_rule=singleton_rule)
    out_p = dq_ops.delta_q_argmax(*args, use_pallas=True, **kw)
    out_r = dq_ops.delta_q_argmax(*args, use_pallas=False, **kw)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), rtol=1e-5,
                                   atol=1e-5)


def test_kernels_under_jit():
    """Kernels must compose with jit (static shapes, no host callbacks)."""
    rng = np.random.default_rng(0)
    nbr_lab = jnp.asarray(rng.integers(0, 5, (16, 8)), jnp.int32)
    w = jnp.asarray(rng.random((16, 8)), jnp.float32)
    cur = jnp.asarray(rng.integers(0, 5, (16,)), jnp.int32)
    rows = jnp.arange(16, dtype=jnp.int32)

    @jax.jit
    def f(nl, ww, cc, rr):
        return la_ops.label_argmax(nl, ww, cc, rr, jnp.uint32(0),
                                   tie_eps=0.1, sentinel=100, use_pallas=True)

    out = f(nbr_lab, w, cur, rows)
    assert out[0].shape == (16,)


# ---------------------------------------------------------- flash attention


@pytest.mark.parametrize("b,hq,hk,sq,sk,d,bq,bk,causal", [
    (2, 4, 2, 64, 64, 16, 16, 16, True),
    (1, 8, 8, 128, 128, 32, 32, 64, True),
    (2, 4, 1, 64, 128, 16, 32, 32, False),
    (1, 2, 2, 256, 256, 64, 128, 128, True),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_matches_ref(b, hq, hk, sq, sk, d, bq, bk, causal, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    rng = np.random.default_rng(b * sq + sk)
    dt = jnp.float32 if dtype == "float32" else jnp.bfloat16
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dt)
    k = jnp.asarray(rng.standard_normal((b, hk, sk, d)), dt)
    v = jnp.asarray(rng.standard_normal((b, hk, sk, d)), dt)
    out_p = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                            use_pallas=True)
    out_r = flash_attention(q, k, v, causal=causal, use_pallas=False)
    tol = 1e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_matches_model_path():
    """The kernel oracle must agree with models/attention.full_attention."""
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.models.attention import full_attention
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 32, 16)), jnp.float32)
    from repro.models.attention import repeat_kv
    out_m = full_attention(q, repeat_kv(k, 2), repeat_kv(v, 2), causal=True)
    out_k = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_k),
                               atol=2e-5, rtol=2e-5)
