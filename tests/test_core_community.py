"""Core community-detection tests: PLP + Louvain vs oracles and baselines."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.baselines import nx_modularity, seq_louvain, seq_lpa
from repro.core.louvain import LouvainConfig, louvain
from repro.core.modularity import modularity, modularity_dense_reference
from repro.core.plp import PLPConfig, plp
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import nmi, ring_of_cliques, sbm


def _ring(nc=8, k=6):
    u, v, w, gt = ring_of_cliques(nc, k)
    return from_numpy_edges(u, v, w), gt


def test_modularity_matches_dense_reference():
    u, v, w, gt = sbm(60, 4, p_in=0.4, p_out=0.05, seed=3)
    g = from_numpy_edges(u, v, w)
    n = int(g.n_valid)
    adj = np.zeros((n, n))
    for a, b, ww in zip(*g.to_numpy_edges()):
        adj[a, b] += ww
    com = np.asarray(gt)
    q_fast = float(modularity(g, jnp.asarray(np.concatenate(
        [com, np.arange(com.size, g.n_max)]), jnp.int32)))
    q_ref = modularity_dense_reference(adj, com)
    assert abs(q_fast - q_ref) < 1e-5


def test_plp_recovers_cliques():
    g, gt = _ring()
    r = plp(g, PLPConfig(max_iterations=50))
    assert nmi(np.asarray(r.labels)[: len(gt)], gt) > 0.95
    assert r.iterations <= 50


def test_plp_frontier_shrinks():
    g, gt = _ring()
    r = plp(g, PLPConfig(max_iterations=50))
    # active set must shrink as labels stabilize (paper's V_active)
    assert r.active_history[-1] <= r.active_history[0]
    assert r.delta_n_history[-1] == 0


def test_plp_backends_agree_on_quality():
    g, gt = _ring(6, 5)
    for backend in ("segment", "ell", "pallas"):
        r = plp(g, PLPConfig(max_iterations=60, backend=backend, seed=3))
        assert nmi(np.asarray(r.labels)[: len(gt)], gt) > 0.9, backend


def test_louvain_quality_vs_sequential():
    u, v, w, gt = sbm(300, 6, p_in=0.3, p_out=0.02, seed=1)
    g = from_numpy_edges(u, v, w)
    res = louvain(g)
    c_seq = seq_louvain(g)
    q_par = res.modularity
    q_seq = nx_modularity(g, c_seq)
    # paper Fig.3: parallel lands within a few percent of sequential
    assert q_par > q_seq - 0.03
    assert nmi(np.asarray(res.labels)[: len(gt)], gt) > 0.85


def test_louvain_monotone_modularity():
    g, gt = _ring()
    res = louvain(g, LouvainConfig(track_modularity=True))
    hist = res.modularity_history
    assert all(b >= a - 1e-4 for a, b in zip(hist, hist[1:])), hist


def test_louvain_coarsening_levels():
    g, _ = _ring(10, 5)
    res = louvain(g)
    assert res.levels >= 2
    assert res.n_communities <= 12


def test_seq_lpa_baseline_runs():
    g, gt = _ring(4, 5)
    labels = seq_lpa(g)
    assert nmi(labels[: len(gt)], gt) > 0.8


def test_leiden_refinement_quality():
    """Beyond-paper: Leiden-style refinement must match or beat Louvain Q and
    converge to the same planted structure."""
    from repro.core.louvain import leiden
    u, v, w, gt = sbm(300, 6, p_in=0.3, p_out=0.02, seed=5)
    g = from_numpy_edges(u, v, w)
    r_louv = louvain(g, LouvainConfig(seed=5))
    r_leid = leiden(g, LouvainConfig(seed=5))
    assert r_leid.modularity > r_louv.modularity - 0.01, (
        r_leid.modularity, r_louv.modularity)
    assert nmi(np.asarray(r_leid.labels)[: len(gt)], gt) > 0.85
    # refinement phase must actually have run: the fused pipeline runs it on
    # device (no timer entry), so check via the per-level driver, which is
    # bit-identical to the pipeline (tests/test_pipeline.py)
    r_step = leiden(g, LouvainConfig(seed=5, pipeline_fused=False))
    assert "refinement" in r_step.timer.totals
    assert r_step.modularity == r_leid.modularity


def test_leiden_on_ring_of_cliques():
    from repro.core.louvain import leiden
    g, gt = _ring(8, 6)
    r = leiden(g)
    assert nmi(np.asarray(r.labels)[: len(gt)], gt) > 0.95
