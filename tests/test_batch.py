"""Batched many-graph engine (DESIGN.md §Serving).

The contract under test, in order of importance:

  1. **Parity** — for every graph in a batch, ``louvain_batch``/``plp_batch``
     return results bit-identical to the single-graph drivers (the
     capacity-portability contract extended over the vmap batch axis), on
     every backend including the documented pallas→ell vmap fallback.
  2. **Bucketing** — ``capacity_signature`` quantizes arbitrary graph sizes
     onto the menu anchored at the cascade floors; realistic many-graph
     workloads land on a handful of buckets (≤4 at the default menus).
  3. **Program reuse** — same-signature traffic hits the bounded LRU
     program caches; the caches expose stats and honor their maxsize.
  4. **Robustness** — degenerate graphs (zero-capacity, all-isolates)
     flow through a batch without poisoning their batch-mates, with the
     PR-7 per-graph RunReport discipline intact.
"""
import numpy as np
import pytest

from repro.core import progcache
from repro.core.batch import louvain_batch, pick_batch_slots, plp_batch
from repro.core.louvain import LouvainConfig, louvain
from repro.core.plp import PLPConfig, plp
from repro.graph import packing
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import rmat, sbm
from repro.kernels.common import bucket_capacity, capacity_signature

E = np.zeros(0, np.int64)


def _sbm_graphs(sizes, seed0=0):
    gs = []
    for i, n in enumerate(sizes):
        u, v, _w, _t = sbm(n, 4, p_in=0.3, p_out=0.02, seed=seed0 + i)
        gs.append(from_numpy_edges(u, v, n=n))
    return gs


# ----------------------------------------------------------------- signature


def test_bucket_capacity_menu():
    assert bucket_capacity(1, 64) == 64
    assert bucket_capacity(64, 64) == 64
    assert bucket_capacity(65, 64) == 128
    assert bucket_capacity(5000, 64) == 8192
    with pytest.raises(ValueError):
        bucket_capacity(-1, 64)


def test_capacity_signature_quantizes_and_schedules():
    a = capacity_signature(100, 900)
    b = capacity_signature(120, 1000)
    assert a == b                      # same bucket despite different sizes
    assert a.n_cap == 128 and a.m_cap == 1024
    assert a.ell_width > 0
    assert isinstance(a.schedule, tuple)
    big = capacity_signature(5000, 200000)
    assert big.n_cap > a.n_cap and len(big.schedule) >= 1


def test_realistic_workloads_land_on_few_buckets():
    """Planted-partition ego-net stand-ins (the serving workload) and an
    R-MAT sweep each land on a handful of buckets at the default menus —
    the serving premise that makes request batching effective."""
    egonets = _sbm_graphs([30, 40, 45, 55, 60, 40, 35, 50, 60, 30])
    sigs = {capacity_signature(g.n_max, g.m_max) for g in egonets}
    assert len(sigs) <= 4, sorted(sigs)
    rmats = []
    for scale in (6, 7, 8):
        u, v, _w = rmat(scale, 8, seed=scale)
        rmats.append(from_numpy_edges(u, v, n=1 << scale))
    rsigs = {capacity_signature(g.n_max, g.m_max) for g in rmats}
    assert len(rsigs) <= 4, sorted(rsigs)


def test_pick_batch_slots():
    assert [pick_batch_slots(k) for k in (1, 2, 3, 5, 64, 65)] == \
        [1, 2, 4, 8, 64, 128]
    with pytest.raises(ValueError):
        pick_batch_slots(0)


# ------------------------------------------------------------------- packing


def test_pad_graph_grow_only_and_parity():
    g = _sbm_graphs([50])[0]
    p = packing.pad_graph(g, 256, 2048)
    assert (p.n_max, p.m_max) == (256, 2048)
    assert int(p.n_valid) == int(g.n_valid)
    assert int(p.m_valid) == int(g.m_valid)
    # padded run is bit-identical on valid vertices (capacity portability)
    r0 = louvain(g)
    r1 = louvain(p)
    assert np.array_equal(r0.labels, r1.labels[:g.n_max])
    assert r0.modularity == r1.modularity
    with pytest.raises(ValueError):
        packing.pad_graph(p, 128, 2048)


def test_stack_graphs_validates():
    a, b = _sbm_graphs([50, 80])
    with pytest.raises(ValueError):
        packing.stack_graphs([a, b])   # capacity mismatch
    pa = packing.pad_graph(a, 256, 2048)
    pb = packing.pad_graph(b, 256, 2048)
    gb = packing.stack_graphs([pa, pb])
    assert gb.src.shape == (2, 2048)
    assert gb.n_valid.shape == (2,)
    assert gb.n_max == 256


# -------------------------------------------------------------------- parity


BACKENDS = ("segment", "ell", "pallas")


@pytest.mark.parametrize("backend", BACKENDS)
def test_louvain_batch_parity(backend):
    """Batched results are bit-identical to the unbatched driver per graph
    (mixed sizes → multiple buckets in one call)."""
    gs = _sbm_graphs([40, 90, 150, 300, 60])
    cfg = LouvainConfig(backend=backend)
    batched = louvain_batch(gs, cfg)
    for g, r in zip(gs, batched):
        u = louvain(g, cfg)
        assert np.array_equal(r.labels, u.labels)
        assert r.modularity == u.modularity
        assert r.levels == u.levels
        assert r.n_communities == u.n_communities
        assert r.sweeps_per_level == u.sweeps_per_level
        assert r.modularity_history == u.modularity_history
        assert r.delta_n_per_level == u.delta_n_per_level
        # watchdog/precision warnings are part of parity; the static
        # pallas→ell fallback is telemetry, never a degradation
        assert r.run_report.warnings == u.run_report.warnings
        assert r.run_report.degradations == []


@pytest.mark.parametrize("backend", BACKENDS)
def test_plp_batch_parity(backend):
    gs = _sbm_graphs([40, 90, 150, 300, 60], seed0=10)
    cfg = PLPConfig(backend=backend)
    batched = plp_batch(gs, cfg)
    for g, r in zip(gs, batched):
        u = plp(g, cfg)
        assert np.array_equal(r.labels, u.labels)
        assert r.iterations == u.iterations
        assert r.delta_n_history == u.delta_n_history
        assert r.active_history == u.active_history


def test_batch_padding_slots_do_not_change_results():
    """Results are invariant to batch-mates and slot padding: a graph
    clustered alone, in a ragged batch, and in a full batch gets identical
    labels (vmap-lane independence)."""
    gs = _sbm_graphs([70, 70, 70, 70, 70], seed0=20)
    alone = louvain_batch(gs[:1])[0]
    ragged = louvain_batch(gs[:3])[0]      # 3 → 4 slots, 1 filler
    full = louvain_batch(gs)[0]            # 5 → 8 slots, 3 fillers
    assert np.array_equal(alone.labels, ragged.labels)
    assert np.array_equal(alone.labels, full.labels)
    assert alone.modularity == ragged.modularity == full.modularity


def test_leiden_batch_parity():
    gs = _sbm_graphs([60, 120], seed0=30)
    cfg = LouvainConfig(refine=True)
    batched = louvain_batch(gs, cfg)
    for g, r in zip(gs, batched):
        u = louvain(g, cfg)
        assert np.array_equal(r.labels, u.labels)
        assert r.modularity == u.modularity


def test_lane_scheduling_orders_chunks_and_preserves_parity():
    """Per-bucket lane scheduling (``_schedule_lanes``): lanes are ordered
    descending by predicted sweep cost before chunking, heavy graphs land
    in the front chunks, and — the contract that matters — per-graph
    results are bit-identical with scheduling on, off, and unbatched."""
    from repro.core.batch import _chunks, _schedule_lanes
    from repro.utils import telemetry

    # one shared signature (pinned capacities), heterogeneous sizes so the
    # heuristic has real work: interleave heavy and light graphs
    sizes = [30, 110, 25, 100, 35, 120, 40, 90, 28, 105]
    gs = []
    for i, n in enumerate(sizes):
        u, v, _w, _t = sbm(n, 4, p_in=0.3, p_out=0.02, seed=200 + i)
        gs.append(from_numpy_edges(u, v, n=128, m_max=2048))
    assert len({capacity_signature(g.n_max, g.m_max) for g in gs}) == 1

    order = _schedule_lanes(gs, list(range(len(gs))))
    mvs = [int(gs[i].m_valid) for i in order]
    assert mvs == sorted(mvs, reverse=True)       # densest first
    # with max_slots=4, every chunk's heaviest lane ≥ next chunk's heaviest
    chunks = list(_chunks(order, 4))
    heaviest = [max(int(gs[i].m_valid) for i in c) for c in chunks]
    assert heaviest == sorted(heaviest, reverse=True)

    cfg = LouvainConfig()
    before = telemetry.get("batch.lane_scheduled_buckets")
    scheduled = louvain_batch(gs, cfg, max_slots=4)
    assert telemetry.get("batch.lane_scheduled_buckets") > before
    unscheduled = louvain_batch(gs, cfg, max_slots=4, lane_schedule=False)
    for g, rs, ru in zip(gs, scheduled, unscheduled):
        u = louvain(g, cfg)
        assert np.array_equal(rs.labels, u.labels)
        assert np.array_equal(ru.labels, u.labels)
        assert rs.modularity == ru.modularity == u.modularity
        assert rs.sweeps_per_level == u.sweeps_per_level
        assert rs.delta_n_per_level == u.delta_n_per_level

    pcfg = PLPConfig()
    p_sched = plp_batch(gs, pcfg, max_slots=4)
    p_plain = plp_batch(gs, pcfg, max_slots=4, lane_schedule=False)
    for g, rs, ru in zip(gs, p_sched, p_plain):
        u = plp(g, pcfg)
        assert np.array_equal(rs.labels, u.labels)
        assert np.array_equal(ru.labels, u.labels)
        assert rs.iterations == ru.iterations == u.iterations


# ------------------------------------------------------------- program cache


def test_same_signature_hits_program_cache():
    """Same-signature traffic reuses the compiled batch program: after a
    warm call, a second batch with DIFFERENT graphs of the same signature
    adds zero cache misses (the zero-steady-state-recompile contract)."""
    def gs(sizes, seed0):
        # pin capacities so both waves provably share one bucket signature
        out = []
        for i, n in enumerate(sizes):
            u, v, _w, _t = sbm(n, 4, p_in=0.3, p_out=0.02, seed=seed0 + i)
            out.append(from_numpy_edges(u, v, n=100, m_max=1000))
        return out

    cfg = LouvainConfig()
    louvain_batch(gs([50, 80], seed0=40), cfg)               # warm
    info0 = progcache.cache_stats()["batch.louvain"]
    louvain_batch(gs([66, 99], seed0=50), cfg)               # same signature
    info1 = progcache.cache_stats()["batch.louvain"]
    assert info1["misses"] == info0["misses"]
    assert info1["hits"] > info0["hits"]


def test_cache_stats_exposes_bounded_caches():
    """Every compiled-program cache is registered, observable, and bounded
    (satellite: the formerly-unbounded lru_caches now declare a maxsize)."""
    stats = progcache.cache_stats()
    for name in ("batch.louvain", "batch.plp", "engine.fused_phase",
                 "engine.step", "engine.distributed_phase", "louvain.stage",
                 "louvain.shrink"):
        assert name in stats, name
        assert stats[name]["maxsize"] is not None
        assert stats[name]["maxsize"] > 0


# ---------------------------------------------------------------- degenerate


def test_batch_with_empty_graph_slot():
    """A zero-capacity graph in a batch short-circuits to the trivial
    result (PR-7 contract) without poisoning its batch-mates."""
    gs = _sbm_graphs([60], seed0=60)
    empty = from_numpy_edges(E, E, n=0)
    mixed = [gs[0], empty, gs[0]]
    out = louvain_batch(mixed)
    assert out[1].labels.shape == (0,)
    assert out[1].n_communities == 0
    assert out[1].modularity == 0.0
    assert out[1].run_report.clean
    oracle = louvain(gs[0])
    for r in (out[0], out[2]):
        assert np.array_equal(r.labels, oracle.labels)
        assert r.modularity == oracle.modularity

    pout = plp_batch(mixed)
    assert pout[1].labels.shape == (0,)
    assert pout[1].iterations == 0
    p_oracle = plp(gs[0])
    assert np.array_equal(pout[0].labels, p_oracle.labels)


def test_batch_with_all_isolates_slot():
    """All-isolated-vertices graphs (0 edges, n > 0) batch cleanly next to
    normal graphs and keep their singleton answer."""
    iso = from_numpy_edges(E, E, n=5)
    gs = _sbm_graphs([60], seed0=70)
    out = louvain_batch([iso, gs[0]])
    oracle_iso = louvain(iso)
    assert np.array_equal(out[0].labels, oracle_iso.labels)
    assert out[0].n_communities == 5
    assert out[0].modularity == 0.0
    assert np.array_equal(out[1].labels, louvain(gs[0]).labels)


# ------------------------------------------------------------------- service


def test_serve_engine_end_to_end():
    from launch.community_serve import (CommunityRequest,
                                        CommunityServeEngine)

    eng = CommunityServeEngine()
    sizes = [50, 80, 120, 50]
    for i, n in enumerate(sizes):
        u, v, _w, _t = sbm(n, 4, p_in=0.3, p_out=0.02, seed=80 + i)
        eng.submit(CommunityRequest(request_id=f"r{i}", u=u, v=v, n=n,
                                    algo="plp" if i == 3 else "louvain"))
    # poisoned request: rejected at ingest, never joins a batch
    eng.submit(CommunityRequest(
        request_id="bad", u=np.array([0, 1]), v=np.array([1, 2]),
        w=np.array([np.nan, 1.0])))
    assert eng.pending() == 4
    resp = eng.flush()
    assert [r.request_id for r in resp] == ["r0", "r1", "r2", "r3", "bad"]
    by_id = {r.request_id: r for r in resp}
    assert not by_id["bad"].ok and "InputValidationError" in by_id["bad"].error
    for i, n in enumerate(sizes):
        r = by_id[f"r{i}"]
        assert r.ok and r.labels.shape == (n,)
        assert r.latency_s > 0 and r.batch_size >= 1
    # bitwise parity through the whole service path
    u, v, _w, _t = sbm(50, 4, p_in=0.3, p_out=0.02, seed=80)
    assert np.array_equal(by_id["r0"].labels,
                          louvain(from_numpy_edges(u, v, n=50)).labels)
    stats = eng.stats()
    assert stats["served"] == 4
    assert stats["pending"] == 0
    assert "batch.louvain" in stats["programs"]
    assert stats["counters"].get("serve.ingest_reject", 0) >= 1
    # a second flush serves fresh same-signature traffic from cache
    misses0 = eng.stats()["programs"]["batch.louvain"]["misses"]
    u, v, _w, _t = sbm(66, 4, p_in=0.3, p_out=0.02, seed=99)
    eng.submit(CommunityRequest(request_id="r9", u=u, v=v, n=66))
    r9 = eng.flush()[0]
    assert r9.ok
    assert eng.stats()["programs"]["batch.louvain"]["misses"] == misses0
