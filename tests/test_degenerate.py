"""Degenerate-graph coverage (DESIGN.md §Robustness).

Empty, single-vertex, all-self-loops, all-isolates and fully-disconnected
graphs through ``louvain`` / ``leiden`` / ``plp`` on every single-device
backend.  These shapes historically break sparse pipelines (0/0 volumes,
empty reductions, degree-0 frontiers); the contract here is: they run, the
answers are sane, and modularity is finite (the vol=0 guard returns 0.0
rather than NaN).
"""
import numpy as np
import pytest

from repro.core.louvain import LouvainConfig, leiden, louvain
from repro.core.plp import PLPConfig, plp
from repro.graph.builders import from_numpy_edges

BACKENDS = ("segment", "ell", "pallas")

E = np.zeros(0, np.int64)
EW = np.zeros(0, np.float64)


def _graphs():
    """name -> (graph builder args, expected community count or None)."""
    two_cliques_u = np.array([0, 0, 1, 3, 3, 4], np.int64)
    two_cliques_v = np.array([1, 2, 2, 4, 5, 5], np.int64)
    return {
        "single_vertex": ((E, E, EW), {"n": 1}, 1),
        "all_isolates": ((E, E, EW), {"n": 5}, 5),
        "all_self_loops": ((np.arange(4), np.arange(4),
                            np.ones(4)), {"n": 4}, 4),
        "fully_disconnected": ((two_cliques_u, two_cliques_v,
                                np.ones(6)), {"n": 6}, 2),
    }


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(_graphs()))
def test_louvain_degenerate(name, backend):
    args, kw, expect = _graphs()[name]
    g = from_numpy_edges(*args, **kw)
    res = louvain(g, LouvainConfig(backend=backend))
    n = kw["n"]
    assert res.labels.shape == (n,)
    assert np.isfinite(res.modularity)
    assert res.n_communities == expect
    # labels are contiguous community ids
    assert set(np.unique(res.labels)) == set(range(expect))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(_graphs()))
def test_leiden_degenerate(name, backend):
    args, kw, expect = _graphs()[name]
    g = from_numpy_edges(*args, **kw)
    res = leiden(g, LouvainConfig(backend=backend))
    assert np.isfinite(res.modularity)
    assert res.n_communities == expect


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(_graphs()))
def test_plp_degenerate(name, backend):
    args, kw, expect = _graphs()[name]
    g = from_numpy_edges(*args, **kw)
    res = plp(g, PLPConfig(backend=backend))
    n = kw["n"]
    assert res.labels.shape == (n,)
    # no edges to propagate over -> every vertex keeps its own label;
    # disconnected components never share labels across components
    if name != "fully_disconnected":
        assert len(np.unique(res.labels)) == expect


def test_empty_graph_all_drivers():
    g = from_numpy_edges(E, E, EW, n=0)
    res = louvain(g)
    assert res.n_communities == 0 and res.labels.shape == (0,)
    res = leiden(g)
    assert res.n_communities == 0
    p = plp(g)
    assert p.labels.shape == (0,) and p.iterations == 0


def test_isolates_modularity_is_zero_not_nan():
    g = from_numpy_edges(E, E, EW, n=5)
    res = louvain(g)
    assert res.modularity == 0.0
    assert res.run_report.clean


def test_degenerate_graphs_inside_a_batch():
    """Every degenerate shape above also flows through the BATCHED engine
    (DESIGN.md §Serving) next to a normal graph, with the same answers:
    zero-capacity inputs short-circuit to the trivial result without
    occupying a slot, and no degenerate slot poisons its batch-mates."""
    from repro.core.batch import louvain_batch, plp_batch
    from repro.graph.generators import sbm

    u, v, _w, _t = sbm(40, 4, p_in=0.3, p_out=0.05, seed=3)
    normal = from_numpy_edges(u, v, n=40)
    names = sorted(_graphs())
    degenerates = [from_numpy_edges(*a, **kw)
                   for a, kw, _ in (_graphs()[n] for n in names)]
    batch = degenerates + [from_numpy_edges(E, E, EW, n=0), normal]

    out = louvain_batch(batch)
    for name, r in zip(names, out):
        expect = _graphs()[name][2]
        assert r.n_communities == expect, name
        assert np.isfinite(r.modularity), name
        assert r.run_report.clean, name
    assert out[-2].labels.shape == (0,) and out[-2].n_communities == 0
    assert np.array_equal(out[-1].labels, louvain(normal).labels)

    pout = plp_batch(batch)
    assert pout[-2].labels.shape == (0,) and pout[-2].iterations == 0
    assert np.array_equal(pout[-1].labels, plp(normal).labels)
