"""Capacity-scheduled coarse-level cascade (DESIGN.md §Pipeline).

Contract: for ANY capacity schedule, ``louvain()``/``leiden()`` results are
BIT-FOR-BIT identical to the single-capacity pipeline
(``capacity_schedule="none"``, the parity oracle) — final labels, levels and
every per-level history — while the cascade executes at most
``len(schedule)`` compiled stage programs, descending through strictly
shrinking static capacities, with one bulk readback plus one 5-scalar sync
per stage boundary.  Coarse levels inside a cascade run the ell/pallas
backends through the traced per-stage ELL re-bucketing instead of the
segment fallback, which must not change a single bit either.
"""
import importlib

import numpy as np
import pytest
import jax.numpy as jnp

louvain_mod = importlib.import_module("repro.core.louvain")
from repro.core.louvain import (LouvainConfig, auto_capacity_schedule,
                                leiden, louvain)
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import sbm


def _banded_graph(n=6144, band=40, k=6, seed=5):
    """Deep-hierarchy graph: ~n/band communities after level 0, collapsing
    over many levels — shrinks past >= 2 capacity steps of the auto
    schedule."""
    rng = np.random.default_rng(seed)
    u = np.repeat(np.arange(n), k)
    v = np.clip(u + rng.integers(1, band, size=n * k), 0, n - 1)
    keep = u != v
    u, v = u[keep], v[keep]
    uu, vv = np.concatenate([u, v]), np.concatenate([v, u])
    return from_numpy_edges(uu, vv, np.ones(uu.size, np.float32))


def _planted_graph(n=5000, communities=40, seed=11):
    u, v, w, _ = sbm(n, communities, p_in=0.08, p_out=0.0008, seed=seed)
    return from_numpy_edges(u, v, w)


def _assert_bitwise_equal(r_a, r_b):
    np.testing.assert_array_equal(np.asarray(r_a.labels),
                                  np.asarray(r_b.labels))
    assert r_a.levels == r_b.levels
    assert r_a.n_communities == r_b.n_communities
    assert r_a.modularity == r_b.modularity
    assert r_a.modularity_history == r_b.modularity_history
    assert r_a.sweeps_per_level == r_b.sweeps_per_level
    assert r_a.n_comm_per_level == r_b.n_comm_per_level
    assert r_a.delta_n_per_level == r_b.delta_n_per_level


# ------------------------------------------------------------ schedule policy


def test_auto_schedule_bounded_and_descending():
    caps = auto_capacity_schedule(1 << 20, 1 << 24)
    assert len(caps) <= 4
    assert caps[0] == (1 << 20, 1 << 24)
    for a, b in zip(caps, caps[1:]):
        assert b[0] < a[0] or b[1] < a[1]
        assert b[0] <= a[0] and b[1] <= a[1]
    # floors hold
    assert all(n >= 256 and m >= 2048 for n, m in caps)


def test_auto_schedule_small_graph_degenerates():
    assert auto_capacity_schedule(200, 4000) == ((200, 4000),)
    assert auto_capacity_schedule(4095, 40000) == ((4095, 40000),)


@pytest.mark.parametrize("bad", [
    "bogus",
    (),
    ((0, 10),),
    ((10, -1),),
    ((10,),),
    ((100, 100), (200, 100)),          # not descending
    ((100, 100), (100, 100)),          # stalled
    (("a", 10),),
])
def test_schedule_validation_rejects(bad):
    with pytest.raises(ValueError, match="capacity_schedule"):
        LouvainConfig(capacity_schedule=bad)


def test_schedule_validation_accepts_forms():
    LouvainConfig(capacity_schedule="auto")
    LouvainConfig(capacity_schedule="none")
    LouvainConfig(capacity_schedule=((4096, 65536), (1024, 16384)))


# ------------------------------------------------------------ parity suite


@pytest.mark.parametrize("backend", ["segment", "ell"])
@pytest.mark.parametrize("algo", ["louvain", "leiden"])
def test_cascade_parity_deep_banded(algo, backend):
    """Deep-hierarchy banded graph: the run must actually descend >= 2
    capacity steps and stay bit-identical to the single-capacity oracle."""
    g = _banded_graph()
    run = leiden if algo == "leiden" else louvain
    cfg = LouvainConfig(seed=5, backend=backend)
    r_c = run(g, cfg.replace(capacity_schedule="auto"))
    r_f = run(g, cfg.replace(capacity_schedule="none"))
    _assert_bitwise_equal(r_c, r_f)
    assert len(r_c.cascade_stages) >= 2, r_c.cascade_stages
    assert r_c.cascade_stages[0] == (g.n_max, g.m_max)
    for a, b in zip(r_c.cascade_stages, r_c.cascade_stages[1:]):
        assert b[0] < a[0] and b[1] < a[1]
    assert r_f.cascade_stages == [(g.n_max, g.m_max)]
    # the schedule bound on compiled stage programs
    assert len(r_c.cascade_stages) <= len(
        auto_capacity_schedule(g.n_max, g.m_max))


def test_cascade_parity_planted_partition():
    g = _planted_graph()
    cfg = LouvainConfig(seed=2, backend="segment")
    r_c = louvain(g, cfg.replace(capacity_schedule="auto"))
    r_f = louvain(g, cfg.replace(capacity_schedule="none"))
    _assert_bitwise_equal(r_c, r_f)
    assert len(r_c.cascade_stages) >= 2, r_c.cascade_stages


def test_cascade_parity_pallas_backend():
    """pallas coarse levels run the fused kernel over the traced tile."""
    g = _banded_graph(n=4608, band=32, k=5, seed=9)
    cfg = LouvainConfig(seed=9, backend="pallas", track_modularity=False)
    r_c = louvain(g, cfg.replace(capacity_schedule="auto"))
    r_f = louvain(g, cfg.replace(capacity_schedule="none"))
    _assert_bitwise_equal(r_c, r_f)
    assert len(r_c.cascade_stages) >= 2


def test_cascade_never_shrinking_degenerates():
    """A hierarchy that never fits the next capacity must stay in the one
    full-capacity program (today's pipeline) and still agree.

    A perfect matching collapses to exactly n/2 communities at level 0 and
    converges at level 1 (the coarse graph is pure self-loops), so the live
    counts never drop below the first capacity step n/4."""
    n = 4500
    u = np.arange(0, n, 2)
    v = u + 1
    g = from_numpy_edges(u, v, np.ones(u.size, np.float32))
    assert g.n_max >= 4096  # auto schedule is NOT degenerate
    assert len(auto_capacity_schedule(g.n_max, g.m_max)) > 1
    cfg = LouvainConfig(seed=1, backend="segment")
    r_c = louvain(g, cfg.replace(capacity_schedule="auto"))
    r_f = louvain(g, cfg.replace(capacity_schedule="none"))
    _assert_bitwise_equal(r_c, r_f)
    # ~n/2 communities never fit the n/4 capacity step: one stage, no descent
    assert r_c.n_communities > n // 4
    assert r_c.cascade_stages == [(g.n_max, g.m_max)]


def test_cascade_capacity_padded_sparse_graph():
    """Schedule floors must clamp to the graph's OWN capacities: a
    capacity-padded sparse graph (m_max below the 2048 m-floor) used to be
    scheduled to GROW its edge capacity, crashing the second stage with a
    shape mismatch."""
    from repro.graph.structure import graph_from_arrays

    rng = np.random.default_rng(0)
    u = rng.integers(0, 900, 800)
    v = rng.integers(0, 900, 800)
    keep = u != v
    uu = np.concatenate([u[keep], v[keep]])
    vv = np.concatenate([v[keep], u[keep]])
    order = np.lexsort((vv, uu))
    g = graph_from_arrays(
        jnp.asarray(uu[order], jnp.int32), jnp.asarray(vv[order], jnp.int32),
        jnp.ones((uu.size,), jnp.float32), n_max=5000, m_max=1800,
        n_valid=900, sorted_by="src")
    assert g.m_max < 2048 <= 4096 <= g.n_max
    caps = auto_capacity_schedule(g.n_max, g.m_max)
    assert all(m <= g.m_max for _, m in caps)
    cfg = LouvainConfig(seed=0, backend="segment")
    r_c = louvain(g, cfg.replace(capacity_schedule="auto"))
    r_f = louvain(g, cfg.replace(capacity_schedule="none"))
    _assert_bitwise_equal(r_c, r_f)
    assert len(r_c.cascade_stages) >= 2


def test_explicit_schedule_and_oversized_entries():
    g = _banded_graph(n=4352, band=40, k=6, seed=3)
    sched = ((1 << 20, 1 << 24),        # larger than the graph: dropped
             (1024, 12288), (320, 4096))
    cfg = LouvainConfig(seed=3, backend="segment")
    r_c = louvain(g, cfg.replace(capacity_schedule=sched))
    r_f = louvain(g, cfg.replace(capacity_schedule="none"))
    _assert_bitwise_equal(r_c, r_f)
    assert r_c.cascade_stages[0] == (g.n_max, g.m_max)
    assert all(s in ((g.n_max, g.m_max),) + sched[1:]
               for s in r_c.cascade_stages)
    assert len(r_c.cascade_stages) >= 2


def test_cascade_transfer_accounting():
    """One bulk readback per run; one 5-scalar sync per stage boundary
    crossed (never more than the schedule allows); zero syncs when the
    schedule degenerates."""
    g = _banded_graph(n=4608, band=32, k=5, seed=7)
    cfg = LouvainConfig(seed=7, backend="segment", track_modularity=False)
    louvain(g, cfg)  # warm (compile outside the counted window)

    before_rb = louvain_mod._transfer_count
    before_sync = louvain_mod._stage_sync_count
    r = louvain(g, cfg)
    assert louvain_mod._transfer_count == before_rb + 1
    syncs = louvain_mod._stage_sync_count - before_sync
    assert 1 <= syncs <= len(auto_capacity_schedule(g.n_max, g.m_max))
    assert len(r.cascade_stages) >= 2

    # degenerate schedule: single program, zero stage syncs
    r0 = louvain(g, cfg.replace(capacity_schedule="none"))
    before_sync = louvain_mod._stage_sync_count
    louvain(g, cfg.replace(capacity_schedule="none"))
    assert louvain_mod._stage_sync_count == before_sync
    _assert_bitwise_equal(r, r0)


def test_stage_program_count_bounded_by_schedule():
    """Distinct compiled stage programs per run <= len(schedule)."""
    g = _banded_graph(n=4864, band=36, k=5, seed=13)
    cfg = LouvainConfig(seed=13, backend="segment", track_modularity=False)
    louvain(g, cfg)  # warm
    before = louvain_mod._stage_fn.cache_info().misses
    r = louvain(g, cfg)
    assert louvain_mod._stage_fn.cache_info().misses == before  # all cached
    assert len(r.cascade_stages) <= len(
        auto_capacity_schedule(g.n_max, g.m_max))


# ------------------------------------------------------------ traced tile


def test_traced_ell_tile_covers_and_flags_tail():
    from repro.core import aggregation
    from repro.graph.ell import traced_ell_tile

    u, v, w, gt = sbm(300, 10, p_in=0.3, p_out=0.02, seed=4)
    g0 = from_numpy_edges(u, v, w)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g0.n_max)]), jnp.int32)
    _, _, cg = aggregation.remap_and_coarsen(g0, com)

    rows, nbr, wt, is_tail = traced_ell_tile(cg, 16)
    n = cg.n_max
    deg = np.zeros(n, np.int64)
    src, dst, wv = cg.to_numpy_edges()
    np.add.at(deg, src, 1)
    nv = int(cg.n_valid)
    np.testing.assert_array_equal(np.asarray(is_tail)[:nv], deg[:nv] > 16)
    # non-tail rows reproduce the exact non-loop neighbor multiset
    rows_np, nbr_np, wt_np = (np.asarray(rows), np.asarray(nbr),
                              np.asarray(wt))
    for vtx in range(nv):
        if deg[vtx] > 16:
            assert rows_np[vtx] == n  # tail row is pure padding
            continue
        assert rows_np[vtx] == vtx
        want = sorted((d, ww) for s, d, ww in zip(src, dst, wv)
                      if s == vtx and d != vtx)
        got = sorted((d, ww) for d, ww in zip(nbr_np[vtx], wt_np[vtx])
                     if d < n)
        assert got == want, vtx
    # weights of padding slots are zero
    assert float(wt_np[nbr_np == n].sum()) == 0.0


@pytest.mark.parametrize("evaluator", ["louvain", "plp"])
@pytest.mark.parametrize("width", [4, 64])
def test_traced_engine_matches_segment(evaluator, width):
    """Traced ell/pallas coarse evaluator == segment evaluator, bit-for-bit,
    including a width small enough to force the cond-gated tail path."""
    from repro.core import aggregation
    from repro.core.engine import EngineSpec, SweepEngine
    from repro.graph.ell import traced_ell_tile

    u, v, w, gt = sbm(400, 12, p_in=0.35, p_out=0.03, seed=7)
    g0 = from_numpy_edges(u, v, w)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g0.n_max)]), jnp.int32)
    _, _, cg = aggregation.remap_and_coarsen(g0, com)
    if width == 4:   # sanity: the forced-tail case really has a tail
        *_, it = traced_ell_tile(cg, width)
        assert bool(jnp.any(it))

    res = {}
    for backend, ew in (("segment", 0), ("ell", width), ("pallas", width)):
        spec = EngineSpec(evaluator=evaluator, backend=backend,
                          max_sweeps=12, move_prob=0.5, ell_width=ew)
        eng = SweepEngine(cg, spec)
        res[backend] = eng.run_phase(*eng.singleton_state(), it0=1000, seed=3)
    for backend in ("ell", "pallas"):
        np.testing.assert_array_equal(
            np.asarray(res[backend].labels), np.asarray(res["segment"].labels))
        assert res[backend].sweeps == res["segment"].sweeps
        assert (res[backend].delta_n_history
                == res["segment"].delta_n_history)


def test_ell_width_spec_validation():
    from repro.core.engine import EngineSpec

    with pytest.raises(ValueError, match="ell_width"):
        EngineSpec(backend="segment", ell_width=16)
    with pytest.raises(ValueError, match="ell_width"):
        EngineSpec(backend="ell", ell_width=-1)
    EngineSpec(backend="pallas", ell_width=64)


def test_pick_ell_width_menu():
    from repro.kernels.common import STAGE_WIDTH_MENU, pick_ell_width

    assert pick_ell_width(3, 1024, 8192) == STAGE_WIDTH_MENU[0]
    assert pick_ell_width(64, 1024, 8192) == 64
    assert pick_ell_width(65, 1024, 8192) == 256
    assert pick_ell_width(10_000, 1024, 8192) == STAGE_WIDTH_MENU[-1]
    # static heuristic (stage 0): 4x average degree, floored at the menu min
    assert pick_ell_width(None, 1024, 2048) == STAGE_WIDTH_MENU[0]
    assert pick_ell_width(None, 1024, 32768) == 256
