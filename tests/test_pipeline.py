"""Multi-level pipeline fusion (DESIGN.md §Pipeline).

Contract: ``pipeline_fused=True`` (whole level loop in one jitted
lax.while_loop, one host readback) and ``pipeline_fused=False`` (per-level
Python driver) produce BIT-FOR-BIT identical final labels, levels, and
per-level histories at fixed seed, for louvain and leiden on the ``segment``
and ``ell`` backends — and the fused pipeline performs exactly one
device→host transfer per call after graph build.
"""
import dataclasses

import numpy as np
import pytest
import jax

from repro.core.louvain import LouvainConfig, leiden, louvain

# repro.core.__init__ re-exports the louvain FUNCTION under the module's
# name, so fetch the actual module object for monkeypatching hooks
import importlib
louvain_mod = importlib.import_module("repro.core.louvain")
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import ring_of_cliques, sbm


def _graph(seed=7, n=200, k=5):
    u, v, w, _ = sbm(n, k, p_in=0.3, p_out=0.03, seed=seed)
    return from_numpy_edges(u, v, w)


def _assert_bitwise_equal(r_fused, r_step):
    np.testing.assert_array_equal(
        np.asarray(r_fused.labels), np.asarray(r_step.labels))
    assert r_fused.levels == r_step.levels
    assert r_fused.n_communities == r_step.n_communities
    assert r_fused.modularity == r_step.modularity
    assert r_fused.modularity_history == r_step.modularity_history
    assert r_fused.sweeps_per_level == r_step.sweeps_per_level
    assert r_fused.n_comm_per_level == r_step.n_comm_per_level
    assert r_fused.delta_n_per_level == r_step.delta_n_per_level


@pytest.mark.parametrize("backend", ["segment", "ell"])
@pytest.mark.parametrize("algo", ["louvain", "leiden"])
def test_pipeline_fused_matches_per_level(algo, backend):
    g = _graph()
    run = leiden if algo == "leiden" else louvain
    cfg = LouvainConfig(seed=3, backend=backend)
    r_fused = run(g, cfg.replace(pipeline_fused=True))
    r_step = run(g, cfg.replace(pipeline_fused=False))
    _assert_bitwise_equal(r_fused, r_step)


def test_pipeline_parity_without_modularity_tracking():
    g = _graph(seed=11)
    cfg = LouvainConfig(seed=1, track_modularity=False)
    r_fused = louvain(g, cfg.replace(pipeline_fused=True))
    r_step = louvain(g, cfg.replace(pipeline_fused=False))
    assert r_fused.modularity_history == [] == r_step.modularity_history
    _assert_bitwise_equal(r_fused, r_step)


def test_pipeline_parity_under_level_budget():
    """Budget exhaustion (max_levels smaller than natural depth) must agree."""
    g = _graph(seed=4)
    cfg = LouvainConfig(seed=4, max_levels=2)
    r_fused = louvain(g, cfg.replace(pipeline_fused=True))
    r_step = louvain(g, cfg.replace(pipeline_fused=False))
    assert r_fused.levels <= 2
    _assert_bitwise_equal(r_fused, r_step)


def test_pipeline_single_readback():
    """The fused pipeline makes exactly ONE device→host transfer per call
    (the `_readback` of the history buffers), and no other jax.device_get."""
    g = _graph(seed=5)
    cfg = LouvainConfig(seed=5)
    louvain(g, cfg)  # warm: compile outside the counted window

    calls = {"readback": 0, "device_get": 0}
    orig_readback = louvain_mod._readback
    orig_device_get = jax.device_get

    def counting_readback(tree):
        calls["readback"] += 1
        return orig_readback(tree)

    def counting_device_get(tree):
        calls["device_get"] += 1
        return orig_device_get(tree)

    louvain_mod._readback = counting_readback
    jax.device_get = counting_device_get
    try:
        louvain(g, cfg)
    finally:
        louvain_mod._readback = orig_readback
        jax.device_get = orig_device_get
    assert calls["readback"] == 1
    assert calls["device_get"] == 1   # only the one inside _readback


def test_pipeline_transfer_counter_hook():
    g = _graph(seed=6)
    before = louvain_mod._transfer_count
    louvain(g, LouvainConfig(seed=6))
    assert louvain_mod._transfer_count == before + 1


def test_max_levels_one_regression():
    """max_levels=1 used to be the smallest legal value; it must run and the
    two drivers must agree (the old driver raised UnboundLocalError for
    max_levels < 1, which is now rejected at config construction)."""
    g = _graph(seed=8)
    cfg = LouvainConfig(seed=8, max_levels=1)
    r_fused = louvain(g, cfg.replace(pipeline_fused=True))
    r_step = louvain(g, cfg.replace(pipeline_fused=False))
    assert r_fused.levels == 1 == r_step.levels
    _assert_bitwise_equal(r_fused, r_step)


@pytest.mark.parametrize("bad", [
    dict(max_levels=0), dict(max_levels=-3),
    dict(move_prob=0.0), dict(move_prob=-0.5), dict(move_prob=1.5),
    dict(refine_sweeps=0),
])
def test_config_validation_rejects(bad):
    with pytest.raises(ValueError):
        LouvainConfig(**bad)


def test_config_validation_survives_replace():
    cfg = LouvainConfig()
    with pytest.raises(ValueError):
        cfg.replace(max_levels=0)
    assert dataclasses.replace(cfg, max_levels=1).max_levels == 1


def test_pipeline_histories_well_formed():
    """Histories must cover exactly `levels` entries with sane values."""
    u, v, w, _ = ring_of_cliques(10, 5)
    g = from_numpy_edges(u, v, w)
    res = louvain(g, LouvainConfig(seed=2))
    assert res.levels >= 2
    assert len(res.sweeps_per_level) == res.levels
    assert len(res.n_comm_per_level) == res.levels
    assert len(res.modularity_history) == res.levels
    assert len(res.delta_n_per_level) == res.levels
    assert all(s >= 1 for s in res.sweeps_per_level)
    # community counts shrink monotonically and end at the final count
    nc = res.n_comm_per_level
    assert all(b <= a for a, b in zip(nc, nc[1:]))
    assert nc[-1] == res.n_communities
    # ΔN histories are the executed prefix (no -1 sentinels leak out)
    for dn, s in zip(res.delta_n_per_level, res.sweeps_per_level):
        assert len(dn) == s
        assert all(x >= 0 for x in dn)


def test_pipeline_stepwise_sweeps_fall_back_to_per_level():
    """fused=False (stepwise sweeps) cannot run inside the fused pipeline;
    the driver must fall back to the per-level path and still agree."""
    g = _graph(seed=9)
    cfg = LouvainConfig(seed=9)
    r = louvain(g, cfg.replace(fused=False, pipeline_fused=True))
    r_ref = louvain(g, cfg.replace(fused=False, pipeline_fused=False))
    _assert_bitwise_equal(r, r_ref)
    # and the stepwise-sweep run matches the fully fused pipeline too
    r_pipe = louvain(g, cfg)
    _assert_bitwise_equal(r_pipe, r)
