"""Serving-engine integration tests."""
import numpy as np
import pytest

from repro import configs
from repro.models import api as model_api
from repro.models.common import init_params
from repro.launch.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    c = configs.get("qwen3-1.7b", reduced=True)
    m = model_api.build(c)
    params = init_params(m.decls, seed=0)
    return c, params, ServeEngine(c, params, batch_slots=2, max_seq=64)


def test_serves_all_requests(engine):
    _, _, eng = engine
    reqs = [Request(prompt=[1, 2, 3], max_new=5),
            Request(prompt=[4, 5], max_new=4),
            Request(prompt=[7, 8, 9, 10], max_new=3)]
    done = eng.run(reqs)
    assert len(done) == 3
    for r in done:
        assert len(r.output) == r.max_new


def test_batched_matches_unbatched(engine):
    """Slot-batched decoding must produce the same greedy tokens as a
    dedicated single-slot engine."""
    c, params, _ = engine
    single = ServeEngine(c, params, batch_slots=1, max_seq=64)
    multi = ServeEngine(c, params, batch_slots=2, max_seq=64)
    prompts = [[1, 2, 3, 4], [9, 8, 7]]
    outs_single = [single.run([Request(prompt=p, max_new=6)])[0].output
                   for p in prompts]
    done = multi.run([Request(prompt=p, max_new=6) for p in prompts])
    outs_multi = [sorted(done, key=lambda r: prompts.index(list(r.prompt)))[i].output
                  for i in range(2)]
    assert outs_single == outs_multi


def test_recycled_slots(engine):
    _, _, eng = engine
    reqs = [Request(prompt=[i + 1], max_new=2) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
