"""Fault-injection suite (DESIGN.md §Robustness).

The contract under test: every armed fault point either lands on a fallback
path whose result is BIT-IDENTICAL to the clean oracle, or raises a typed
``CommunityDetectionError`` with a populated ``RunReport`` — never a silent
wrong answer.  Run in CI under ``REPRO_VMEM_BUDGET_BYTES=1024`` so the
capacity-adaptive policies are additionally exercised in their starved
regime.
"""
import os
import importlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.louvain import LouvainConfig, louvain
from repro.core.plp import PLPConfig, plp
from repro.graph.builders import (canonicalize_edges, from_numpy_edges,
                                  from_numpy_edges_robust)
from repro.graph.generators import sbm
from repro.utils import faultinject, telemetry
from repro.utils.errors import (CommunityDetectionError, InputValidationError,
                                KernelError, NumericError, RunReport,
                                ShardError)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def graph():
    u, v, w, _ = sbm(200, 4, p_in=0.3, p_out=0.02, seed=3)
    return from_numpy_edges(u, v, w)


# ------------------------------------------------------------------ registry


class TestRegistry:
    def test_unknown_point_rejected(self):
        with pytest.raises(ValueError, match="unknown fault"):
            faultinject.is_active("not_a_fault")
        with pytest.raises(ValueError, match="unknown fault"):
            faultinject.arm("not_a_fault")

    def test_arm_disarm_inject(self):
        assert faultinject.active() == frozenset()
        faultinject.arm("oscillation")
        assert faultinject.is_active("oscillation")
        faultinject.disarm("oscillation")
        assert not faultinject.is_active("oscillation")
        with faultinject.inject("nan_weight", "binned_overflow"):
            assert faultinject.active() == {"nan_weight", "binned_overflow"}
        assert faultinject.active() == frozenset()

    def test_inject_restores_on_exception(self):
        with pytest.raises(RuntimeError):
            with faultinject.inject("nan_weight"):
                raise RuntimeError("boom")
        assert faultinject.active() == frozenset()

    def test_engine_spec_rejects_unknown_faults(self):
        from repro.core.engine import EngineSpec

        with pytest.raises(ValueError, match="unknown fault"):
            EngineSpec(evaluator="plp", backend="segment",
                       faults=("not_a_fault",))

    def test_nested_inject_each_level_restores_what_it_saw(self):
        with faultinject.inject("nan_weight"):
            with faultinject.inject("oscillation", "vmem_starve"):
                assert faultinject.active() == {
                    "nan_weight", "oscillation", "vmem_starve"}
                # re-arming an already-armed point nests harmlessly
                with faultinject.inject("nan_weight"):
                    assert "nan_weight" in faultinject.active()
                assert "nan_weight" in faultinject.active()
            assert faultinject.active() == {"nan_weight"}
        assert faultinject.active() == frozenset()

    def test_nested_inject_restores_through_exceptions(self):
        with faultinject.inject("nan_weight"):
            with pytest.raises(RuntimeError):
                with faultinject.inject("oscillation"):
                    raise RuntimeError("boom")
            assert faultinject.active() == {"nan_weight"}
        assert faultinject.active() == frozenset()

    def test_bare_disarm_restores_env_baseline(self, monkeypatch):
        """A test's bare ``disarm()`` must not switch off the faults a CI
        chaos step configured for the whole process via REPRO_FAULTS."""
        monkeypatch.setenv(faultinject.FAULT_ENV, "oscillation,nan_weight")
        faultinject.arm("vmem_starve")
        faultinject.disarm()
        assert faultinject.active() == {"oscillation", "nan_weight"}
        monkeypatch.delenv(faultinject.FAULT_ENV)
        faultinject.disarm()
        assert faultinject.active() == frozenset()

    def test_rate_schedule_is_bresenham_exact(self):
        faultinject.arm("transient_batch_fail")
        faultinject.set_rate("transient_batch_fail", 0.25)
        fires = [faultinject.should_fire("transient_batch_fail")
                 for _ in range(20)]
        assert sum(fires) == 5          # exactly ⌊20 · 0.25⌋, no RNG
        assert fires == fires[:4] * 5   # periodic: every 4th query
        faultinject.disarm()
        assert not faultinject.should_fire("transient_batch_fail")

    def test_burst_turns_one_fire_into_consecutive_fires(self):
        faultinject.arm("transient_batch_fail")
        faultinject.set_rate("transient_batch_fail", 0.2)
        faultinject.set_burst("transient_batch_fail", 3)
        fires = [faultinject.should_fire("transient_batch_fail")
                 for _ in range(10)]
        assert fires == [False] * 4 + [True] * 3 + [False] * 3
        faultinject.disarm()

    def test_fuel_bounds_total_fires(self):
        faultinject.arm("slow_dispatch")
        faultinject.set_fuel("slow_dispatch", 2)
        fires = [faultinject.should_fire("slow_dispatch") for _ in range(5)]
        assert fires == [True, True, False, False, False]
        faultinject.disarm()

    def test_consume_fires_once_then_self_disarms(self):
        faultinject.arm("preempt_stage")
        assert faultinject.consume("preempt_stage")
        assert not faultinject.is_active("preempt_stage")
        assert not faultinject.consume("preempt_stage")


# ------------------------------------------------------- typed-error faults


class TestNanWeight:
    def test_fused_pipeline_raises_numeric(self, graph):
        with faultinject.inject("nan_weight"):
            with pytest.raises(NumericError) as ei:
                louvain(graph, LouvainConfig())
        assert "nan_weight" in ei.value.report.faults

    def test_per_level_driver_raises_numeric(self, graph):
        with faultinject.inject("nan_weight"):
            with pytest.raises(NumericError) as ei:
                louvain(graph, LouvainConfig(pipeline_fused=False))
        assert "nan_weight" in ei.value.report.faults


class TestShardDrop:
    def test_coverage_guard_raises(self):
        """A dropped shard must be refused before any compute dispatches
        (subprocess: needs 8 fake devices)."""
        code = textwrap.dedent("""
            import numpy as np, jax
            from jax.sharding import Mesh
            from repro.graph.generators import sbm
            from repro.graph.builders import from_numpy_edges
            from repro.core.distributed import distributed_louvain
            from repro.utils import faultinject
            from repro.utils.errors import ShardError
            u, v, w, _ = sbm(200, 4, p_in=0.3, p_out=0.02, seed=3)
            g = from_numpy_edges(u, v, w)
            mesh = Mesh(np.array(jax.devices()).reshape(8), ('data',))
            with faultinject.inject("shard_drop"):
                try:
                    distributed_louvain(g, mesh)
                except ShardError as e:
                    print("SHARD_ERROR", e)
                else:
                    raise SystemExit("no ShardError raised")
        """)
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env=env, cwd=REPO, timeout=900)
        assert p.returncode == 0, p.stdout + "\n" + p.stderr
        assert "SHARD_ERROR" in p.stdout


# --------------------------------------------------- bit-identical fallbacks


class TestBitIdenticalFallbacks:
    def test_binned_overflow_forces_sort_fallback(self, graph):
        clean = louvain(graph, LouvainConfig())
        telemetry.reset()
        with faultinject.inject("binned_overflow"):
            faulted = louvain(graph, LouvainConfig())
        assert np.array_equal(clean.labels, faulted.labels)
        assert clean.modularity == faulted.modularity
        assert clean.n_comm_per_level == faulted.n_comm_per_level
        assert faulted.run_report.faults == ["binned_overflow"]
        assert telemetry.get("fault.binned_overflow.forced") > 0

    def test_vmem_starve_lands_on_streamed_regime(self, graph):
        clean = louvain(graph, LouvainConfig(backend="pallas"))
        telemetry.reset()
        with faultinject.inject("vmem_starve"):
            starved = louvain(graph, LouvainConfig(backend="pallas"))
        assert np.array_equal(clean.labels, starved.labels)
        assert clean.modularity == starved.modularity
        assert telemetry.get("fault.vmem_starve.budget_clamped") > 0

    def test_oscillation_bounded_by_sweep_watchdog(self, graph):
        # move_prob=1.0 (pure Jacobi): a converged labeling is a fixpoint,
        # so forcing the loop to re-sweep cannot change labels — only burn
        # the watchdog budget, which the RunReport must record.
        cfg = LouvainConfig(move_prob=1.0, use_need_check=False, max_sweeps=6)
        clean = louvain(graph, cfg)
        with faultinject.inject("oscillation"):
            faulted = louvain(graph, cfg)
        assert np.array_equal(clean.labels, faulted.labels)
        assert clean.modularity == faulted.modularity
        assert all(s == cfg.max_sweeps for s in faulted.sweeps_per_level)
        assert any(w.startswith("watchdog:max_sweeps")
                   for w in faulted.run_report.warnings)
        assert not faulted.run_report.clean

    def test_oscillation_plp_watchdog(self, graph):
        cfg = PLPConfig(move_prob=1.0, use_frontier=False, max_iterations=5)
        clean = plp(graph, cfg)
        with faultinject.inject("oscillation"):
            faulted = plp(graph, cfg)
        assert np.array_equal(clean.labels, faulted.labels)
        assert faulted.iterations == cfg.max_iterations
        assert "watchdog:max_iterations" in faulted.run_report.warnings


# ------------------------------------------------------- degradation ladder


class TestDegradationLadder:
    def test_backend_descent_to_segment(self, graph, monkeypatch):
        """A non-taxonomy failure in the pallas backend descends
        pallas → ell → segment and still returns the segment answer."""
        louvain_mod = importlib.import_module("repro.core.louvain")

        real = louvain_mod._louvain_pipeline

        def flaky(g, cfg, g0, faults=frozenset(), promote=False):
            if cfg.backend in ("pallas", "ell"):
                raise RuntimeError(f"synthetic {cfg.backend} kernel failure")
            return real(g, cfg, g0, faults, promote)

        monkeypatch.setattr(louvain_mod, "_louvain_pipeline", flaky)
        oracle = louvain(graph, LouvainConfig(backend="segment"))
        res = louvain(graph, LouvainConfig(backend="pallas"))
        assert np.array_equal(res.labels, oracle.labels)
        assert [d["from"] for d in res.run_report.degradations] == \
            ["pallas", "ell"]
        assert all(d["kind"] == "backend_descent"
                   for d in res.run_report.degradations)

    def test_ladder_exhaustion_raises_kernel_error(self, graph, monkeypatch):
        louvain_mod = importlib.import_module("repro.core.louvain")

        def broken(g, cfg, g0, faults=frozenset(), promote=False):
            raise RuntimeError("synthetic failure on every backend")

        monkeypatch.setattr(louvain_mod, "_louvain_pipeline", broken)
        with pytest.raises(KernelError) as ei:
            louvain(graph, LouvainConfig(backend="pallas"))
        # the report shows the whole descent was tried before giving up
        assert [d["from"] for d in ei.value.report.degradations] == \
            ["pallas", "ell"]

    def test_capacity_retry_on_single_capacity_program(self, graph,
                                                       monkeypatch):
        louvain_mod = importlib.import_module("repro.core.louvain")
        from repro.utils.errors import CapacityError

        real = louvain_mod._louvain_pipeline

        def busted(g, cfg, g0, faults=frozenset(), promote=False):
            if cfg.capacity_schedule != "none":
                raise CapacityError("synthetic cascade capacity bust")
            return real(g, cfg, g0, faults, promote)

        monkeypatch.setattr(louvain_mod, "_louvain_pipeline", busted)
        oracle = louvain(graph, LouvainConfig(capacity_schedule="none"))
        res = louvain(graph, LouvainConfig(capacity_schedule="auto"))
        assert np.array_equal(res.labels, oracle.labels)
        assert res.run_report.retries == [{
            "kind": "capacity", "from": "'auto'", "to": "none",
            "error": "synthetic cascade capacity bust"}]

    def test_typed_errors_do_not_descend(self, graph, monkeypatch):
        """Taxonomy errors mean the ANSWER is unsafe: no backend retry."""
        louvain_mod = importlib.import_module("repro.core.louvain")

        calls = []

        def poisoned(g, cfg, g0, faults=frozenset(), promote=False):
            calls.append(cfg.backend)
            raise NumericError("synthetic numeric refusal")

        monkeypatch.setattr(louvain_mod, "_louvain_pipeline", poisoned)
        with pytest.raises(NumericError):
            louvain(graph, LouvainConfig(backend="pallas"))
        assert calls == ["pallas"]

    def test_clean_run_report_is_clean(self, graph):
        res = louvain(graph, LouvainConfig())
        assert res.run_report.clean
        assert res.run_report.as_dict()["faults"] == []


# ------------------------------------------------------------------- ingest


class TestIngestRepair:
    def test_clean_input_passes_through_bit_identical(self):
        u = np.array([0, 1, 2], np.int64)
        v = np.array([1, 2, 3], np.int64)
        w = np.array([1.0, 2.0, 3.0])
        u2, v2, w2, n, rep = canonicalize_edges(u, v, w, n=4)
        assert rep.clean and rep.actions == ()
        assert u2 is u and v2 is v and w2 is w

    def test_duplicates_coalesce_to_manual_dedup(self):
        u = np.array([0, 1, 0, 2, 1], np.int64)
        v = np.array([1, 0, 1, 3, 2], np.int64)
        w = np.array([1.0, 2.0, 0.5, 1.0, 1.0])
        u2, v2, w2, n, rep = canonicalize_edges(u, v, w, n=4)
        assert rep.duplicates_coalesced == 2
        g = from_numpy_edges(u2, v2, w2, n=n)
        gm = from_numpy_edges(np.array([0, 1, 2]), np.array([1, 2, 3]),
                              np.array([3.5, 1.0, 1.0]), n=4)
        assert np.array_equal(np.asarray(g.src), np.asarray(gm.src))
        assert np.array_equal(np.asarray(g.w), np.asarray(gm.w))

    def test_bad_weight_policies(self):
        u = np.array([0, 1], np.int64)
        v = np.array([1, 2], np.int64)
        w = np.array([1.0, np.nan])
        with pytest.raises(InputValidationError):
            canonicalize_edges(u, v, w, n=3)
        u2, v2, w2, n, rep = canonicalize_edges(u, v, w, n=3,
                                                bad_weights="drop")
        assert rep.nonfinite_weights == 1 and len(w2) == 1

    def test_out_of_range_ids_and_loops(self):
        u = np.array([0, 1, 2, 9], np.int64)
        v = np.array([1, 1, 0, 0], np.int64)
        w = np.ones(4)
        with pytest.raises(InputValidationError):
            canonicalize_edges(u, v, w, n=3)
        u2, v2, w2, n, rep = canonicalize_edges(
            u, v, w, n=3, bad_ids="drop", self_loops="drop")
        assert rep.out_of_range_ids == 1 and rep.self_loops_dropped == 1
        assert len(u2) == 2

    def test_robust_entry_point_reports(self):
        u = np.array([0, 1, 0], np.int64)
        v = np.array([1, 2, 1], np.int64)
        w = np.array([1.0, 1.0, 2.0])
        g, rep = from_numpy_edges_robust(u, v, w, n=3)
        assert rep.duplicates_coalesced == 1
        assert int(g.m_valid) == 4  # 2 undirected edges, symmetrized


# ------------------------------------------------------------ trivial cases


def test_empty_capacity_early_out():
    g = from_numpy_edges(np.zeros(0, np.int64), np.zeros(0, np.int64),
                         np.zeros(0), n=0)
    res = louvain(g)
    assert res.n_communities == 0 and res.levels == 0
    assert isinstance(res.run_report, RunReport)
    p = plp(g)
    assert p.iterations == 0
