"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import coarsen_graph, remap_communities
from repro.core.modularity import modularity
from repro.graph import segment as seg
from repro.graph.builders import from_numpy_edges
from repro.train import optim

# --- strategies ------------------------------------------------------------


@st.composite
def small_graphs(draw):
    n = draw(st.integers(4, 24))
    m = draw(st.integers(n, 4 * n))
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    u, v = u[keep], v[keep]
    if u.size == 0:
        u, v = np.array([0]), np.array([1 % n])
    w = rng.random(u.size).astype(np.float32) + 0.1
    return from_numpy_edges(u, v, w)


@st.composite
def partitions(draw, g):
    n = g.n_max
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    k = draw(st.integers(1, max(1, int(g.n_valid))))
    return jnp.asarray(rng.integers(0, k, n).astype(np.int32))


# --- modularity invariants ---------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_modularity_bounds(data):
    g = data.draw(small_graphs())
    com = data.draw(partitions(g))
    q = float(modularity(g, com))
    assert -0.5 - 1e-5 <= q <= 1.0 + 1e-5


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_modularity_label_permutation_invariant(data):
    g = data.draw(small_graphs())
    com = np.asarray(data.draw(partitions(g)))
    perm = np.random.default_rng(0).permutation(int(com.max()) + 1)
    q1 = float(modularity(g, jnp.asarray(com)))
    q2 = float(modularity(g, jnp.asarray(perm[com].astype(np.int32))))
    assert abs(q1 - q2) < 1e-5


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_coarsening_preserves_volume_and_modularity(data):
    """Aggregation (paper §III-B2) must preserve total volume exactly and the
    modularity of the induced partition."""
    g = data.draw(small_graphs())
    com = data.draw(partitions(g))
    new_com, n_comm = remap_communities(com, g.vertex_mask())
    q_fine = float(modularity(g, new_com))
    cg = coarsen_graph(g, new_com, n_comm)
    assert abs(float(cg.total_volume()) - float(g.total_volume())) < 1e-3
    ident = jnp.arange(cg.n_max, dtype=jnp.int32)
    q_coarse = float(modularity(cg, ident))
    assert abs(q_fine - q_coarse) < 1e-4


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_remap_is_contiguous_bijection(data):
    g = data.draw(small_graphs())
    com = np.asarray(data.draw(partitions(g)))
    new_com, n_comm = remap_communities(jnp.asarray(com), g.vertex_mask())
    nv = int(g.n_valid)
    nc = int(n_comm)
    got = np.asarray(new_com)[:nv]
    assert set(got) == set(range(nc))
    # same old label -> same new label
    for old in np.unique(com[:nv]):
        idx = np.where(com[:nv] == old)[0]
        assert len(set(got[idx])) == 1


# --- groupby/segment primitives vs numpy ------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 12), st.integers(0, 2**16))
def test_groupby_sum_matches_numpy(m, k, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, k, m).astype(np.int32)
    vals = rng.standard_normal(m).astype(np.float32)
    (gk,), gs, gvalid, n_groups = seg.groupby_sum((jnp.asarray(keys),),
                                                  jnp.asarray(vals))
    ng = int(n_groups)
    got = {int(a): float(b) for a, b in zip(np.asarray(gk)[:ng],
                                            np.asarray(gs)[:ng])}
    expect = {}
    for a, b in zip(keys, vals):
        expect[int(a)] = expect.get(int(a), 0.0) + float(b)
    assert set(got) == set(expect)
    for kk in expect:
        assert abs(got[kk] - expect[kk]) < 1e-3


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 100), st.integers(1, 9), st.integers(0, 2**16))
def test_segment_argmax_matches_numpy(m, nseg, seed):
    rng = np.random.default_rng(seed)
    segs = rng.integers(0, nseg, m).astype(np.int32)
    scores = rng.standard_normal(m).astype(np.float32)
    cands = rng.integers(0, 50, m).astype(np.int32)
    best, cand = seg.segment_argmax(jnp.asarray(scores), jnp.asarray(cands),
                                    jnp.asarray(segs), nseg)
    for s in range(nseg):
        idx = np.where(segs == s)[0]
        if idx.size == 0:
            assert int(cand[s]) == -1
        else:
            mx = scores[idx].max()
            assert abs(float(best[s]) - mx) < 1e-6
            winners = cands[idx[scores[idx] == mx]]
            assert int(cand[s]) == winners.min()


# --- optimizer invariants -----------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16))
def test_grad_clip_bounds_norm(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal(5) * 100, jnp.float32)}
    clipped, gn = optim.clip_by_global_norm(tree, 1.0)
    assert float(optim.global_norm(clipped)) <= 1.0 + 1e-4


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**10))
def test_adafactor_memory_is_factored(seed):
    params = {"w": jnp.zeros((256, 512), jnp.bfloat16),
              "small": jnp.zeros((4, 4), jnp.float32)}
    state = optim.adafactor_init(params)
    assert set(state.stats["w"]) == {"vr", "vc"}
    assert state.stats["w"]["vr"].shape == (256,)
    assert state.stats["w"]["vc"].shape == (512,)
    assert set(state.stats["small"]) == {"v"}   # too small to factor


# --- data pipeline determinism ------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 2**10))
def test_data_pipeline_deterministic(step, seed):
    from repro.models.arch_config import ShapeCell
    from repro import configs
    from repro.train.data import DataConfig, make_batch
    c = configs.get("qwen3-1.7b", reduced=True)
    cell = ShapeCell("t", "train", 32, 2)
    b1 = make_batch(c, cell, step, DataConfig(seed=seed))
    b2 = make_batch(c, cell, step, DataConfig(seed=seed))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < c.vocab_size
    assert b1["tokens"].min() >= 0
