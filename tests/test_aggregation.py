"""Aggregation edge cases + GroupBy compaction equivalence.

Covers the paper's Alg. 3 aggregation phase where the pipeline loop leans on
it hardest: all-intra partitions (coarse graph collapses to pure self-loops),
all-invalid levels (masked-out graphs), the one-sort scatter compaction in
``graph/segment.py::groupby_sum`` vs the legacy two-sort argsort path, the
FUSED one-sort ``remap_and_coarsen`` vs the two-step reference (bit-for-bit,
the §Pipeline one-sort coarsening invariant), and the capacity-changing
``shrink_graph`` compaction the cascade descends through.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.modularity import modularity
from repro.graph import segment as seg
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import ring_of_cliques, sbm
from repro.graph.structure import Graph, graph_from_arrays


# ------------------------------------------------------------ edge cases


def test_coarsen_all_intra_edges_become_self_loops():
    """Aggregating by a partition with NO cut edges: every coarse edge is a
    self-loop and the vol/deg/modularity invariants survive exactly."""
    k = 5
    u, v, w, gt = ring_of_cliques(6, k)
    # drop the ring edges so ground-truth communities are fully intra
    keep = (u // k) == (v // k)
    g = from_numpy_edges(u[keep], v[keep], w[keep], n=len(gt))
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)

    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    cg = aggregation.coarsen_graph(g, new_com, n_comm)

    assert int(n_comm) == 6
    # every surviving coarse edge is a self-loop
    em = np.asarray(cg.edge_mask)
    assert em.sum() == 6
    np.testing.assert_array_equal(
        np.asarray(cg.src)[em], np.asarray(cg.dst)[em])
    # volume invariant: total directed weight (2W) is preserved
    assert float(cg.total_volume()) == pytest.approx(
        float(g.total_volume()), rel=1e-6)
    # degree invariant: coarse deg(c) == sum of member degrees (community vol)
    deg = np.asarray(g.weighted_degrees())
    vol_c = np.zeros(g.n_max, np.float64)
    np.add.at(vol_c, np.asarray(new_com)[: len(gt)], deg[: len(gt)])
    np.testing.assert_allclose(
        np.asarray(cg.weighted_degrees())[: int(n_comm)],
        vol_c[: int(n_comm)], rtol=1e-6)
    # modularity invariant: Q(fine, partition) == Q(coarse, identity)
    ident = jnp.arange(cg.n_max, dtype=jnp.int32)
    q_fine = float(modularity(g, new_com))
    q_coarse = float(modularity(cg, ident))
    assert q_fine == pytest.approx(q_coarse, abs=1e-6)
    # all-intra partition of a disconnected union of cliques: Q = 1 - sum s_c^2
    assert q_fine == pytest.approx(1.0 - 6 * (1.0 / 6) ** 2, abs=1e-5)


def test_coarsen_preserves_modularity_with_cut_edges():
    u, v, w, gt = sbm(120, 4, p_in=0.4, p_out=0.05, seed=13)
    g = from_numpy_edges(u, v, w)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    cg = aggregation.coarsen_graph(g, new_com, n_comm)
    ident = jnp.arange(cg.n_max, dtype=jnp.int32)
    assert float(modularity(cg, ident)) == pytest.approx(
        float(modularity(g, new_com)), abs=1e-6)
    assert float(cg.total_volume()) == pytest.approx(
        float(g.total_volume()), rel=1e-6)


def _empty_graph(n_max=16, m_max=32) -> Graph:
    """A fully masked-out level: zero valid vertices, zero valid edges."""
    sentinel = jnp.int32(n_max)
    return Graph(
        src=jnp.full((m_max,), sentinel),
        dst=jnp.full((m_max,), sentinel),
        w=jnp.zeros((m_max,), jnp.float32),
        edge_mask=jnp.zeros((m_max,), bool),
        n_valid=jnp.int32(0),
        m_valid=jnp.int32(0),
        n_max=n_max,
        m_max=m_max,
        sorted_by=None,
    )


def test_remap_and_coarsen_all_invalid_level():
    """An all-masked-invalid level must stay a well-formed empty graph:
    no phantom communities, no phantom edges, zero volumes/degrees."""
    g = _empty_graph()
    com = jnp.arange(g.n_max, dtype=jnp.int32)
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    assert int(n_comm) == 0
    # every vertex slot maps to the sentinel
    np.testing.assert_array_equal(
        np.asarray(new_com), np.full(g.n_max, g.n_max, np.int32))

    cg = aggregation.coarsen_graph(g, new_com, n_comm)
    assert int(cg.n_valid) == 0
    assert int(cg.m_valid) == 0
    assert not bool(np.asarray(cg.edge_mask).any())
    assert float(cg.total_volume()) == 0.0
    np.testing.assert_array_equal(
        np.asarray(cg.weighted_degrees()), np.zeros(g.n_max, np.float32))
    # invalid slots hold sentinels, preserving the Graph convention
    np.testing.assert_array_equal(
        np.asarray(cg.src), np.full(g.m_max, g.n_max, np.int32))


def test_coarsen_partially_masked_vertices():
    """Vertices beyond n_valid are excluded from the coarse graph even if
    stray (masked) edges mention them."""
    u = np.array([0, 1, 2, 3], dtype=np.int64)
    v = np.array([1, 0, 3, 2], dtype=np.int64)
    w = np.ones(4, dtype=np.float32)
    g = graph_from_arrays(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                          jnp.asarray(w), n_max=8, m_max=8, n_valid=4)
    com = jnp.asarray([0, 0, 1, 1, 7, 7, 7, 7], jnp.int32)
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    assert int(n_comm) == 2
    cg = aggregation.coarsen_graph(g, new_com, n_comm)
    assert int(cg.n_valid) == 2
    em = np.asarray(cg.edge_mask)
    assert set(map(tuple, np.stack(
        [np.asarray(cg.src)[em], np.asarray(cg.dst)[em]], axis=1))) == {
            (0, 0), (1, 1)}
    assert float(cg.total_volume()) == pytest.approx(4.0)


# ------------------------------------------------------------ fused one-sort


def _coarsen_two_step(g, com):
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    return new_com, n_comm, aggregation.coarsen_graph(g, new_com, n_comm)


def _assert_graphs_bitwise(a, b):
    for f in ("src", "dst", "w", "edge_mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f)
    assert int(a.n_valid) == int(b.n_valid)
    assert int(a.m_valid) == int(b.m_valid)
    assert (a.n_max, a.m_max) == (b.n_max, b.m_max)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_remap_and_coarsen_matches_two_step(seed):
    """The fused one-sort remap+coarsen must reproduce the two-step
    reference bit-for-bit: new_com, n_comm, and every coarse-graph array
    including the unspecified-slot sentinels."""
    u, v, w, gt = sbm(150, 5, p_in=0.3, p_out=0.04, seed=seed)
    g = from_numpy_edges(u, v, w, m_max=2 * len(u) + 37)   # padded capacity
    rng = np.random.default_rng(seed)
    # a messy, non-contiguous partition (not the ground truth): random
    # labels drawn from a sparse id set, plus junk on the invalid slots
    com = jnp.asarray(np.concatenate([
        rng.choice(np.arange(0, 150, 7), size=150),
        rng.integers(0, g.n_max, size=g.n_max - 150),
    ]), jnp.int32)
    nc1, n1, cg1 = _coarsen_two_step(g, com)
    nc2, n2, cg2 = aggregation.remap_and_coarsen(g, com)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))
    _assert_graphs_bitwise(cg1, cg2)


def test_remap_and_coarsen_all_intra_and_empty():
    # all-intra: pure self-loops (mirrors the two-step edge-case test)
    k = 5
    u, v, w, gt = ring_of_cliques(6, k)
    keep = (u // k) == (v // k)
    g = from_numpy_edges(u[keep], v[keep], w[keep], n=len(gt))
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    nc1, n1, cg1 = _coarsen_two_step(g, com)
    nc2, n2, cg2 = aggregation.remap_and_coarsen(g, com)
    assert int(n1) == int(n2) == 6
    np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))
    _assert_graphs_bitwise(cg1, cg2)

    # fully masked-out level
    ge = _empty_graph()
    com = jnp.arange(ge.n_max, dtype=jnp.int32)
    nc2, n2, cg2 = aggregation.remap_and_coarsen(ge, com)
    assert int(n2) == 0
    assert int(cg2.m_valid) == 0
    assert not bool(np.asarray(cg2.edge_mask).any())
    np.testing.assert_array_equal(
        np.asarray(nc2), np.full(ge.n_max, ge.n_max, np.int32))


def test_shrink_graph_preserves_live_content():
    """Capacity descent: slicing a front-compacted coarse graph must keep
    every live edge/vertex and only rewrite the padding sentinels."""
    u, v, w, gt = sbm(120, 4, p_in=0.4, p_out=0.05, seed=13)
    g = from_numpy_edges(u, v, w)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    _, n_comm, cg = aggregation.remap_and_coarsen(g, com)
    n_out = int(n_comm) + 2
    m_out = int(cg.m_valid) + 3
    sg = aggregation.shrink_graph(cg, n_out, m_out)
    assert (sg.n_max, sg.m_max) == (n_out, m_out)
    assert int(sg.n_valid) == int(cg.n_valid)
    assert int(sg.m_valid) == int(cg.m_valid)
    mv = int(cg.m_valid)
    for f in ("src", "dst", "w"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sg, f))[:mv], np.asarray(getattr(cg, f))[:mv])
    em = np.asarray(sg.edge_mask)
    np.testing.assert_array_equal(
        np.asarray(sg.src)[~em], np.full((~em).sum(), n_out, np.int32))
    assert float(sg.total_volume()) == float(cg.total_volume())
    # modularity invariant survives the capacity change
    ident = jnp.arange(sg.n_max, dtype=jnp.int32)
    assert float(modularity(sg, ident)) == pytest.approx(
        float(modularity(g, jnp.asarray(
            np.asarray(aggregation.remap_communities(
                com, g.vertex_mask())[0]), jnp.int32))), abs=1e-6)


# ------------------------------------------------------------ groupby compaction


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_groupby_sum_scatter_matches_argsort(seed):
    """The one-sort scatter compaction must agree with the legacy two-sort
    argsort compaction on every valid slot (slots beyond n_groups are
    unspecified by contract and masked by group_valid)."""
    rng = np.random.default_rng(seed)
    m = 257
    k1 = jnp.asarray(rng.integers(0, 12, m), jnp.int32)
    k2 = jnp.asarray(rng.integers(0, 7, m), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(m), jnp.float32)
    valid = jnp.asarray(rng.random(m) < 0.8)

    (ka, sa, va, na) = seg.groupby_sum((k1, k2), vals, valid=valid,
                                       compact_via="argsort")
    (kb, sb, vb, nb) = seg.groupby_sum((k1, k2), vals, valid=valid,
                                       compact_via="scatter")
    n = int(na)
    assert n == int(nb)
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    for a, b in zip(ka, kb):
        np.testing.assert_array_equal(np.asarray(a)[:n], np.asarray(b)[:n])
    # sums agree bitwise on the valid prefix (same sort, same segment_sum)
    np.testing.assert_array_equal(np.asarray(sa)[:n], np.asarray(sb)[:n])


def test_groupby_sum_matches_numpy_reference():
    rng = np.random.default_rng(3)
    m = 200
    k = rng.integers(0, 15, m)
    vals = rng.standard_normal(m).astype(np.float32)
    valid = rng.random(m) < 0.7
    (gk,), gs, gv, ng = seg.groupby_sum(
        (jnp.asarray(k, jnp.int32),), jnp.asarray(vals),
        valid=jnp.asarray(valid))
    expect = {}
    for ki, vi, ok in zip(k, vals, valid):
        if ok:
            expect[int(ki)] = expect.get(int(ki), 0.0) + float(vi)
    n = int(ng)
    assert n == len(expect)
    got = {int(a): float(b) for a, b in
           zip(np.asarray(gk)[:n], np.asarray(gs)[:n])}
    assert set(got) == set(expect)
    for key in expect:
        assert got[key] == pytest.approx(expect[key], abs=1e-5)


def test_groupby_sum_all_invalid():
    m = 33
    (gk,), gs, gv, ng = seg.groupby_sum(
        (jnp.zeros((m,), jnp.int32),), jnp.ones((m,), jnp.float32),
        valid=jnp.zeros((m,), bool))
    assert int(ng) == 0
    assert not bool(np.asarray(gv).any())


@pytest.mark.parametrize("seed", [0, 1])
def test_compact_scatter_matches_argsort(seed):
    """The sort-free scatter compaction builds the SAME stable permutation
    the legacy argsort did (full array, not just the valid prefix)."""
    rng = np.random.default_rng(seed)
    m = 131
    mask = jnp.asarray(rng.random(m) < 0.6)
    arrays = (jnp.arange(m, dtype=jnp.int32),
              jnp.asarray(rng.standard_normal(m), jnp.float32))
    out_s, n_s = seg.compact(mask, arrays, via="scatter")
    out_a, n_a = seg.compact(mask, arrays, via="argsort")
    assert int(n_s) == int(n_a)
    for a, b in zip(out_s, out_a):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        seg.compact(mask, arrays, via="bogus")
