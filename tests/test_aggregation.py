"""Aggregation edge cases + GroupBy compaction equivalence.

Covers the paper's Alg. 3 aggregation phase where the pipeline loop leans on
it hardest: all-intra partitions (coarse graph collapses to pure self-loops),
all-invalid levels (masked-out graphs), the one-sort scatter compaction in
``graph/segment.py::groupby_sum`` vs the legacy two-sort argsort path, the
FUSED one-sort ``remap_and_coarsen`` vs the two-step reference (bit-for-bit,
the §Pipeline one-sort coarsening invariant), the capacity-changing
``shrink_graph`` compaction the cascade descends through, and the SORT-FREE
binned path (DESIGN.md §Aggregation kernel): bitmap-cumsum remap + hash-bin
scatter merge vs the one-sort oracle, bit-for-bit, across multigraphs,
forced-overflow fallbacks, capacity-padded graphs, every cascade stage
capacity, the Pallas rank kernel vs its jnp ref, and end-to-end
louvain/leiden runs under ``aggregation="binned"`` vs ``"sort"``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.modularity import modularity
from repro.graph import segment as seg
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import ring_of_cliques, sbm
from repro.graph.structure import Graph, graph_from_arrays


# ------------------------------------------------------------ edge cases


def test_coarsen_all_intra_edges_become_self_loops():
    """Aggregating by a partition with NO cut edges: every coarse edge is a
    self-loop and the vol/deg/modularity invariants survive exactly."""
    k = 5
    u, v, w, gt = ring_of_cliques(6, k)
    # drop the ring edges so ground-truth communities are fully intra
    keep = (u // k) == (v // k)
    g = from_numpy_edges(u[keep], v[keep], w[keep], n=len(gt))
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)

    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    cg = aggregation.coarsen_graph(g, new_com, n_comm)

    assert int(n_comm) == 6
    # every surviving coarse edge is a self-loop
    em = np.asarray(cg.edge_mask)
    assert em.sum() == 6
    np.testing.assert_array_equal(
        np.asarray(cg.src)[em], np.asarray(cg.dst)[em])
    # volume invariant: total directed weight (2W) is preserved
    assert float(cg.total_volume()) == pytest.approx(
        float(g.total_volume()), rel=1e-6)
    # degree invariant: coarse deg(c) == sum of member degrees (community vol)
    deg = np.asarray(g.weighted_degrees())
    vol_c = np.zeros(g.n_max, np.float64)
    np.add.at(vol_c, np.asarray(new_com)[: len(gt)], deg[: len(gt)])
    np.testing.assert_allclose(
        np.asarray(cg.weighted_degrees())[: int(n_comm)],
        vol_c[: int(n_comm)], rtol=1e-6)
    # modularity invariant: Q(fine, partition) == Q(coarse, identity)
    ident = jnp.arange(cg.n_max, dtype=jnp.int32)
    q_fine = float(modularity(g, new_com))
    q_coarse = float(modularity(cg, ident))
    assert q_fine == pytest.approx(q_coarse, abs=1e-6)
    # all-intra partition of a disconnected union of cliques: Q = 1 - sum s_c^2
    assert q_fine == pytest.approx(1.0 - 6 * (1.0 / 6) ** 2, abs=1e-5)


def test_coarsen_preserves_modularity_with_cut_edges():
    u, v, w, gt = sbm(120, 4, p_in=0.4, p_out=0.05, seed=13)
    g = from_numpy_edges(u, v, w)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    cg = aggregation.coarsen_graph(g, new_com, n_comm)
    ident = jnp.arange(cg.n_max, dtype=jnp.int32)
    assert float(modularity(cg, ident)) == pytest.approx(
        float(modularity(g, new_com)), abs=1e-6)
    assert float(cg.total_volume()) == pytest.approx(
        float(g.total_volume()), rel=1e-6)


def _empty_graph(n_max=16, m_max=32) -> Graph:
    """A fully masked-out level: zero valid vertices, zero valid edges."""
    sentinel = jnp.int32(n_max)
    return Graph(
        src=jnp.full((m_max,), sentinel),
        dst=jnp.full((m_max,), sentinel),
        w=jnp.zeros((m_max,), jnp.float32),
        edge_mask=jnp.zeros((m_max,), bool),
        n_valid=jnp.int32(0),
        m_valid=jnp.int32(0),
        n_max=n_max,
        m_max=m_max,
        sorted_by=None,
    )


def test_remap_and_coarsen_all_invalid_level():
    """An all-masked-invalid level must stay a well-formed empty graph:
    no phantom communities, no phantom edges, zero volumes/degrees."""
    g = _empty_graph()
    com = jnp.arange(g.n_max, dtype=jnp.int32)
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    assert int(n_comm) == 0
    # every vertex slot maps to the sentinel
    np.testing.assert_array_equal(
        np.asarray(new_com), np.full(g.n_max, g.n_max, np.int32))

    cg = aggregation.coarsen_graph(g, new_com, n_comm)
    assert int(cg.n_valid) == 0
    assert int(cg.m_valid) == 0
    assert not bool(np.asarray(cg.edge_mask).any())
    assert float(cg.total_volume()) == 0.0
    np.testing.assert_array_equal(
        np.asarray(cg.weighted_degrees()), np.zeros(g.n_max, np.float32))
    # invalid slots hold sentinels, preserving the Graph convention
    np.testing.assert_array_equal(
        np.asarray(cg.src), np.full(g.m_max, g.n_max, np.int32))


def test_coarsen_partially_masked_vertices():
    """Vertices beyond n_valid are excluded from the coarse graph even if
    stray (masked) edges mention them."""
    u = np.array([0, 1, 2, 3], dtype=np.int64)
    v = np.array([1, 0, 3, 2], dtype=np.int64)
    w = np.ones(4, dtype=np.float32)
    g = graph_from_arrays(jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
                          jnp.asarray(w), n_max=8, m_max=8, n_valid=4)
    com = jnp.asarray([0, 0, 1, 1, 7, 7, 7, 7], jnp.int32)
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    assert int(n_comm) == 2
    cg = aggregation.coarsen_graph(g, new_com, n_comm)
    assert int(cg.n_valid) == 2
    em = np.asarray(cg.edge_mask)
    assert set(map(tuple, np.stack(
        [np.asarray(cg.src)[em], np.asarray(cg.dst)[em]], axis=1))) == {
            (0, 0), (1, 1)}
    assert float(cg.total_volume()) == pytest.approx(4.0)


# ------------------------------------------------------------ fused one-sort


def _coarsen_two_step(g, com):
    new_com, n_comm = aggregation.remap_communities(com, g.vertex_mask())
    return new_com, n_comm, aggregation.coarsen_graph(g, new_com, n_comm)


def _assert_graphs_bitwise(a, b):
    for f in ("src", "dst", "w", "edge_mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f)
    assert int(a.n_valid) == int(b.n_valid)
    assert int(a.m_valid) == int(b.m_valid)
    assert (a.n_max, a.m_max) == (b.n_max, b.m_max)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_remap_and_coarsen_matches_two_step(seed):
    """The fused one-sort remap+coarsen must reproduce the two-step
    reference bit-for-bit: new_com, n_comm, and every coarse-graph array
    including the unspecified-slot sentinels."""
    u, v, w, gt = sbm(150, 5, p_in=0.3, p_out=0.04, seed=seed)
    g = from_numpy_edges(u, v, w, m_max=2 * len(u) + 37)   # padded capacity
    rng = np.random.default_rng(seed)
    # a messy, non-contiguous partition (not the ground truth): random
    # labels drawn from a sparse id set, plus junk on the invalid slots
    com = jnp.asarray(np.concatenate([
        rng.choice(np.arange(0, 150, 7), size=150),
        rng.integers(0, g.n_max, size=g.n_max - 150),
    ]), jnp.int32)
    nc1, n1, cg1 = _coarsen_two_step(g, com)
    nc2, n2, cg2 = aggregation.remap_and_coarsen(g, com)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))
    _assert_graphs_bitwise(cg1, cg2)


def test_remap_and_coarsen_all_intra_and_empty():
    # all-intra: pure self-loops (mirrors the two-step edge-case test)
    k = 5
    u, v, w, gt = ring_of_cliques(6, k)
    keep = (u // k) == (v // k)
    g = from_numpy_edges(u[keep], v[keep], w[keep], n=len(gt))
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    nc1, n1, cg1 = _coarsen_two_step(g, com)
    nc2, n2, cg2 = aggregation.remap_and_coarsen(g, com)
    assert int(n1) == int(n2) == 6
    np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))
    _assert_graphs_bitwise(cg1, cg2)

    # fully masked-out level
    ge = _empty_graph()
    com = jnp.arange(ge.n_max, dtype=jnp.int32)
    nc2, n2, cg2 = aggregation.remap_and_coarsen(ge, com)
    assert int(n2) == 0
    assert int(cg2.m_valid) == 0
    assert not bool(np.asarray(cg2.edge_mask).any())
    np.testing.assert_array_equal(
        np.asarray(nc2), np.full(ge.n_max, ge.n_max, np.int32))


def test_shrink_graph_preserves_live_content():
    """Capacity descent: slicing a front-compacted coarse graph must keep
    every live edge/vertex and only rewrite the padding sentinels."""
    u, v, w, gt = sbm(120, 4, p_in=0.4, p_out=0.05, seed=13)
    g = from_numpy_edges(u, v, w)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    _, n_comm, cg = aggregation.remap_and_coarsen(g, com)
    n_out = int(n_comm) + 2
    m_out = int(cg.m_valid) + 3
    sg = aggregation.shrink_graph(cg, n_out, m_out)
    assert (sg.n_max, sg.m_max) == (n_out, m_out)
    assert int(sg.n_valid) == int(cg.n_valid)
    assert int(sg.m_valid) == int(cg.m_valid)
    mv = int(cg.m_valid)
    for f in ("src", "dst", "w"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sg, f))[:mv], np.asarray(getattr(cg, f))[:mv])
    em = np.asarray(sg.edge_mask)
    np.testing.assert_array_equal(
        np.asarray(sg.src)[~em], np.full((~em).sum(), n_out, np.int32))
    assert float(sg.total_volume()) == float(cg.total_volume())
    # modularity invariant survives the capacity change
    ident = jnp.arange(sg.n_max, dtype=jnp.int32)
    assert float(modularity(sg, ident)) == pytest.approx(
        float(modularity(g, jnp.asarray(
            np.asarray(aggregation.remap_communities(
                com, g.vertex_mask())[0]), jnp.int32))), abs=1e-6)


# ------------------------------------------------------------ groupby compaction


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_groupby_sum_scatter_matches_argsort(seed):
    """The one-sort scatter compaction must agree with the legacy two-sort
    argsort compaction on every valid slot (slots beyond n_groups are
    unspecified by contract and masked by group_valid)."""
    rng = np.random.default_rng(seed)
    m = 257
    k1 = jnp.asarray(rng.integers(0, 12, m), jnp.int32)
    k2 = jnp.asarray(rng.integers(0, 7, m), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(m), jnp.float32)
    valid = jnp.asarray(rng.random(m) < 0.8)

    (ka, sa, va, na) = seg.groupby_sum((k1, k2), vals, valid=valid,
                                       compact_via="argsort")
    (kb, sb, vb, nb) = seg.groupby_sum((k1, k2), vals, valid=valid,
                                       compact_via="scatter")
    n = int(na)
    assert n == int(nb)
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    for a, b in zip(ka, kb):
        np.testing.assert_array_equal(np.asarray(a)[:n], np.asarray(b)[:n])
    # sums agree bitwise on the valid prefix (same sort, same segment_sum)
    np.testing.assert_array_equal(np.asarray(sa)[:n], np.asarray(sb)[:n])


def test_groupby_sum_matches_numpy_reference():
    rng = np.random.default_rng(3)
    m = 200
    k = rng.integers(0, 15, m)
    vals = rng.standard_normal(m).astype(np.float32)
    valid = rng.random(m) < 0.7
    (gk,), gs, gv, ng = seg.groupby_sum(
        (jnp.asarray(k, jnp.int32),), jnp.asarray(vals),
        valid=jnp.asarray(valid))
    expect = {}
    for ki, vi, ok in zip(k, vals, valid):
        if ok:
            expect[int(ki)] = expect.get(int(ki), 0.0) + float(vi)
    n = int(ng)
    assert n == len(expect)
    got = {int(a): float(b) for a, b in
           zip(np.asarray(gk)[:n], np.asarray(gs)[:n])}
    assert set(got) == set(expect)
    for key in expect:
        assert got[key] == pytest.approx(expect[key], abs=1e-5)


def test_groupby_sum_all_invalid():
    m = 33
    (gk,), gs, gv, ng = seg.groupby_sum(
        (jnp.zeros((m,), jnp.int32),), jnp.ones((m,), jnp.float32),
        valid=jnp.zeros((m,), bool))
    assert int(ng) == 0
    assert not bool(np.asarray(gv).any())


# ------------------------------------------------------------ sort-free binned


def _random_multigraph(rng, n, m, *, n_pad=0, m_pad=0, mask_p=0.85,
                       weighted=True):
    """A directed multigraph with duplicate/parallel edges, random float
    weights, partial edge masks and capacity padding — the adversarial input
    shape for the binned-vs-sort parity contract."""
    n_max, m_max = n + n_pad, m + m_pad
    src = rng.integers(0, n, m)
    # bias toward duplicates: half the edges reuse an earlier endpoint pair
    dst = rng.integers(0, n, m)
    dup = rng.random(m) < 0.5
    if m > 1:
        j = rng.integers(0, m, m)
        src = np.where(dup, src[j], src)
        dst = np.where(dup, dst[j], dst)
    w = (rng.random(m).astype(np.float32) if weighted
         else np.ones(m, np.float32))
    em = np.zeros(m_max, bool)
    em[:m] = rng.random(m) < mask_p
    pad_i = np.full(m_pad, n_max, np.int32)
    return Graph(
        src=jnp.asarray(np.concatenate([src.astype(np.int32), pad_i])),
        dst=jnp.asarray(np.concatenate([dst.astype(np.int32), pad_i])),
        w=jnp.asarray(np.concatenate([w, np.zeros(m_pad, np.float32)])),
        edge_mask=jnp.asarray(em),
        n_valid=jnp.int32(n), m_valid=jnp.int32(m),
        n_max=n_max, m_max=m_max, sorted_by=None)


def _random_partition(rng, g, groups=None):
    n, n_max = int(g.n_valid), g.n_max
    groups = groups if groups is not None else max(1, n // 3)
    return jnp.asarray(np.concatenate([
        rng.integers(0, groups, n),
        rng.integers(0, n_max, n_max - n),     # junk on invalid slots
    ]), jnp.int32)


def _assert_binned_matches_oracle(g, com, **kw):
    nc1, n1, cg1 = aggregation.remap_and_coarsen(g, com)
    nc2, n2, cg2 = aggregation.remap_and_coarsen_binned(g, com, **kw)
    np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))
    assert int(n1) == int(n2)
    _assert_graphs_bitwise(cg1, cg2)


def test_remap_communities_bitmap_matches_sorted():
    """The sort-free (presence bitmap + cumsum) remap must reproduce the
    sorted oracle bit-for-bit, junk-on-invalid-slots included."""
    rng = np.random.default_rng(0)
    for _ in range(10):
        n_max = int(rng.integers(2, 80))
        n = int(rng.integers(0, n_max + 1))
        com = jnp.asarray(rng.integers(0, n_max, n_max), jnp.int32)
        vmask = jnp.asarray(np.arange(n_max) < n)
        nc1, k1 = aggregation.remap_communities_sorted(com, vmask)
        nc2, k2 = aggregation.remap_communities(com, vmask)
        assert int(k1) == int(k2)
        np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))


def test_contiguize_ids_basics():
    table, count = seg.contiguize_ids(
        jnp.asarray([5, 2, 5, 9], jnp.int32),
        jnp.asarray([True, True, True, False]), 10)
    assert int(count) == 2
    got = np.asarray(table)
    assert got[2] == 0 and got[5] == 1
    # absent keys (incl. the masked 9) map to the size sentinel
    assert all(got[k] == 10 for k in range(10) if k not in (2, 5))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("width", [16, 64, None])
def test_binned_matches_oracle_random_multigraphs(seed, width):
    """The sort-free binned coarsening must reproduce the one-sort oracle
    bit-for-bit — parallel edges merged to identical float sums, identical
    slot order and padding sentinels — at every width, including widths
    small enough to trip the overflow fallback."""
    rng = np.random.default_rng(seed)
    for _ in range(4):
        n = int(rng.integers(4, 70))
        m = int(rng.integers(4, 400))
        g = _random_multigraph(rng, n, m, n_pad=int(rng.integers(0, 9)),
                               m_pad=int(rng.integers(0, 17)))
        com = _random_partition(rng, g)
        _assert_binned_matches_oracle(g, com, width=width, impl="ref")


def test_binned_all_intra_and_empty():
    # all-intra partition: pure self-loop coarse graph
    k = 5
    u, v, w, gt = ring_of_cliques(6, k)
    keep = (u // k) == (v // k)
    g = from_numpy_edges(u[keep], v[keep], w[keep], n=len(gt))
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    _assert_binned_matches_oracle(g, com, impl="ref")

    # fully masked-out level
    ge = _empty_graph()
    _assert_binned_matches_oracle(
        ge, jnp.arange(ge.n_max, dtype=jnp.int32), impl="ref")


def test_binned_capacity_padded_sparse_graph():
    """Capacities far above the live counts (the cascade's padded stages):
    the sentinel/sink routing must keep the parity exact."""
    rng = np.random.default_rng(7)
    g = _random_multigraph(rng, 12, 30, n_pad=100, m_pad=400)
    com = _random_partition(rng, g, groups=5)
    for width in (16, 256):
        _assert_binned_matches_oracle(g, com, width=width, impl="ref")


def test_binned_forced_overflow_takes_sort_fallback():
    """A community with more distinct neighbor communities than the bin
    width must raise the overflow predicate and fall back to the one-sort
    path — bit-for-bit with the oracle either way."""
    from repro.kernels.aggregation.ops import community_edge_keys, insert_bins

    n = 40
    # star: vertex 0's community sees 30 distinct neighbor communities
    src = np.zeros(30, np.int32)
    dst = np.arange(1, 31, dtype=np.int32)
    g = graph_from_arrays(jnp.asarray(src), jnp.asarray(dst),
                          jnp.ones(30, jnp.float32), n_max=n, m_max=80,
                          n_valid=n)
    com = jnp.arange(n, dtype=jnp.int32)   # singletons: out-degree 30 > 16
    new_com, _ = aggregation.remap_communities(com, g.vertex_mask())
    cs, cd = community_edge_keys(g, new_com)
    _, _, overflow, rounds = insert_bins(g, cs, cd, width=16)
    assert bool(overflow)
    assert int(rounds) == 0        # the degree pre-gate skipped probing
    _assert_binned_matches_oracle(g, com, width=16, impl="ref")
    # at width 64 the same graph fits the bins
    _, _, overflow64, _ = insert_bins(g, cs, cd, width=64)
    assert not bool(overflow64)
    _assert_binned_matches_oracle(g, com, width=64, impl="ref")


def test_binned_every_cascade_stage_capacity():
    """Parity at every capacity of the cascade schedule (and so every
    STAGE_WIDTH_MENU pick the capacities induce): shrink a real coarsening
    chain into each stage and compare binned vs oracle there."""
    from repro.core.louvain import auto_capacity_schedule

    u, v, w, gt = sbm(300, 6, p_in=0.3, p_out=0.03, seed=5)
    g = from_numpy_edges(u, v, w)
    sched = auto_capacity_schedule(g.n_max, g.m_max, min_n=0,
                                   n_floor=max(16, g.n_max // 64),
                                   m_floor=max(64, g.m_max // 64))
    assert len(sched) > 1
    rng = np.random.default_rng(5)
    com = jnp.asarray(np.concatenate(
        [gt, np.arange(len(gt), g.n_max)]), jnp.int32)
    _, _, cg = aggregation.remap_and_coarsen(g, com)
    for cap in sched:
        if int(cg.n_valid) > cap[0] or int(cg.m_valid) > cap[1]:
            continue
        cur = (aggregation.shrink_graph(cg, *cap)
               if cap != (cg.n_max, cg.m_max) else cg)
        com_c = _random_partition(rng, cur, groups=max(1, int(cur.n_valid)))
        _assert_binned_matches_oracle(cur, com_c)   # width=None: menu pick
        _assert_binned_matches_oracle(cur, com_c, width=16, impl="ref")


def test_bin_rank_kernel_matches_ref():
    """The Pallas rank kernel (interpret mode off-TPU) must agree with the
    jnp ref on the same post-insert key table — the kernel ≡ ref leg of the
    kernel's by-construction parity contract."""
    from repro.kernels.aggregation.kernel import bin_rank_pallas
    from repro.kernels.aggregation.ops import community_edge_keys, insert_bins
    from repro.kernels.aggregation.ref import bin_rank_ref

    rng = np.random.default_rng(11)
    g = _random_multigraph(rng, 24, 160, n_pad=4, m_pad=8)
    com = _random_partition(rng, g, groups=9)
    new_com, _ = aggregation.remap_communities(com, g.vertex_mask())
    cs, cd = community_edge_keys(g, new_com)
    for width in (64, 128):
        keys, _, overflow, _ = insert_bins(g, cs, cd, width=width)
        assert not bool(overflow)
        kf = keys[:-1]
        cs_c = jnp.clip(cs, 0, g.n_max)
        r_ref = bin_rank_ref(kf, cs_c, cd, width=width, empty=g.n_max)
        r_ker = bin_rank_pallas(kf, cs_c, cd, width=width, empty=g.n_max,
                                interpret=True, row_block=32)
        np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_ker))


def test_binned_kernel_impl_full_coarsen_matches_ref():
    """binned_coarsen with the Pallas kernel rank pass (interpret mode) must
    equal the oracle too — the end-to-end kernel-impl leg."""
    from repro.kernels import common as kc

    rng = np.random.default_rng(13)
    g = _random_multigraph(rng, 20, 120)
    com = _random_partition(rng, g, groups=7)
    # interpret-mode pallas is slow; force it only for this small case
    orig = kc.default_interpret
    try:
        kc.default_interpret = lambda: True
        _assert_binned_matches_oracle(g, com, width=16, impl="kernel")
    finally:
        kc.default_interpret = orig


def test_aggregation_dispatch_and_config_validation():
    from repro.core.louvain import LouvainConfig

    with pytest.raises(ValueError):
        aggregation.remap_and_coarsen_by("bogus", _empty_graph(),
                                         jnp.zeros((16,), jnp.int32))
    with pytest.raises(ValueError):
        LouvainConfig(aggregation="bogus")
    assert LouvainConfig().aggregation == "binned"
    assert LouvainConfig(aggregation="sort").aggregation == "sort"


@pytest.mark.parametrize("refine", [False, True])
@pytest.mark.parametrize("pipeline_fused", [False, True])
def test_e2e_binned_equals_sort(refine, pipeline_fused):
    """Whole louvain/leiden runs under aggregation="binned" vs "sort" must
    be indistinguishable: labels, Q, and every per-level history."""
    from repro.core.louvain import LouvainConfig, louvain

    u, v, w, _ = sbm(200, 5, p_in=0.3, p_out=0.03, seed=2)
    g = from_numpy_edges(u, v, w)
    cfg = LouvainConfig(refine=refine, pipeline_fused=pipeline_fused, seed=4)
    rb = louvain(g, cfg)
    rs = louvain(g, cfg.replace(aggregation="sort"))
    np.testing.assert_array_equal(rb.labels, rs.labels)
    assert rb.n_communities == rs.n_communities
    assert rb.levels == rs.levels
    assert rb.modularity == rs.modularity
    assert rb.modularity_history == rs.modularity_history
    assert rb.sweeps_per_level == rs.sweeps_per_level
    assert rb.n_comm_per_level == rs.n_comm_per_level


# ------------------------------------------------------------ compact


@pytest.mark.parametrize("seed", [0, 1])
def test_compact_scatter_matches_argsort(seed):
    """The sort-free scatter compaction builds the SAME stable permutation
    the legacy argsort did (full array, not just the valid prefix)."""
    rng = np.random.default_rng(seed)
    m = 131
    mask = jnp.asarray(rng.random(m) < 0.6)
    arrays = (jnp.arange(m, dtype=jnp.int32),
              jnp.asarray(rng.standard_normal(m), jnp.float32))
    out_s, n_s = seg.compact(mask, arrays, via="scatter")
    out_a, n_a = seg.compact(mask, arrays, via="argsort")
    assert int(n_s) == int(n_a)
    for a, b in zip(out_s, out_a):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        seg.compact(mask, arrays, via="bogus")
