"""Training-loop integration: fault tolerance, resume parity, checkpoints."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, env=None, check=True):
    p = subprocess.run([sys.executable, "-m", "repro.launch.train"] + args,
                       capture_output=True, text=True, env=env or ENV,
                       cwd=REPO, timeout=900)
    if check and p.returncode != 0:
        raise AssertionError(f"train failed rc={p.returncode}\n{p.stdout}\n{p.stderr}")
    return p


BASE = ["--arch", "qwen3-1.7b", "--reduced", "--steps", "10",
        "--seq-len", "64", "--global-batch", "4"]


def test_loss_decreases(tmp_path):
    # synthetic Zipf tokens: the learnable signal is the unigram skew, so a
    # modest-but-real decrease is expected within ~60 steps
    p = _run(BASE + ["--steps", "60"])
    losses = [float(l.split("loss ")[1].split()[0])
              for l in p.stdout.splitlines() if "loss " in l and "step" in l]
    assert losses[-1] < losses[0] - 0.01, losses


def test_failure_resume_bit_parity(tmp_path):
    ck_a = str(tmp_path / "a")
    ck_b = str(tmp_path / "b")
    # run A: fail at step 6, then resume to 10
    _run(BASE + ["--ckpt-dir", ck_a, "--ckpt-every", "4",
                 "--simulate-failure-at", "6"], check=False)
    pa = _run(BASE + ["--ckpt-dir", ck_a, "--ckpt-every", "4"])
    # run B: uninterrupted
    pb = _run(BASE + ["--ckpt-dir", ck_b, "--ckpt-every", "4"])
    la = json.loads(pa.stdout.strip().splitlines()[-1])["final_loss"]
    lb = json.loads(pb.stdout.strip().splitlines()[-1])["final_loss"]
    assert la == lb, (la, lb)   # counter-based data => bit-identical resume


def test_checkpoint_atomicity_and_gc(tmp_path):
    from repro.train import checkpoint as ck
    import jax.numpy as jnp
    tree = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "step": jnp.int32(3)}
    for s in (1, 2, 3, 4):
        ck.save(str(tmp_path), s, tree, keep=2)
    assert ck.all_steps(str(tmp_path)) == [3, 4]
    # a stale tmp dir must be invisible
    os.makedirs(str(tmp_path / "step_00000099.tmp"))
    assert ck.latest_step(str(tmp_path)) == 4
    # roundtrip preserves values + dtypes (incl. bf16)
    back = ck.restore(str(tmp_path), 4, tree)
    assert back["w"].dtype == tree["w"].dtype
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_restore_rejects_config_mismatch(tmp_path):
    from repro.train import checkpoint as ck
    import jax.numpy as jnp
    tree = {"w": jnp.zeros(3)}
    ck.save(str(tmp_path), 1, tree, config_json='{"d_model": 64}')
    with pytest.raises(ValueError, match="config mismatch"):
        ck.restore(str(tmp_path), 1, tree, expect_config='{"d_model": 128}')


def test_elastic_restore_different_device_count(tmp_path):
    """Save on 1 device, restore + continue on 4 devices (elastic restart)."""
    ck = str(tmp_path / "ck")
    _run(BASE + ["--ckpt-dir", ck, "--ckpt-every", "5", "--steps", "5"])
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    p = _run(BASE + ["--ckpt-dir", ck, "--ckpt-every", "5", "--steps", "8",
                     "--data", "2", "--model", "2"], env=env)
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["steps_run"] == 3  # resumed from 5
    assert "resuming from checkpoint step 5" in p.stdout
