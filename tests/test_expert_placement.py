"""Beyond-paper integration: Louvain-driven MoE expert placement."""
import numpy as np

from repro.core.expert_placement import (
    coactivation_graph, louvain_placement, placement_traffic, random_placement)


def _skewed_routing(n_tokens=4000, n_experts=32, top_k=4, n_latent=8, seed=0):
    rng = np.random.default_rng(seed)
    topic_of_expert = rng.integers(0, n_latent, n_experts)
    pools = [np.where(topic_of_expert == t)[0] for t in range(n_latent)]
    out = np.zeros((n_tokens, top_k), np.int32)
    for i in range(n_tokens):
        pool = pools[rng.integers(0, n_latent)]
        if rng.random() < 0.2 or pool.size < top_k:
            out[i] = rng.choice(n_experts, top_k, replace=False)
        else:
            out[i] = rng.choice(pool, top_k, replace=pool.size < top_k)
    return out


def test_placement_is_balanced():
    routing = _skewed_routing()
    g = coactivation_graph(routing, 32)
    pl = louvain_placement(g, 32, 8)
    counts = np.bincount(pl, minlength=8)
    assert counts.max() - counts.min() <= 1, counts
    assert pl.shape == (32,) and pl.min() >= 0 and pl.max() < 8


def test_louvain_beats_random_placement():
    routing = _skewed_routing()
    g = coactivation_graph(routing, 32)
    t_rand = placement_traffic(routing, random_placement(32, 8), 8)
    t_louv = placement_traffic(routing, louvain_placement(g, 32, 8), 8)
    assert t_louv < t_rand, (t_louv, t_rand)


def test_top1_uses_sequence_adjacency():
    rng = np.random.default_rng(0)
    routing = rng.integers(0, 16, (500, 1)).astype(np.int32)
    g = coactivation_graph(routing, 16)
    assert int(g.m_valid) > 0
    pl = louvain_placement(g, 16, 4)
    assert pl.shape == (16,)
