"""Property-based tests (hypothesis) pinning the sort-free binned
aggregation to the one-sort oracle.

Random directed multigraphs — duplicate/parallel edges, partial edge
masks, junk labels on invalid slots, capacity padding — must coarsen
BIT-FOR-BIT identically through ``remap_and_coarsen_binned`` and the
``remap_and_coarsen`` oracle (DESIGN.md §Aggregation kernel), at every
menu bin width and at every cascade stage capacity; whole louvain runs
must be history-for-history indistinguishable between the two methods.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation
from repro.core.louvain import auto_capacity_schedule
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import sbm
from repro.graph.structure import Graph
from repro.kernels.common import STAGE_WIDTH_MENU

# --- strategies ------------------------------------------------------------


def _multigraph(rng, n, m, *, n_pad=0, m_pad=0, mask_p=0.85, weighted=True):
    """A directed multigraph with duplicate-biased parallel edges, random
    float weights, partial edge masks and capacity padding."""
    n_max, m_max = n + n_pad, m + m_pad
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    dup = rng.random(m) < 0.5
    if m > 1:
        j = rng.integers(0, m, m)
        src = np.where(dup, src[j], src)
        dst = np.where(dup, dst[j], dst)
    w = (rng.random(m).astype(np.float32) if weighted
         else np.ones(m, np.float32))
    em = np.zeros(m_max, bool)
    em[:m] = rng.random(m) < mask_p
    pad_i = np.full(m_pad, n_max, np.int32)
    return Graph(
        src=jnp.asarray(np.concatenate([src.astype(np.int32), pad_i])),
        dst=jnp.asarray(np.concatenate([dst.astype(np.int32), pad_i])),
        w=jnp.asarray(np.concatenate([w, np.zeros(m_pad, np.float32)])),
        edge_mask=jnp.asarray(em),
        n_valid=jnp.int32(n), m_valid=jnp.int32(m),
        n_max=n_max, m_max=m_max, sorted_by=None)


def _partition(rng, g, groups):
    n, n_max = int(g.n_valid), g.n_max
    return jnp.asarray(np.concatenate([
        rng.integers(0, groups, n),
        rng.integers(0, n_max, n_max - n),     # junk on invalid slots
    ]), jnp.int32)


@st.composite
def multigraph_cases(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    n = draw(st.integers(4, 24))
    m = draw(st.integers(n, 5 * n))
    g = _multigraph(
        rng, n, m,
        n_pad=draw(st.sampled_from([0, 1, 7])),
        m_pad=draw(st.sampled_from([0, 3, 17])),
        mask_p=draw(st.sampled_from([0.5, 0.85, 1.0])),
        weighted=draw(st.booleans()))
    com = _partition(rng, g, groups=draw(st.integers(1, n)))
    return g, com


# --- coarse-graph parity -----------------------------------------------------


def _assert_parity(g, com, **kw):
    nc1, n1, cg1 = aggregation.remap_and_coarsen(g, com)
    nc2, n2, cg2 = aggregation.remap_and_coarsen_binned(g, com, **kw)
    np.testing.assert_array_equal(np.asarray(nc1), np.asarray(nc2))
    assert int(n1) == int(n2)
    for f in ("src", "dst", "w", "edge_mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(cg1, f)), np.asarray(getattr(cg2, f)),
            err_msg=f)
    assert int(cg1.n_valid) == int(cg2.n_valid)
    assert int(cg1.m_valid) == int(cg2.m_valid)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_binned_equals_oracle_on_multigraphs(data):
    g, com = data.draw(multigraph_cases())
    width = data.draw(st.sampled_from((None,) + STAGE_WIDTH_MENU))
    _assert_parity(g, com, width=width)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_binned_equals_oracle_at_every_cascade_capacity(data):
    """The same valid contents, embedded at each capacity of a forced
    multi-stage cascade schedule, coarsen identically under policy width."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    n = data.draw(st.integers(4, 12))
    m = data.draw(st.integers(n, 3 * n))
    sched = auto_capacity_schedule(
        256, 1024, min_n=0, n_floor=max(n, 8), m_floor=max(m, 32))
    assert len(sched) > 1
    groups = data.draw(st.integers(1, n))
    for n_cap, m_cap in sched:
        g = _multigraph(rng, n, m, n_pad=n_cap - n, m_pad=m_cap - m)
        com = _partition(rng, g, groups=groups)
        _assert_parity(g, com, width=None)


# --- end-to-end parity -------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_e2e_binned_equals_sort(data):
    """Whole louvain runs under aggregation="binned" vs "sort" must be
    indistinguishable: labels, Q, and every per-level history."""
    from repro.core.louvain import LouvainConfig, louvain

    u, v, w, _ = sbm(
        data.draw(st.sampled_from([60, 120])),
        data.draw(st.sampled_from([3, 5])),
        p_in=0.3, p_out=0.05, seed=data.draw(st.integers(0, 7)))
    g = from_numpy_edges(u, v, w)
    cfg = LouvainConfig(
        refine=data.draw(st.booleans()),
        pipeline_fused=data.draw(st.booleans()), seed=4)
    rb = louvain(g, cfg)
    rs = louvain(g, cfg.replace(aggregation="sort"))
    np.testing.assert_array_equal(rb.labels, rs.labels)
    assert rb.n_communities == rs.n_communities
    assert rb.levels == rs.levels
    assert rb.modularity == rs.modularity
    assert rb.modularity_history == rs.modularity_history
    assert rb.sweeps_per_level == rs.sweeps_per_level
    assert rb.n_comm_per_level == rs.n_comm_per_level
