"""Sweep-engine parity: the fused while_loop phase must reproduce the
stepwise (one jitted call per sweep) reference bit-for-bit at fixed seed,
for both evaluators on both single-device backends (DESIGN.md §Engine)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.engine import EngineSpec, SweepEngine
from repro.core.louvain import LouvainConfig, louvain
from repro.core.plp import PLPConfig, plp
from repro.graph.builders import from_numpy_edges
from repro.graph.ell import build_ell, to_device
from repro.graph.generators import ring_of_cliques, sbm


def _graph(seed=7):
    u, v, w, _ = sbm(200, 5, p_in=0.3, p_out=0.03, seed=seed)
    return from_numpy_edges(u, v, w)


def _spec(evaluator, backend, **kw):
    base = dict(max_sweeps=30, threshold=0, move_prob=0.75)
    base.update(kw)
    return EngineSpec(evaluator=evaluator, backend=backend, **base)


@pytest.mark.parametrize("evaluator", ["plp", "louvain"])
@pytest.mark.parametrize("backend", ["segment", "ell"])
def test_fused_matches_stepwise_bitwise(evaluator, backend):
    g = _graph()
    engine = SweepEngine(g, _spec(evaluator, backend))
    r_fused = engine.run_phase(*engine.singleton_state(), seed=3, fused=True)
    r_step = engine.run_phase(*engine.singleton_state(), seed=3, fused=False)
    np.testing.assert_array_equal(
        np.asarray(r_fused.labels), np.asarray(r_step.labels))
    np.testing.assert_array_equal(
        np.asarray(r_fused.active), np.asarray(r_step.active))
    assert r_fused.sweeps == r_step.sweeps
    assert r_fused.delta_n_history == r_step.delta_n_history
    assert r_fused.active_history == r_step.active_history


def test_fused_matches_stepwise_with_tail():
    # tiny bucket widths force high-degree vertices onto the tail path
    g = _graph(seed=11)
    ell = to_device(g, build_ell(g, widths=(4, 8)))
    assert ell.has_tail
    engine = SweepEngine(g, _spec("plp", "ell"), ell=ell)
    r_fused = engine.run_phase(*engine.singleton_state(), seed=1, fused=True)
    r_step = engine.run_phase(*engine.singleton_state(), seed=1, fused=False)
    np.testing.assert_array_equal(
        np.asarray(r_fused.labels), np.asarray(r_step.labels))
    assert r_fused.delta_n_history == r_step.delta_n_history


def test_convergence_contract():
    """Fused loop must stop at the first sweep with ΔN <= threshold and
    record exactly the executed sweeps."""
    g = _graph()
    engine = SweepEngine(g, _spec("plp", "segment", threshold=2))
    res = engine.run_phase(*engine.singleton_state(), seed=0, fused=True)
    assert 0 < res.sweeps <= 30
    assert len(res.delta_n_history) == res.sweeps
    assert res.delta_n_history[-1] <= 2
    assert all(dn > 2 for dn in res.delta_n_history[:-1])


@pytest.mark.parametrize("backend", ["segment", "ell"])
def test_plp_driver_fused_matches_stepwise(backend):
    u, v, w, _ = ring_of_cliques(8, 6)
    g = from_numpy_edges(u, v, w)
    cfg = PLPConfig(max_iterations=50, backend=backend, seed=5)
    r_fused = plp(g, cfg.replace(fused=True))
    r_step = plp(g, cfg.replace(fused=False))
    np.testing.assert_array_equal(r_fused.labels, r_step.labels)
    assert r_fused.iterations == r_step.iterations
    assert r_fused.delta_n_history == r_step.delta_n_history


@pytest.mark.parametrize("backend", ["segment", "ell"])
def test_louvain_driver_fused_matches_stepwise(backend):
    g = _graph(seed=4)
    cfg = LouvainConfig(seed=4, backend=backend, track_modularity=False)
    r_fused = louvain(g, cfg.replace(fused=True))
    r_step = louvain(g, cfg.replace(fused=False))
    np.testing.assert_array_equal(r_fused.labels, r_step.labels)
    assert r_fused.levels == r_step.levels
    assert r_fused.sweeps_per_level == r_step.sweeps_per_level
    assert r_fused.modularity == r_step.modularity


def test_leiden_fused_matches_stepwise():
    from repro.core.louvain import leiden

    g = _graph(seed=9)
    cfg = LouvainConfig(seed=9, track_modularity=False)
    r_fused = leiden(g, cfg.replace(fused=True))
    r_step = leiden(g, cfg.replace(fused=False))
    np.testing.assert_array_equal(r_fused.labels, r_step.labels)
    assert r_fused.modularity == r_step.modularity


def test_restrict_requires_segment_backend():
    g = _graph()
    engine = SweepEngine(g, _spec("louvain", "ell"))
    with pytest.raises(ValueError, match="segment"):
        engine.run_phase(*engine.singleton_state(),
                         restrict=jnp.zeros((g.n_max,), jnp.int32))


def test_device_ell_roundtrip_covers_all_edges():
    """Chunk-stacked device layout must contain every non-loop edge exactly
    once across buckets + tail."""
    g = _graph(seed=2)
    n = g.n_max
    ell = to_device(g, build_ell(g, widths=(4, 8)))
    src, dst, w = g.to_numpy_edges()
    expect = int(np.sum(src != dst))
    got = int(np.asarray(ell.tail_src).size)
    # tail keeps self-loops of tail vertices (full in-edge slice); subtract
    t_src, t_dst = np.asarray(ell.tail_src), np.asarray(ell.tail_dst)
    got -= int(np.sum(t_src == t_dst))
    for b in ell.buckets:
        got += int(np.sum(np.asarray(b.nbr) < n))
    assert got == expect
