"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
distributed tests spawn subprocesses with their own device-count flags."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _validate_graphs():
    """Run every test with graph validation ON (DESIGN.md §Robustness):
    ``graph_from_arrays`` / ``from_numpy_edges`` structurally check their
    output unless a call site opts out with ``validate=False``.  Production
    default stays off — the flag only flips here, so the suite doubles as
    a continuous audit of every fixture and every builder path."""
    from repro.graph import builders

    prev = builders.DEFAULT_VALIDATE
    builders.DEFAULT_VALIDATE = True
    try:
        yield
    finally:
        builders.DEFAULT_VALIDATE = prev


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    """No test may leak armed fault-injection points into the next."""
    from repro.utils import faultinject

    yield
    faultinject.disarm()
