"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
distributed tests spawn subprocesses with their own device-count flags."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
