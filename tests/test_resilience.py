"""Resilience layer (DESIGN.md §Resilience).

Units for the primitives in ``repro.utils.resilience`` (deadlines/watchdog,
backoff, retryability over the PR-7 taxonomy, circuit breaker), then the
serving integrations: deadline misses fail ONLY the offending requests,
admission control sheds with typed ``OverloadError``, transient batch
failures retry-with-backoff to success, breakers trip/route/probe/close,
and a mid-cascade kill resumes from the stage checkpoint bit-identically.
"""
import os
import time

import numpy as np
import pytest

from launch.community_serve import (CommunityRequest, CommunityServeEngine,
                                    _estimate_cost)
from repro.core.louvain import LouvainConfig, louvain
from repro.graph.builders import from_numpy_edges
from repro.graph.generators import sbm
from repro.utils import faultinject, resilience, telemetry
from repro.utils.errors import (CapacityError, DeadlineError, KernelError,
                                NumericError, OverloadError)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------------------------------ deadlines


class TestDeadline:
    def test_remaining_and_expiry_follow_the_clock(self):
        clk = FakeClock()
        d = resilience.Deadline(1.5, clock=clk)
        assert d.remaining_s() == pytest.approx(1.5)
        clk.advance(1.0)
        assert d.remaining_s() == pytest.approx(0.5)
        assert not d.expired
        clk.advance(0.6)
        assert d.expired

    def test_min_remaining_skips_none_members(self):
        clk = FakeClock()
        a = resilience.Deadline(2.0, clock=clk)
        b = resilience.Deadline(0.7, clock=clk)
        assert resilience.min_remaining_s([a, None, b]) == pytest.approx(0.7)
        assert resilience.min_remaining_s([None, None]) is None
        assert resilience.min_remaining_s([]) is None

    def test_call_inline_when_no_deadline(self):
        assert resilience.call_with_deadline(lambda: 41 + 1, None) == 42

    def test_preflight_expired_never_dispatches(self):
        calls = []
        with pytest.raises(DeadlineError, match="already expired"):
            resilience.call_with_deadline(lambda: calls.append(1), -0.1)
        assert not calls

    def test_watchdog_cancels_a_hung_call(self):
        telemetry.reset()
        t0 = time.perf_counter()
        with pytest.raises(DeadlineError, match="watchdog"):
            resilience.call_with_deadline(lambda: time.sleep(5.0), 0.1)
        assert time.perf_counter() - t0 < 2.0   # released on time, not at 5s
        assert telemetry.get("resilience.watchdog_fired") == 1

    def test_result_and_exception_relay(self):
        assert resilience.call_with_deadline(lambda: "ok", 5.0) == "ok"

        def boom():
            raise NumericError("typed boom")

        with pytest.raises(NumericError, match="typed boom"):
            resilience.call_with_deadline(boom, 5.0)

        def killed():
            raise resilience.Preempted("kill relays too")

        with pytest.raises(resilience.Preempted):
            resilience.call_with_deadline(killed, 5.0)


# -------------------------------------------------------------------- retries


class TestBackoffAndRetryability:
    def test_backoff_is_deterministic_and_bounded(self):
        a = list(resilience.backoff_delays(6, base_s=0.1, max_s=0.5, seed=7))
        b = list(resilience.backoff_delays(6, base_s=0.1, max_s=0.5, seed=7))
        assert a == b
        assert all(d <= 0.5 * 1.5 for d in a)       # max_s · (1 + jitter)
        assert all(d >= 0.05 for d in a)            # base · (1 - jitter)
        assert a != list(resilience.backoff_delays(6, base_s=0.1, max_s=0.5,
                                                   seed=8))

    def test_backoff_rejects_degenerate_jitter(self):
        with pytest.raises(ValueError, match="jitter"):
            list(resilience.backoff_delays(2, jitter=1.0))

    def test_retryability_follows_the_taxonomy(self):
        assert resilience.is_retryable(KernelError("transient infra"))
        assert resilience.is_retryable(RuntimeError("infra surprise"))
        assert not resilience.is_retryable(NumericError("unsafe answer"))
        assert not resilience.is_retryable(CapacityError("won't fit again"))
        assert not resilience.is_retryable(DeadlineError("budget spent"))
        assert not resilience.is_retryable(OverloadError("shed"))
        assert not resilience.is_retryable(resilience.Preempted("kill"))
        assert not resilience.is_retryable(KeyboardInterrupt())


# ------------------------------------------------------------ circuit breaker


class TestCircuitBreaker:
    def test_trips_at_threshold_and_probes_back(self):
        telemetry.reset()
        clk = FakeClock()
        br = resilience.CircuitBreaker(threshold=3, reset_after_s=10.0,
                                       name="t", clock=clk)
        assert br.state("sig") == "closed"
        br.record_failure("sig")
        br.record_failure("sig")
        assert br.state("sig") == "closed"
        br.record_failure("sig")
        assert br.state("sig") == "open"
        assert telemetry.get("t.breaker_trip") == 1
        clk.advance(9.0)
        assert br.state("sig") == "open"
        clk.advance(1.5)
        assert br.state("sig") == "half_open"
        br.record_success("sig")                    # probe succeeded
        assert br.state("sig") == "closed"
        assert telemetry.get("t.breaker_close") == 1
        assert telemetry.values()["t.breaker_open_s"]["last"] \
            == pytest.approx(10.5)

    def test_failed_probe_reopens_for_a_full_window(self):
        telemetry.reset()
        clk = FakeClock()
        br = resilience.CircuitBreaker(threshold=1, reset_after_s=5.0,
                                       name="t2", clock=clk)
        br.record_failure("k")
        assert br.state("k") == "open"
        clk.advance(5.1)
        assert br.state("k") == "half_open"
        br.record_failure("k")                      # probe failed
        assert br.state("k") == "open"
        clk.advance(4.9)
        assert br.state("k") == "open"              # fresh full window
        assert telemetry.get("t2.breaker_trip") == 2

    def test_success_resets_the_consecutive_count(self):
        br = resilience.CircuitBreaker(threshold=2, name="t3")
        br.record_failure("k")
        br.record_success("k")
        br.record_failure("k")
        assert br.state("k") == "closed"            # never 2 consecutive
        assert br.snapshot()["'k'"]["failures"] == 1

    def test_keys_are_independent(self):
        br = resilience.CircuitBreaker(threshold=1, name="t4")
        br.record_failure("bad")
        assert br.state("bad") == "open"
        assert br.state("good") == "closed"


# --------------------------------------------------------- serve integrations


def _reqs(count, n=40, seed0=500, deadline_ms=None, algo="louvain"):
    out = []
    for i in range(count):
        u, v, _w, _t = sbm(n, 4, p_in=0.3, p_out=0.02, seed=seed0 + i)
        out.append(CommunityRequest(request_id=f"q{i}", u=u, v=v, n=n,
                                    algo=algo, deadline_ms=deadline_ms))
    return out


class TestServeResilience:
    def test_admission_sheds_on_depth_with_typed_overload(self):
        telemetry.reset()
        eng = CommunityServeEngine(max_queue_depth=2)
        accepted = [eng.submit(r) for r in _reqs(2)]
        assert accepted == [None, None]
        shed = eng.submit(_reqs(1, seed0=900)[0])
        assert shed is not None and not shed.ok
        assert "OverloadError" in shed.error and "depth" in shed.error
        assert eng.pending() == 2
        assert eng.stats()["shed"] == 1
        # the queued traffic still gets served
        assert all(r.ok for r in eng.flush())

    def test_admission_sheds_on_estimated_cost(self):
        reqs = _reqs(3)
        cost1 = _estimate_cost(reqs[0])
        eng = CommunityServeEngine(max_queue_cost=2 * cost1)
        assert eng.submit(reqs[0]) is None
        assert eng.submit(reqs[1]) is None
        shed = eng.submit(reqs[2])
        assert shed is not None and "OverloadError" in shed.error
        assert eng.stats()["queued_cost"] == 2 * cost1
        eng.flush()
        assert eng.stats()["queued_cost"] == 0

    def test_deadline_miss_fails_only_with_typed_error(self, monkeypatch):
        monkeypatch.setenv(faultinject.SLOW_DISPATCH_ENV, "3.0")
        eng = CommunityServeEngine(max_retries=0)
        for r in _reqs(2, deadline_ms=400.0):
            eng.submit(r)
        with faultinject.inject("slow_dispatch"):
            resp = eng.flush()
        assert len(resp) == 2
        for r in resp:
            assert not r.ok and "DeadlineError" in r.error
            assert r.report is not None
        assert eng.stats()["counters"].get(
            "resilience.watchdog_fired", 0) >= 1

    def test_expired_while_queued_fails_before_dispatching(self):
        eng = CommunityServeEngine()
        for r in _reqs(1, deadline_ms=0.5):
            eng.submit(r)
        time.sleep(0.01)
        dispatches0 = eng.stats()["dispatches"]
        resp = eng.flush()
        assert not resp[0].ok and "DeadlineError" in resp[0].error
        # the group dispatch ran but never reached the batch engine
        assert eng.stats()["dispatches"] == dispatches0 + 1
        assert eng.stats()["counters"].get(
            "serve.deadline_expired_queued", 0) >= 1

    def test_transient_batch_failure_retries_to_success(self):
        telemetry.reset()
        eng = CommunityServeEngine(max_retries=2, backoff_base_s=0.01)
        for r in _reqs(2):
            eng.submit(r)
        faultinject.arm("transient_batch_fail")
        faultinject.set_fuel("transient_batch_fail", 1)   # one-shot fault
        try:
            resp = eng.flush()
        finally:
            faultinject.disarm()
        assert all(r.ok for r in resp)
        c = eng.stats()["counters"]
        assert c.get("serve.retry", 0) == 1
        # absorbed by retry: no sequential fallback, breaker stays closed
        assert c.get("serve.batch_fallback_sequential", 0) == 0
        assert all(b["state"] == "closed"
                   for b in eng.stats()["breakers"].values())

    def test_breaker_trips_routes_sequential_and_probes_back(self):
        telemetry.reset()
        clk = FakeClock()
        br = resilience.CircuitBreaker(threshold=2, reset_after_s=30.0,
                                       name="serve", clock=clk)
        eng = CommunityServeEngine(max_retries=0, breaker=br)
        reqs = _reqs(6, seed0=700)

        faultinject.arm("transient_batch_fail")
        try:
            # two consecutive failing flushes of the same signature trip it;
            # the sequential fallback still answers every request
            for r in reqs[:2]:
                eng.submit(r)
            assert all(r.ok for r in eng.flush())
            for r in reqs[2:3]:
                eng.submit(r)
            assert all(r.ok for r in eng.flush())
            key = next(iter(eng.stats()["breakers"]))
            assert eng.stats()["breakers"][key]["state"] == "open"

            # OPEN: a request for the poisoned signature is rejected at the
            # door — no queue slot, and the breaker is not touched further
            trips0 = telemetry.get("serve.breaker_trip")
            door = eng.submit(reqs[3])
            assert door is not None and not door.ok
            assert "OverloadError" in door.error and "breaker" in door.error
            assert eng.pending() == 0
            assert telemetry.get("serve.breaker_trip") == trips0
            assert telemetry.get("serve.breaker_reject") == 1

            # HALF-OPEN after the window: traffic is admitted again; with
            # the fault still armed the probe fails and re-opens
            clk.advance(31.0)
            assert eng.submit(reqs[4]) is None
            assert all(r.ok for r in eng.flush())   # sequential fallback
            assert eng.stats()["breakers"][key]["state"] == "open"
        finally:
            faultinject.disarm()

        # fault gone: the next half-open probe succeeds and closes it
        clk.advance(31.0)
        assert eng.submit(reqs[5]) is None
        assert all(r.ok for r in eng.flush())
        assert eng.stats()["breakers"][key]["state"] == "closed"
        assert telemetry.get("serve.breaker_close") == 1

    def test_open_breaker_routes_queued_members_around_batched_path(self):
        """A member queued BEFORE its signature's breaker tripped (the
        door can't have seen it) is served via the sequential ladder, and
        its outcome feeds the breaker nothing."""
        from repro.kernels.common import capacity_signature

        telemetry.reset()
        br = resilience.CircuitBreaker(threshold=1, reset_after_s=1e9,
                                       name="serve")
        eng = CommunityServeEngine(max_retries=0, breaker=br)
        req = _reqs(1, seed0=760)[0]
        assert eng.submit(req) is None
        q = eng._queue[0]
        key = ("louvain",
               tuple(capacity_signature(q.graph.n_max, q.graph.m_max)))
        br.record_failure(key)                      # trips between ticks
        assert br.state(key) == "open"
        resp = eng.flush()
        assert resp[0].ok
        assert telemetry.get("serve.breaker_routed_sequential") == 1
        assert br.state(key) == "open"              # success didn't feed it
        assert br.snapshot()[repr(key)]["failures"] == 1


# ------------------------------------------------- checkpoint/resume (kill)


def _ring_of_cliques(n=600, k=20):
    edges = []
    for c in range(n // k):
        base = c * k
        for i in range(k):
            for j in range(i + 1, k):
                edges.append((base + i, base + j))
        edges.append((base, ((c + 1) % (n // k)) * k))
    e = np.array(edges, np.int64)
    return from_numpy_edges(e[:, 0], e[:, 1], n=n)


class TestCheckpointResume:
    def test_mid_cascade_kill_resumes_bit_identical(self, tmp_path):
        g = _ring_of_cliques()
        cfg = LouvainConfig(capacity_schedule=((256, 2048),),
                            backend="segment")
        oracle = louvain(g, cfg)
        assert len(oracle.cascade_stages) == 2  # the kill window exists

        telemetry.reset()
        cfg_ck = cfg.replace(checkpoint_dir=str(tmp_path))
        with pytest.raises(resilience.Preempted):
            with faultinject.inject("preempt_stage"):
                louvain(g, cfg_ck)
        # the stage boundary committed before the kill
        assert any(p.startswith("step_") for p in os.listdir(tmp_path))
        assert telemetry.get("louvain.ckpt_save") == 1

        resumed = louvain(g, cfg_ck)
        assert telemetry.get("louvain.ckpt_resume") == 1
        assert np.array_equal(resumed.labels, oracle.labels)
        assert resumed.modularity == oracle.modularity
        assert resumed.modularity_history == oracle.modularity_history
        assert resumed.n_communities == oracle.n_communities
        assert resumed.sweeps_per_level == oracle.sweeps_per_level
        assert resumed.cascade_stages == oracle.cascade_stages
        # success clears the committed boundaries: next run starts fresh
        assert not any(p.startswith("step_") for p in os.listdir(tmp_path))

    def test_mismatched_fingerprint_is_ignored_not_resumed(self, tmp_path):
        g = _ring_of_cliques()
        cfg = LouvainConfig(capacity_schedule=((256, 2048),),
                            backend="segment",
                            checkpoint_dir=str(tmp_path))
        with pytest.raises(resilience.Preempted):
            with faultinject.inject("preempt_stage"):
                louvain(g, cfg)
        telemetry.reset()
        # a different config must NOT resume someone else's stage state
        other = louvain(g, cfg.replace(seed=cfg.seed + 1))
        assert telemetry.get("louvain.ckpt_mismatch_ignored") == 1
        assert telemetry.get("louvain.ckpt_resume") == 0
        assert other.run_report.clean

    def test_clean_run_with_checkpoint_dir_leaves_no_debris(self, tmp_path):
        g = _ring_of_cliques()
        cfg = LouvainConfig(capacity_schedule=((256, 2048),),
                            backend="segment",
                            checkpoint_dir=str(tmp_path))
        res = louvain(g, cfg)
        assert res.run_report.clean
        assert not any(p.startswith("step_") for p in os.listdir(tmp_path))
