"""MoE layer correctness: grouped dispatch vs a dense per-token reference."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models.moe import moe_layer


def _dense_reference(x, w_router, w_gate, w_up, w_down, top_k):
    """Every token through its top-k experts, no capacity, no dispatch."""
    b, s, d = x.shape
    e = w_router.shape[-1]
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # per-token expert FFN
    g = jnp.einsum("td,edf->tef", xt, w_gate)
    u = jnp.einsum("td,edf->tef", xt, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, w_down)       # (T, E, D)
    out = jnp.zeros_like(xt)
    for k in range(top_k):
        sel = y_all[jnp.arange(xt.shape[0]), top_e[:, k]]
        out = out + sel * top_p[:, k][:, None].astype(x.dtype)
    return out.reshape(b, s, d)


def _params(e, d, f, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *sh: jnp.asarray(rng.standard_normal(sh) * 0.05, jnp.float32)
    return (mk(d, e), mk(e, d, f), mk(e, d, f), mk(e, f, d))


def test_moe_matches_dense_reference_ample_capacity():
    b, s, d, e, f, k = 2, 16, 8, 4, 16, 2
    wr, wg, wu, wd = _params(e, d, f)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    out = moe_layer(x, wr, wg, wu, wd, top_k=k, capacity_factor=8.0)
    ref = _dense_reference(x, wr, wg, wu, wd, k)
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(ref),
                               atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop, but output stays finite and
    the kept fraction is >= capacity/assignments."""
    b, s, d, e, f, k = 2, 32, 8, 4, 16, 2
    wr, wg, wu, wd = _params(e, d, f, seed=3)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    out = moe_layer(x, wr, wg, wu, wd, top_k=k, capacity_factor=0.5)
    assert bool(jnp.all(jnp.isfinite(out.y)))
    # at least some tokens got an expert
    assert float(jnp.mean(jnp.abs(out.y))) > 0


def test_moe_aux_loss_decreases_with_balance():
    """A uniform router must have lower balance loss than a collapsed one."""
    b, s, d, e, f, k = 2, 64, 8, 8, 16, 1
    _, wg, wu, wd = _params(e, d, f)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    wr_uniform = jnp.zeros((d, e), jnp.float32)
    wr_collapse = jnp.zeros((d, e), jnp.float32).at[:, 0].set(5.0)
    aux_u = moe_layer(x, wr_uniform, wg, wu, wd, top_k=k).aux_loss
    aux_c = moe_layer(x, wr_collapse, wg, wu, wd, top_k=k).aux_loss
    assert float(aux_u) < float(aux_c)


def test_moe_grad_flows():
    b, s, d, e, f, k = 1, 8, 8, 4, 16, 2
    wr, wg, wu, wd = _params(e, d, f)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)

    def loss(wg_):
        out = moe_layer(x, wr, wg_, wu, wd, top_k=k, capacity_factor=4.0)
        return jnp.sum(out.y ** 2) + out.aux_loss

    g = jax.grad(loss)(wg)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0
