"""Benchmark harness — one benchmark per paper table/figure.

  table1  — dataset statistics (paper's Table I + our stand-in actuals)
  fig1    — LPA runtime:     NetworkX-LPA vs seq-LPA vs Arachne-JAX-PLP
  fig2    — Louvain runtime: NetworkX vs seq vs Arachne-JAX-Louvain
  fig3    — Louvain modularity parity across implementations
  fig4    — strong scaling of parallel Louvain over device counts,
            with the paper's phase breakdown (local-moving vs aggregation)
  sweep_fusion — fused (one while_loop/level) vs stepwise engine timings
  level_fusion — whole-run pipeline (one dispatch per louvain()) vs the
            per-level driver, with the fig4 per-level local-moving /
            aggregation split and the groupby-compaction delta
  gather_fusion — fused gather-in-kernel local_move vs the legacy two-step
            (HBM-gathered tiles + scoring kernel, ± the old lax.scan chunk
            chain), per bucket width (artifact: BENCH_gather_fusion.json)
  table_streaming — windowed streamed table layout vs the VMEM-resident
            fast path vs two-step, per bucket width, with window stats
            (artifact: BENCH_table_streaming.json)
  coarse_cascade — capacity-scheduled coarse-level cascade vs the
            fixed-capacity pipeline vs per-level, with the Fig. 4 level-0 /
            coarse-tail split, stage-program count and bit-identical check
            (artifact: BENCH_coarse_cascade.json)
  aggregation — sort-free binned coarsening vs the one-sort oracle vs the
            two-step reference, per level and per cascade stage capacity,
            with bit-identical checks and the per-level aggregation share
            for both paths (artifact: BENCH_aggregation.json)
  batch_serve — batched many-graph engine (capacity-bucketed
            louvain_batch/plp_batch) vs a sequential single-graph loop on
            an ego-net-scale serving workload: throughput, p50/p99 latency,
            per-graph bitwise parity and a steady-state zero-recompile
            check (artifact: BENCH_batch_serve.json)
  serve_resilience — steady-state serving under 0%/5%/20% injected
            transient dispatch faults: throughput/p99, shed-rate, retry
            absorption, breaker trips and recovery time
            (artifact: BENCH_serve_resilience.json)
  dist_scale — shard-local coarsening scale-out on 1/2/4/8 emulated
            devices: bit-identical check vs the replicated oracle and the
            local fused driver, comm-bytes counter (halo labels + gathered
            partial groups vs the replicated all_gather baseline), the
            Fig.-4-style phase split and per-device aggregation-work trend
            (artifact: BENCH_dist_scale.json)
  roofline— §Roofline tables from the dry-run artifacts (see roofline.py)

Artifacts: benchmarks/artifacts/<name>.json (+ printed tables).
Usage: PYTHONPATH=src python -m benchmarks.run [names...]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCH_DATASETS = ["com-amazon", "com-dblp", "com-youtube", "as-skitter",
                  "com-livejournal", "com-orkut"]


def _save(name: str, obj) -> None:
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


def _time(fn, *a, repeat=3, **kw):
    best = None
    out = None
    for _ in range(repeat):
        t0 = time.time()
        out = fn(*a, **kw)
        dt = time.time() - t0
        best = dt if best is None else min(best, dt)
    return best, out


# ------------------------------------------------------------------ table I


def bench_table1():
    from repro.graph import datasets
    rows = []
    for name in BENCH_DATASETS:
        lg = datasets.load(name)
        rows.append({
            "graph": name,
            "paper_V": lg.meta.paper_vertices, "paper_E": lg.meta.paper_edges,
            "paper_diam": lg.meta.paper_diameter,
            "standin_V": lg.n, "standin_E": lg.m_undirected,
            "standin_kind": lg.meta.description,
        })
    _save("table1_datasets", rows)
    print(f"{'graph':18s} {'paper |V|':>11s} {'paper |E|':>12s} "
          f"{'ours |V|':>9s} {'ours |E|':>10s}  kind")
    for r in rows:
        print(f"{r['graph']:18s} {r['paper_V']:>11,d} {r['paper_E']:>12,d} "
              f"{r['standin_V']:>9,d} {r['standin_E']:>10,d}  {r['standin_kind']}")
    return rows


# ------------------------------------------------------------------ fig 1/2/3


def _quality(g, labels):
    from repro.core.baselines import nx_modularity
    return nx_modularity(g, np.asarray(labels))


def bench_fig1_lpa(repeat=2):
    import jax.numpy as jnp
    from repro.core.baselines import nx_lpa, seq_lpa
    from repro.core.plp import PLPConfig, plp
    from repro.graph import datasets
    rows = []
    for name in BENCH_DATASETS:
        lg = datasets.load(name)
        g = lg.graph
        t_nx = t_seq = None
        if lg.n <= 60_000:
            t_nx, lab_nx = _time(nx_lpa, g, repeat=1)
            t_seq, lab_seq = _time(seq_lpa, g, repeat=1)
        # warm once (jit), then time (single timed run on the big graphs)
        cfg = PLPConfig(max_iterations=60)
        plp(g, cfg)
        rep = repeat if lg.n <= 50_000 else 1
        t_jax, r = _time(lambda: plp(g, cfg), repeat=rep)
        rows.append({
            "graph": name, "V": lg.n, "E": lg.m_undirected,
            "networkx_s": t_nx, "seq_python_s": t_seq, "arachne_jax_s": t_jax,
            "speedup_vs_nx": (t_nx / t_jax) if t_nx else None,
            "iterations": r.iterations,
        })
        print(f"[fig1] {name:18s} nx={t_nx and f'{t_nx:6.2f}s' or '   n/a'} "
              f"seq={t_seq and f'{t_seq:6.2f}s' or '   n/a'} "
              f"jax={t_jax:6.2f}s "
              f"speedup={t_nx and f'{t_nx/t_jax:5.1f}x' or '  -'}")
    _save("fig1_lpa_runtime", rows)
    return rows


def bench_fig2_fig3_louvain(repeat=2):
    from repro.core.baselines import nx_louvain, seq_louvain, nx_modularity
    from repro.core.louvain import LouvainConfig, louvain
    from repro.graph import datasets
    rows = []
    for name in BENCH_DATASETS:
        lg = datasets.load(name)
        g = lg.graph
        t_nx = q_nx = t_seq = q_seq = None
        if lg.n <= 60_000:
            t_nx, lab_nx = _time(nx_louvain, g, repeat=1)
            q_nx = _quality(g, lab_nx)
            t_seq, lab_seq = _time(seq_louvain, g, repeat=1)
            q_seq = _quality(g, lab_seq)
        cfg = LouvainConfig(track_modularity=False)
        if lg.n <= 50_000:
            louvain(g, cfg)  # warm (compile); big graphs: one cold timed run
        rep = repeat if lg.n <= 50_000 else 1
        t_jax, res = _time(lambda: louvain(g, cfg), repeat=rep)
        q_jax = float(res.modularity)
        rows.append({
            "graph": name, "V": lg.n, "E": lg.m_undirected,
            "networkx_s": t_nx, "seq_python_s": t_seq, "arachne_jax_s": t_jax,
            "speedup_vs_nx": (t_nx / t_jax) if t_nx else None,
            "Q_networkx": q_nx, "Q_seq": q_seq, "Q_arachne_jax": q_jax,
            "levels": res.levels, "n_communities": int(res.n_communities),
        })
        print(f"[fig2/3] {name:18s} "
              f"nx={t_nx and f'{t_nx:6.2f}s' or '   n/a'} "
              f"jax={t_jax:6.2f}s "
              f"Q(nx)={q_nx and f'{q_nx:.4f}' or '  -  '} Q(jax)={q_jax:.4f}")
    _save("fig2_louvain_runtime_fig3_modularity", rows)
    return rows


# ------------------------------------------------------------------ fig 4


_SCALING_SNIPPET = r"""
import os, json, time, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import numpy as np, jax
from jax.sharding import Mesh
from repro.graph import datasets
from repro.core.distributed import distributed_louvain
lg = datasets.load("com-livejournal")
nd = int(sys.argv[1])
mesh = Mesh(np.array(jax.devices()[:nd]).reshape(nd), ("data",))
# fused pipeline (default): one dispatch for the whole level loop
res = distributed_louvain(lg.graph, mesh)      # warm compile + run
t0 = time.time()
res = distributed_louvain(lg.graph, mesh)
total = time.time() - t0
# per-level driver: the paper's local-moving/aggregation phase breakdown
distributed_louvain(lg.graph, mesh, pipeline_fused=False)   # warm
t0 = time.time()
res_pl = distributed_louvain(lg.graph, mesh, pipeline_fused=False)
total_pl = time.time() - t0
print(json.dumps({"devices": nd, "total_s": total,
                  "per_level_total_s": total_pl,
                  "pipeline_speedup": total_pl / total,
                  "phases": dict(res_pl.timer.totals),
                  "sweeps_per_level": res.sweeps_per_level,
                  "n_comm_per_level": res.n_comm_per_level,
                  "modularity": float(res.modularity)}))
"""


def bench_fig4_strong_scaling(device_counts=(1, 2, 4, 8)):
    rows = []
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    for nd in device_counts:
        p = subprocess.run([sys.executable, "-c", _SCALING_SNIPPET, str(nd)],
                           capture_output=True, text=True, env=env, cwd=REPO,
                           timeout=1800)
        if p.returncode != 0:
            print(f"[fig4] devices={nd} FAILED\n{p.stderr[-800:]}")
            continue
        rec = json.loads(p.stdout.strip().splitlines()[-1])
        rows.append(rec)
        ph = rec.get("phases", {})
        print(f"[fig4] devices={nd:3d} total={rec['total_s']:6.2f}s "
              f"Q={rec['modularity']:.4f} phases={ {k: round(v,2) for k,v in ph.items()} }")
    if rows:
        base = rows[0]["total_s"]
        for r in rows:
            r["speedup"] = base / r["total_s"]
    _save("fig4_strong_scaling", rows)
    return rows


# ------------------------------------------------------------------ dist scale


_DIST_SCALE_SNIPPET = r"""
import os, json, time, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import numpy as np, jax
from jax.sharding import Mesh
from repro.graph import datasets
from repro.core.louvain import LouvainConfig, louvain
from repro.core.distributed import distributed_louvain
nd = int(sys.argv[1])
lg = datasets.load(sys.argv[2])
g = lg.graph
mesh = Mesh(np.array(jax.devices()[:nd]).reshape(nd), ("data",))
rs = distributed_louvain(g, mesh, coarsening="shard_local")     # warm compile
t0 = time.time()
rs = distributed_louvain(g, mesh, coarsening="shard_local")
t_shard = time.time() - t0
rr = distributed_louvain(g, mesh, coarsening="replicated")      # warm compile
t0 = time.time()
rr = distributed_louvain(g, mesh, coarsening="replicated")
t_repl = time.time() - t0
rl = louvain(g, LouvainConfig())
# bit-identical: shard-local == replicated oracle == local fused driver
assert np.array_equal(rs.labels, rr.labels)
assert np.array_equal(rs.labels, np.asarray(rl.labels))
assert rs.modularity == rr.modularity == float(rl.modularity)
assert rs.sweeps_per_level == rr.sweeps_per_level == rl.sweeps_per_level
assert rs.n_comm_per_level == rr.n_comm_per_level == rl.n_comm_per_level
assert not rs.run_report.degradations
# comm-bytes counter: the per-level collective payload must stay
# O(boundary + communities), never the replicated all_gather's O(m)
cs = rs.comm_stats
rep_bytes = cs["bytes_per_level_model"]["replicated"]
assert all(b < rep_bytes for b in cs["actual_bytes_per_level"])
# fig4-style phase split from the per-level reference driver
distributed_louvain(g, mesh, pipeline_fused=False)              # warm
t0 = time.time()
rp = distributed_louvain(g, mesh, pipeline_fused=False)
t_pl = time.time() - t0
m_pad, h_cap = cs["m_pad"], cs["halo_cap"]
print(json.dumps({
    "devices": nd, "graph": sys.argv[2],
    "V": int(lg.n), "E": int(lg.m_undirected),
    "shard_local_total_s": t_shard, "replicated_total_s": t_repl,
    "per_level_total_s": t_pl,
    "phases_fused": dict(rs.timer.totals),
    "phases_per_level": dict(rp.timer.totals),
    "modularity": rs.modularity, "levels": rs.levels,
    "m_pad": m_pad, "halo_cap": h_cap,
    "agg_rows_per_device_shard_local": m_pad + nd * h_cap,
    "agg_rows_per_device_replicated": nd * m_pad,
    "comm_bytes_model": cs["bytes_per_level_model"],
    "actual_bytes_per_level": cs["actual_bytes_per_level"],
    "gathered_groups_per_level": cs["gathered_groups_per_level"],
    "halo_labels": cs["halo_labels"],
    "partition_stats": rs.partition_stats,
    "bit_identical": True,
}))
"""


def bench_dist_scale(device_counts=(1, 2, 4, 8), dataset="com-dblp"):
    """Shard-local coarsening scale-out (DESIGN.md §Distributed pipeline):
    per device count, bit-identical check (shard_local vs replicated oracle
    vs local fused driver), the measured collective payload vs the
    replicated all_gather baseline, and the per-device aggregation-work
    trend that carries the weak-scaling claim on emulated meshes."""
    rows = []
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    for nd in device_counts:
        p = subprocess.run([sys.executable, "-c", _DIST_SCALE_SNIPPET,
                            str(nd), dataset],
                           capture_output=True, text=True, env=env, cwd=REPO,
                           timeout=1800)
        if p.returncode != 0:
            print(f"[dist_scale] devices={nd} FAILED\n{p.stderr[-800:]}")
            continue
        rec = json.loads(p.stdout.strip().splitlines()[-1])
        rows.append(rec)
        model = rec["comm_bytes_model"]
        print(f"[dist_scale] devices={nd:2d} "
              f"shard_local={rec['shard_local_total_s']:6.2f}s "
              f"replicated={rec['replicated_total_s']:6.2f}s "
              f"Q={rec['modularity']:.4f} "
              f"agg_rows/dev={rec['agg_rows_per_device_shard_local']:,d} "
              f"(repl {rec['agg_rows_per_device_replicated']:,d})  "
              f"bytes/level model shard={model['shard_local']:,d} "
              f"repl={model['replicated']:,d} "
              f"actual={rec['actual_bytes_per_level']}")
        pq = rec["partition_stats"]
        print(f"    partition imbalance={pq['imbalance']:.3f} "
              f"cut={pq['cut_fraction']:.1%} halo_factor={pq['halo_factor']:.2f} "
              f"ghosts={pq['total_ghosts']:,d}  "
              f"phases={ {k: round(v, 3) for k, v in rec['phases_per_level'].items()} }")
    # weak-scaling invariant: per-device aggregation work shrinks with the
    # mesh (m_pad ~ m/D while the merge stays O(D * h_cap))
    if len(rows) >= 2:
        assert (rows[-1]["agg_rows_per_device_shard_local"]
                < rows[0]["agg_rows_per_device_shard_local"]), \
            "per-device aggregation work did not shrink with the mesh"
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_dist_scale{suffix}", rows)
    return rows


# ------------------------------------------------------------------ sweep fusion


def bench_sweep_fusion(datasets=("com-amazon", "com-dblp")):
    """Fused (one while_loop per level) vs stepwise (per-sweep dispatch)
    engine timings — the measurement for DESIGN.md §Engine."""
    from benchmarks.perf_variants import run_community
    rows = []
    for name in datasets:
        rec = run_community(name, algo="both", repeat=2)
        rows.append(rec)
        print(f"[fusion] {name:18s} "
              f"plp {rec['plp_stepwise_s']:.3f}s -> {rec['plp_fused_s']:.3f}s "
              f"({rec['plp_fused_speedup']:.2f}x)  "
              f"louvain {rec['louvain_stepwise_s']:.3f}s -> "
              f"{rec['louvain_fused_s']:.3f}s "
              f"({rec['louvain_fused_speedup']:.2f}x)")
    _save("sweep_fusion", rows)
    return rows


# ------------------------------------------------------------------ level fusion


def bench_level_fusion(datasets=("com-amazon", "com-dblp")):
    """Whole-run pipeline fusion vs per-level driver (DESIGN.md §Pipeline),
    with the paper's fig4 phase breakdown per level."""
    from benchmarks.perf_variants import run_level_fusion
    rows = []
    for name in datasets:
        rec = run_level_fusion(name, algo="louvain", repeat=4)
        rows.append(rec)
        print(f"[level_fusion] {name:18s} "
              f"louvain {rec['louvain_per_level_s']:.3f}s -> "
              f"{rec['louvain_pipeline_s']:.3f}s "
              f"({rec['louvain_pipeline_speedup']:.2f}x)  "
              f"groupby 2-sort {rec['groupby_argsort_s']*1e3:.2f}ms -> "
              f"1-sort {rec['groupby_scatter_s']*1e3:.2f}ms "
              f"({rec['groupby_scatter_speedup']:.2f}x)")
        for s in rec["louvain_phase_split"]:
            print(f"    L{s['level']:02d} local_moving={s['local_moving_s']:.4f}s "
                  f"aggregation={s['aggregation_s']:.4f}s "
                  f"(agg share {s['aggregation_share']:.1%})")
    _save("level_fusion", rows)
    return rows


# ------------------------------------------------------------------ gather fusion


def bench_gather_fusion(datasets=("com-dblp",)):
    """Fused gather-in-kernel local_move vs the legacy two-step path
    (DESIGN.md §Kernels) — the measurement behind the local_move kernel."""
    from benchmarks.perf_variants import run_gather_fusion
    rows = []
    for name in datasets:
        rec = run_gather_fusion(name, algo="both", repeat=3)
        rows.append(rec)
        for alg in ("plp", "louvain"):
            ks = rec[f"{alg}_kernel_speedup_vs_two_step"]
            es = rec[f"{alg}_engine_speedup_vs_two_step"]
            print(f"[gather_fusion] {name:18s} {alg:8s} kernel "
                  f"two-step {rec[f'{alg}_kernel_two_step_s']*1e3:.2f}ms -> "
                  f"fused {rec[f'{alg}_kernel_fused_s']*1e3:.2f}ms "
                  f"({ks and f'{ks:.2f}x' or 'n/a'})  "
                  f"engine+skip {es and f'{es:.2f}x' or 'n/a'}  "
                  f"bit_identical={rec[f'{alg}_bit_identical']}")
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_gather_fusion{suffix}", rows)
    return rows


# ------------------------------------------------------------------ table streaming


def bench_table_streaming(datasets=("com-dblp",)):
    """Windowed table streaming vs resident fast path (DESIGN.md §Kernels) —
    the measurement behind the beyond-VMEM local_move layout."""
    from benchmarks.perf_variants import run_table_streaming
    rows = []
    for name in datasets:
        rec = run_table_streaming(name, algo="both", repeat=3)
        rows.append(rec)
        for alg in ("plp", "louvain"):
            sr = rec[f"{alg}_streamed_vs_resident"]
            rt = rec[f"{alg}_resident_speedup_vs_two_step"]
            print(f"[table_streaming] {name:18s} {alg:8s} "
                  f"resident {rec[f'{alg}_kernel_resident_s']*1e3:.2f}ms  "
                  f"streamed {rec[f'{alg}_kernel_streamed_s']*1e3:.2f}ms "
                  f"(streamed/resident {sr and f'{1/sr:.2f}x' or 'n/a'})  "
                  f"resident-vs-two-step {rt and f'{rt:.2f}x' or 'n/a'}  "
                  f"bit_identical={rec[f'{alg}_bit_identical']}")
            for r in rec[f"{alg}_per_width"]:
                print(f"    W={r['width']:<5d} rows={r['rows_real']:<8d} "
                      f"blocks={r['n_blocks']:<5d} "
                      f"window={r['window_frac']:.1%} of table  "
                      f"resident={r['resident_s']*1e3:.2f}ms "
                      f"streamed={r['streamed_s']*1e3:.2f}ms")
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_table_streaming{suffix}", rows)
    return rows


# ------------------------------------------------------------------ coarse cascade


def bench_coarse_cascade(datasets=("com-amazon",)):
    """Capacity-scheduled cascade vs fixed-capacity pipeline vs per-level
    driver (DESIGN.md §Pipeline) — the measurement behind the shrink-aware
    coarse-level machinery.  com-amazon is the deep-hierarchy dataset the
    issue targets (10 coarsening levels on the stand-in)."""
    from benchmarks.perf_variants import run_coarse_cascade
    rows = []
    for name in datasets:
        rec = run_coarse_cascade(name, algo="louvain", repeat=3)
        rows.append(rec)
        sp = rec["louvain_cascade_speedup_vs_fixed"]
        ts = rec["louvain_coarse_tail_speedup"]
        print(f"[coarse_cascade] {name:18s} "
              f"fixed {rec['louvain_fixed_s']:.3f}s -> "
              f"cascade {rec['louvain_cascade_s']:.3f}s ({sp:.2f}x)  "
              f"coarse+agg tail {rec['louvain_fixed_coarse_tail_s']:.3f}s -> "
              f"{rec['louvain_cascade_coarse_tail_s']:.3f}s "
              f"({ts and f'{ts:.2f}x' or 'n/a'})  "
              f"stages={[c[0] for c in rec['louvain_cascade_stages']]} "
              f"programs={rec['louvain_stage_programs']}"
              f"<={len(rec['schedule'])}  "
              f"bit_identical={rec['louvain_bit_identical']}")
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_coarse_cascade{suffix}", rows)
    return rows


# ------------------------------------------------------------------ aggregation


def bench_aggregation(datasets=("com-amazon", "com-dblp")):
    """Sort-free binned coarsening vs the one-sort oracle vs two-step
    (DESIGN.md §Aggregation kernel) — the measurement behind replacing the
    coarsening GroupBy's lax.sort with the binned scatter kernel."""
    from benchmarks.perf_variants import run_aggregation
    rows = []
    for name in datasets:
        rec = run_aggregation(name, algo="louvain", repeat=3)
        rows.append(rec)
        sp = rec["aggregation_speedup_vs_sort"]
        print(f"[aggregation] {name:18s} "
              f"sort {rec['aggregation_sort_s']*1e3:.2f}ms -> "
              f"binned {rec['aggregation_binned_s']*1e3:.2f}ms ({sp:.2f}x)  "
              f"two-step {rec['aggregation_two_step_s']*1e3:.2f}ms  "
              f"e2e {rec['louvain_e2e_speedup']:.2f}x  "
              f"bit_identical={rec['bit_identical']}")
        for r in rec["per_level"]:
            print(f"    L{r['level']:02d} cap=({r['n_cap']},{r['m_cap']}) "
                  f"W={r['bin_width']} impl={r['bin_impl']} "
                  f"sort={r['sort_s']*1e3:.2f}ms "
                  f"binned={r['binned_s']*1e3:.2f}ms "
                  f"({r['binned_speedup_vs_sort']:.2f}x)")
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_aggregation{suffix}", rows)
    return rows


# ------------------------------------------------------------------ batch serve


def bench_batch_serve(datasets=("com-dblp",)):
    """Batched many-graph engine vs a sequential single-graph loop
    (DESIGN.md §Serving) — the measurement behind ``louvain_batch``/
    ``plp_batch`` and the request-batching service."""
    from benchmarks.perf_variants import run_batch_serve
    smoke = bool(os.environ.get("REPRO_DATASET_SCALE"))
    # full scale records the flagship fused (ell) serving configuration AND
    # the segment compute-bound floor; smoke keeps CI to one backend
    backends = ("ell",) if smoke else ("ell", "segment")
    rows = []
    for name in datasets:
        for backend in backends:
            rec = run_batch_serve(name, algo="both", repeat=3,
                                  n_graphs=16 if smoke else 64,
                                  backend=backend)
            rows.append(rec)
            for alg in ("plp", "louvain"):
                print(f"[batch_serve] {name:14s} {backend:8s} {alg:8s} "
                      f"seq {rec[f'{alg}_throughput_sequential_gps']:.1f} g/s -> "
                      f"batched {rec[f'{alg}_throughput_batched_gps']:.1f} g/s "
                      f"({rec[f'{alg}_throughput_speedup']:.2f}x)  "
                      f"p99 {rec[f'{alg}_sequential_p99_ms']:.1f}ms -> "
                      f"{rec[f'{alg}_batched_p99_ms']:.1f}ms  "
                      f"bitwise_ok={rec[f'{alg}_bitwise_ok']} "
                      f"recompiles={rec[f'{alg}_recompiles_measured']}")
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_batch_serve{suffix}", rows)
    return rows


# ------------------------------------------------------------- serve resilience


def bench_serve_resilience(datasets=("com-dblp",)):
    """Steady-state serving under 0%/5%/20% injected transient faults
    (DESIGN.md §Resilience) — the measurement behind the deadline/retry/
    breaker machinery: shed-rate, breaker trips and recovery time."""
    from benchmarks.perf_variants import run_serve_resilience
    smoke = bool(os.environ.get("REPRO_DATASET_SCALE"))
    rows = []
    for name in datasets:
        rec = run_serve_resilience(name,
                                   ticks=12 if smoke else 90,
                                   per_tick=4 if smoke else 8,
                                   n_graphs=3 if smoke else 6)
        rows.append(rec)
        for arm in rec["arms"]:
            rs = arm["recovery_s"]
            p99 = arm["p99_ms"]
            print(f"[serve_resilience] {name:14s} {arm['arm']:12s} "
                  f"{arm['throughput_gps']:6.1f} g/s  "
                  f"p99={p99 and f'{p99:.1f}ms' or 'n/a'}  "
                  f"ok={arm['served']}/{arm['submitted']} "
                  f"shed={arm['shed_rate']:.1%} "
                  f"retries={arm['retries']} trips={arm['breaker_trips']} "
                  f"recovery={rs and f'{rs:.2f}s' or '-'}")
    # smoke runs (REPRO_DATASET_SCALE set) must not clobber the committed
    # full-scale baseline artifact
    suffix = "_smoke" if os.environ.get("REPRO_DATASET_SCALE") else ""
    _save(f"BENCH_serve_resilience{suffix}", rows)
    return rows


# ------------------------------------------------------------------ roofline


def bench_roofline():
    from benchmarks import roofline
    return roofline.main([])


# ------------------------------------------------------------------ driver


ALL = {
    "table1": bench_table1,
    "fig1": bench_fig1_lpa,
    "fig2_fig3": bench_fig2_fig3_louvain,
    "fig4": bench_fig4_strong_scaling,
    "sweep_fusion": bench_sweep_fusion,
    "level_fusion": bench_level_fusion,
    "gather_fusion": bench_gather_fusion,
    "table_streaming": bench_table_streaming,
    "coarse_cascade": bench_coarse_cascade,
    "aggregation": bench_aggregation,
    "batch_serve": bench_batch_serve,
    "serve_resilience": bench_serve_resilience,
    "dist_scale": bench_dist_scale,
    "roofline": bench_roofline,
}


def main(argv=None) -> int:
    """Run the named benchmarks (all by default), crash-tolerantly.

    One broken variant must not take down a whole (hours-long) sweep: each
    benchmark runs under its own try/except, failures are recorded as
    structured ``{"variant": ..., "error": ...}`` rows in the
    ``BENCH_run_status`` artifact alongside the survivors' own artifacts,
    and the exit code reports whether anything failed.
    """
    import traceback

    names = (argv or sys.argv[1:]) or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}, want {list(ALL)}")
    status = []
    for n in names:
        print(f"\n===== {n} =====")
        try:
            ALL[n]()
            status.append({"variant": n, "ok": True, "error": None})
        except KeyboardInterrupt:
            raise
        except BaseException as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            status.append({
                "variant": n, "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc(limit=20),
            })
            print(f"[run] {n} FAILED ({type(e).__name__}); continuing")
    _save("BENCH_run_status", {"benchmarks": status})
    failed = [s["variant"] for s in status if not s["ok"]]
    print(f"\n[run] {len(status) - len(failed)}/{len(status)} benchmarks ok"
          + (f"; failed: {failed}" if failed else ""))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
